//! `.mobiq` artifact bundle reader (writer: python/compile/export.py).
//!
//! Layout: `b"MOBIQ1\0\0" | u64 manifest_len | JSON manifest | blob`.
//! The manifest's `tensors` directory maps names to dtype/shape/offset
//! into the blob.  The whole bundle is loaded into memory once at startup;
//! the request path only ever sees borrowed slices.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

const MAGIC: &[u8; 8] = b"MOBIQ1\x00\x00";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    U8,
    I32,
    U64,
}

impl DType {
    fn from_str(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "u8" => DType::U8,
            "i32" => DType::I32,
            "u64" => DType::U64,
            other => bail!("unknown dtype {other}"),
        })
    }
    pub fn size(self) -> usize {
        match self {
            DType::U8 => 1,
            DType::F32 | DType::I32 => 4,
            DType::U64 => 8,
        }
    }
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    U64(Vec<u64>),
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }
    pub fn u8(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            _ => Err(anyhow!("tensor is not u8")),
        }
    }
    pub fn i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }
    pub fn u64(&self) -> Result<&[u64]> {
        match &self.data {
            TensorData::U64(v) => Ok(v),
            _ => Err(anyhow!("tensor is not u64")),
        }
    }
}

pub struct Bundle {
    pub manifest: Value,
    tensors: BTreeMap<String, Tensor>,
}

impl Bundle {
    pub fn load(path: impl AsRef<Path>) -> Result<Bundle> {
        let path = path.as_ref();
        let data = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&data)
    }

    pub fn from_bytes(data: &[u8]) -> Result<Bundle> {
        if data.len() < 16 || &data[..8] != MAGIC {
            bail!("not a .mobiq bundle (bad magic)");
        }
        let mlen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        if data.len() < 16 + mlen {
            bail!("truncated manifest");
        }
        let manifest_str = std::str::from_utf8(&data[16..16 + mlen])
            .context("manifest utf-8")?;
        let manifest = json::parse(manifest_str.trim_end())
            .map_err(|e| anyhow!("manifest: {e}"))?;
        let blob = &data[16 + mlen..];

        let dir = manifest
            .get("tensors")
            .and_then(|t| t.as_obj())
            .ok_or_else(|| anyhow!("manifest missing tensors"))?;
        let mut tensors = BTreeMap::new();
        for (name, info) in dir {
            let dtype = DType::from_str(
                info.get("dtype").and_then(|v| v.as_str()).unwrap_or(""))?;
            let shape: Vec<usize> = info
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing shape"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let offset = info.get("offset").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{name}: missing offset"))?;
            let nbytes = info.get("nbytes").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{name}: missing nbytes"))?;
            if offset + nbytes > blob.len() {
                bail!("{name}: tensor out of bounds");
            }
            let n: usize = shape.iter().product();
            if n * dtype.size() != nbytes {
                bail!("{name}: shape/nbytes mismatch");
            }
            let raw = &blob[offset..offset + nbytes];
            let data = match dtype {
                DType::F32 => TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect()),
                DType::U8 => TensorData::U8(raw.to_vec()),
                DType::I32 => TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect()),
                DType::U64 => TensorData::U64(
                    raw.chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                        .collect()),
            };
            tensors.insert(name.clone(), Tensor { shape, data });
        }
        Ok(Bundle { manifest, tensors })
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name)
            .ok_or_else(|| anyhow!("missing tensor {name}"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn f32(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let t = self.tensor(name)?;
        Ok((&t.shape, t.f32()?))
    }

    /// Model config accessors ------------------------------------------------
    pub fn cfg_usize(&self, section: &str, key: &str) -> Result<usize> {
        self.manifest
            .path(&[section, key])
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing {section}.{key}"))
    }

    pub fn cfg_f64(&self, section: &str, key: &str) -> Result<f64> {
        self.manifest
            .path(&[section, key])
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("manifest missing {section}.{key}"))
    }

    /// Static-PTQ method keys present in this bundle (e.g. "gptq3").
    pub fn static_methods(&self) -> Vec<String> {
        self.manifest
            .get("static_methods")
            .and_then(|v| v.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_bundle() -> Vec<u8> {
        // hand-assembled bundle: one f32 tensor [2,2] and one u8 [3]
        let manifest = r#"{"model":{"d_model":4},"tensors":{
            "a":{"dtype":"f32","shape":[2,2],"offset":0,"nbytes":16},
            "b":{"dtype":"u8","shape":[3],"offset":16,"nbytes":3}}}"#;
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
        out.extend_from_slice(manifest.as_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&[7u8, 8, 9]);
        out
    }

    #[test]
    fn loads_tensors() {
        let b = Bundle::from_bytes(&tiny_bundle()).unwrap();
        let (shape, data) = b.f32("a").unwrap();
        assert_eq!(shape, &[2, 2]);
        assert_eq!(data, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.tensor("b").unwrap().u8().unwrap(), &[7, 8, 9]);
        assert_eq!(b.cfg_usize("model", "d_model").unwrap(), 4);
        assert!(b.tensor("zzz").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut data = tiny_bundle();
        data[0] = b'X';
        assert!(Bundle::from_bytes(&data).is_err());
    }

    #[test]
    fn rejects_oob_tensor() {
        let manifest = r#"{"tensors":{
            "a":{"dtype":"f32","shape":[64],"offset":0,"nbytes":256}}}"#;
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
        out.extend_from_slice(manifest.as_bytes());
        out.extend_from_slice(&[0u8; 8]); // far too short
        assert!(Bundle::from_bytes(&out).is_err());
    }
}
