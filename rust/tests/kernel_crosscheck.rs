//! L1 <-> L3 cross-check: the AOT-lowered Pallas kernel module
//! (tiny-s_kernel.hlo.txt) executed through PJRT must agree with the Rust
//! engine's own dequant-GEMV math on identical inputs.
//!
//! This closes the loop across all three layers: the Pallas kernel (L1)
//! was pinned to the pure-jnp ref by pytest; here the same semantics are
//! pinned to the Rust kernels (L3) through the PJRT runtime.

use mobiquant::mobiq::quantizer::GroupParams;
use mobiquant::runtime::{literal_f32, literal_i32, PjrtRuntime};
use mobiquant::util::prng::Pcg;

/// Unpack the Pallas kernel's int32 plane layout (E, B, K/32, N):
/// bit j of word w of plane p == bit p of codes[(w*32 + j), o].
fn unpack_i32_planes(planes: &[i32], e: usize, slice_bits: usize,
                     n_words: usize, n: usize) -> Vec<Vec<u8>> {
    let k = n_words * 32;
    let mut out = vec![vec![0u8; k * n]; e];
    for (idx, &word) in planes.iter().enumerate() {
        let w = word as u32;
        let o = idx % n;
        let wi = (idx / n) % n_words;
        let p = (idx / (n * n_words)) % slice_bits;
        let ei = idx / (n * n_words * slice_bits);
        for j in 0..32 {
            if (w >> j) & 1 == 1 {
                out[ei][(wi * 32 + j) * n + o] |= 1 << p;
            }
        }
    }
    out
}

/// The batched weight-stationary kernel must match the dequant-GEMV
/// oracle bit-for-token across mixed per-token slice masks, ragged T
/// (including T=1), and both LUT regimes: d_in 512 builds byte tables,
/// d_in 2048 sits at NIBBLE_THRESHOLD and builds nibble tables.
#[test]
fn batched_kernel_matches_dequant_oracle() {
    use mobiquant::mobiq::bitplane::PackedSlice;
    use mobiquant::mobiq::gemv::{dequant_gemv, gemm_lut_batch, BatchLut};
    use mobiquant::mobiq::quantizer::decompose;

    let gs = 32;
    for &(d_in, d_out, nibble, tol) in &[
        (512usize, 24usize, false, 1e-2f32),
        (2048, 8, true, 2e-2),
    ] {
        let mut rng = Pcg::new(41 + d_in as u64);
        let w = rng.normal_vec(d_in * d_out, 0.2);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = decompose(&w, &base, 4);
        let slices: Vec<PackedSlice> = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        for &t in &[1usize, 3, 6] {
            let xs = rng.normal_vec(d_in * t, 1.0);
            let mut batch = BatchLut::new(d_in, gs);
            batch.ensure_tokens(t);
            for i in 0..t {
                // mixed routed masks; slice 0 (shared expert) always on
                let mask = vec![true, rng.bool(0.5), rng.bool(0.5),
                                rng.bool(0.5)];
                batch.set_mask(i, &mask);
                batch.build_token(i, &xs[i * d_in..(i + 1) * d_in], gs);
            }
            assert_eq!(batch.luts[0].nibble, nibble,
                       "d_in {d_in} must exercise the {} regime",
                       if nibble { "nibble" } else { "byte" });
            let mut out = vec![0f32; t * d_out];
            gemm_lut_batch(&slices, &base, &batch, t, &mut out);
            let mut y_ref = vec![0f32; d_out];
            for i in 0..t {
                dequant_gemv(&slices, &base,
                             &xs[i * d_in..(i + 1) * d_in],
                             &batch.masks[i], &mut y_ref);
                for (o, (a, b)) in out[i * d_out..(i + 1) * d_out].iter()
                    .zip(&y_ref).enumerate() {
                    assert!((a - b).abs() < tol,
                            "d_in {d_in} T={t} token {i} out[{o}]: \
                             batched {a} vs oracle {b}");
                }
            }
        }
    }
}

#[test]
fn pallas_kernel_matches_rust_engine() {
    if !PjrtRuntime::available() {
        eprintln!("SKIP: built without the `pjrt` feature");
        return;
    }
    let dir = mobiquant::artifacts_dir();
    let path = mobiquant::runtime::hlo_path(&dir, "tiny-s", "kernel");
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)",
                  path.display());
        return;
    }
    // kernel module shapes (see aot.py::lower_model_hlos): tiny-s d=96
    let (t, k, n) = (16usize, 96usize, 96usize);
    let (e, sb, gs) = (4usize, 2usize, 32usize);
    let n_words = k / 32;

    let mut rng = Pcg::new(99);
    let x: Vec<f32> = rng.normal_vec(t * k, 1.0);
    let planes: Vec<i32> = (0..e * sb * n_words * n)
        .map(|_| rng.next_u32() as i32)
        .collect();
    let scale: Vec<f32> = (0..(k / gs) * n)
        .map(|_| rng.range_f32(0.01, 0.2))
        .collect();
    let zero: Vec<f32> = (0..(k / gs) * n)
        .map(|_| rng.range_f32(0.0, 4.0))
        .collect();
    let mut mask = vec![0f32; t * e];
    for ti in 0..t {
        mask[ti * e] = 1.0;
        for ei in 1..e {
            mask[ti * e + ei] = rng.bool(0.5) as u32 as f32;
        }
    }

    // --- PJRT execution of the Pallas kernel ---
    let rt = PjrtRuntime::cpu().expect("pjrt client");
    let module = rt.load(&path).expect("kernel module");
    let y_pjrt = module.run_f32(&[
        literal_f32(&x, &[t, k]).unwrap(),
        literal_i32(&planes, &[e, sb, n_words, n]).unwrap(),
        literal_f32(&scale, &[k / gs, n]).unwrap(),
        literal_f32(&zero, &[k / gs, n]).unwrap(),
        literal_f32(&mask, &[t, e]).unwrap(),
    ]).expect("kernel run");
    assert_eq!(y_pjrt.len(), t * n);

    // --- Rust reference: dequant + dense matvec per token ---
    let base = GroupParams {
        scale: scale.clone(),
        zero: zero.clone(),
        n_groups: k / gs,
        d_out: n,
        bits: sb as u32,
        group_size: gs,
    };
    let codes = unpack_i32_planes(&planes, e, sb, n_words, n);
    let mut y_ref = vec![0f32; t * n];
    for ti in 0..t {
        let xt = &x[ti * k..(ti + 1) * k];
        let mut acc = vec![0f32; n];
        for ei in 0..e {
            if mask[ti * e + ei] == 0.0 {
                continue;
            }
            let deq = mobiquant::mobiq::quantizer::dequantize(
                &codes[ei], &base.residual(ei));
            let mut y = vec![0f32; n];
            mobiquant::mobiq::gemv::matvec(&deq, xt, &mut y, k, n);
            for (a, b) in acc.iter_mut().zip(&y) {
                *a += b;
            }
        }
        y_ref[ti * n..(ti + 1) * n].copy_from_slice(&acc);
    }

    let max_diff = y_pjrt.iter().zip(&y_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3,
            "Pallas kernel (PJRT) vs Rust engine: max diff {max_diff}");
}
