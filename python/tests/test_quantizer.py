"""Floor-aligned quantizer properties (paper Eq. 11-12, App. B)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quant import quantizer as Q


def rand_w(seed, d_in=32, d_out=8, scale=0.2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((d_in, d_out)) * scale,
                       jnp.float32)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4]),
       st.sampled_from([8, 16, 32]))
def test_roundtrip_error_bounded(seed, bits, gs):
    w = rand_w(seed)
    p = Q.calc_params(w, bits, gs)
    deq = Q.dequantize(Q.quantize(w, p), p)
    # centred floor quantization: |err| <= s/2 within range (no clipping)
    max_s = float(jnp.max(p.scale))
    assert float(jnp.max(jnp.abs(w - deq))) <= max_s * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_codes_in_range(seed):
    w = rand_w(seed)
    for bits in (2, 4):
        p = Q.calc_params(w, bits, 16)
        q = np.asarray(Q.quantize(w, p))
        assert q.min() >= 0 and q.max() <= 2 ** bits - 1


def test_clipping_shrinks_range():
    w = rand_w(1)
    p_full = Q.calc_params(w, 2, 16)
    p_clip = Q.calc_params(w, 2, 16,
                           clip_lo=jnp.full((2, 8), 0.5),
                           clip_hi=jnp.full((2, 8), 0.5))
    assert float(jnp.max(p_clip.scale)) < float(jnp.max(p_full.scale))


def test_ste_forward_matches_hard():
    w = rand_w(2)
    p = Q.calc_params(w, 2, 16)
    hard = Q.dequantize(Q.quantize(w, p), p)
    ste = Q.quantize_ste(w, p)
    np.testing.assert_allclose(np.asarray(ste), np.asarray(hard),
                               atol=1e-6)


def test_ste_has_gradients():
    import jax
    w = rand_w(3)

    def loss(clip_raw):
        p = Q.calc_params(w, 2, 16,
                          clip_lo=jax.nn.sigmoid(clip_raw),
                          clip_hi=jax.nn.sigmoid(clip_raw))
        return jnp.sum(Q.quantize_ste(w, p) ** 2)

    g = jax.grad(loss)(jnp.full((2, 8), 2.0))
    assert float(jnp.sum(jnp.abs(g))) > 0.0


def test_rtn_reduces_with_bits():
    w = rand_w(4)
    errs = []
    for bits in (2, 3, 4, 6):
        deq, _ = Q.rtn(w, bits, 16)
        errs.append(float(jnp.mean((w - deq) ** 2)))
    assert errs == sorted(errs, reverse=True)


def test_groups_shape_guard():
    with pytest.raises(AssertionError):
        Q.n_groups(30, 16)
