//! L1 <-> L3 cross-check: the AOT-lowered Pallas kernel module
//! (tiny-s_kernel.hlo.txt) executed through PJRT must agree with the Rust
//! engine's own dequant-GEMV math on identical inputs.
//!
//! This closes the loop across all three layers: the Pallas kernel (L1)
//! was pinned to the pure-jnp ref by pytest; here the same semantics are
//! pinned to the Rust kernels (L3) through the PJRT runtime.

use mobiquant::mobiq::quantizer::GroupParams;
use mobiquant::runtime::{literal_f32, literal_i32, PjrtRuntime};
use mobiquant::util::prng::Pcg;

/// Unpack the Pallas kernel's int32 plane layout (E, B, K/32, N):
/// bit j of word w of plane p == bit p of codes[(w*32 + j), o].
fn unpack_i32_planes(planes: &[i32], e: usize, slice_bits: usize,
                     n_words: usize, n: usize) -> Vec<Vec<u8>> {
    let k = n_words * 32;
    let mut out = vec![vec![0u8; k * n]; e];
    for (idx, &word) in planes.iter().enumerate() {
        let w = word as u32;
        let o = idx % n;
        let wi = (idx / n) % n_words;
        let p = (idx / (n * n_words)) % slice_bits;
        let ei = idx / (n * n_words * slice_bits);
        for j in 0..32 {
            if (w >> j) & 1 == 1 {
                out[ei][(wi * 32 + j) * n + o] |= 1 << p;
            }
        }
    }
    out
}

#[test]
fn pallas_kernel_matches_rust_engine() {
    let dir = mobiquant::artifacts_dir();
    let path = mobiquant::runtime::hlo_path(&dir, "tiny-s", "kernel");
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)",
                  path.display());
        return;
    }
    // kernel module shapes (see aot.py::lower_model_hlos): tiny-s d=96
    let (t, k, n) = (16usize, 96usize, 96usize);
    let (e, sb, gs) = (4usize, 2usize, 32usize);
    let n_words = k / 32;

    let mut rng = Pcg::new(99);
    let x: Vec<f32> = rng.normal_vec(t * k, 1.0);
    let planes: Vec<i32> = (0..e * sb * n_words * n)
        .map(|_| rng.next_u32() as i32)
        .collect();
    let scale: Vec<f32> = (0..(k / gs) * n)
        .map(|_| rng.range_f32(0.01, 0.2))
        .collect();
    let zero: Vec<f32> = (0..(k / gs) * n)
        .map(|_| rng.range_f32(0.0, 4.0))
        .collect();
    let mut mask = vec![0f32; t * e];
    for ti in 0..t {
        mask[ti * e] = 1.0;
        for ei in 1..e {
            mask[ti * e + ei] = rng.bool(0.5) as u32 as f32;
        }
    }

    // --- PJRT execution of the Pallas kernel ---
    let rt = PjrtRuntime::cpu().expect("pjrt client");
    let module = rt.load(&path).expect("kernel module");
    let y_pjrt = module.run_f32(&[
        literal_f32(&x, &[t, k]).unwrap(),
        literal_i32(&planes, &[e, sb, n_words, n]).unwrap(),
        literal_f32(&scale, &[k / gs, n]).unwrap(),
        literal_f32(&zero, &[k / gs, n]).unwrap(),
        literal_f32(&mask, &[t, e]).unwrap(),
    ]).expect("kernel run");
    assert_eq!(y_pjrt.len(), t * n);

    // --- Rust reference: dequant + dense matvec per token ---
    let base = GroupParams {
        scale: scale.clone(),
        zero: zero.clone(),
        n_groups: k / gs,
        d_out: n,
        bits: sb as u32,
        group_size: gs,
    };
    let codes = unpack_i32_planes(&planes, e, sb, n_words, n);
    let mut y_ref = vec![0f32; t * n];
    for ti in 0..t {
        let xt = &x[ti * k..(ti + 1) * k];
        let mut acc = vec![0f32; n];
        for ei in 0..e {
            if mask[ti * e + ei] == 0.0 {
                continue;
            }
            let deq = mobiquant::mobiq::quantizer::dequantize(
                &codes[ei], &base.residual(ei));
            let mut y = vec![0f32; n];
            mobiquant::mobiq::gemv::matvec(&deq, xt, &mut y, k, n);
            for (a, b) in acc.iter_mut().zip(&y) {
                *a += b;
            }
        }
        y_ref[ti * n..(ti + 1) * n].copy_from_slice(&acc);
    }

    let max_diff = y_pjrt.iter().zip(&y_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3,
            "Pallas kernel (PJRT) vs Rust engine: max diff {max_diff}");
}
