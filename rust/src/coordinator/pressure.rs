//! Memory-pressure controller: the degradation ladder's policy half.
//!
//! [`ElasticController`](super::controller::ElasticController) turns
//! resource pressure into *weight* bits; this module turns live KV
//! arena occupancy into *cache* actions, so the scheduler never
//! hard-fails on memory.  Occupancy maps to a band, and each band
//! unlocks one more rung of the ladder:
//!
//! * **Calm** — nothing; admissions keep their requested KV precision.
//! * **Moderate** — new admissions are floored to i8 KV storage (the
//!   admission-time knob PR 5 built; resident sequences untouched).
//! * **High** — admissions floor to i4 AND resident sequences'
//!   exclusively-owned tail pages are requantized in place
//!   (f32→i8; see [`KvArena::requant_seq_tail`]
//!   (crate::model::kvcache::KvArena::requant_seq_tail)), AND — when
//!   a host swap tier is configured — cold pages of the LRU-most
//!   sequences move to host memory until occupancy re-enters the
//!   band's entry threshold (exact byte copies; see
//!   [`KvArena::swap_out_seq_cold`]
//!   (crate::model::kvcache::KvArena::swap_out_seq_cold)).
//! * **Critical** — requant target drops to i4 and the scheduler may
//!   preempt the youngest sequence: its cold KV parks in the host
//!   tier (resume restores it by memcpy and re-feeds only the
//!   unparked suffix) and only when the host tier is disabled or
//!   exhausted does the resume fall back to a full re-prefill.
//!
//! Escalation is immediate (pressure is dangerous), de-escalation is
//! hysteretic: the controller only steps down once occupancy falls
//! `hysteresis` *below* the band's entry threshold, so a sequence
//! retiring and its successor admitting do not make the ladder
//! oscillate between rungs tick over tick.

use crate::model::kvcache::KvPrecision;

/// Occupancy thresholds (fractions of the arena byte budget) at which
/// each band engages, plus the de-escalation hysteresis margin.
#[derive(Debug, Clone)]
pub struct PressureConfig {
    /// Occupancy at which admissions degrade to i8.
    pub moderate: f64,
    /// Occupancy at which resident tails requantize (and admissions
    /// degrade to i4).
    pub high: f64,
    /// Occupancy at which the scheduler may preempt.
    pub critical: f64,
    /// De-escalation margin: step down only when occupancy drops this
    /// far below the current band's entry threshold.
    pub hysteresis: f64,
}

impl Default for PressureConfig {
    fn default() -> Self {
        PressureConfig {
            moderate: 0.70,
            high: 0.85,
            critical: 0.97,
            hysteresis: 0.05,
        }
    }
}

/// The ladder's rungs, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum PressureLevel {
    #[default]
    Calm,
    Moderate,
    High,
    Critical,
}

impl PressureLevel {
    pub fn label(self) -> &'static str {
        match self {
            PressureLevel::Calm => "calm",
            PressureLevel::Moderate => "moderate",
            PressureLevel::High => "high",
            PressureLevel::Critical => "critical",
        }
    }

    /// Index into per-band counters (0..4).
    pub fn index(self) -> usize {
        match self {
            PressureLevel::Calm => 0,
            PressureLevel::Moderate => 1,
            PressureLevel::High => 2,
            PressureLevel::Critical => 3,
        }
    }
}

#[derive(Debug)]
pub struct PressureController {
    cfg: PressureConfig,
    level: PressureLevel,
    escalations: u64,
}

impl PressureController {
    pub fn new(cfg: PressureConfig) -> PressureController {
        PressureController {
            cfg,
            level: PressureLevel::Calm,
            escalations: 0,
        }
    }

    /// Entry threshold of a band (Calm has none).
    fn entry(&self, level: PressureLevel) -> f64 {
        match level {
            PressureLevel::Calm => 0.0,
            PressureLevel::Moderate => self.cfg.moderate,
            PressureLevel::High => self.cfg.high,
            PressureLevel::Critical => self.cfg.critical,
        }
    }

    /// Band the raw occupancy lands in, ignoring hysteresis.
    fn raw_level(&self, occupancy: f64) -> PressureLevel {
        if occupancy >= self.cfg.critical {
            PressureLevel::Critical
        } else if occupancy >= self.cfg.high {
            PressureLevel::High
        } else if occupancy >= self.cfg.moderate {
            PressureLevel::Moderate
        } else {
            PressureLevel::Calm
        }
    }

    /// Feed the tick's arena occupancy (resident/capacity bytes, in
    /// [0, 1]); returns the band to act on this tick.  Escalation is
    /// immediate; de-escalation waits until occupancy clears the
    /// current band's entry threshold by `hysteresis`.
    pub fn update(&mut self, occupancy: f64) -> PressureLevel {
        let raw = self.raw_level(occupancy);
        if raw > self.level {
            self.level = raw;
            self.escalations += 1;
        } else if raw < self.level {
            let release = self.entry(self.level) - self.cfg.hysteresis;
            if occupancy < release {
                self.level = raw;
            }
        }
        self.level
    }

    pub fn level(&self) -> PressureLevel {
        self.level
    }

    pub fn escalations(&self) -> u64 {
        self.escalations
    }

    pub fn config(&self) -> &PressureConfig {
        &self.cfg
    }

    /// Admission-time KV storage floor for the current band: the
    /// request keeps what it asked for unless the band demands
    /// something cheaper (a request that already asked for i4 is never
    /// *upgraded*).
    pub fn admission_precision(&self, requested: KvPrecision)
                               -> KvPrecision {
        let floor = match self.level {
            PressureLevel::Calm => KvPrecision::F32,
            PressureLevel::Moderate => KvPrecision::Int8,
            PressureLevel::High | PressureLevel::Critical => {
                KvPrecision::Int4
            }
        };
        if floor.rank() > requested.rank() {
            floor
        } else {
            requested
        }
    }

    /// In-place requant target for resident sequences' tails, if the
    /// band calls for one.
    pub fn requant_target(&self) -> Option<KvPrecision> {
        match self.level {
            PressureLevel::High => Some(KvPrecision::Int8),
            PressureLevel::Critical => Some(KvPrecision::Int4),
            _ => None,
        }
    }

    /// Whether the band calls for swapping resident sequences' cold
    /// pages out to the host tier (the rung between in-place requant
    /// and preemption: exact byte relief where requant is lossy and
    /// preemption costs recompute).
    pub fn should_swap(&self) -> bool {
        self.level >= PressureLevel::High
    }

    /// Occupancy the swap rung drives toward: the High band's entry
    /// threshold.  Swapping stops as soon as occupancy drops below
    /// it — going further would stall more sequences than pressure
    /// requires.
    pub fn swap_target(&self) -> f64 {
        self.cfg.high
    }

    /// Whether the band permits preempting the youngest sequence.
    pub fn should_preempt(&self) -> bool {
        self.level == PressureLevel::Critical
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_map_to_levels() {
        let mut c = PressureController::new(PressureConfig::default());
        assert_eq!(c.update(0.10), PressureLevel::Calm);
        assert_eq!(c.update(0.72), PressureLevel::Moderate);
        assert_eq!(c.update(0.90), PressureLevel::High);
        assert_eq!(c.update(0.99), PressureLevel::Critical);
    }

    #[test]
    fn deescalation_needs_hysteresis_margin() {
        let mut c = PressureController::new(PressureConfig::default());
        assert_eq!(c.update(0.90), PressureLevel::High);
        // just below the entry threshold: still High (hysteresis)
        assert_eq!(c.update(0.83), PressureLevel::High);
        // clears entry - hysteresis = 0.80: steps down
        assert_eq!(c.update(0.78), PressureLevel::Moderate);
        // all the way down only once below moderate - hysteresis
        assert_eq!(c.update(0.66), PressureLevel::Moderate);
        assert_eq!(c.update(0.60), PressureLevel::Calm);
        assert_eq!(c.escalations(), 1);
    }

    /// Escalation boundaries are inclusive: occupancy exactly at a
    /// band's entry threshold engages that band (raw_level uses >=),
    /// and one ulp below it does not.
    #[test]
    fn escalation_engages_exactly_at_threshold() {
        let cfg = PressureConfig::default();
        let mut c = PressureController::new(cfg.clone());
        assert_eq!(c.update(cfg.moderate - 1e-9), PressureLevel::Calm);
        assert_eq!(c.update(cfg.moderate), PressureLevel::Moderate);
        assert_eq!(c.update(cfg.high - 1e-9), PressureLevel::Moderate);
        assert_eq!(c.update(cfg.high), PressureLevel::High);
        assert_eq!(c.update(cfg.critical - 1e-9), PressureLevel::High);
        assert_eq!(c.update(cfg.critical), PressureLevel::Critical);
        assert_eq!(c.escalations(), 3);
    }

    /// De-escalation is strict: occupancy exactly at entry − hysteresis
    /// holds the band; only strictly below it releases.  Checked at
    /// every band edge of the default config.
    #[test]
    fn deescalation_release_points_are_strict() {
        let cfg = PressureConfig::default();
        // Critical: entry 0.97, release 0.92.
        let mut c = PressureController::new(cfg.clone());
        assert_eq!(c.update(0.99), PressureLevel::Critical);
        let release = cfg.critical - cfg.hysteresis;
        assert_eq!(c.update(release), PressureLevel::Critical);
        // Strictly below release: steps down to the raw band (High,
        // since release - eps is still above cfg.high).
        assert_eq!(c.update(release - 1e-9), PressureLevel::High);

        // High: entry 0.85, release 0.80.
        let release = cfg.high - cfg.hysteresis;
        assert_eq!(c.update(release), PressureLevel::High);
        assert_eq!(c.update(release - 1e-9), PressureLevel::Moderate);

        // Moderate: entry 0.70, release 0.65.
        let release = cfg.moderate - cfg.hysteresis;
        assert_eq!(c.update(release), PressureLevel::Moderate);
        assert_eq!(c.update(release - 1e-9), PressureLevel::Calm);
    }

    /// A collapse in occupancy drops straight to the raw band — the
    /// ladder does not unwind one rung per tick.
    #[test]
    fn deescalation_skips_bands_on_collapse() {
        let mut c = PressureController::new(PressureConfig::default());
        assert_eq!(c.update(0.99), PressureLevel::Critical);
        assert_eq!(c.update(0.10), PressureLevel::Calm);
        // And straight from Critical into a mid band.
        assert_eq!(c.update(0.99), PressureLevel::Critical);
        assert_eq!(c.update(0.72), PressureLevel::Moderate);
        assert_eq!(c.escalations(), 2);
    }

    #[test]
    fn admission_floor_never_upgrades() {
        let mut c = PressureController::new(PressureConfig::default());
        let _ = c.update(0.72); // Moderate -> i8 floor
        assert_eq!(c.admission_precision(KvPrecision::F32),
                   KvPrecision::Int8);
        assert_eq!(c.admission_precision(KvPrecision::Int4),
                   KvPrecision::Int4);
        let _ = c.update(0.99); // Critical -> i4 floor
        assert_eq!(c.admission_precision(KvPrecision::F32),
                   KvPrecision::Int4);
        assert_eq!(c.admission_precision(KvPrecision::Int8),
                   KvPrecision::Int4);
    }

    #[test]
    fn ladder_actions_per_band() {
        let mut c = PressureController::new(PressureConfig::default());
        let _ = c.update(0.1);
        assert_eq!(c.requant_target(), None);
        assert!(!c.should_swap());
        assert!(!c.should_preempt());
        let _ = c.update(0.72);
        assert!(!c.should_swap(), "Moderate floors admissions only");
        let _ = c.update(0.86);
        assert_eq!(c.requant_target(), Some(KvPrecision::Int8));
        assert!(c.should_swap());
        assert!(!c.should_preempt());
        let _ = c.update(0.99);
        assert_eq!(c.requant_target(), Some(KvPrecision::Int4));
        assert!(c.should_swap(), "Critical swaps before it preempts");
        assert!(c.should_preempt());
        assert!((c.swap_target() - 0.85).abs() < 1e-12,
                "swap rung drives occupancy back under High entry");
    }
}
