//! Descriptive statistics for bench reports and the analysis module.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt() + 1e-30)
}

/// Spearman rank correlation (robust to monotone nonlinearity; used for the
/// Fig. 5 router-score vs error-increment analysis).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Histogram with fixed bin count over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x.is_finite() && x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        } else if x == hi {
            h[bins - 1] += 1;
        }
    }
    h
}

/// Counter ratio guarded against an empty denominator (hit rates,
/// skip fractions — e.g. the KV prefix-cache hit rate in
/// `coordinator::metrics` and the Fig. 7 memory accounting).
pub fn rate(num: u64, den: u64) -> f64 {
    if den == 0 {
        return 0.0;
    }
    num as f64 / den as f64
}

/// Overlap fraction between two index sets (outlier-migration metric,
/// App. E.1/E.2: "top outlier tokens overlap by only 41% / 16%").
pub fn overlap_fraction(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let set: std::collections::HashSet<_> = b.iter().collect();
    let inter = a.iter().filter(|x| set.contains(x)).count();
    inter as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.29099).abs() < 1e-4);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 8.0, 27.0, 64.0]; // nonlinear but monotone
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn overlap() {
        assert_eq!(overlap_fraction(&[1, 2, 3, 4], &[3, 4, 5, 6]), 0.5);
        assert_eq!(overlap_fraction(&[], &[1]), 0.0);
    }

    #[test]
    fn rate_guards_zero() {
        assert_eq!(rate(0, 0), 0.0);
        assert_eq!(rate(3, 4), 0.75);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }
}
