//! PJRT runtime — executes the AOT HLO modules lowered by
//! python/compile/aot.py for cross-validation of the native engine
//! (PJRT logits vs Rust logits over the same bundle) and for
//! fixed-precision PPL harnesses; the elastic request path runs the
//! native engine (per-token routing is not expressible in a static HLO
//! module).
//!
//! The real backend ([`pjrt`]) needs the vendored `xla` bindings and
//! sits behind the off-by-default `pjrt` feature; the default build
//! gets an API-compatible [`stub`] whose constructors error, so
//! `cargo build`/`cargo test` work on machines without the XLA
//! toolchain (callers already skip when HLO artifacts are missing).

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_f32, literal_i32, HloModule, Literal,
               PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{literal_f32, literal_i32, HloModule, Literal,
               PjrtRuntime};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Locate a model's HLO module in the artifacts dir.
pub fn hlo_path(artifacts: &Path, model: &str, variant: &str) -> PathBuf {
    artifacts.join("hlo").join(format!("{model}_{variant}.hlo.txt"))
}

/// PPL over a PJRT fixed-precision module (window = the module's T).
pub fn ppl_via_pjrt(module: &HloModule, tokens: &[u32], window: usize,
                    vocab: usize, max_windows: usize) -> Result<f64> {
    let n = ((tokens.len().saturating_sub(1)) / window).min(max_windows);
    anyhow::ensure!(n > 0, "not enough tokens");
    let mut total = 0f64;
    let mut count = 0usize;
    for i in 0..n {
        let chunk = &tokens[i * window..i * window + window + 1];
        let inp: Vec<i32> = chunk[..window].iter().map(|&t| t as i32)
            .collect();
        let logits = module.run_tokens(&inp)
            .context("pjrt window execute")?;
        anyhow::ensure!(logits.len() == window * vocab,
                        "bad logits shape");
        for j in 0..window {
            total += crate::data::ppl::nll_of(
                &logits[j * vocab..(j + 1) * vocab], chunk[j + 1]);
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}
