//! KV storage: the process-wide paged arena (serving path) and the
//! contiguous per-sequence slab (oracle/test path).
//!
//! Until PR 4 every sequence slot eagerly allocated
//! `n_layers x 2 x n_kv_heads x max_seq_len x head_dim` floats up
//! front, so KV memory was budgeted for worst-case context even for a
//! 30-token request, and admission had to assume the worst case.  The
//! [`KvArena`] replaces those slabs with one vLLM-style pool of
//! fixed-size pages ([`KV_PAGE`] positions each):
//!
//! * each sequence x layer holds a page table ([`LayerTable`]) instead
//!   of a slab, and pages are allocated lazily as positions are
//!   appended — resident bytes track actual context, not `max_seq_len`;
//! * pages are refcounted, so a detected shared prompt prefix maps the
//!   same physical pages into many sequences ([`KvArena::fork_prefix`]);
//!   the first append into a shared partial page copies it
//!   (copy-on-write), full shared pages are never copied;
//! * the free list makes retire-then-readmit reuse pages without
//!   touching the allocator, and the scheduler admits against real
//!   free-page counts (`coordinator/scheduler.rs`).
//!
//! Page layout: within a page, `[kv_head][pos_in_page][head_dim]` —
//! the same head-major order as the slab, so one head's K (or V) rows
//! for any run of positions inside a page are contiguous.  [`KV_PAGE`]
//! is a multiple of the attention kernel's `ATTN_TILE`, so a position
//! tile never straddles a page and the flash-style tile math streams
//! the exact same contiguous rows it streamed over the slab — the two
//! storages are bit-identical under the kernel (pinned by tests).
//!
//! The [`KvSource`] trait is the read interface the attention kernels
//! stream through; both [`KvCache`] (slab) and [`KvLayerView`] (one
//! sequence x layer of the arena) implement it.

use super::attention::RopeCache;

/// Positions per KV page.  A multiple of `attention::ATTN_TILE` (32)
/// so tiles never straddle a page; at head_dim 64 one page side is
/// 16 KB per kv head.
pub const KV_PAGE: usize = 64;

// ---------------------------------------------------------------------------
// Read interface shared by slab and paged storage
// ---------------------------------------------------------------------------

/// Read access to one sequence x layer of K/V, in head-major runs.
/// The attention kernels are generic over this, so the tiled
/// online-softmax math is literally the same code over the slab oracle
/// and the paged arena.
pub trait KvSource: Sync {
    /// Number of positions stored.
    fn len(&self) -> usize;
    /// Contiguous K rows for positions `[p0, p1)` of kv head `h`.
    /// For paged sources the range must not straddle a page boundary;
    /// `ATTN_TILE`-aligned tiles always satisfy this because
    /// `KV_PAGE % ATTN_TILE == 0`.
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> &[f32];
    /// Contiguous V rows for positions `[p0, p1)` of kv head `h`.
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> &[f32];
}

// ---------------------------------------------------------------------------
// Slab cache (oracle / kernel-test path)
// ---------------------------------------------------------------------------

/// KV tensors of one sequence, one layer, as contiguous
/// `(n_kv_heads, max_seq, head_dim)` slabs for K and V.  This is the
/// eager layout the arena replaced on the serving path; it stays as
/// the parity oracle the paged views are pinned against, and as the
/// simplest harness for kernel tests/benches.
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, n_kv_heads: usize,
               head_dim: usize) -> KvCache {
        KvCache {
            k: vec![0f32; n_kv_heads * max_seq * head_dim],
            v: vec![0f32; n_kv_heads * max_seq * head_dim],
            len: 0,
            n_kv_heads,
            head_dim,
            max_seq,
        }
    }

    /// Row width of one position across all kv heads.
    pub fn width(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Claim `t` fresh positions; returns the first.  Callers write the
    /// claimed rows through the `*_row_mut` accessors — this is what
    /// lets block writers land results in the slab directly.
    pub fn reserve(&mut self, t: usize) -> usize {
        assert!(self.len + t <= self.max_seq, "kv cache overflow");
        let pos = self.len;
        self.len += t;
        pos
    }

    /// Append one position's head-interleaved `(n_kv_heads * head_dim)`
    /// K/V rows (the scalar-oracle path); returns the position index.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        let hd = self.head_dim;
        debug_assert_eq!(k_row.len(), self.width());
        debug_assert_eq!(v_row.len(), self.width());
        let pos = self.reserve(1);
        for h in 0..self.n_kv_heads {
            let base = self.slab_off(h, pos);
            self.k[base..base + hd]
                .copy_from_slice(&k_row[h * hd..(h + 1) * hd]);
            self.v[base..base + hd]
                .copy_from_slice(&v_row[h * hd..(h + 1) * hd]);
        }
        pos
    }

    #[inline]
    fn slab_off(&self, h: usize, pos: usize) -> usize {
        (h * self.max_seq + pos) * self.head_dim
    }

    /// Head `h`'s contiguous `(len, head_dim)` key slab.
    #[inline]
    pub fn k_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.k[lo..lo + self.len * self.head_dim]
    }

    /// Head `h`'s contiguous `(len, head_dim)` value slab.
    #[inline]
    pub fn v_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.v[lo..lo + self.len * self.head_dim]
    }

    #[inline]
    pub fn k_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.v[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn k_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.v[lo..lo + self.head_dim]
    }

    pub fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

impl KvSource for KvCache {
    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> &[f32] {
        debug_assert!(p0 < p1 && p1 <= self.len);
        let lo = self.slab_off(h, p0);
        &self.k[lo..lo + (p1 - p0) * self.head_dim]
    }

    #[inline]
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> &[f32] {
        debug_assert!(p0 < p1 && p1 <= self.len);
        let lo = self.slab_off(h, p0);
        &self.v[lo..lo + (p1 - p0) * self.head_dim]
    }
}

// ---------------------------------------------------------------------------
// Paged arena
// ---------------------------------------------------------------------------

/// Opaque handle to one sequence's KV state inside a [`KvArena`].
/// Obtained from [`KvArena::alloc_seq`] / [`KvArena::fork_prefix`];
/// invalid after [`KvArena::free_seq`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvHandle(u32);

impl KvHandle {
    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Error returned when an append needs more pages than the arena has
/// free.  The scheduler's admission accounting is sized so this never
/// fires mid-flight; hitting it means the caller over-admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfPages {
    pub needed: usize,
    pub free: usize,
}

impl std::fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv arena out of pages: need {} but only {} free",
               self.needed, self.free)
    }
}

impl std::error::Error for OutOfPages {}

/// Page table of one sequence x layer: physical page ids covering
/// positions `[0, len)`.  Invariant: `pages.len() == ceil(len / KV_PAGE)`
/// between appends (the final page may be partially filled).
#[derive(Debug, Clone, Default)]
pub struct LayerTable {
    pages: Vec<u32>,
    len: usize,
}

struct SeqState {
    layers: Vec<LayerTable>,
}

/// Process-wide paged KV pool: all sequences' K/V for all layers live
/// in one pair of page-granular slabs, with refcounted pages, a free
/// list, lazy allocation and copy-on-write (see module docs).
pub struct KvArena {
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    /// Floats per page per side: `n_kv_heads * KV_PAGE * head_dim`.
    page_elems: usize,
    /// Page `p`'s data is `[p * page_elems, (p + 1) * page_elems)`.
    /// The backing grows lazily with the page high-water mark (the
    /// free list hands out low ids first), so process RSS tracks peak
    /// *used* pages, not the worst-case budget.
    k: Vec<f32>,
    v: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<u32>,
    peak_resident: usize,
    seqs: Vec<Option<SeqState>>,
    free_seqs: Vec<usize>,
}

impl KvArena {
    pub fn new(n_layers: usize, max_seq: usize, n_kv_heads: usize,
               head_dim: usize, capacity_pages: usize) -> KvArena {
        let page_elems = n_kv_heads * KV_PAGE * head_dim;
        KvArena {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            page_elems,
            k: Vec::new(),
            v: Vec::new(),
            refcount: vec![0; capacity_pages],
            // pop() hands out low page ids first, so the lazily grown
            // backing slabs stay dense
            free: (0..capacity_pages as u32).rev().collect(),
            peak_resident: 0,
            seqs: Vec::new(),
            free_seqs: Vec::new(),
        }
    }

    /// Pages needed to hold `positions` KV rows of one layer.
    pub fn pages_for(positions: usize) -> usize {
        (positions + KV_PAGE - 1) / KV_PAGE
    }

    /// Worst-case pages a sequence reaching `positions` total context
    /// needs across all layers (what eager slab allocation always paid
    /// at `positions = max_seq_len`).
    pub fn seq_worst_pages(&self, positions: usize) -> usize {
        self.n_layers * Self::pages_for(positions.min(self.max_seq))
    }

    pub fn capacity_pages(&self) -> usize {
        self.refcount.len()
    }

    /// Pages currently mapped by at least one sequence.
    pub fn resident_pages(&self) -> usize {
        self.capacity_pages() - self.free.len()
    }

    pub fn peak_resident_pages(&self) -> usize {
        self.peak_resident
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Bytes of one page (K + V sides).
    pub fn page_bytes(&self) -> usize {
        2 * self.page_elems * 4
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_pages() * self.page_bytes()
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_resident * self.page_bytes()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Park a sequence state in a (possibly recycled) handle slot.
    fn insert_seq(&mut self, state: SeqState) -> KvHandle {
        let idx = match self.free_seqs.pop() {
            Some(i) => {
                self.seqs[i] = Some(state);
                i
            }
            None => {
                self.seqs.push(Some(state));
                self.seqs.len() - 1
            }
        };
        KvHandle(idx as u32)
    }

    /// Allocate an empty sequence (no pages yet — pages are claimed
    /// lazily as positions are appended).
    pub fn alloc_seq(&mut self) -> KvHandle {
        let state = SeqState {
            layers: vec![LayerTable::default(); self.n_layers],
        };
        self.insert_seq(state)
    }

    /// Fork a new sequence sharing `src`'s first `len` positions: page
    /// tables are cloned up to `ceil(len / KV_PAGE)` entries with every
    /// shared page's refcount bumped — no K/V bytes are copied.  A
    /// partially filled shared tail page is copied lazily on the fork's
    /// (or the source's) first append into it (COW).  `len` must not
    /// exceed `src`'s current length on any layer.
    pub fn fork_prefix(&mut self, src: KvHandle, len: usize) -> KvHandle {
        let n_pages = Self::pages_for(len);
        let mut layers = Vec::with_capacity(self.n_layers);
        {
            let s = self.seqs[src.idx()].as_ref().expect("stale handle");
            for t in &s.layers {
                assert!(t.len >= len, "fork_prefix past source length");
                layers.push(LayerTable {
                    pages: t.pages[..n_pages].to_vec(),
                    len,
                });
            }
        }
        for t in &layers {
            for &p in &t.pages {
                self.refcount[p as usize] += 1;
            }
        }
        self.insert_seq(SeqState { layers })
    }

    /// Fork sharing the source's whole current length.
    pub fn fork_seq(&mut self, src: KvHandle) -> KvHandle {
        let len = self.seq_len(src);
        self.fork_prefix(src, len)
    }

    /// Drop all of a sequence's pages (refcounts decremented, pages
    /// with no remaining owner return to the free list) and recycle the
    /// handle slot.  The handle must not be used afterwards.
    pub fn free_seq(&mut self, h: KvHandle) {
        let state = self.seqs[h.idx()].take().expect("double free_seq");
        for t in &state.layers {
            for &p in &t.pages {
                self.decref(p);
            }
        }
        self.free_seqs.push(h.idx());
    }

    /// Drop a sequence's pages but keep the handle alive at length 0
    /// (the window-reset idiom of the PPL evaluator and probes).
    pub fn reset_seq(&mut self, h: KvHandle) {
        let mut tables = Vec::new();
        {
            let s = self.seqs[h.idx()].as_mut().expect("stale handle");
            for t in &mut s.layers {
                tables.push(std::mem::take(&mut t.pages));
                t.len = 0;
            }
        }
        for pages in tables {
            for p in pages {
                self.decref(p);
            }
        }
    }

    /// Sequence length (layer 0; all layers agree between forward
    /// calls — they only diverge transiently inside a layer loop).
    pub fn seq_len(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers[0].len
    }

    /// Length of one layer's table (differs from [`Self::seq_len`]
    /// only mid-tick, while a layer loop appends layer by layer).
    pub fn layer_len(&self, h: KvHandle, layer: usize) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers[layer].len
    }

    /// Total pages mapped by this sequence across all layers (shared
    /// pages count once per mapping — this is the table size, not
    /// exclusive ownership).
    pub fn seq_pages(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers.iter().map(|t| t.pages.len()).sum()
    }

    /// Read view of one sequence x layer for the attention kernels.
    pub fn layer(&self, h: KvHandle, layer: usize) -> KvLayerView<'_> {
        let t = &self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers[layer];
        KvLayerView {
            k: &self.k,
            v: &self.v,
            pages: &t.pages,
            len: t.len,
            head_dim: self.head_dim,
            page_elems: self.page_elems,
        }
    }

    /// Append a `(t, n_kv_heads * head_dim)` row-major K/V block to one
    /// sequence x layer, applying RoPE to the K rows from the cached
    /// tables while scattering into the head-major page layout — the
    /// paged equivalent of `attention::append_kv_block`, with identical
    /// per-row math (each row's rotation uses the same table rows, so
    /// the stored floats are bit-identical to the slab's).  Claims
    /// fresh pages as position `len` crosses page boundaries and
    /// copies a shared partial tail page before the first write into
    /// it (COW).  Returns the first appended position; the caller must
    /// have `rope.ensure(pos0 + t)`d.
    pub fn append_kv_block(&mut self, h: KvHandle, layer: usize,
                           rope: &RopeCache, k_block: &[f32],
                           v_block: &[f32], t: usize)
                           -> Result<usize, OutOfPages> {
        let hd = self.head_dim;
        let half = hd / 2;
        let w = self.n_kv_heads * hd;
        debug_assert!(k_block.len() >= t * w && v_block.len() >= t * w);
        let pos0 = self.layer_len(h, layer);
        assert!(pos0 + t <= self.max_seq, "kv arena sequence overflow");
        if t == 0 {
            return Ok(pos0);
        }
        self.ensure_tail_pages(h, layer, pos0, t)?;

        // Touched page ids, copied out so the table borrow does not
        // pin `self` while we write the page slabs.
        let first = pos0 / KV_PAGE;
        let n_touched = Self::pages_for(pos0 + t) - first;
        let pages: Vec<u32> = {
            let s = self.seqs[h.idx()].as_ref().expect("stale handle");
            s.layers[layer].pages[first..first + n_touched].to_vec()
        };
        for i in 0..t {
            let pos = pos0 + i;
            let page = pages[pos / KV_PAGE - first] as usize;
            let off = pos % KV_PAGE;
            debug_assert_eq!(self.refcount[page], 1,
                             "append into a shared page (COW missed)");
            let (cos, sin) = rope.row(pos);
            for head in 0..self.n_kv_heads {
                let base = page * self.page_elems
                    + (head * KV_PAGE + off) * hd;
                let src = &k_block[i * w + head * hd..][..hd];
                let dst = &mut self.k[base..base + hd];
                for j in 0..half {
                    let (a, b) = (src[2 * j], src[2 * j + 1]);
                    dst[2 * j] = a * cos[j] - b * sin[j];
                    dst[2 * j + 1] = a * sin[j] + b * cos[j];
                }
                let vsrc = &v_block[i * w + head * hd..][..hd];
                self.v[base..base + hd].copy_from_slice(vsrc);
            }
        }
        self.seqs[h.idx()].as_mut().expect("stale handle")
            .layers[layer].len = pos0 + t;
        Ok(pos0)
    }

    /// Make positions `[pos0, pos0 + t)` writable: COW a shared
    /// partial tail page, then claim fresh pages to cover the range.
    /// Page availability is checked up front so a failure leaves the
    /// table untouched (no half-grown state).
    fn ensure_tail_pages(&mut self, h: KvHandle, layer: usize,
                         pos0: usize, t: usize) -> Result<(), OutOfPages> {
        let need_pages = Self::pages_for(pos0 + t);
        let (have, tail_page) = {
            let tbl = &self.seqs[h.idx()].as_ref().expect("stale handle")
                .layers[layer];
            debug_assert_eq!(tbl.pages.len(), Self::pages_for(pos0));
            let tail = if pos0 % KV_PAGE != 0 {
                Some(tbl.pages[pos0 / KV_PAGE])
            } else {
                None
            };
            (tbl.pages.len(), tail)
        };
        let cow = tail_page
            .is_some_and(|p| self.refcount[p as usize] > 1);
        let fresh_needed = (need_pages - have) + cow as usize;
        if self.free.len() < fresh_needed {
            return Err(OutOfPages {
                needed: fresh_needed,
                free: self.free.len(),
            });
        }
        if cow {
            let old = tail_page.unwrap();
            let fresh = self.alloc_page();
            self.copy_page_prefix(old, fresh, pos0 % KV_PAGE);
            self.refcount[old as usize] -= 1;
            self.seqs[h.idx()].as_mut().expect("stale handle")
                .layers[layer].pages[pos0 / KV_PAGE] = fresh;
        }
        for _ in have..need_pages {
            let p = self.alloc_page();
            self.seqs[h.idx()].as_mut().expect("stale handle")
                .layers[layer].pages.push(p);
        }
        Ok(())
    }

    /// Pop a free page (caller has already checked availability) with
    /// refcount 1, growing the backing slabs to cover it if this page
    /// id has never been touched before.
    fn alloc_page(&mut self) -> u32 {
        let p = self.free.pop().expect("alloc_page past free check");
        debug_assert_eq!(self.refcount[p as usize], 0);
        self.refcount[p as usize] = 1;
        let end = (p as usize + 1) * self.page_elems;
        if self.k.len() < end {
            self.k.resize(end, 0.0);
            self.v.resize(end, 0.0);
        }
        self.peak_resident = self.peak_resident.max(self.resident_pages());
        p
    }

    fn decref(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "decref of a free page");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    /// Copy the first `rows` positions of every head from page `src`
    /// to page `dst` (the COW body).
    fn copy_page_prefix(&mut self, src: u32, dst: u32, rows: usize) {
        let hd = self.head_dim;
        for head in 0..self.n_kv_heads {
            let s = src as usize * self.page_elems + head * KV_PAGE * hd;
            let d = dst as usize * self.page_elems + head * KV_PAGE * hd;
            self.k.copy_within(s..s + rows * hd, d);
            self.v.copy_within(s..s + rows * hd, d);
        }
    }
}

/// Read view of one sequence x layer of a [`KvArena`]: resolves page
/// tables so the attention kernels see contiguous head-major runs.
pub struct KvLayerView<'a> {
    k: &'a [f32],
    v: &'a [f32],
    pages: &'a [u32],
    len: usize,
    head_dim: usize,
    page_elems: usize,
}

impl KvSource for KvLayerView<'_> {
    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> &[f32] {
        debug_assert!(p0 < p1 && p1 <= self.len);
        debug_assert_eq!(p0 / KV_PAGE, (p1 - 1) / KV_PAGE,
                         "K run straddles a page");
        let page = self.pages[p0 / KV_PAGE] as usize;
        let lo = page * self.page_elems
            + (h * KV_PAGE + p0 % KV_PAGE) * self.head_dim;
        &self.k[lo..lo + (p1 - p0) * self.head_dim]
    }

    #[inline]
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> &[f32] {
        debug_assert!(p0 < p1 && p1 <= self.len);
        debug_assert_eq!(p0 / KV_PAGE, (p1 - 1) / KV_PAGE,
                         "V run straddles a page");
        let page = self.pages[p0 / KV_PAGE] as usize;
        let lo = page * self.page_elems
            + (h * KV_PAGE + p0 % KV_PAGE) * self.head_dim;
        &self.v[lo..lo + (p1 - p0) * self.head_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 1, 2);
        assert_eq!(c.push(&[1.0, 2.0], &[3.0, 4.0]), 0);
        assert_eq!(c.push(&[5.0, 6.0], &[7.0, 8.0]), 1);
        assert_eq!(c.k_head_at(0, 0), &[1.0, 2.0]);
        assert_eq!(c.v_head_at(0, 1), &[7.0, 8.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(c.len, 2);
        assert_eq!(c.k_run(0, 0, 2), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(c.v_run(0, 1, 2), &[7.0, 8.0]);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    fn head_major_scatter() {
        // 2 kv heads x head_dim 2: interleaved rows land in per-head
        // slabs, contiguous over positions.
        let mut c = KvCache::new(3, 2, 2);
        c.push(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        c.push(&[10.0, 20.0, 30.0, 40.0], &[50.0, 60.0, 70.0, 80.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 10.0, 20.0]);
        assert_eq!(c.k_head(1), &[3.0, 4.0, 30.0, 40.0]);
        assert_eq!(c.v_head(0), &[5.0, 6.0, 50.0, 60.0]);
        assert_eq!(c.v_head(1), &[7.0, 8.0, 70.0, 80.0]);
    }

    #[test]
    fn reserve_claims_positions() {
        let mut c = KvCache::new(6, 1, 2);
        assert_eq!(c.reserve(4), 0);
        assert_eq!(c.len, 4);
        assert_eq!(c.reserve(2), 4);
        assert_eq!(c.len, 6);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1);
        c.push(&[0.0], &[0.0]);
        c.push(&[0.0], &[0.0]);
    }

    // -- arena ------------------------------------------------------------

    /// 1 layer, 1 kv head, head_dim 2 arena with a tiny page budget.
    fn small_arena(cap_pages: usize) -> KvArena {
        KvArena::new(1, 4 * KV_PAGE, 1, 2, cap_pages)
    }

    fn ident_rope() -> RopeCache {
        // theta irrelevant for these tests; positions must be ensured
        let mut r = RopeCache::new(2, 1e4);
        r.ensure(4 * KV_PAGE);
        r
    }

    /// Append `t` constant rows (value tagging the call) to `h`.
    fn fill(a: &mut KvArena, rope: &RopeCache, h: KvHandle, t: usize,
            val: f32) -> Result<usize, OutOfPages> {
        let k: Vec<f32> = vec![val; t * 2];
        let v: Vec<f32> = vec![val + 0.5; t * 2];
        a.append_kv_block(h, 0, rope, &k, &v, t)
    }

    #[test]
    fn lazy_alloc_and_free_list_reuse() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        assert_eq!(a.resident_pages(), 0, "no eager pages");
        fill(&mut a, &rope, h, KV_PAGE + 1, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        assert_eq!(a.seq_len(h), KV_PAGE + 1);
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 0, "retire frees pages");
        // readmit: pages come from the free list, peak unchanged
        let h2 = a.alloc_seq();
        fill(&mut a, &rope, h2, 2 * KV_PAGE, 2.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        assert_eq!(a.peak_resident_pages(), 2);
    }

    #[test]
    fn out_of_pages_is_clean() {
        let mut a = small_arena(1);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, KV_PAGE, 1.0).unwrap();
        let before = a.seq_len(h);
        let err = fill(&mut a, &rope, h, 1, 2.0).unwrap_err();
        assert_eq!(err, OutOfPages { needed: 1, free: 0 });
        assert_eq!(a.seq_len(h), before, "failed append must not grow");
        // freeing recovers the budget
        a.free_seq(h);
        let h2 = a.alloc_seq();
        fill(&mut a, &rope, h2, 3, 3.0).unwrap();
        assert_eq!(a.seq_len(h2), 3);
    }

    #[test]
    fn fork_shares_pages_and_cow_splits() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        // 1.5 pages: one full shared page + one shared partial page
        let t0 = KV_PAGE + KV_PAGE / 2;
        fill(&mut a, &rope, h, t0, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 2);

        let f = a.fork_prefix(h, t0);
        assert_eq!(a.seq_len(f), t0);
        assert_eq!(a.resident_pages(), 2, "fork copies no pages");
        // both views read the same bytes
        let want: Vec<f32> = a.layer(h, 0).k_run(0, 0, KV_PAGE).to_vec();
        assert_eq!(a.layer(f, 0).k_run(0, 0, KV_PAGE), &want[..]);

        // appending to the fork COWs only the partial page
        fill(&mut a, &rope, f, 1, 9.0).unwrap();
        assert_eq!(a.resident_pages(), 3, "COW copies one page");
        // source rows are untouched, fork kept the shared prefix
        let src_tail = a.layer(h, 0)
            .k_run(0, KV_PAGE, t0).to_vec();
        let fork_tail = a.layer(f, 0)
            .k_run(0, KV_PAGE, t0).to_vec();
        assert_eq!(src_tail, fork_tail,
                   "COW must preserve the shared rows");
        assert_eq!(a.seq_len(f), t0 + 1);
        assert_eq!(a.seq_len(h), t0);

        // freeing the source releases only its exclusive claim on the
        // still-shared full page
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 2);
        a.free_seq(f);
        assert_eq!(a.resident_pages(), 0);
    }

    #[test]
    fn source_append_after_fork_also_cows() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 10, 1.0).unwrap();
        let f = a.fork_prefix(h, 10);
        // the *source* appends first: it must COW too (the fork holds
        // a reference to the partial page)
        fill(&mut a, &rope, h, 1, 5.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        let hv = a.layer(h, 0).k_run(0, 0, 10).to_vec();
        let fv = a.layer(f, 0).k_run(0, 0, 10).to_vec();
        assert_eq!(hv, fv, "shared prefix must survive source COW");
        assert_eq!(a.seq_len(f), 10);
    }

    #[test]
    fn reset_seq_keeps_handle() {
        let mut a = small_arena(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 5, 1.0).unwrap();
        a.reset_seq(h);
        assert_eq!(a.seq_len(h), 0);
        assert_eq!(a.resident_pages(), 0);
        fill(&mut a, &rope, h, 3, 2.0).unwrap();
        assert_eq!(a.seq_len(h), 3);
    }

    #[test]
    fn paged_view_matches_slab_append() {
        // identical K/V blocks through the slab writer and the arena:
        // every head-major run must be bit-identical
        use crate::util::prng::Pcg;
        let (n_kv, hd) = (2usize, 4usize);
        let t = KV_PAGE + 17; // crosses a page boundary
        let mut rng = Pcg::new(77);
        let w = n_kv * hd;
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);
        let mut rope = RopeCache::new(hd, 1e4);
        rope.ensure(t);

        let mut slab = KvCache::new(2 * KV_PAGE, n_kv, hd);
        super::super::attention::append_kv_block(
            &mut slab, &rope, &k_block, &v_block, t);

        let mut a = KvArena::new(1, 2 * KV_PAGE, n_kv, hd, 4);
        let h = a.alloc_seq();
        a.append_kv_block(h, 0, &rope, &k_block, &v_block, t).unwrap();
        let view = a.layer(h, 0);
        assert_eq!(view.len(), t);
        for head in 0..n_kv {
            let mut p = 0usize;
            while p < t {
                let end = (p + KV_PAGE).min(t);
                assert_eq!(view.k_run(head, p, end),
                           slab.k_run(head, p, end),
                           "K head {head} run [{p}, {end})");
                assert_eq!(view.v_run(head, p, end),
                           slab.v_run(head, p, end),
                           "V head {head} run [{p}, {end})");
                p = end;
            }
        }
    }
}
