//! §Sharding — tensor-parallel shard study (EXPERIMENTS.md §Sharding).
//!
//! Three sections:
//!   * **partition maps** — exact per-shard head / kv-head / FFN /
//!     vocab ranges from [`ShardPlan`] for the synthetic test shape and
//!     two production-like GQA shapes, including the remainder rule
//!     (kv heads not divisible by N);
//!   * **reduction volumes** — exact per-layer / per-token join traffic
//!     of the two-barrier-pair protocol (join A: full-width context +
//!     attn output, join B: SwiGLU activations + MLP output), computed
//!     from the config, no measurement involved;
//!   * **measured + analytic scaling** — greedy decode on the synthetic
//!     model at N = 1/2/4 shards (measured on this box), plus an
//!     analytic latency projection T(N) = compute/N + join traffic for
//!     the production shapes that do not fit a CI box (rows labeled
//!     `analytic`).
//!
//! Writes `target/bench_reports/BENCH_shard.json`.

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::weights::ModelConfig;
use mobiquant::model::{ShardPlan, ShardRuntime};
use mobiquant::util::bench::{black_box, Suite};

/// Multiply-accumulates per token through the linears (attention score
/// math excluded: it is O(len * d) and KV-sharded anyway).
fn macs_per_token(c: &ModelConfig) -> f64 {
    let d = c.d_model as f64;
    let dkv = c.kv_dim() as f64;
    let ff = c.d_ff as f64;
    let l = c.n_layers as f64;
    l * (d * d          // wq
        + 2.0 * d * dkv // wk, wv
        + d * d         // wo
        + 3.0 * d * ff) // w_gate, w_up, w_down
        + d * c.vocab_size as f64 // lm_head
}

fn shaped(name: &str, d_model: usize, n_layers: usize, n_heads: usize,
          n_kv_heads: usize, d_ff: usize, vocab: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        vocab_size: vocab,
        d_model,
        n_layers,
        n_heads,
        n_kv_heads,
        d_ff,
        max_seq_len: 4096,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

fn main() {
    let mut suite = Suite::new("BENCH_shard");
    suite.header();

    let shapes = [
        shaped("synth-6h3kv", 96, 2, 6, 3, 128, 256),
        shaped("7b-gqa", 4096, 32, 32, 8, 11008, 32000),
        shaped("70b-gqa", 8192, 80, 64, 8, 28672, 32000),
    ];

    // -- exact partition maps + reduction volumes (no timing) ---------
    for cfg in &shapes {
        for n in [2usize, 3, 4, 8] {
            let plan = match ShardPlan::new(cfg, n) {
                Ok(p) => p,
                Err(_) => continue, // n > n_kv_heads for this shape
            };
            for s in 0..n {
                let (h0, h1) = plan.heads[s];
                let (k0, k1) = plan.kv[s];
                let (f0, f1) = plan.d_ff[s];
                let (v0, v1) = plan.vocab[s];
                suite.row(&format!("{} N={n} shard{s} partition",
                                   cfg.name), &[
                    ("heads", (h1 - h0) as f64),
                    ("head_lo", h0 as f64),
                    ("kv_heads", (k1 - k0) as f64),
                    ("kv_lo", k0 as f64),
                    ("d_ff_cols", (f1 - f0) as f64),
                    ("vocab_cols", (v1 - v0) as f64),
                ]);
            }
            // join A publishes d_model ctx + d_model attn_out columns;
            // join B publishes d_ff activations + d_model mlp_out —
            // the canonical "2 joins x d_model" cost plus the SwiGLU
            // staging, all gathers (no reduction arithmetic).
            let join_elems = plan.join_elems_per_token(cfg) as f64;
            let per_layer_bytes = join_elems * 4.0;
            let per_token_bytes = per_layer_bytes * cfg.n_layers as f64;
            suite.row(&format!("{} N={n} reduction volume", cfg.name),
                      &[
                ("join_elems_per_layer_token", join_elems),
                ("join_bytes_per_layer_token", per_layer_bytes),
                ("join_bytes_per_token", per_token_bytes),
                ("barriers_per_layer", 4.0),
                ("canonical_2joins_elems",
                 2.0 * cfg.d_model as f64),
            ]);
        }
    }
    suite.note("partitions are output-channel shards: every element \
                is computed whole by one shard with the serial kernel, \
                so joins are gathers and shard counts cannot change \
                bits (tests/shard_parity.rs pins this)");

    // -- measured scaling on the synthetic shape ----------------------
    let model = synth_model_shaped(7, 8, 4, 256);
    let prompt: Vec<u32> =
        (0..48).map(|i| ((i * 7 + 3) % 256) as u32).collect();
    let prec = Precision::elastic(4.0);
    let n_new = 16usize;
    let ns1 = suite.bench("synth-8h4kv N=1 generate", || {
        let mut stats = DecodeStats::new(model.cfg.n_layers);
        let out = model.generate(&prompt, n_new, prec, &mut stats)
            .unwrap();
        black_box(out.len());
    });
    for n in [2usize, 4] {
        let mut rt = ShardRuntime::new(&model, n).unwrap();
        let ns = suite.bench(
            &format!("synth-8h4kv N={n} generate"), || {
                let mut stats = DecodeStats::new(model.cfg.n_layers);
                let out = rt.generate(&model, &prompt, n_new, prec,
                                      &mut stats).unwrap();
                black_box(out.len());
            });
        suite.row(&format!("synth-8h4kv N={n} measured"), &[
            ("tok_s", n_new as f64 / (ns * 1e-9)),
            ("speedup_vs_N1", ns1 / ns),
            ("ideal", n as f64),
        ]);
    }
    suite.note("the synthetic shape is barrier-bound (d_model=128 \
                puts microseconds of compute between joins); the \
                production shapes below carry ~3 orders of magnitude \
                more compute per join, which is where the analytic \
                rows apply");

    // -- analytic projection for the production shapes ----------------
    // T(N) = macs/N + K * join_elems: each joined element is costed at
    // K MAC-equivalents (gather store + load + barrier amortization;
    // K=8 is deliberately pessimistic for a shared-memory gather).
    let k_cost = 8.0;
    for cfg in &shapes[1..] {
        let macs = macs_per_token(cfg);
        for n in [2usize, 4, 8] {
            let plan = ShardPlan::new(cfg, n).unwrap();
            let join = plan.join_elems_per_token(cfg) as f64
                * cfg.n_layers as f64;
            let t_n = macs / n as f64 + k_cost * join;
            let speedup = macs / t_n;
            suite.row(&format!("{} N={n} analytic", cfg.name), &[
                ("projected_speedup", speedup),
                ("ideal", n as f64),
                ("efficiency", speedup / n as f64),
                ("join_frac_of_shard_compute",
                 k_cost * join / (macs / n as f64)),
            ]);
        }
    }
    suite.note("analytic rows are projections, not measurements: \
                T(N) = macs/N + 8*join_elems, join_elems from \
                ShardPlan::join_elems_per_token (exact); CI boxes \
                cannot hold the production shapes");
    suite.finish();
}
