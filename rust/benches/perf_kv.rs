//! §Perf §KV-Arena — paged KV arena study (EXPERIMENTS.md §KV-Arena).
//!
//! Three questions, all on the synthetic model (no `make artifacts`):
//!
//! 1. **Decode throughput over the arena** at 1 / 8 / 32 coalesced
//!    slots — the paged page-table walk must not cost the coalesced
//!    tick anything measurable vs the old per-slot slabs (the tile
//!    inner loops are unchanged; only the run base pointer differs).
//! 2. **Resident KV memory** at the same slot counts: measured arena
//!    residency vs what the eager slab deployment
//!    (`KvFootprint::eager_bytes`) would have committed — the
//!    ISSUE's >= 4x claim for short sequences.
//! 3. **Shared-prefix prefill**: a 512-token shared prompt attached
//!    from the prefix pages + a 32-token unique tail, vs cold-filling
//!    all 544 tokens — the "million users, one system prompt" path
//!    (>= 90% of prefill work skipped by construction: 512/544).
//!
//! Writes `target/bench_reports/BENCH_kv.json`.

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::engine::Precision;
use mobiquant::mobiq::footprint::KvFootprint;
use mobiquant::model::transformer::{DecodeSlot, DecodeStats};
use mobiquant::model::KV_PAGE;
use mobiquant::util::bench::{black_box, Suite};

fn main() {
    let mut suite = Suite::new("BENCH_kv");
    suite.header();
    let prec = Precision::Fixed(2);

    // one model shape for the whole study: 4h/2kv, head_dim 16,
    // 2 layers, ctx budget 1024 (so the shared 512-token prompt fits
    // with a tail and generation headroom)
    let model = synth_model_shaped(201, 4, 2, 1024);
    let cfg = &model.cfg;
    let fp = KvFootprint {
        n_layers: cfg.n_layers,
        n_kv_heads: cfg.n_kv_heads,
        head_dim: cfg.head_dim(),
        max_seq_len: cfg.max_seq_len,
        kv_page: KV_PAGE,
    };

    // ---------------- decode throughput + residency vs slots ---------
    let prompt_len = 48usize; // short sequences: under one page
    for &n_slots in &[1usize, 8, 32] {
        let mut arena = model.new_arena(n_slots);
        let mut scratch = model.new_scratch();
        let seqs: Vec<_> = (0..n_slots).map(|_| arena.alloc_seq())
            .collect();
        let mut stats: Vec<DecodeStats> = (0..n_slots)
            .map(|_| DecodeStats::new(cfg.n_layers))
            .collect();
        let prompts: Vec<Vec<u32>> = (0..n_slots)
            .map(|s| (0..prompt_len)
                .map(|i| ((i * 5 + 7 * s + 2) % 256) as u32)
                .collect())
            .collect();
        let mut dstats = DecodeStats::new(cfg.n_layers);
        for (s, p) in prompts.iter().enumerate() {
            model.prefill(p, &mut arena, seqs[s], prec, &mut scratch,
                          &mut dstats).unwrap();
        }
        // memory: measured arena residency vs the eager slab
        // deployment at the same slot count (the ISSUE >= 4x claim)
        let resident = arena.resident_bytes();
        let eager = fp.eager_bytes(n_slots);
        suite.row(&format!("kv memory {n_slots} slots @len {prompt_len}"),
                  &[
            ("arena_resident_bytes", resident as f64),
            ("eager_slab_bytes", eager as f64),
            ("eager_over_arena", eager as f64 / resident.max(1) as f64),
        ]);

        let mut len = prompt_len;
        let ns = suite.bench(
            &format!("decode_batch {n_slots} slots"), || {
                if len + 1 >= cfg.max_seq_len {
                    for (s, p) in prompts.iter().enumerate() {
                        arena.reset_seq(seqs[s]);
                        model.prefill(p, &mut arena, seqs[s], prec,
                                      &mut scratch, &mut dstats)
                            .unwrap();
                    }
                    len = prompt_len;
                }
                let mut slots: Vec<DecodeSlot> = seqs.iter()
                    .zip(stats.iter_mut())
                    .map(|(&seq, st)| DecodeSlot {
                        token: 65,
                        seq,
                        stats: st,
                    })
                    .collect();
                model.decode_batch(&mut slots, &mut arena, prec,
                                   &mut scratch).unwrap();
                len += 1;
                black_box(scratch.block.logits[0]);
            });
        suite.row(&format!("decode {n_slots} slots summary"), &[
            ("ns_per_tick", ns),
            ("tok_s", n_slots as f64 / (ns * 1e-9)),
        ]);
    }

    // ---------------- shared-prefix vs cold prefill -------------------
    let shared_len = 8 * KV_PAGE; // 512 tokens, page-aligned
    let tail_len = 32usize;
    let total = shared_len + tail_len;
    let prompt: Vec<u32> = (0..total)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();
    let mut arena = model.new_arena(4);
    let mut scratch = model.new_scratch();
    let mut pstats = DecodeStats::new(cfg.n_layers);
    // the donor sequence holds the shared prompt's pages (what the
    // scheduler's prefix cache parks)
    let donor = arena.alloc_seq();
    model.prefill(&prompt[..shared_len], &mut arena, donor, prec,
                  &mut scratch, &mut pstats).unwrap();

    let ns_cold = suite.bench(
        &format!("cold prefill {total} tokens"), || {
            let h = arena.alloc_seq();
            model.prefill(&prompt, &mut arena, h, prec, &mut scratch,
                          &mut pstats).unwrap();
            black_box(scratch.logits[0]);
            arena.free_seq(h);
        });
    let ns_warm = suite.bench(
        &format!("shared prefill {tail_len}-token tail"), || {
            let h = arena.fork_prefix(donor, shared_len);
            model.prefill(&prompt[shared_len..], &mut arena, h, prec,
                          &mut scratch, &mut pstats).unwrap();
            black_box(scratch.logits[0]);
            arena.free_seq(h);
        });
    suite.row("shared-prefix summary", &[
        ("prefill_skip_fraction", shared_len as f64 / total as f64),
        ("cold_over_shared", ns_cold / ns_warm),
        ("ns_cold", ns_cold),
        ("ns_shared_tail", ns_warm),
        ("shared_pages_per_layer",
         (shared_len / KV_PAGE) as f64),
    ]);

    suite.note(&format!(
        "targets: eager_over_arena >= 4x at 32 short slots (exact \
         ratio = max_seq/pages: {}/{} pages); prefill_skip_fraction \
         {:.3} >= 0.9 by construction; cold_over_shared should \
         approach the linear-work ratio (attention over the shared \
         ctx is still paid by the tail)",
        cfg.max_seq_len / KV_PAGE,
        (prompt_len + KV_PAGE - 1) / KV_PAGE,
        shared_len as f64 / total as f64));
    suite.finish();
}
