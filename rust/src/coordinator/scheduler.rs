//! The decode scheduler: continuous batching with elastic precision
//! over the process-wide paged KV arena.
//!
//! Each tick the scheduler (1) reads arena occupancy into the pressure
//! ladder and picks the tick's weight precision from the elastic
//! controller, (2) admits queued requests against *real free byte
//! counts* (worst-case bytes for prompt + generation headroom at the
//! request's KV storage precision — an i8 request reserves a quarter
//! of an f32 one — discounted by any shared prompt prefix found in the
//! prefix cache), (3) advances every active sequence by one token —
//! prefilling sequences consume a whole prompt chunk through one
//! batched kernel call, and all decoding sequences are **coalesced
//! into one batched call per layer** (`Model::decode_batch`) — and
//! (4) retires finished sequences, returning their pages to the
//! arena's free list.  The structure mirrors a vLLM-style continuous
//! batcher with paged attention.
//!
//! ## Pressure ladder
//!
//! Memory pressure never hard-fails a tick.  The
//! [`PressureController`] maps occupancy bands to rungs: Moderate
//! floors new admissions to i8 KV storage, High additionally
//! requantizes resident sequences' exclusively-owned tail pages in
//! place (f32→i8), reclaims prefix-cache pages, and — when a host
//! swap tier is configured (`--host-swap`) — moves cold pages of the
//! LRU-most sequences to host memory until occupancy re-enters the
//! band (exact byte copies, O(memcpy) instead of O(recompute)),
//! Critical drops the requant target to i4 and preempts the youngest
//! sequence — its cold KV parks in the host tier and its tokens park
//! in the batcher's resume queue; the resume restores the cold
//! prefix by memcpy and re-feeds only the unparked suffix, falling
//! back to a full re-prefill when the host tier is disabled,
//! exhausted, or its restore fails (greedy decoding makes either
//! resumed completion bit-identical to an uninterrupted run).  A
//! mid-tick `OutOfPages` fault walks the same rungs — prefix-evict →
//! requant → swap → preempt — via [`Scheduler::tick`]'s recovery
//! loop instead of propagating out of `run_to_completion`.
//!
//! A sequence whose pages are (partly) host-resident is *stalled*:
//! it is excluded from prefill/decode dispatch until
//! [`Scheduler::tick`]'s swap-in pass restores it, which is gated on
//! occupancy clearing the High band's entry by the de-escalation
//! hysteresis so the swap rung and its undo cannot thrash tick over
//! tick.  When every active sequence is stalled the gate yields and
//! the oldest restores unconditionally (deadlock guard).
//!
//! ## Prefix sharing
//!
//! The "million users, one system prompt" scenario: when a sequence
//! finishes prefill at a single precision, its page-aligned prompt
//! prefix is parked in a small LRU cache (a forked arena handle keeps
//! the pages alive).  A later request whose prompt starts with a
//! cached prefix *at the same weight precision AND the same KV storage
//! precision* forks those pages instead of recomputing them — prefill
//! skips the shared tokens entirely, and the arena's refcounts/COW
//! keep writers isolated.  KV content is a pure function of (token
//! prefix, weight precision, KV storage precision, weights), so shared
//! pages are bit-identical to recomputed ones; a cached f32-page
//! prefix must never be forked into an i8 sequence (or vice versa) —
//! the pools do not even share page-id spaces.  At least one prompt
//! token is always re-fed so the last-token logits that seed the first
//! generated token exist.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{Admission, Batcher};
use super::controller::ElasticController;
use super::metrics::Metrics;
use super::pressure::{PressureConfig, PressureController, PressureLevel};
use super::request::{PreemptedSeq, Request, RequestId, RequestMetrics,
                     Response};
use crate::mobiq::engine::Precision;
use crate::mobiq::router::draft_delta;
use crate::model::kvcache::{KvHandle, KvPrecision, KvShards, OutOfPages,
                            SeqCheckpoint, SwapSummary, KV_PAGE};
use crate::model::shard::ShardRuntime;
use crate::model::transformer::{argmax, DecodeScratch, DecodeSlot,
                                DecodeStats, MAX_PREFILL_BLOCK};
use crate::model::{Model, SpecCapture, SpecConfig, SpecState};

/// Max parked shared-prefix entries; the LRU entry is evicted on
/// insertion past this, or one per tick under page backpressure.
const PREFIX_CACHE_MAX: usize = 16;

/// Mid-tick `OutOfPages` recovery attempts that may use the gentle
/// rungs (prefix eviction, tail requant) before recovery goes straight
/// to preemption.  Bounds the retry loop: each gentle rung either
/// frees bytes or reports it cannot, and each preemption shrinks the
/// active set.
const MAX_OOM_GENTLE: u32 = 8;

struct ActiveSeq {
    req: Request,
    seq: KvHandle,
    tokens: Vec<u32>,
    prompt_len: usize,
    /// Tokens to feed before decode starts: the (truncated) prompt on
    /// a fresh admission, prompt + generated-so-far on a resume from
    /// preemption (the re-prefill reproduces the parked decode state).
    prefill_len: usize,
    /// Tokens that have entered the model; starts at the shared-prefix
    /// length when admission attached cached pages.
    fed: usize,
    generated: usize,
    /// Storage precision of this sequence's KV *appends* (requant can
    /// lower it mid-flight; already-written shared pages keep theirs).
    kv_prec: KvPrecision,
    /// Worst-case budget bytes reserved at admission (minus the shared
    /// discount); with `bytes_at_admission` this bounds what the
    /// sequence may still allocate.
    reserved_bytes: usize,
    bytes_at_admission: usize,
    /// Precision every prefill chunk ran at so far; entries are only
    /// registered in the prefix cache when this stayed uniform.
    prefill_prec: Option<Precision>,
    prefill_uniform: bool,
    registered: bool,
    /// Admission order (monotone across the run) — "youngest" for
    /// preemption is the max of these, so the sequence that loses its
    /// pages is the one with the least sunk prefill/decode work.
    admit_ord: u64,
    /// Tick at which this sequence's host-tier pages were last
    /// restored (0 = never).  The OOM ladder's swap rung skips
    /// sequences restored in the current tick — re-evicting pages the
    /// deadlock-guarded swap-in just paid to bring back would livelock
    /// the two passes against each other.
    swapped_in_tick: u64,
    stats: DecodeStats,
    /// Self-speculative decode state (accept-rate EMA driving draft
    /// depth and draft bits) when the batcher enables speculation.
    /// Preemption drops it — a resumed sequence re-learns its accept
    /// rate from the neutral seed rather than trusting a stale EMA.
    spec: Option<SpecState>,
    prefill_ms: f64,
    decode_ms: f64,
    admitted_at: Instant,
}

impl ActiveSeq {
    /// Budget bytes this sequence may still claim from the arena (its
    /// admission reservation minus what it has already allocated).
    fn reserved_remaining(&self, arena: &KvShards) -> usize {
        let grown = arena.seq_bytes(self.seq)
            .saturating_sub(self.bytes_at_admission);
        self.reserved_bytes.saturating_sub(grown)
    }
}

/// One parked shared prompt prefix: `handle` is a cache-owned arena
/// sequence whose pages hold the KV of `tokens` computed at weight
/// precision `precision` and stored at `kv_prec` — both are part of
/// the match key, since pages of different storage precisions hold
/// different bytes in different pools.
struct PrefixEntry {
    tokens: Vec<u32>,
    precision: Precision,
    kv_prec: KvPrecision,
    handle: KvHandle,
    last_used: u64,
}

pub struct Scheduler<'m> {
    pub model: &'m Model,
    pub batcher: Batcher,
    pub controller: ElasticController,
    pub metrics: Metrics,
    /// The process-wide paged KV pool all sequences live in: one arena
    /// per shard (a single mirrored element when unsharded), sharing
    /// one logical byte budget.
    pub arena: KvShards,
    /// Tensor-parallel execution engine when serving with `--shards`
    /// N > 1; `None` runs the pre-PR single-arena model entry points.
    shard_rt: Option<ShardRuntime>,
    active: Vec<ActiveSeq>,
    prefix: Vec<PrefixEntry>,
    pressure: PressureController,
    scratch: DecodeScratch,
    /// Verify-pass capture scratch (per-position pre-RoPE K/V rows +
    /// logits), reused across speculative rounds and sequences.
    spec_cap: SpecCapture,
    started: Instant,
    ticks: u64,
    admit_counter: u64,
}

/// Worst-case budget bytes a request needs: its (truncated) prompt
/// plus full generation headroom, across all layers, at its KV
/// storage precision.
fn worst_bytes(arena: &KvShards, prompt_len: usize, max_new: usize,
               kv_prec: KvPrecision) -> usize {
    arena.seq_worst_bytes(prompt_len + max_new, kv_prec)
}

/// Longest usable shared prefix of `prompt` in the cache at this
/// (weight precision, KV storage precision) pair: returns
/// `(entry index, shared token count)`.  Capped at `prompt.len() - 1`
/// (one token must be re-fed for its logits) and gated at one full
/// page (shorter shares are not worth a fork+COW).
fn best_prefix(entries: &[PrefixEntry], prompt: &[u32],
               precision: Precision, kv_prec: KvPrecision)
               -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize)> = None;
    for (i, e) in entries.iter().enumerate() {
        if e.precision != precision || e.kv_prec != kv_prec {
            continue;
        }
        let cap = prompt.len().saturating_sub(1).min(e.tokens.len());
        let mut n = 0usize;
        while n < cap && prompt[n] == e.tokens[n] {
            n += 1;
        }
        let better = match best {
            None => true,
            Some((_, bn)) => bn < n,
        };
        if n >= KV_PAGE && better {
            best = Some((i, n));
        }
    }
    best
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, batcher: Batcher,
               controller: ElasticController) -> Scheduler<'m> {
        let mut scratch = model.new_scratch();
        // Pre-warm the RoPE sin/cos tables over the whole context
        // budget: the cache grows on demand, but growing it mid-tick
        // would show up as a latency blip on whichever request first
        // reaches a new position.  One-off cost at server start.
        scratch.rope.ensure(model.cfg.max_seq_len);
        // Same for the fork-join workers: they normally spawn lazily
        // on the first parallel dispatch, which would charge thread
        // creation to the first request's tick.
        if let Some(pool) = &model.pool {
            pool.warm();
        }
        // The arena: an explicit page budget commits less memory than
        // the worst case (admission queues when pages run short);
        // otherwise size it so every slot can reach full context.
        let arena = KvShards::single(match batcher.kv_page_budget {
            Some(pages) => model.new_arena_with_pages(pages),
            None => model.new_arena(batcher.max_active),
        });
        let mut s = Scheduler {
            scratch,
            model,
            batcher,
            controller,
            metrics: Metrics::default(),
            arena,
            shard_rt: None,
            active: Vec::new(),
            prefix: Vec::new(),
            pressure: PressureController::new(PressureConfig::default()),
            spec_cap: SpecCapture::new(),
            started: Instant::now(),
            ticks: 0,
            admit_counter: 0,
        };
        s.apply_host_budget();
        s
    }

    /// Size the arena's host swap tier from the batcher's byte budget
    /// (rounded down to whole f32-page slots; a non-zero budget always
    /// grants at least one page so `--host-swap` with a small number
    /// is not a silent no-op).  Re-applied after `with_shards` rebuilds
    /// the arena.
    fn apply_host_budget(&mut self) {
        if self.batcher.host_swap_bytes == 0 {
            return;
        }
        let pb = self.arena.page_bytes().max(1);
        let pages = (self.batcher.host_swap_bytes / pb).max(1);
        self.arena.set_host_budget_pages(pages);
    }

    /// Override the pressure ladder's occupancy bands.
    pub fn with_pressure(mut self, cfg: PressureConfig) -> Scheduler<'m> {
        self.pressure = PressureController::new(cfg);
        self
    }

    /// Shard the model over `n` tensor-parallel workers.  Replaces the
    /// KV store with one mirrored arena per shard — each holding that
    /// shard's kv heads under the *same* page-slot budget as the
    /// unsharded arena, so byte totals, occupancy fractions, and the
    /// pressure ladder's behavior are unchanged.  Must be called on a
    /// fresh scheduler (before any admission).  `n = 1` keeps the
    /// pre-PR single-arena execution path.
    pub fn with_shards(mut self, n: usize) -> Result<Scheduler<'m>> {
        assert!(self.active.is_empty() && self.prefix.is_empty(),
                "with_shards on a scheduler that already has state");
        if n <= 1 {
            return Ok(self);
        }
        let rt = ShardRuntime::new(self.model, n)?;
        self.arena = match self.batcher.kv_page_budget {
            Some(pages) => rt.new_shards_with_pages(self.model, pages),
            None => rt.new_shards_arena(self.model,
                                        self.batcher.max_active),
        };
        self.shard_rt = Some(rt);
        self.apply_host_budget();
        Ok(self)
    }

    /// Tensor-parallel worker count (1 = unsharded).
    pub fn n_shards(&self) -> usize {
        self.arena.n_shards()
    }

    pub fn submit(&mut self, req: Request) {
        if matches!(self.batcher.submit(req), Admission::Rejected) {
            self.metrics.rejected += 1;
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// The pressure band acted on at the last tick.
    pub fn pressure_level(&self) -> PressureLevel {
        self.pressure.level()
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.batcher.queued() == 0
            && self.batcher.parked() == 0
    }

    /// Drop the least-recently-used prefix entry, returning its pages.
    fn evict_lru_prefix(&mut self) {
        let Some(i) = self.prefix.iter().enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        else {
            return;
        };
        let e = self.prefix.swap_remove(i);
        self.arena.free_seq(e.handle);
        self.metrics.prefix_evictions += 1;
    }

    fn index_of(&self, id: RequestId) -> Option<usize> {
        self.active.iter().position(|s| s.req.id == id)
    }

    /// Youngest (most recently admitted) active sequence, optionally
    /// excluding one request — the preemption victim choice: it has
    /// the least sunk work to recompute.
    fn youngest_active(&self, protect: Option<RequestId>)
                       -> Option<usize> {
        self.active.iter().enumerate()
            .filter(|(_, s)| Some(s.req.id) != protect)
            .max_by_key(|(_, s)| s.admit_ord)
            .map(|(i, _)| i)
    }

    fn seq_finished(&self, s: &ActiveSeq) -> bool {
        let kv_full = self.arena.seq_len(s.seq) + 1
            >= self.model.cfg.max_seq_len;
        s.generated >= s.req.max_new_tokens || kv_full
    }

    /// Retire one sequence: free its pages, assemble and send the
    /// response, record request metrics.
    fn retire_at(&mut self, i: usize) {
        let seq = self.active.swap_remove(i);
        self.arena.free_seq(seq.seq);
        if let Some(st) = &seq.spec {
            self.metrics.record_spec_hist(&st.draft_stats.bits_hist);
        }
        let total_ms =
            seq.req.submitted.elapsed().as_secs_f64() * 1000.0;
        let queue_ms =
            (seq.admitted_at - seq.req.submitted).as_secs_f64() * 1000.0;
        let prompt_len = seq.prompt_len;
        let resp = Response {
            id: seq.req.id,
            generated: seq.tokens[prompt_len..].to_vec(),
            tokens: seq.tokens,
            metrics: RequestMetrics {
                queue_ms,
                prefill_ms: seq.prefill_ms,
                decode_ms: seq.decode_ms,
                total_ms,
                generated_tokens: seq.generated,
                avg_bits: seq.stats.avg_bits(),
            },
        };
        self.metrics.record_request(total_ms, seq.generated);
        let _ = seq.req.reply.send(resp); // receiver may have gone away
    }

    /// Evict sequence `i` from the arena and park its tokens for a
    /// later resume.  A sequence that already finished generating is
    /// retired instead — parking it would make the resume prefill push
    /// one argmax token past what an unpressured run produces.
    fn preempt(&mut self, i: usize) {
        if self.seq_finished(&self.active[i]) {
            self.retire_at(i);
            return;
        }
        let s = self.active.swap_remove(i);
        self.metrics.preemptions += 1;
        // the spec state is dropped with the eviction (see ActiveSeq);
        // bank its draft-bit histogram before it goes
        if let Some(st) = &s.spec {
            self.metrics.record_spec_hist(&st.draft_stats.bits_hist);
        }
        // swap-then-preempt: the cold KV prefix moves to the host
        // tier (when one is configured and has room) so the resume is
        // a memcpy + short suffix re-feed instead of a full
        // re-prefill; everything that could not move is released
        let host_kv = self.park_kv(s.seq);
        // park the *ask* precision, not the possibly-degraded one: the
        // resume admission re-applies whatever floor holds then
        self.batcher.park(PreemptedSeq {
            host_kv,
            tokens: s.tokens,
            prompt_len: s.prompt_len,
            generated: s.generated,
            kv_prec: s.req.kv_precision,
            stats: s.stats,
            prefill_ms: s.prefill_ms,
            decode_ms: s.decode_ms,
            admitted_at: s.admitted_at,
            req: s.req,
        });
    }

    /// Try to park a preempted sequence's KV in the host tier: swap
    /// its cold pages out, then truncate the sequence to the
    /// contiguous host-resident prefix (releasing the device tail and
    /// any cold pages that could not move — shared, budget-stopped,
    /// or failpoint-denied).  Returns the still-live handle plus the
    /// token count its host pages cover, or frees the sequence
    /// entirely when nothing made it to the host tier (the resume
    /// then takes the full re-prefill path).
    fn park_kv(&mut self, seq: KvHandle) -> Option<(KvHandle, usize)> {
        let sum = self.arena.swap_out_seq_cold(seq);
        self.note_swap_out(sum);
        let kept = self.arena.seq_host_prefix_len(seq);
        if kept == 0 {
            self.arena.free_seq(seq);
            return None;
        }
        self.arena.truncate_seq(seq, kept);
        Some((seq, kept))
    }

    fn note_swap_out(&mut self, sum: SwapSummary) {
        if sum.pages > 0 {
            self.metrics.swap_out_events += 1;
            self.metrics.swap_out_pages += sum.pages as u64;
            self.metrics.swap_out_bytes += sum.bytes as u64;
        }
    }

    fn note_swap_in(&mut self, sum: SwapSummary) {
        if sum.pages > 0 {
            self.metrics.swap_in_events += 1;
            self.metrics.swap_in_pages += sum.pages as u64;
            self.metrics.swap_in_bytes += sum.bytes as u64;
        }
    }

    /// High/Critical band rung: move cold pages of the oldest-admitted
    /// (LRU-most) sequences to the host tier until occupancy drops
    /// below `target` (a fraction of the device budget).  The pages
    /// move byte-exactly, and each affected sequence stalls — excluded
    /// from dispatch — until the swap-in pass restores it.
    fn swap_out_lru_until(&mut self, target: f64) {
        let capacity = self.arena.capacity_bytes();
        if capacity == 0 || self.arena.host_capacity_bytes() == 0 {
            return;
        }
        let mut order: Vec<(u64, KvHandle)> = self.active.iter()
            .map(|s| (s.admit_ord, s.seq))
            .collect();
        order.sort_unstable();
        for (_, h) in order {
            let occ = self.arena.resident_bytes() as f64
                / capacity as f64;
            if occ < target {
                break;
            }
            let sum = self.arena.swap_out_seq_cold(h);
            self.note_swap_out(sum);
        }
    }

    /// The OOM ladder's swap rung: sweep cold pages of other
    /// sequences to the host tier (oldest first) until the fault's
    /// byte shortage is covered or nothing more can move.  The
    /// faulting sequence is skipped — its retry needs its own pages
    /// device-resident — and so is anything the swap-in pass restored
    /// this tick (see `ActiveSeq::swapped_in_tick`).  Returns bytes
    /// freed from the device budget.
    fn swap_out_rung(&mut self, needed: usize,
                     protect: Option<RequestId>) -> usize {
        if self.arena.host_capacity_bytes() == 0 {
            return 0;
        }
        let mut order: Vec<(u64, RequestId)> = self.active.iter()
            .filter(|s| Some(s.req.id) != protect
                && s.swapped_in_tick != self.ticks)
            .map(|s| (s.admit_ord, s.req.id))
            .collect();
        order.sort_unstable();
        let mut bytes = 0usize;
        for (_, id) in order {
            if bytes >= needed {
                break;
            }
            let Some(i) = self.index_of(id) else { continue };
            let sum = self.arena.swap_out_seq_cold(self.active[i].seq);
            self.note_swap_out(sum);
            bytes += sum.bytes;
        }
        bytes
    }

    /// Tick-start restore pass for stalled sequences (host-resident
    /// pages exclude a sequence from dispatch).  Oldest first — they
    /// carry the most sunk work — and gated on the *projected*
    /// occupancy after the restore clearing the High band's entry by
    /// the de-escalation hysteresis, so the swap rung does not evict
    /// the same pages right back next tick.  The pass stops at the
    /// first sequence that does not fit (no out-of-order restores).
    /// Deadlock guard: when every active sequence is stalled no
    /// dispatch could ever lower occupancy, so the oldest restores
    /// unconditionally, walking the OOM ladder on failure.
    fn swap_in_stalled(&mut self) {
        let capacity = self.arena.capacity_bytes();
        if capacity == 0 || self.arena.host_resident_pages() == 0 {
            return;
        }
        let mut stalled: Vec<(u64, RequestId)> = self.active.iter()
            .filter(|s| self.arena.seq_swapped_pages(s.seq) > 0)
            .map(|s| (s.admit_ord, s.req.id))
            .collect();
        if stalled.is_empty() {
            return;
        }
        stalled.sort_unstable();
        let all_stalled = stalled.len() == self.active.len();
        let release = (self.pressure.swap_target()
            - self.pressure.config().hysteresis).max(0.0);
        for (k, &(_, id)) in stalled.iter().enumerate() {
            let forced = all_stalled && k == 0;
            let mut attempt = 0u32;
            loop {
                let Some(i) = self.index_of(id) else { break };
                let h = self.active[i].seq;
                let need = self.arena.seq_host_bytes(h);
                let projected =
                    (self.arena.resident_bytes() + need) as f64
                        / capacity as f64;
                if !forced && projected >= release {
                    return;
                }
                match self.arena.swap_in_seq(h) {
                    Ok(sum) => {
                        self.note_swap_in(sum);
                        self.active[i].swapped_in_tick = self.ticks;
                        break;
                    }
                    Err(oom) => {
                        // partial progress is kept (the restore is
                        // retryable); on the gated path just wait for
                        // a later tick, on the forced path free bytes
                        // through the ladder and retry
                        if !forced {
                            return;
                        }
                        attempt += 1;
                        if !self.recover_oom(&oom, Some(id), attempt) {
                            break;
                        }
                    }
                }
            }
        }
    }

    /// Whether a sequence is stalled on host-resident pages (must not
    /// reach the attention kernels until swapped back in).
    fn seq_stalled(&self, i: usize) -> bool {
        self.arena.seq_swapped_pages(self.active[i].seq) > 0
    }

    /// Requantize every resident sequence stored costlier than
    /// `target` (exclusively-owned tail pages convert in place; shared
    /// prefix pages keep their precision until COW).  Returns pages
    /// converted.
    fn requant_active(&mut self, target: KvPrecision) -> usize {
        let max_seq = self.model.cfg.max_seq_len;
        let mut pages = 0usize;
        let mut bytes = 0usize;
        for i in 0..self.active.len() {
            if self.active[i].kv_prec.rank() >= target.rank() {
                continue;
            }
            let h = self.active[i].seq;
            let sum = self.arena.requant_seq_tail(h, target);
            pages += sum.pages;
            bytes += sum.bytes_freed;
            let s = &mut self.active[i];
            s.kv_prec = target;
            // requantized pages are foreign to any prefix entry keyed
            // on the original storage precision — never register them
            s.registered = true;
            // re-baseline the admission reservation at the cheaper
            // rate (conservative: worst case from scratch at `target`)
            let final_len = (s.prompt_len + s.req.max_new_tokens)
                .min(max_seq);
            s.reserved_bytes =
                self.arena.seq_worst_bytes(final_len, target);
            s.bytes_at_admission = self.arena.seq_bytes(h);
        }
        if pages > 0 {
            self.metrics.requant_events += 1;
            self.metrics.requant_pages += pages as u64;
            self.metrics.requant_bytes_freed += bytes as u64;
        }
        pages
    }

    /// Walk the degradation ladder after a mid-tick `OutOfPages`
    /// fault.  Returns true when the caller should retry the failed
    /// operation; false means the faulting request itself was parked
    /// (the operation is abandoned — the request resumes later, it is
    /// never dropped).
    ///
    /// A synthetic failpoint fault reports the arena's *real* free
    /// bytes, which already cover the need — gentle rungs cannot
    /// satisfy a denial that is not about bytes, so those faults go
    /// straight to preemption (this is also what lets the parity test
    /// preempt without perturbing other residents).
    fn recover_oom(&mut self, oom: &OutOfPages,
                   protect: Option<RequestId>, attempt: u32) -> bool {
        self.metrics.oom_recoveries += 1;
        let real_shortage = oom.free_bytes < oom.needed_bytes;
        if real_shortage && attempt <= MAX_OOM_GENTLE {
            if !self.prefix.is_empty() {
                self.evict_lru_prefix();
                return true;
            }
            for target in [KvPrecision::Int8, KvPrecision::Int4] {
                if self.requant_active(target) > 0 {
                    return true;
                }
            }
            // host-swap rung: relieve the byte shortage by memcpy
            // before any sequence loses work — the swapped sequences
            // stall for the rest of the tick but keep their exact KV
            let short = oom.needed_bytes - oom.free_bytes;
            if self.swap_out_rung(short, protect) > 0 {
                return true;
            }
        }
        if let Some(i) = self.youngest_active(protect) {
            self.preempt(i);
            return true;
        }
        // only the faulting sequence remains: park it too and tell the
        // caller to abandon the operation
        if let Some(id) = protect {
            if let Some(i) = self.index_of(id) {
                self.preempt(i);
            }
        }
        false
    }

    /// Advance one decode group by one token through a single
    /// coalesced batched call.  On OutOfPages: roll every member back
    /// one appended position, walk the ladder, retry with the
    /// surviving members.  Returns model steps (tokens) executed.
    fn decode_group_plain(&mut self, group: &[RequestId],
                          precision: Precision) -> Result<usize> {
        let model = self.model;
        let vocab = model.cfg.vocab_size;
        let mut steps = 0usize;
        let mut attempt = 0u32;
        loop {
            // re-resolve per attempt: OOM recovery may preempt
            // (remove) members or stall them behind a host swap-out —
            // a stalled member's pages are not readable, so it sits
            // this tick out and restores at the next swap-in pass
            let members: Vec<usize> = group.iter()
                .filter_map(|id| self.index_of(*id))
                .filter(|&i| !self.seq_stalled(i))
                .collect();
            if members.is_empty() {
                break;
            }
            let len0: Vec<(KvHandle, usize)> = members.iter()
                .map(|&i| {
                    let h = self.active[i].seq;
                    (h, self.arena.seq_len(h))
                })
                .collect();
            // stats move out so DecodeSlot can hold &mut into them
            // while the member list indexes self.active
            let mut stats: Vec<DecodeStats> = members.iter()
                .map(|&i| {
                    std::mem::take(&mut self.active[i].stats)
                })
                .collect();
            let t0 = Instant::now();
            let res = {
                let active = &self.active;
                let mut slots: Vec<DecodeSlot> = members.iter()
                    .zip(stats.iter_mut())
                    .map(|(&i, st)| DecodeSlot {
                        token: active[i].tokens[active[i].fed],
                        seq: active[i].seq,
                        stats: st,
                    })
                    .collect();
                match &mut self.shard_rt {
                    Some(rt) => rt.decode_batch(
                        model, &mut slots, &mut self.arena, precision,
                        &mut self.scratch.block.logits),
                    None => model.decode_batch(
                        &mut slots, self.arena.only_mut(), precision,
                        &mut self.scratch),
                }
            };
            for (&i, st) in members.iter().zip(stats) {
                self.active[i].stats = st;
            }
            match res {
                Ok(()) => {
                    // per-token latency attribution: the batch
                    // advanced every member one token in one wall
                    // interval
                    let ms = t0.elapsed().as_secs_f64() * 1000.0
                        / members.len() as f64;
                    for (row, &i) in members.iter().enumerate() {
                        let lo = row * vocab;
                        let next = argmax(
                            &self.scratch.block.logits
                                [lo..lo + vocab]) as u32;
                        let s = &mut self.active[i];
                        s.fed += 1;
                        s.tokens.push(next);
                        s.generated += 1;
                        s.decode_ms += ms;
                        self.metrics.record_token(ms);
                        steps += 1;
                    }
                    break;
                }
                Err(e) => match e.downcast::<OutOfPages>() {
                    Ok(oom) => {
                        for &(h, l) in &len0 {
                            self.arena.truncate_seq(h, l);
                        }
                        attempt += 1;
                        if !self.recover_oom(&oom, None, attempt) {
                            break;
                        }
                    }
                    Err(e) => return Err(e),
                },
            }
        }
        Ok(steps)
    }

    /// Advance one decode group speculatively: draft up to `k` tokens
    /// per member through `k` coalesced batched calls at the group's
    /// low-bit draft precision, roll every member's arena state back
    /// *exactly* (checkpoint/rollback — absmax scales widened by draft
    /// appends must not leak into committed pages), then verify and
    /// commit per member with one batched full-precision pass each
    /// ([`Model::verify_commit`]).  Greedy outputs are bit-identical
    /// to [`Scheduler::decode_group_plain`]'s; a fully accepted round
    /// commits k+1 tokens for a single verify step.
    ///
    /// The group drafts in lockstep: `k` is the min over members'
    /// adaptive depths (and per-member remaining-token / context
    /// headroom), the draft bits are the weakest member's, capped by
    /// the controller's [`ElasticController::draft_bits_ceiling`] so
    /// system pressure also shrinks the draft budget, and the router
    /// threshold shift comes from the members' mean accept EMA.
    ///
    /// OOM recovery ordering matters: a mid-draft fault first rolls
    /// every member back to its checkpoint and only then walks the
    /// degradation ladder — an in-place requant of draft-polluted
    /// pages would otherwise bake the widened scales in permanently.
    fn decode_group_spec(&mut self, group: &[RequestId],
                         precision: Precision, cfg: &SpecConfig)
                         -> Result<usize> {
        let model = self.model;
        let vocab = model.cfg.vocab_size;
        let max_seq = model.cfg.max_seq_len;
        let n_layers = model.cfg.n_layers;
        let mut attempt = 0u32;
        // phase A: lockstep drafting, bracketed by exact checkpoints
        let (ids, chains, draft_ms) = loop {
            // like decode_group_plain: drop members preempted or
            // stalled (host-swapped) by a previous attempt's recovery
            let members: Vec<usize> = group.iter()
                .filter_map(|id| self.index_of(*id))
                .filter(|&i| !self.seq_stalled(i))
                .collect();
            if members.is_empty() {
                return Ok(0);
            }
            // a sequence admitted before speculation was switched on
            // (tests toggle the pub batcher field) starts neutral
            for &i in &members {
                if self.active[i].spec.is_none() {
                    self.active[i].spec =
                        Some(SpecState::new(cfg, n_layers));
                }
            }
            let mut group_k = usize::MAX;
            let mut bits = f64::INFINITY;
            let mut ema_sum = 0.0;
            for &i in &members {
                let s = &self.active[i];
                // seeded just above for every member; a logic slip
                // must cost this member its vote on the group's draft
                // shape, not the dispatcher thread
                let Some(st) = s.spec.as_ref() else { continue };
                let remaining = s.req.max_new_tokens
                    .saturating_sub(s.generated);
                let len = self.arena.seq_len(s.seq);
                group_k = group_k
                    .min(st.k)
                    .min(remaining.saturating_sub(1))
                    .min(max_seq.saturating_sub(len + 1))
                    .min(MAX_PREFILL_BLOCK - 1);
                bits = bits.min(st.draft_bits);
                ema_sum += st.ema;
            }
            if group_k == 0 {
                // nothing left to gamble on (some member is one token
                // from done or from the context edge): plain decode
                return self.decode_group_plain(group, precision);
            }
            let bits = bits.min(self.controller.draft_bits_ceiling());
            let ema = ema_sum / members.len() as f64;
            let dprec = Precision::elastic(bits).with_delta(
                draft_delta(ema, cfg.accept_lo, cfg.accept_hi,
                            cfg.max_delta));
            // per-shard checkpoints (one per mirrored arena; a single
            // element when unsharded)
            let cks: Vec<(KvHandle, Vec<SeqCheckpoint>)> = members.iter()
                .map(|&i| {
                    let h = self.active[i].seq;
                    (h, self.arena.checkpoint_seq(h))
                })
                .collect();
            let t0 = Instant::now();
            // chains[m][0] = the member's pending token; drafts follow
            let mut chains: Vec<Vec<u32>> = members.iter()
                .map(|&i| {
                    let s = &self.active[i];
                    vec![s.tokens[s.fed]]
                })
                .collect();
            let mut fault: Option<OutOfPages> = None;
            'draft: for _ in 0..group_k {
                // draft stats move out (like decode_group_plain's) —
                // they live on the spec state so scaffolding tokens
                // never pollute the request's routing stats
                // spec state exists for every member (seeded above);
                // a missing one degrades to fresh stats for this
                // round rather than killing the dispatcher
                let mut dstats: Vec<DecodeStats> = members.iter()
                    .map(|&i| {
                        self.active[i].spec.as_mut()
                            .map(|st| {
                                std::mem::take(&mut st.draft_stats)
                            })
                            .unwrap_or_else(|| {
                                DecodeStats::new(n_layers)
                            })
                    })
                    .collect();
                let res = {
                    let active = &self.active;
                    let mut slots: Vec<DecodeSlot> = members.iter()
                        .zip(dstats.iter_mut())
                        .zip(chains.iter())
                        .map(|((&i, st), chain)| DecodeSlot {
                            token: *chain.last().unwrap(),
                            seq: active[i].seq,
                            stats: st,
                        })
                        .collect();
                    match &mut self.shard_rt {
                        Some(rt) => rt.decode_batch(
                            model, &mut slots, &mut self.arena, dprec,
                            &mut self.scratch.block.logits),
                        None => model.decode_batch(
                            &mut slots, self.arena.only_mut(), dprec,
                            &mut self.scratch),
                    }
                };
                for (&i, st) in members.iter().zip(dstats) {
                    if let Some(sp) = self.active[i].spec.as_mut() {
                        sp.draft_stats = st;
                    }
                }
                match res {
                    Ok(()) => {
                        for (row, chain) in
                            chains.iter_mut().enumerate()
                        {
                            let lo = row * vocab;
                            chain.push(argmax(
                                &self.scratch.block.logits
                                    [lo..lo + vocab]) as u32);
                        }
                    }
                    Err(e) => match e.downcast::<OutOfPages>() {
                        Ok(oom) => {
                            fault = Some(oom);
                            break 'draft;
                        }
                        Err(e) => return Err(e),
                    },
                }
            }
            // draft KV is scaffolding either way: restore every
            // member's exact committed bytes/scales *before* recovery
            // can requantize pages the drafts polluted
            for (h, ck) in &cks {
                self.arena.rollback_seq(*h, ck);
            }
            match fault {
                None => {
                    let ids: Vec<RequestId> = members.iter()
                        .map(|&i| self.active[i].req.id)
                        .collect();
                    break (ids, chains,
                           t0.elapsed().as_secs_f64() * 1000.0);
                }
                Some(oom) => {
                    attempt += 1;
                    if !self.recover_oom(&oom, None, attempt) {
                        return Ok(0);
                    }
                }
            }
        };
        // phase B: per-member batched verify + exact commit.
        // verify_commit takes its own fresh checkpoint, so a member's
        // OOM recovery (which may requant others' tails) never leaves
        // half-verified state behind.
        let mut steps = 0usize;
        let share = draft_ms / ids.len() as f64;
        for (m, id) in ids.iter().enumerate() {
            let drafts = &chains[m][1..];
            let mut vattempt = 0u32;
            loop {
                let Some(i) = self.index_of(*id) else { break };
                if self.seq_stalled(i) {
                    // a previous member's OOM recovery swapped this
                    // sequence's cold pages out: its verify pass
                    // waits for the next tick's swap-in restore
                    break;
                }
                let t0 = Instant::now();
                let seq = self.active[i].seq;
                let last = self.active[i].tokens[self.active[i].fed];
                debug_assert_eq!(last, chains[m][0]);
                let mut stats =
                    std::mem::take(&mut self.active[i].stats);
                let res = match &mut self.shard_rt {
                    Some(rt) => rt.verify_commit(
                        model, last, drafts, &mut self.arena, seq,
                        precision, &mut stats),
                    None => model.verify_commit(
                        last, drafts, self.arena.only_mut(), seq,
                        precision, &mut self.scratch,
                        &mut self.spec_cap, &mut stats),
                };
                self.active[i].stats = stats;
                match res {
                    Ok(round) => {
                        let committed = round.tokens.len();
                        let ms = t0.elapsed().as_secs_f64() * 1000.0
                            + share;
                        let s = &mut self.active[i];
                        s.fed += committed;
                        s.tokens.extend_from_slice(&round.tokens);
                        s.generated += committed;
                        s.decode_ms += ms;
                        // seeded in phase A; a missing state only
                        // costs this member its accept-EMA update
                        // (0.5 is SpecState's neutral seed)
                        let ema = match s.spec.as_mut() {
                            Some(st) => {
                                st.observe(cfg, round.drafted,
                                           round.matched, committed);
                                st.ema
                            }
                            None => 0.5,
                        };
                        let per_tok = ms / committed as f64;
                        for _ in 0..committed {
                            self.metrics.record_token(per_tok);
                        }
                        self.metrics.record_spec_round(
                            round.drafted, round.matched, committed,
                            ema);
                        steps += committed;
                        break;
                    }
                    // verify_commit already rolled the member back to
                    // its committed state before surfacing the fault
                    Err(e) => match e.downcast::<OutOfPages>() {
                        Ok(oom) => {
                            vattempt += 1;
                            if !self.recover_oom(&oom, Some(*id),
                                                 vattempt) {
                                break;
                            }
                        }
                        Err(e) => return Err(e),
                    },
                }
            }
        }
        Ok(steps)
    }

    /// One scheduling tick under the given external pressure.
    /// Returns the number of model steps executed.
    pub fn tick(&mut self, external_pressure: f64) -> Result<usize> {
        self.ticks += 1;

        // 1. pressure bands from *actual* occupancy at tick start
        // (reservations are admission holdback, not resident bytes),
        // then the tick's weight precision with the memory term
        // coupled in — decided up front so admission can match
        // prefix-cache entries against it
        let capacity = self.arena.capacity_bytes();
        let occupancy = if capacity == 0 {
            0.0
        } else {
            self.arena.resident_bytes() as f64 / capacity as f64
        };
        let band = self.pressure.update(occupancy);
        self.metrics.record_pressure(band.index());
        let precision = self.controller.update_with_memory(
            external_pressure, self.batcher.pressure(), occupancy);

        // 1b. ladder rungs acting on resident state, before admission:
        // reclaim cache pages, requantize resident tails, and under
        // Critical preempt the youngest sequence
        if band >= PressureLevel::High && !self.prefix.is_empty() {
            self.evict_lru_prefix();
        }
        if let Some(target) = self.pressure.requant_target() {
            self.requant_active(target);
        }
        // the swap rung sits between requant (lossy, in place) and
        // preemption (recompute): cold pages of the LRU-most
        // sequences move byte-exactly to the host tier until
        // occupancy re-enters the High band's entry threshold
        if self.pressure.should_swap() {
            self.swap_out_lru_until(self.pressure.swap_target());
        }
        if self.pressure.should_preempt() && self.active.len() > 1 {
            if let Some(i) = self.youngest_active(None) {
                self.preempt(i);
            }
        }

        // 1c. restore stalled sequences' host pages when occupancy
        // (projected past the restore) has hysteresis room below the
        // swap rung's target — see `swap_in_stalled` for the
        // anti-thrash gate and the all-stalled deadlock guard
        self.swap_in_stalled();

        // 2. admission against real free bytes: each queued request
        // needs its worst-case bytes (at its KV storage precision)
        // minus any full pages a cached shared prefix provides; bytes
        // other active sequences have reserved but not yet allocated
        // are held back
        let max_seq = self.model.cfg.max_seq_len;
        let n_layers = self.model.cfg.n_layers;
        let max_prompt = move |req: &Request| {
            max_seq.saturating_sub(req.max_new_tokens + 1).max(1)
                .min(req.prompt.len())
        };
        // requests that could never run — empty prompt (no token to
        // seed generation) or a worst case exceeding the whole arena —
        // are rejected up front instead of deadlocking the FIFO behind
        // them (the dropped reply sender surfaces as a disconnect).
        // Impossibility is judged at the *requested* precision: the
        // pressure floor is transient and must not decide a permanent
        // rejection.
        while let Some(front) = self.batcher.peek() {
            let impossible = front.prompt.is_empty() || {
                let plen = max_prompt(front);
                worst_bytes(&self.arena, plen, front.max_new_tokens,
                            front.kv_precision) > capacity
            };
            if !impossible {
                break;
            }
            let _ = self.batcher.drop_head();
            self.metrics.rejected += 1;
        }

        // 2a. resume preempted sequences first — strictly ahead of the
        // FIFO: they were already admitted once, and letting newcomers
        // starve them would turn preemption into a drop
        while self.active.len() < self.batcher.max_active {
            let (eff, worst) = {
                let Some(p) = self.batcher.peek_resume() else { break };
                let eff = self.pressure.admission_precision(p.kv_prec);
                let left =
                    p.req.max_new_tokens.saturating_sub(p.generated);
                let total = (p.tokens.len() + left).min(max_seq);
                (eff, self.arena.seq_worst_bytes(total, eff))
            };
            let held: usize = self.active.iter()
                .map(|s| s.reserved_remaining(&self.arena))
                .sum();
            let avail = self.arena.free_bytes().saturating_sub(held);
            // starvation guard: with an empty active set the resume
            // always goes — the ladder absorbs any mid-flight
            // shortfall, whereas waiting for a budget that never
            // frees would wedge the queue
            if !self.active.is_empty() && worst > avail {
                break;
            }
            // the peek above saw a head; a logic slip in between must
            // end the resume pass, not panic the dispatcher thread
            let Some(p) = self.batcher.pop_resume() else { break };
            if eff.rank() > p.kv_prec.rank() {
                self.metrics.admissions_degraded += 1;
            }
            let left =
                p.req.max_new_tokens.saturating_sub(p.generated);
            let total = (p.tokens.len() + left).min(max_seq);
            // host-tier fast path: restore the parked cold prefix by
            // memcpy and re-feed only the unparked suffix; any
            // restore failure (device bytes, failpoint denial) falls
            // back to the full re-prefill — either way the request is
            // never dropped, and greedy decoding makes both paths
            // produce the same tokens (swapped pages round-trip
            // byte-exactly)
            let (seq, fed, kv_prec, reserved) = match p.host_kv {
                Some((h, kv_len)) => {
                    match self.arena.swap_in_seq(h) {
                        Ok(sum) => {
                            self.note_swap_in(sum);
                            // appends continue at the precision the
                            // parked sequence was left at (requant
                            // may have degraded it below the ask);
                            // re-make the reservation at that rate
                            let prec = self.arena.seq_precision(h);
                            let r = self.arena
                                .seq_worst_bytes(total, prec);
                            (h, kv_len, prec, r)
                        }
                        Err(_) => {
                            // partially-restored pages are released
                            // with the rest of the handle
                            self.arena.free_seq(h);
                            self.metrics.swap_fallback_reprefills += 1;
                            (self.arena.alloc_seq_at(eff), 0, eff,
                             worst)
                        }
                    }
                }
                None => {
                    // parked without host KV while a host tier was
                    // configured: the tier was exhausted (or denied)
                    // at preempt time — this resume pays the full
                    // re-prefill the swap tier exists to avoid
                    if self.arena.host_capacity_bytes() > 0 {
                        self.metrics.swap_fallback_reprefills += 1;
                    }
                    (self.arena.alloc_seq_at(eff), 0, eff, worst)
                }
            };
            let bytes_at_admission = self.arena.seq_bytes(seq);
            self.metrics.resumes += 1;
            self.admit_counter += 1;
            self.active.push(ActiveSeq {
                seq,
                prompt_len: p.prompt_len,
                // feed the parked state not yet in KV: the whole
                // prompt + generated-so-far on a re-prefill, only the
                // suffix past the restored prefix on the host path
                // (greedy decoding makes either reproduce the parked
                // logits exactly)
                prefill_len: p.tokens.len(),
                fed,
                kv_prec,
                reserved_bytes: reserved,
                bytes_at_admission,
                prefill_prec: None,
                prefill_uniform: false,
                registered: true,
                admit_ord: self.admit_counter,
                swapped_in_tick: if fed > 0 { self.ticks } else { 0 },
                tokens: p.tokens,
                generated: p.generated,
                spec: self.batcher.spec.as_ref()
                    .map(|c| SpecState::new(c, n_layers)),
                stats: p.stats,
                prefill_ms: p.prefill_ms,
                decode_ms: p.decode_ms,
                admitted_at: p.admitted_at,
                req: p.req,
            });
        }

        let held: usize = self.active.iter()
            .map(|s| s.reserved_remaining(&self.arena))
            .sum();
        let avail = self.arena.free_bytes().saturating_sub(held);
        let deferred_before = self.batcher.deferred();
        // prefix matches are recorded here by the accounting closure
        // (one scan per request) and reused for the fork below — the
        // cache must not change in between, which is why eviction
        // waits until after the admitted loop
        let mut hits: Vec<(Option<(usize, usize)>, KvPrecision)> =
            Vec::new();
        let admitted = if self.batcher.parked() > 0 {
            // a deferred resume blocks newcomers (strict priority)
            Vec::new()
        } else {
            let arena = &self.arena;
            let prefix = &self.prefix;
            let pressure = &self.pressure;
            let n_active = self.active.len();
            self.batcher.admit_with(n_active, avail, |req| {
                let plen = max_prompt(req);
                // pressure floors the admission's KV storage precision
                let eff =
                    pressure.admission_precision(req.kv_precision);
                let worst = worst_bytes(arena, plen,
                                        req.max_new_tokens, eff);
                let hit = best_prefix(prefix, &req.prompt[..plen],
                                      precision, eff);
                hits.push((hit, eff));
                // only full shared pages are free; a shared partial
                // page may still cost its COW copy, which `worst`
                // already counts
                let shared = hit.map_or(0, |(_, n)| n);
                let discount = n_layers * (shared / KV_PAGE)
                    * arena.page_bytes_at(eff);
                worst.saturating_sub(discount)
            })
        };
        // the closure also ran once for a deferred head, if any
        hits.truncate(admitted.len());
        let page_blocked =
            self.batcher.deferred() > deferred_before;
        self.metrics.admissions_deferred +=
            self.batcher.deferred() - deferred_before;

        for (req, (hit, kv_prec)) in admitted.into_iter().zip(hits) {
            let plen = max_prompt(&req);
            if kv_prec.rank() > req.kv_precision.rank() {
                self.metrics.admissions_degraded += 1;
            }
            let mut tokens = req.prompt.clone();
            tokens.truncate(plen);
            let worst = worst_bytes(&self.arena, plen,
                                    req.max_new_tokens, kv_prec);
            // attach the shared prefix (fork = refcount bump, no copy;
            // best_prefix only matched entries at this KV storage
            // precision, so the fork lands in the right pool)
            let (seq, shared, reserved) = match hit {
                Some((i, n)) => {
                    self.prefix[i].last_used = self.ticks;
                    debug_assert_eq!(self.prefix[i].kv_prec, kv_prec,
                                     "prefix hit across KV precisions");
                    let h = self.arena
                        .fork_prefix(self.prefix[i].handle, n);
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefix_tokens_reused += n as u64;
                    let discount = self.model.cfg.n_layers
                        * (n / KV_PAGE)
                        * self.arena.page_bytes_at(kv_prec);
                    (h, n, worst.saturating_sub(discount))
                }
                None => {
                    self.metrics.prefix_misses += 1;
                    (self.arena.alloc_seq_at(kv_prec), 0, worst)
                }
            };
            let bytes_at_admission = self.arena.seq_bytes(seq);
            self.admit_counter += 1;
            self.active.push(ActiveSeq {
                seq,
                prompt_len: tokens.len(),
                prefill_len: tokens.len(),
                fed: shared,
                kv_prec,
                reserved_bytes: reserved,
                bytes_at_admission,
                prefill_prec: (shared > 0).then_some(precision),
                prefill_uniform: true,
                registered: false,
                admit_ord: self.admit_counter,
                swapped_in_tick: 0,
                tokens,
                generated: 0,
                spec: self.batcher.spec.as_ref()
                    .map(|c| SpecState::new(c, n_layers)),
                stats: DecodeStats::new(self.model.cfg.n_layers),
                prefill_ms: 0.0,
                decode_ms: 0.0,
                admitted_at: Instant::now(),
                req,
            });
        }
        // under page pressure, reclaim cache pages one entry per tick
        // — after the admitted forks, so a just-matched entry cannot
        // disappear between its page accounting and its fork (evicting
        // a forked entry is harmless: the fork holds its own refs)
        if page_blocked && !self.prefix.is_empty() {
            self.evict_lru_prefix();
        }

        // 3. advance sequences: prefill chunks first (one batched call
        // per chunk), then one coalesced decode step across every
        // sequence that was already past prefill at tick start.
        // Membership is snapshotted by request id — OOM recovery may
        // preempt (remove) sequences mid-phase, so indices are
        // re-resolved per attempt and missing members are skipped.
        let model = self.model;
        let mut steps = 0usize;
        // stalled sequences (host-resident pages) sit the tick out —
        // their KV is not readable until the swap-in pass restores it
        let arena = &self.arena;
        let prefill_ids: Vec<RequestId> = self.active.iter()
            .filter(|s| s.fed < s.prefill_len
                && arena.seq_swapped_pages(s.seq) == 0)
            .map(|s| s.req.id)
            .collect();
        let decode_ids: Vec<RequestId> = self.active.iter()
            .filter(|s| s.fed >= s.prefill_len
                && arena.seq_swapped_pages(s.seq) == 0)
            .map(|s| s.req.id)
            .collect();
        let prefill_chunk = self.batcher.prefill_chunk;

        // 3a. chunked prefill — a whole prompt chunk per tick through
        // the weight-stationary kernel instead of per-token decodes.
        // On OutOfPages: roll the sequence back to its pre-chunk
        // length (layers diverge transiently mid-chunk), walk the
        // ladder, retry.
        for id in prefill_ids {
            let mut attempt = 0u32;
            loop {
                let Some(idx) = self.index_of(id) else { break };
                if self.seq_stalled(idx) {
                    // OOM recovery swapped this sequence out while
                    // retrying: its prefill resumes after the next
                    // swap-in pass (fed was not advanced)
                    break;
                }
                let len0 = self.arena.seq_len(self.active[idx].seq);
                let t0 = Instant::now();
                let fed_before = self.active[idx].fed;
                let end = (fed_before + prefill_chunk)
                    .min(self.active[idx].prefill_len);
                let res = {
                    let s = &mut self.active[idx];
                    match &mut self.shard_rt {
                        Some(rt) => rt.prefill(
                            model, &s.tokens[s.fed..end],
                            &mut self.arena, s.seq, precision,
                            &mut s.stats, &mut self.scratch.logits),
                        None => model.prefill(
                            &s.tokens[s.fed..end],
                            self.arena.only_mut(), s.seq, precision,
                            &mut self.scratch, &mut s.stats),
                    }
                };
                match res {
                    Ok(()) => {
                        let s = &mut self.active[idx];
                        match s.prefill_prec {
                            None => s.prefill_prec = Some(precision),
                            Some(p) if p != precision => {
                                s.prefill_uniform = false;
                            }
                            _ => {}
                        }
                        s.fed = end;
                        s.prefill_ms +=
                            t0.elapsed().as_secs_f64() * 1000.0;
                        steps += end - fed_before;
                        if s.fed == s.prefill_len {
                            // emit the next token right after prefill
                            // (on a resume this is the token the
                            // preempted decode would have produced)
                            let next =
                                argmax(&self.scratch.logits) as u32;
                            s.tokens.push(next);
                            s.generated += 1;
                        }
                        break;
                    }
                    Err(e) => match e.downcast::<OutOfPages>() {
                        Ok(oom) => {
                            let h = self.active[idx].seq;
                            self.arena.truncate_seq(h, len0);
                            attempt += 1;
                            if !self.recover_oom(&oom, Some(id),
                                                 attempt) {
                                break;
                            }
                        }
                        Err(e) => return Err(e),
                    },
                }
            }
        }

        // 3b. register freshly completed, uniform-precision prompts in
        // the prefix cache (page-aligned prefix; the fork only bumps
        // refcounts).  Registration is what turns the *next* identical
        // prompt into a page-table copy instead of a recompute.
        for i in 0..self.active.len() {
            let (attempt, worth, aligned, prec, kv_prec) = {
                let s = &self.active[i];
                let aligned = (s.prompt_len / KV_PAGE) * KV_PAGE;
                (s.fed == s.prefill_len && !s.registered,
                 s.prefill_uniform && aligned >= KV_PAGE,
                 aligned,
                 s.prefill_prec,
                 s.kv_prec)
            };
            if !attempt {
                continue;
            }
            if self.seq_stalled(i) {
                // a later sequence's OOM recovery swapped this one
                // out after its prefill completed: registering now
                // would fork host-resident pages.  Leave `registered`
                // unset so the attempt retries once restored.
                continue;
            }
            // one registration attempt per sequence, made the tick its
            // prefill completes
            self.active[i].registered = true;
            if !worth {
                continue;
            }
            let Some(prec) = prec else { continue };
            let cand = &self.active[i].tokens[..aligned];
            // the same token prefix at a different KV storage
            // precision is a different entry: its pages hold different
            // bytes in a different pool
            let covered = self.prefix.iter().any(|e| {
                e.precision == prec && e.kv_prec == kv_prec
                    && e.tokens.len() >= aligned
                    && e.tokens[..aligned] == *cand
            });
            if covered {
                continue;
            }
            if self.prefix.len() >= PREFIX_CACHE_MAX {
                self.evict_lru_prefix();
            }
            let cand = self.active[i].tokens[..aligned].to_vec();
            let handle = self.arena
                .fork_prefix(self.active[i].seq, aligned);
            self.prefix.push(PrefixEntry {
                tokens: cand,
                precision: prec,
                kv_prec,
                handle,
                last_used: self.ticks,
            });
        }

        // 3c. coalesced decode: fuse ready sequences (up to
        // max_decode_batch per group) into one batched call per layer.
        // With speculation enabled each group drafts in lockstep at a
        // low-bit slice mask and verifies per member in one batched
        // full-precision pass (greedy outputs stay bit-identical, a
        // fully accepted round commits k+1 tokens per verify step);
        // otherwise every member advances exactly one token.
        let cap = self.batcher.max_decode_batch;
        let spec_cfg = self.batcher.spec.clone();
        for group in decode_ids.chunks(cap) {
            steps += match &spec_cfg {
                Some(cfg) => {
                    self.decode_group_spec(group, precision, cfg)?
                }
                None => self.decode_group_plain(group, precision)?,
            };
        }

        // 4. retire: pages go back to the free list (minus any still
        // shared with the prefix cache or forked siblings)
        let mut finished: Vec<usize> = Vec::new();
        for (i, seq) in self.active.iter().enumerate() {
            if self.seq_finished(seq) {
                finished.push(i);
            }
        }
        for &i in finished.iter().rev() {
            self.retire_at(i);
        }

        let avg_bits = if self.active.is_empty() {
            self.controller.target_bits()
        } else {
            self.active.iter().map(|s| s.stats.avg_bits()).sum::<f64>()
                / self.active.len() as f64
        };
        self.metrics.record_tick(avg_bits, self.controller.target_bits());
        self.metrics.record_kv(&self.arena);
        Ok(steps)
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(
        &mut self,
        pressure_at: impl Fn(f64) -> f64,
    ) -> Result<()> {
        while !self.idle() {
            let t_ms = self.started.elapsed().as_secs_f64() * 1000.0;
            self.tick(pressure_at(t_ms))?;
        }
        Ok(())
    }

    pub fn current_precision(&self) -> Precision {
        self.controller.precision()
    }

    pub fn wall_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}
