//! §Perf §Speculative — self-speculative decoding study
//! (EXPERIMENTS.md §Speculative).  All on the synthetic model, no
//! `make artifacts` needed.
//!
//! 1. **Acceptance controls (exact):** drafting at the verify
//!    precision makes the draft chain the greedy oracle chain, so
//!    every round must fully accept — accept rate exactly 1.0 and
//!    tokens per verify step exactly k+1 (the "> 1" acceptance bar),
//!    asserted at run time so regenerated rows can never silently
//!    regress.  Output parity with `generate_at` is asserted too.
//! 2. **Feedback trajectory (exact):** the adaptation rule
//!    ([`SpecState::observe`]) is pure arithmetic; scripted outcomes
//!    pin the k / draft-bits / EMA walk.
//! 3. **Wall clock** on the synthetic model (the 2-layer toy is too
//!    small for the draft to win on wall time — the projection rows
//!    model real shapes) plus the analytic expectation
//!    `E[tokens/verify] = (1 - a^(k+1)) / (1 - a)` for per-token
//!    draft accept probability `a` and draft/verify cost ratio `r`.
//!
//! Writes `target/bench_reports/BENCH_spec.json`.

use std::time::Instant;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::{DecodeStats, KvPrecision, SpecConfig, SpecState};
use mobiquant::util::bench::{black_box, Suite};

fn main() {
    let mut suite = Suite::new("BENCH_spec");
    suite.header();
    let model = synth_model_shaped(71, 4, 2, 256);
    let n_layers = model.cfg.n_layers;
    let prec = Precision::elastic(4.0);
    let prompt: Vec<u32> =
        (0..32).map(|i| ((i * 5 + 3) % 256) as u32).collect();
    // n_new - 1 = 60 divides by k+1 for k in {1, 2, 4}: every verify
    // round runs the full window, so tokens_per_verify is exactly k+1
    let n_new = 61usize;

    // ---------------- exact acceptance controls -----------------------
    for &kvp in &[KvPrecision::F32, KvPrecision::Int8] {
        for &k in &[1usize, 2, 4] {
            let cfg = SpecConfig {
                k_min: k,
                k_max: k,
                draft_bits_min: 4.0,
                draft_bits_max: 4.0,
                max_delta: 0.0,
                ..SpecConfig::default()
            };
            let mut st = SpecState::new(&cfg, n_layers);
            let mut stats = DecodeStats::new(n_layers);
            let t0 = Instant::now();
            let spec = model
                .generate_speculative(&prompt, n_new, prec, kvp, &cfg,
                                      &mut stats, &mut st)
                .unwrap();
            let spec_ms = t0.elapsed().as_secs_f64() * 1000.0;
            let mut ostats = DecodeStats::new(n_layers);
            let t0 = Instant::now();
            let oracle = model
                .generate_at(&prompt, n_new, prec, kvp, &mut ostats)
                .unwrap();
            let plain_ms = t0.elapsed().as_secs_f64() * 1000.0;
            assert_eq!(spec, oracle, "speculative parity broke");
            assert_eq!(st.accept_rate(), 1.0,
                       "self-draft at the verify precision must \
                        fully accept");
            assert_eq!(st.commit_tokens, (n_new - 1) as u64);
            assert_eq!(st.rounds as usize * (k + 1), n_new - 1,
                       "every round must run the full window");
            black_box(&spec);
            suite.row(&format!("spec control {} k {k} exact",
                               kvp.label()),
                      &[
                ("accept_rate", st.accept_rate()),
                ("tokens_per_verify", st.tokens_per_round()),
                ("rounds", st.rounds as f64),
            ]);
            suite.row(&format!("spec control {} k {k} wall",
                               kvp.label()),
                      &[
                ("spec_ms", spec_ms),
                ("plain_ms", plain_ms),
                ("wall_ratio", plain_ms / spec_ms.max(1e-9)),
            ]);
        }
    }

    // ---------------- exact feedback trajectory -----------------------
    // Scripted outcomes through the real adaptation rule: 8 rounds of
    // full acceptance walk the window to k_max at the cheapest draft
    // bits; 6 rounds of total rejection walk it back down and give the
    // draft its bits back.
    let cfg = SpecConfig::default();
    let mut st = SpecState::new(&cfg, n_layers);
    for _ in 0..8 {
        let k = st.k;
        st.observe(&cfg, k, k, k + 1);
    }
    assert_eq!(st.k, cfg.k_max);
    assert_eq!(st.draft_bits, cfg.draft_bits_min);
    suite.row("spec feedback 8 full-accept rounds", &[
        ("k", st.k as f64),
        ("draft_bits", st.draft_bits),
        ("ema", st.ema),
    ]);
    for _ in 0..6 {
        let k = st.k;
        st.observe(&cfg, k, 0, 1);
    }
    assert_eq!(st.k, cfg.k_min);
    assert_eq!(st.draft_bits, cfg.draft_bits_max);
    suite.row("spec feedback +6 full-reject rounds", &[
        ("k", st.k as f64),
        ("draft_bits", st.draft_bits),
        ("ema", st.ema),
    ]);

    // ---------------- analytic projection -----------------------------
    suite.note(
        "projection model: E[tokens/verify] = (1 - a^(k+1)) / (1 - a) \
         for per-token draft accept probability a; round cost = r*k + v \
         full-decode-step equivalents, r = draft/verify cost ratio \
         (~bits ratio: 0.5 = 2b draft under 4b verify, 0.25 = 2b under \
         8b), v = 1.3 (batched k+1-token verify amortizes weight \
         streaming but pays attention for every position). \
         projected_speedup = E / (r*k + v).");
    for &r in &[0.5f64, 0.25] {
        for &a in &[0.5f64, 0.7, 0.9] {
            for &k in &[2usize, 4] {
                let e = (1.0 - a.powi(k as i32 + 1)) / (1.0 - a);
                let cost = r * k as f64 + 1.3;
                suite.row(
                    &format!("spec projection r {r} a {a} k {k}"),
                    &[
                        ("e_tokens_per_verify", e),
                        ("round_cost_full_steps", cost),
                        ("projected_speedup", e / cost),
                    ],
                );
            }
        }
    }
    suite.finish();
}
