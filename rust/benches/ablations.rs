//! App. D ablations over the router-variant bundles produced by
//! `make ablations`:
//!   Tab. 3  — calibration-dataset ablation (wiki/web/news/mix),
//!             cross-evaluated on all three corpora + cloze accuracy.
//!   Fig. 8  — budget-schedule ablation (log/linear/cosine/exp).
//!   Fig. 9  — training target-bit ablation (2.5/3/3.5/4/5).
//!   Fig. 10 — 4-bit activation quantization elasticity (App. E.4).

use mobiquant::bench_support as bs;
use mobiquant::data::{cloze, corpus, ppl};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::{BackendKind, LINEAR_NAMES};
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn abl_bundle(tag: &str) -> Option<Bundle> {
    let path = mobiquant::artifacts_dir()
        .join("ablations")
        .join(format!("tiny-s_{tag}.mobiq"));
    if !path.exists() {
        return None;
    }
    Bundle::load(path).ok()
}

fn main() {
    let mut suite = Suite::new("ablations");
    suite.header();
    let windows = bs::eval_windows(5);
    let dir = mobiquant::artifacts_dir();

    // ------------------- Tab. 3: calibration dataset -------------------
    let mut any = false;
    for dom in ["wiki", "web", "news", "mix"] {
        let Some(bundle) = abl_bundle(&format!("calib_{dom}")) else {
            continue;
        };
        any = true;
        let model = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        let mut cells = Vec::new();
        for eval_dom in ["wiki", "web", "news"] {
            let toks = corpus::load_tokens(&dir, eval_dom,
                                           corpus::Split::Valid).unwrap();
            let r = ppl::evaluate(&model, &toks, Precision::elastic(3.0),
                                  128, windows).unwrap();
            cells.push((eval_dom.to_string(), r.ppl));
        }
        // downstream: cloze accuracy on wiki sentences
        let text = corpus::load(&dir, "wiki", corpus::Split::Valid)
            .unwrap();
        let items = cloze::build_cloze(&text, 24, 3, 11);
        let acc = cloze::eval_cloze(&model, &items,
                                    Precision::elastic(4.0)).unwrap();
        cells.push(("cloze_acc".to_string(), acc));
        let named: Vec<(&str, f64)> = cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("Tab3 calib={dom}"), &named);
    }
    if !any {
        suite.note("ablation bundles missing; run `make ablations`");
    }

    // ------------------- Fig. 8: schedules ----------------------------
    for sched in ["log", "linear", "cosine", "exp"] {
        let Some(bundle) = abl_bundle(&format!("sched_{sched}")) else {
            continue;
        };
        let model = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
            .unwrap();
        let mut cells = Vec::new();
        for target in [2.5, 3.0, 4.0, 6.0] {
            let r = ppl::evaluate(&model, &toks,
                                  Precision::elastic(target), 128,
                                  windows).unwrap();
            cells.push((format!("{target}"), r.ppl));
        }
        let named: Vec<(&str, f64)> = cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("Fig8 sched={sched}"), &named);
    }

    // ------------------- Fig. 9: training target bits ------------------
    for tb in ["2.5", "3.0", "3.5", "4.0", "5.0"] {
        let Some(bundle) = abl_bundle(&format!("target_{tb}")) else {
            continue;
        };
        let model = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
            .unwrap();
        let mut cells = Vec::new();
        for target in [2.5, 3.0, 4.0, 6.0] {
            let r = ppl::evaluate(&model, &toks,
                                  Precision::elastic(target), 128,
                                  windows).unwrap();
            cells.push((format!("{target}"), r.ppl));
        }
        let named: Vec<(&str, f64)> = cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("Fig9 train_target={tb}"), &named);
    }

    // ------------------- Fig. 10: activation quantization --------------
    if let Some(bundle) = bs::try_bundle("tiny-s") {
        let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
            .unwrap();
        let mut model = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        for li in 0..model.cfg.n_layers {
            for name in LINEAR_NAMES {
                if let mobiquant::model::LinearBackend::Mobiq(m) =
                    bs::linear_mut(&mut model, li, name)
                {
                    m.act_bits = Some(4);
                }
            }
        }
        let mut cells = Vec::new();
        for target in [3.0, 4.0, 6.0, 8.0] {
            let r = ppl::evaluate(&model, &toks,
                                  Precision::elastic(target), 128,
                                  windows).unwrap();
            cells.push((format!("W{target}A4"), r.ppl));
        }
        let named: Vec<(&str, f64)> = cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row("Fig10 weight-elastic + A4", &named);
    }
    suite.note("paper shape: log/exp schedules best at low bits; 3.0 \
                training target generalizes widest; W-elasticity \
                survives A4 quantization");
    suite.finish();
}
