//! Elastic serving coordinator — the L3 system contribution.
//!
//! The paper motivates token-adaptive any-precision inference with edge
//! deployments whose resources fluctuate at runtime (§1).  This module is
//! the serving stack that turns MoBiQuant's threshold elasticity (Eq. 10)
//! into a running system:
//!
//! * [`request`]    — request/response types and the submission API.
//! * [`batcher`]    — admission queue + continuous batching.
//! * [`controller`] — elastic precision controller: resource pressure +
//!   queue depth -> (target bits, global delta), with hysteresis.
//! * [`pressure`]   — memory-pressure degradation ladder: arena
//!   occupancy -> admission precision floors, in-place KV tail
//!   requantization, youngest-sequence preemption.
//! * [`scheduler`]  — the decode loop: interleaves active sequences,
//!   applies the controller's precision each tick, retires finished
//!   sequences, admits new ones.
//! * [`server`]     — owns the model + scheduler thread; public facade.
//! * [`metrics`]    — latency/throughput/bits accounting.

pub mod batcher;
pub mod controller;
pub mod metrics;
pub mod pressure;
pub mod request;
pub mod scheduler;
pub mod server;

pub use controller::ElasticController;
pub use pressure::{PressureConfig, PressureController, PressureLevel};
pub use request::{Request, RequestId, Response};
pub use server::{Server, ServerConfig};
