"""Pretraining of the tiny-llama substitute models (build-time only).

The paper quantizes pretrained LLaMA checkpoints; none are available here,
so we pretrain the substitute family from scratch on the mixed synthetic
corpus (DESIGN.md §2).  Hand-rolled Adam with warmup + cosine decay.
Checkpoints are .npz files consumed by the calibration stack and exporter.
"""

from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .config import ModelConfig
from .model import init_params, loss_fn
from .quant.calibrate import adam_init, adam_update


def load_mixed_train(corpus_dir: str) -> np.ndarray:
    """Concatenate the three domain train sets into one token stream."""
    streams = []
    for domain in ("wiki", "web", "news"):
        path = os.path.join(corpus_dir, f"{domain}.train.txt")
        with open(path) as f:
            streams.append(corpus.tokenize(f.read()))
    return np.concatenate(streams)


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int,
            seed: int = 0):
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, hi, size=batch)
        yield np.stack([tokens[s:s + seq + 1] for s in starts]).astype(
            np.int32)


def lr_at(step: int, total: int, peak: float = 3e-3,
          warmup: int = 40) -> float:
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(total - warmup, 1)
    return peak * 0.5 * (1.0 + np.cos(np.pi * frac))


def pretrain(cfg: ModelConfig, corpus_dir: str, steps: int,
             batch: int = 8, seq: int = 128, seed: int = 0,
             log_every: int = 50, verbose: bool = True
             ) -> Tuple[Dict, Dict[str, float]]:
    """Train from scratch; returns (params, summary)."""
    tokens = load_mixed_train(corpus_dir)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(jax.value_and_grad(lambda p, t: loss_fn(p, t, cfg)))
    opt = adam_init(params)
    t0 = time.time()
    first_loss, last_loss = None, None
    curve = []
    for i, tb in enumerate(batches(tokens, batch, seq, steps, seed)):
        loss, grads = step_fn(params, jnp.asarray(tb))
        params, opt = adam_update(params, grads, opt, lr_at(i, steps))
        last_loss = float(loss)
        if first_loss is None:
            first_loss = last_loss
        if i % log_every == 0:
            curve.append((i, last_loss))
            if verbose:
                print(f"  [pretrain:{cfg.name}] step {i}/{steps} "
                      f"loss={last_loss:.4f} ({time.time() - t0:.0f}s)",
                      flush=True)
    curve.append((steps - 1, last_loss))
    summary = {"first_loss": first_loss, "final_loss": last_loss,
               "steps": steps, "seconds": time.time() - t0,
               "curve": curve}
    return params, summary


def save_params(params: Dict, path: str) -> None:
    flat = {}
    flat["embed"] = np.asarray(params["embed"], np.float32)
    flat["final_norm"] = np.asarray(params["final_norm"], np.float32)
    flat["lm_head"] = np.asarray(params["lm_head"], np.float32)
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"layers.{i}.{k}"] = np.asarray(v, np.float32)
    np.savez_compressed(path, **flat)


def load_params(path: str) -> Dict:
    data = np.load(path)
    n_layers = 1 + max(int(k.split(".")[1]) for k in data.files
                       if k.startswith("layers."))
    layers = []
    for i in range(n_layers):
        prefix = f"layers.{i}."
        layers.append({k[len(prefix):]: jnp.asarray(data[k])
                       for k in data.files if k.startswith(prefix)})
    return {"embed": jnp.asarray(data["embed"]),
            "layers": layers,
            "final_norm": jnp.asarray(data["final_norm"]),
            "lm_head": jnp.asarray(data["lm_head"])}
