"""MoBiSlice properties (paper §4.1, App. B Eq. 13-21)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quant import mobislice as M
from compile.quant import quantizer as Q


def setup(seed, d_in=64, d_out=8, gs=32, n_slices=4):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d_in, d_out)) * 0.2, jnp.float32)
    base = Q.calc_params(w, 2, gs)
    return w, base, M.decompose(w, base, n_slices, 2)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_error_shrinks_4x_per_slice(seed):
    w, base, sw = setup(seed)
    prev = np.inf
    for k in range(1, 5):
        err = float(jnp.max(jnp.abs(w - M.reconstruct(sw, k))))
        assert err < prev * 0.51
        prev = err


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_residual_zero_mean(seed):
    """App. B Eq. 19: slice truncation error is ~zero-mean."""
    w, base, sw = setup(seed, d_in=128, d_out=16)
    r = np.asarray(w - M.reconstruct(sw, 2))
    scale = float(np.mean(np.asarray(base.scale))) / 16  # s_3 level
    assert abs(r.mean()) < scale


def test_reconstruct_masked_subsets():
    w, base, sw = setup(0)
    full = M.reconstruct(sw, 4)
    masked = M.reconstruct_masked(sw, [True, True, True, True])
    np.testing.assert_allclose(np.asarray(full), np.asarray(masked))
    # dropping slice 3 only removes its contribution
    m2 = M.reconstruct_masked(sw, [True, True, False, True])
    diff = np.asarray(full) - np.asarray(m2)
    contrib = np.asarray(M.slice_deq(sw, 3))
    np.testing.assert_allclose(diff, contrib, atol=1e-6)


def test_residual_params_derivation():
    _, base, _ = setup(1)
    p2 = M.residual_params(base, 2, 2)
    np.testing.assert_allclose(np.asarray(p2.scale),
                               np.asarray(base.scale) / 4, rtol=1e-6)
    assert float(p2.zero[0, 0]) == 2.0
    p3 = M.residual_params(base, 3, 2)
    np.testing.assert_allclose(np.asarray(p3.scale),
                               np.asarray(base.scale) / 16, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 3]))
def test_bitplane_pack_roundtrip(seed, bits):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** bits,
                         size=(64 * rng.integers(1, 4), 7)).astype(np.int32)
    planes = M.pack_bitplanes(codes, bits)
    back = M.unpack_bitplanes(planes, codes.shape[0])
    np.testing.assert_array_equal(back, codes)


def test_truncation_equals_coarser_quant():
    """App. B Eq. 16-18: dropping a residual slice == quantizing with the
    2^b-coarser derived parameters (codes nest)."""
    w, base, sw = setup(2)
    # k=1 reconstruction == direct base quantization
    direct = Q.dequantize(Q.quantize(w, base), base)
    np.testing.assert_allclose(np.asarray(M.reconstruct(sw, 1)),
                               np.asarray(direct), atol=1e-7)
