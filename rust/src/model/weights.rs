//! Model configuration + weight loading from a `.mobiq` bundle.

use anyhow::{anyhow, bail, Result};

use crate::mobiq::artifact::Bundle;
use crate::mobiq::engine::{MobiqLinear, Precision, Scratch};
use crate::mobiq::gemv::{matvec, matvec_range, SharedOut};
use crate::mobiq::static_quant::StaticLinear;

pub const LINEAR_NAMES: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq_len: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
    // quant config
    pub n_slices: usize,
    pub slice_bits: usize,
    pub group_size: usize,
    pub router_hidden: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Width of one position's K (or V) row across all kv heads.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    pub fn from_bundle(b: &Bundle) -> Result<ModelConfig> {
        let m = |k: &str| b.cfg_usize("model", k);
        let q = |k: &str| b.cfg_usize("quant", k);
        Ok(ModelConfig {
            name: b.manifest.path(&["model", "name"])
                .and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            vocab_size: m("vocab_size")?,
            d_model: m("d_model")?,
            n_layers: m("n_layers")?,
            n_heads: m("n_heads")?,
            n_kv_heads: m("n_kv_heads")?,
            d_ff: m("d_ff")?,
            max_seq_len: m("max_seq_len")?,
            rope_theta: b.cfg_f64("model", "rope_theta")? as f32,
            norm_eps: b.cfg_f64("model", "norm_eps")? as f32,
            n_slices: q("n_slices")?,
            slice_bits: q("slice_bits")?,
            group_size: q("group_size")?,
            router_hidden: q("router_hidden")?,
        })
    }

    /// (d_in, d_out) of a named linear; a name outside
    /// [`LINEAR_NAMES`] is a malformed-bundle error, not a panic — the
    /// server degrades the request instead of aborting.
    pub fn linear_dims(&self, name: &str) -> Result<(usize, usize)> {
        let d = self.d_model;
        let dkv = self.kv_dim();
        Ok(match name {
            "wq" | "wo" => (d, d),
            "wk" | "wv" => (d, dkv),
            "w_gate" | "w_up" => (d, self.d_ff),
            "w_down" => (self.d_ff, d),
            _ => bail!("unknown linear {name}"),
        })
    }
}

/// A linear layer's runtime backend.
pub enum LinearBackend {
    /// Dense f32 (the FP16-comparator path; also used for lm_head).
    Dense { w: Vec<f32>, d_in: usize, d_out: usize },
    /// Token-adaptive MoBiSlice (the paper's method).
    Mobiq(MobiqLinear),
    /// Static-PTQ baseline record.
    Static(StaticLinear),
}

impl LinearBackend {
    /// Forward one token; returns effective weight bits used.
    pub fn forward_token(&self, x: &[f32], precision: Precision,
                         scratch: &mut Scratch, out: &mut [f32]) -> usize {
        match self {
            LinearBackend::Dense { w, d_in, d_out } => {
                matvec(w, x, out, *d_in, *d_out);
                16 // fp16-equivalent comparator
            }
            LinearBackend::Mobiq(m) => {
                m.forward_token(x, precision, scratch, out)
            }
            LinearBackend::Static(s) => {
                s.forward(x, &mut scratch.xq[..s.d_in], out);
                s.bits as usize
            }
        }
    }

    /// Forward a (T, d_in) row-major block; the quantized backend runs
    /// the batched weight-stationary kernel.  Per-token effective bits
    /// are left in `scratch.batch.bits` (all backends fill it so the
    /// caller can record stats uniformly); returns their sum.
    pub fn forward_batch(&self, xs: &[f32], precision: Precision,
                         scratch: &mut Scratch, out: &mut [f32]) -> usize {
        match self {
            LinearBackend::Dense { w, d_in, d_out } => {
                let (di, dn) = (*d_in, *d_out);
                let t = xs.len() / di;
                scratch.batch.bits.clear();
                for i in 0..t {
                    matvec(w, &xs[i * di..(i + 1) * di],
                           &mut out[i * dn..(i + 1) * dn], di, dn);
                    scratch.batch.bits.push(16);
                }
                16 * t
            }
            LinearBackend::Mobiq(m) => {
                m.forward_batch(xs, precision, scratch, out)
            }
            LinearBackend::Static(s) => {
                let t = xs.len() / s.d_in;
                scratch.batch.bits.clear();
                for i in 0..t {
                    s.forward(&xs[i * s.d_in..(i + 1) * s.d_in],
                              &mut scratch.xq[..s.d_in],
                              &mut out[i * s.d_out..(i + 1) * s.d_out]);
                    scratch.batch.bits.push(s.bits as usize);
                }
                s.bits as usize * t
            }
        }
    }

    /// Column-sharded token forward for the tensor-parallel path:
    /// output channels `o0..o1` into the compact `out`, bit-identical
    /// per channel to [`LinearBackend::forward_token`].  `Static` has
    /// no range kernel and is rejected at `ShardRuntime` construction
    /// (baseline backend, never served sharded) — reaching it here is a
    /// caller bug.
    pub fn forward_token_range(&self, x: &[f32], precision: Precision,
                               scratch: &mut Scratch, o0: usize,
                               o1: usize, out: &mut [f32]) -> usize {
        match self {
            LinearBackend::Dense { w, d_in, d_out } => {
                matvec_range(w, x, *d_in, *d_out, o0, o1, out);
                16
            }
            LinearBackend::Mobiq(m) => {
                m.forward_token_range(x, precision, scratch, o0, o1, out)
            }
            LinearBackend::Static(_) => unreachable!(
                "Static backends are rejected at ShardRuntime::new"),
        }
    }

    /// Column-sharded batched forward: channels `o0..o1` of every
    /// token, written at full d_out stride into the shared buffer.
    /// Fills `scratch.batch.bits` identically to
    /// [`LinearBackend::forward_batch`] (replicated routing); returns
    /// the summed bits.
    pub fn forward_batch_range(&self, xs: &[f32], precision: Precision,
                               scratch: &mut Scratch, o0: usize,
                               o1: usize, out: &SharedOut) -> usize {
        match self {
            LinearBackend::Dense { w, d_in, d_out } => {
                let (di, dn) = (*d_in, *d_out);
                let t = xs.len() / di;
                scratch.batch.bits.clear();
                for i in 0..t {
                    // SAFETY: lanes own disjoint (token, o0..o1) cells.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add(i * dn + o0), o1 - o0)
                    };
                    matvec_range(w, &xs[i * di..(i + 1) * di], di, dn,
                                 o0, o1, row);
                    scratch.batch.bits.push(16);
                }
                16 * t
            }
            LinearBackend::Mobiq(m) => {
                m.forward_batch_range(xs, precision, scratch, o0, o1, out)
            }
            LinearBackend::Static(_) => unreachable!(
                "Static backends are rejected at ShardRuntime::new"),
        }
    }

    /// Router-only step (for latency breakdown measurements).
    pub fn route_only(&self, x: &[f32], precision: Precision,
                      scratch: &mut Scratch) -> usize {
        match self {
            LinearBackend::Mobiq(m) => m.route(x, precision, scratch),
            LinearBackend::Dense { .. } => 16,
            LinearBackend::Static(s) => s.bits as usize,
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            LinearBackend::Dense { d_in, d_out, .. } => (*d_in, *d_out),
            LinearBackend::Mobiq(m) => (m.d_in, m.d_out),
            LinearBackend::Static(s) => (s.d_in, s.d_out),
        }
    }
}

pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: LinearBackend,
    pub wk: LinearBackend,
    pub wv: LinearBackend,
    pub wo: LinearBackend,
    pub w_gate: LinearBackend,
    pub w_up: LinearBackend,
    pub w_down: LinearBackend,
}

impl LayerWeights {
    /// Look up a linear by bundle name; an unknown name degrades into
    /// an error the serving loop can reject instead of aborting on.
    pub fn linear(&self, name: &str) -> Result<&LinearBackend> {
        Ok(match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "w_gate" => &self.w_gate,
            "w_up" => &self.w_up,
            "w_down" => &self.w_down,
            _ => bail!("unknown linear {name}"),
        })
    }
}

/// Which backend to build for the quantizable linears.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendKind {
    Fp32,
    Mobiq,
    /// Static method key present in the bundle, e.g. "gptq3".
    Static(String),
    /// Fixed-k dense reconstruction from MoBiSlice (offline-repack
    /// comparator): dense f32 of sum of first k slices.
    MobiqDenseK(usize),
}

pub fn load_fp_dense(b: &Bundle, name: &str) -> Result<LinearBackend> {
    let (shape, data) = b.f32(name)?;
    if shape.len() != 2 {
        return Err(anyhow!("{name}: expected 2-d"));
    }
    Ok(LinearBackend::Dense {
        w: data.to_vec(),
        d_in: shape[0],
        d_out: shape[1],
    })
}

pub fn load_linear(b: &Bundle, cfg: &ModelConfig, layer: usize, name: &str,
                   kind: &BackendKind) -> Result<LinearBackend> {
    match kind {
        BackendKind::Fp32 => {
            load_fp_dense(b, &format!("fp.layers.{layer}.{name}"))
        }
        BackendKind::Mobiq => Ok(LinearBackend::Mobiq(
            MobiqLinear::from_bundle(b, layer, name, cfg.n_slices,
                                     cfg.slice_bits, cfg.group_size)?)),
        BackendKind::Static(method) => Ok(LinearBackend::Static(
            StaticLinear::from_bundle(b, method, layer, name)?)),
        BackendKind::MobiqDenseK(k) => {
            let m = MobiqLinear::from_bundle(b, layer, name, cfg.n_slices,
                                             cfg.slice_bits,
                                             cfg.group_size)?;
            let codes: Vec<Vec<u8>> =
                m.slices.iter().map(|s| s.unpack()).collect();
            let w = crate::mobiq::quantizer::reconstruct(
                &codes, &m.base, (*k).min(cfg.n_slices));
            Ok(LinearBackend::Dense { w, d_in: m.d_in, d_out: m.d_out })
        }
    }
}
