//! Outlier-migration and router analyses backing Figs. 1, 5, 6 and
//! App. E.1/E.2.
//!
//! The central quantity is the per-token quantization error of one linear
//! layer:  err_i = || x_i (W - W_q) ||^2  over probe activations x_i
//! captured from the FP stream (Model::attn_inputs).  "Outlier migration"
//! (paper §3) is the instability of the top-error token set across target
//! bit-widths, measured by the overlap fraction of top-k sets.

use crate::mobiq::engine::MobiqLinear;
use crate::util::stats;

/// Per-token error of a quantized weight vs FP: ||x (W - Wq)||^2.
pub fn token_errors(w_fp: &[f32], w_q: &[f32], xs: &[Vec<f32>],
                    d_in: usize, d_out: usize) -> Vec<f64> {
    let diff: Vec<f32> = w_fp.iter().zip(w_q).map(|(a, b)| a - b).collect();
    let mut out = Vec::with_capacity(xs.len());
    let mut y = vec![0f32; d_out];
    for x in xs {
        crate::mobiq::gemv::matvec(&diff, x, &mut y, d_in, d_out);
        out.push(y.iter().map(|&v| (v as f64).powi(2)).sum());
    }
    out
}

/// Indices of the top-`frac` tokens by error.
pub fn top_outliers(errors: &[f64], frac: f64) -> Vec<usize> {
    let k = ((errors.len() as f64 * frac).ceil() as usize).max(1);
    let mut idx: Vec<usize> = (0..errors.len()).collect();
    idx.sort_by(|&a, &b| errors[b].partial_cmp(&errors[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Overlap of top-outlier sets between two precisions — the App. E.1/E.2
/// migration metric (41% on LLaMA, 16% on Mistral in the paper).
pub fn outlier_overlap(err_a: &[f64], err_b: &[f64], frac: f64) -> f64 {
    stats::overlap_fraction(&top_outliers(err_a, frac),
                            &top_outliers(err_b, frac))
}

/// Fig. 5 (left): correlation between router scores (max over residual
/// slices) and the per-token error *increment* when switching precision.
pub fn router_error_correlation(lin: &MobiqLinear, xs: &[Vec<f32>],
                                err_increment: &[f64]) -> f64 {
    let scores: Vec<f64> = xs.iter()
        .map(|x| {
            lin.router.scores(x).iter().cloned().fold(f32::MIN, f32::max)
                as f64
        })
        .collect();
    stats::spearman(&scores, err_increment)
}

/// Distribution summary used by the figure benches.
#[derive(Debug, Clone)]
pub struct ErrorDist {
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    /// Tail mass: fraction of total error carried by the top 10% tokens
    /// (high = strongly outlier-dominated).
    pub top10_mass: f64,
}

pub fn summarize(errors: &[f64]) -> ErrorDist {
    let total: f64 = errors.iter().sum();
    let top = top_outliers(errors, 0.1);
    let top_sum: f64 = top.iter().map(|&i| errors[i]).sum();
    ErrorDist {
        mean: stats::mean(errors),
        p50: stats::percentile(errors, 50.0),
        p90: stats::percentile(errors, 90.0),
        p99: stats::percentile(errors, 99.0),
        max: errors.iter().cloned().fold(f64::MIN, f64::max),
        top10_mass: if total > 0.0 { top_sum / total } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    #[test]
    fn zero_quant_error_when_equal() {
        let w = vec![1.0f32; 8 * 4];
        let xs = vec![vec![1.0f32; 8]; 3];
        let e = token_errors(&w, &w, &xs, 8, 4);
        assert!(e.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn top_outliers_orders() {
        let e = vec![0.1, 5.0, 0.2, 3.0];
        assert_eq!(top_outliers(&e, 0.5), vec![1, 3]);
        assert_eq!(top_outliers(&e, 0.01), vec![1]);
    }

    #[test]
    fn overlap_of_identical_errors_is_one() {
        let e: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(outlier_overlap(&e, &e, 0.1), 1.0);
    }

    #[test]
    fn overlap_of_disjoint_outliers_is_zero() {
        let mut a = vec![0f64; 100];
        let mut b = vec![0f64; 100];
        for i in 0..10 {
            a[i] = 100.0;
            b[99 - i] = 100.0;
        }
        assert_eq!(outlier_overlap(&a, &b, 0.1), 0.0);
    }

    #[test]
    fn summary_tail_mass() {
        let mut rng = Pcg::new(1);
        let mut e: Vec<f64> = (0..100).map(|_| rng.f64()).collect();
        e[0] = 1e6; // one dominant outlier
        let s = summarize(&e);
        assert!(s.top10_mass > 0.99);
        assert!(s.max >= 1e6);
    }
}
