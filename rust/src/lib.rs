//! # MoBiQuant — token-adaptive any-precision LLM serving
//!
//! Rust reproduction of *"MoBiQuant: Mixture-of-Bits Quantization for
//! Token-Adaptive Any-Precision LLM"* (2026).  Layer 3 of the three-layer
//! stack (see DESIGN.md): the request path is pure Rust; Python/JAX/Pallas
//! run once at build time (`make artifacts`) to pretrain, calibrate and
//! AOT-lower the model.
//!
//! Module map:
//! * [`util`] — substrates built from scratch for this environment
//!   (JSON, CLI, PRNG + property testing, stats, thread pool, bench
//!   harness, runtime-dispatched SIMD kernels).
//! * [`mobiq`] — the paper's core: bit-plane packed MoBiSlice weights,
//!   shared-scale shift-add GEMV kernels, MoBiRoute router inference,
//!   elastic threshold control, static-PTQ baseline records.
//! * [`model`] — native LLaMA-style transformer decode (KV cache, RoPE,
//!   RMSNorm, SwiGLU) dispatching every linear through [`mobiq`].
//! * [`data`] — corpora, byte tokenizer, perplexity / downstream evals,
//!   serving workload traces.
//! * [`baselines`] — kernel simulators for AnyPrecisionLLM, AnyBCQ,
//!   QuIP#/QTIP-style VQ and ABQ-LLM comparisons (Tab. 1, Fig. 7).
//! * [`runtime`] — PJRT client (xla crate) executing the AOT HLO
//!   modules; API-compatible stub unless built with `--features pjrt`.
//! * [`coordinator`] — elastic serving: request queue, dynamic batcher,
//!   precision controller, scheduler, metrics.
//! * [`analysis`] — outlier-migration / router-correlation analyses
//!   backing Figs. 1, 5, 6.

// Deliberate idiom of this codebase that clippy's style lints dislike:
// index-loop kernels (explicit o/g/w indices mirror the paper's math),
// many-argument kernel entry points, and scratch types whose `new` is
// not `Default` on purpose.  Correctness lints stay on — CI runs
// `cargo clippy -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

pub mod analysis;
pub mod baselines;
pub mod bench_support;
pub mod coordinator;
pub mod data;
pub mod mobiq;
pub mod model;
pub mod runtime;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts dir: `$MOBIQ_ARTIFACTS` or ./artifacts, walking up
/// from the current dir so tests/benches work from any workspace subdir.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MOBIQ_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() || cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
