//! Kernel simulators for the baseline systems compared in Tab. 1 and
//! Fig. 7.  None of the baselines' CUDA kernels can run here; what the
//! paper's comparison measures is each design's *characteristic overhead
//! structure*, which these CPU kernels reproduce faithfully
//! (DESIGN.md §2):
//!
//! * [`ap_sim`]   — AnyPrecisionLLM: bit-plane storage but **per-weight
//!   centroid table lookups** (non-uniform codes) — one gather + FMA per
//!   weight instead of per-group arithmetic.
//! * [`abcq_sim`] — AnyBCQ: binary-coded planes with **per-slice scale
//!   sets** — an extra scale load + multiply per plane, and E scale
//!   arrays in memory.
//! * [`vq_sim`]   — QuIP#/QTIP-style vector quantization: 4-wide codebook
//!   entries, one table gather per 4 weights, fixed precision only.
//! * [`abq_sim`]  — ABQ-LLM-style static low-bit kernel: dense dequant
//!   GEMV at a fixed precision, loads every plane regardless of need.

pub mod kernels;

pub use kernels::{AbcqLinear, AbqLinear, ApLinear, VqLinear};
