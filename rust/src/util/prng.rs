//! PCG64 PRNG + a tiny property-testing harness (proptest is not vendored).
//!
//! Deterministic, seedable, dependency-free.  The property harness runs a
//! closure over many generated cases and reports the failing seed so a
//! failure reproduces exactly.

/// PCG-XSH-RR 64/32 with 64-bit output composed of two draws.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        let mut p = Pcg { state: 0, inc: (seed << 1) | 1 };
        p.next_u32();
        p.state = p.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        p.next_u32();
        p
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Property-test harness: runs `body(case_rng, case_index)` for `cases`
/// seeds derived from `seed`; panics with the failing seed on error.
pub fn property(seed: u64, cases: usize, mut body: impl FnMut(&mut Pcg, usize)) {
    for i in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(i as u64);
        let mut rng = Pcg::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || body(&mut rng, i),
        ));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {} (case_seed = {:#x})",
                i, case_seed
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(3);
        for n in 1..50 {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
