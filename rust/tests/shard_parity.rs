//! Tensor-parallel shard parity (ISSUE 8 acceptance).
//!
//! The bar: sharded execution is a *partition*, never an
//! approximation.  Every output channel is still computed whole by
//! exactly one lane running the serial kernels, and the per-layer
//! joins are gather barriers — so for any shard count N the logits,
//! the greedy tokens, the routing stats, and the speculative
//! accept/reject trace must be **bit-identical** to the unsharded
//! model.  Swept across GQA configs (including kv-head counts that do
//! not divide evenly across shards), KV storage precisions, page-seam
//! context lengths, ragged coalesced-decode batches, and the
//! scheduler's memory-pressure ladder.
//!
//! All on synthetic models, so no `make artifacts` is needed.

use std::sync::mpsc;
use std::time::Instant;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::coordinator::batcher::Batcher;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::coordinator::request::{Request, Response};
use mobiquant::coordinator::scheduler::Scheduler;
use mobiquant::coordinator::PressureConfig;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::transformer::{argmax, DecodeSlot, DecodeStats};
use mobiquant::model::{KvPrecision, ShardRuntime, SpecConfig, SpecState,
                       KV_PAGE};

/// The GQA sweep: (n_heads, n_kv_heads).  (6, 3) and (8, 4) make the
/// kv-head remainder rule do real work at N = 2 and N = 3 (3 kv heads
/// over 2 shards -> 2 + 1; 4 kv heads over 3 shards -> 2 + 1 + 1).
const GQA: [(usize, usize); 3] = [(4, 2), (6, 3), (8, 4)];

fn prompt_for(seed: usize, len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 7 + 5 * seed + 3) % 256) as u32).collect()
}

/// Whole-prompt `forward_logits` across every GQA config and every
/// legal shard count in {1, 2, 3}: all-position logits must be exactly
/// equal to the unsharded model's.
#[test]
fn forward_logits_bit_identical_across_shard_counts() {
    for &(n_heads, n_kv) in &GQA {
        let model = synth_model_shaped(131, n_heads, n_kv, 160);
        let tokens = prompt_for(n_heads, 100);
        for prec in [Precision::Fixed(2), Precision::elastic(4.0)] {
            let want = model.forward_logits(&tokens, prec).unwrap();
            for n in [1usize, 2, 3] {
                if n > n_kv {
                    continue;
                }
                let mut rt = ShardRuntime::new(&model, n).unwrap();
                let got = rt.forward_logits(&model, &tokens, prec)
                    .unwrap();
                assert_eq!(got, want,
                           "{n_heads}h/{n_kv}kv N={n} {prec:?}: sharded \
                            forward diverged from unsharded");
            }
        }
    }
}

/// End-to-end greedy generation plus the replayed routing stats: the
/// token stream, the per-token bit histogram, and every per-linear
/// call/bit counter must match the unsharded run exactly — the stats
/// replay from lane 0's log may not lose or duplicate a record.
#[test]
fn generate_and_stats_bit_identical() {
    for &(n_heads, n_kv) in &GQA {
        let model = synth_model_shaped(137, n_heads, n_kv, 128);
        let prompt = prompt_for(n_kv, 24);
        let prec = Precision::elastic(4.0);
        let mut sw = DecodeStats::new(model.cfg.n_layers);
        let want = model.generate(&prompt, 16, prec, &mut sw).unwrap();
        for n in [2usize, 3] {
            if n > n_kv {
                continue;
            }
            let mut rt = ShardRuntime::new(&model, n).unwrap();
            let mut sg = DecodeStats::new(model.cfg.n_layers);
            let got = rt.generate(&model, &prompt, 16, prec, &mut sg)
                .unwrap();
            assert_eq!(got, want,
                       "{n_heads}h/{n_kv}kv N={n}: sharded generation \
                        diverged");
            assert_eq!(sg.tokens, sw.tokens);
            assert_eq!(sg.total_bits, sw.total_bits,
                       "router decisions must be shard-invariant");
            assert_eq!(sg.linear_calls, sw.linear_calls);
            assert_eq!(sg.bits_hist, sw.bits_hist);
            assert_eq!(sg.per_linear_bits, sw.per_linear_bits);
            assert_eq!(sg.per_linear_calls, sw.per_linear_calls);
        }
    }
}

/// Quantized KV storage under sharding: the per-shard arenas quantize
/// each kv head's rows with the same per-(page, head, side) absmax
/// steps as the single arena, so greedy outputs at i8 and u4 KV match
/// the unsharded run bit for bit.
#[test]
fn kv_precision_parity_f32_i8_u4() {
    let model = synth_model_shaped(139, 6, 3, 128);
    let prompt = prompt_for(9, 30);
    let prec = Precision::elastic(4.0);
    for kvp in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        let mut sw = DecodeStats::new(model.cfg.n_layers);
        let want = model.generate_at(&prompt, 12, prec, kvp, &mut sw)
            .unwrap();
        for n in [2usize, 3] {
            let mut rt = ShardRuntime::new(&model, n).unwrap();
            let mut sg = DecodeStats::new(model.cfg.n_layers);
            let got = rt.generate_at(&model, &prompt, 12, prec, kvp,
                                     &mut sg).unwrap();
            assert_eq!(got, want,
                       "{} KV N={n}: sharded generation diverged",
                       kvp.label());
            assert_eq!(sg.total_bits, sw.total_bits);
        }
    }
}

/// Page-seam sweep: context lengths straddling KV page boundaries
/// (KV_PAGE-1 / KV_PAGE / KV_PAGE+1 / 2*KV_PAGE+1) — per-shard arenas
/// claim pages at the same positions as the single arena, so the
/// all-position logits stay exactly equal across the seams.
#[test]
fn page_seam_contexts_bit_identical() {
    let model = synth_model_shaped(149, 4, 2, 3 * KV_PAGE);
    let prec = Precision::Fixed(2);
    let mut rt = ShardRuntime::new(&model, 2).unwrap();
    for len in [KV_PAGE - 1, KV_PAGE, KV_PAGE + 1, 2 * KV_PAGE + 1] {
        let tokens = prompt_for(len, len);
        let want = model.forward_logits(&tokens, prec).unwrap();
        let got = rt.forward_logits(&model, &tokens, prec).unwrap();
        assert_eq!(got, want, "len={len}: sharded logits diverged at \
                               a page seam");
    }
}

/// Coalesced decode: ragged multi-slot `decode_batch` through the
/// sharded runtime vs the unsharded model — every logits row and every
/// greedy token must be exactly equal, step after step, in one shared
/// (per-shard) paged arena.
#[test]
fn decode_batch_bit_identical() {
    let n_slots = 3usize;
    let model = synth_model_shaped(151, 4, 2, 256);
    let prec = Precision::elastic(4.0);
    let n_new = 6usize;
    let vocab = model.cfg.vocab_size;
    let prompts: Vec<Vec<u32>> = (0..n_slots)
        .map(|s| prompt_for(s, 50 + 20 * s))
        .collect();

    // unsharded reference
    let mut scratch = model.new_scratch();
    let mut arena = model.new_arena(n_slots);
    let seqs: Vec<_> = (0..n_slots).map(|_| arena.alloc_seq()).collect();
    let mut stats: Vec<DecodeStats> = (0..n_slots)
        .map(|_| DecodeStats::new(model.cfg.n_layers)).collect();
    let mut next = Vec::new();
    for (s, p) in prompts.iter().enumerate() {
        model.prefill(p, &mut arena, seqs[s], prec, &mut scratch,
                      &mut stats[s]).unwrap();
        next.push(argmax(&scratch.logits) as u32);
    }
    let mut want_tokens: Vec<Vec<u32>> =
        next.iter().map(|&t| vec![t]).collect();
    let mut want_logits = Vec::new();
    for _ in 0..n_new {
        {
            let mut slots: Vec<DecodeSlot> = seqs.iter()
                .zip(stats.iter_mut()).zip(&next)
                .map(|((&seq, st), &tok)| DecodeSlot {
                    token: tok, seq, stats: st,
                })
                .collect();
            model.decode_batch(&mut slots, &mut arena, prec,
                               &mut scratch).unwrap();
        }
        want_logits.push(scratch.block.logits[..n_slots * vocab]
            .to_vec());
        for s in 0..n_slots {
            let tok = argmax(&scratch.block.logits[s * vocab
                ..(s + 1) * vocab]) as u32;
            want_tokens[s].push(tok);
            next[s] = tok;
        }
    }

    // sharded subject, same protocol
    let mut rt = ShardRuntime::new(&model, 2).unwrap();
    let mut kv = rt.new_shards_arena(&model, n_slots);
    let seqs: Vec<_> = (0..n_slots).map(|_| kv.alloc_seq()).collect();
    let mut stats: Vec<DecodeStats> = (0..n_slots)
        .map(|_| DecodeStats::new(model.cfg.n_layers)).collect();
    let mut logits = vec![0f32; vocab];
    let mut next = Vec::new();
    for (s, p) in prompts.iter().enumerate() {
        rt.prefill(&model, p, &mut kv, seqs[s], prec, &mut stats[s],
                   &mut logits).unwrap();
        next.push(argmax(&logits) as u32);
    }
    let mut got_tokens: Vec<Vec<u32>> =
        next.iter().map(|&t| vec![t]).collect();
    let mut block_logits = Vec::new();
    for (step, want) in want_logits.iter().enumerate() {
        {
            let mut slots: Vec<DecodeSlot> = seqs.iter()
                .zip(stats.iter_mut()).zip(&next)
                .map(|((&seq, st), &tok)| DecodeSlot {
                    token: tok, seq, stats: st,
                })
                .collect();
            rt.decode_batch(&model, &mut slots, &mut kv, prec,
                            &mut block_logits).unwrap();
        }
        assert_eq!(&block_logits[..n_slots * vocab], &want[..],
                   "step {step}: sharded decode_batch logits diverged");
        for s in 0..n_slots {
            let tok = argmax(&block_logits[s * vocab
                ..(s + 1) * vocab]) as u32;
            got_tokens[s].push(tok);
            next[s] = tok;
        }
    }
    assert_eq!(got_tokens, want_tokens);
}

/// Self-speculative decoding under sharding: the draft/verify/rollback
/// loop (low-bit drafts, batched verification, exact KV rollback of
/// rejected tails) must replay the unsharded accept/reject trace
/// exactly — same tokens, same round/draft/accept counters, same final
/// draft window and bits.
#[test]
fn speculative_decode_bit_identical() {
    let model = synth_model_shaped(157, 4, 2, 192);
    let prompt = prompt_for(3, 28);
    let prec = Precision::elastic(6.0);
    let cfg = SpecConfig::default();
    for kvp in [KvPrecision::F32, KvPrecision::Int8] {
        let mut sw = DecodeStats::new(model.cfg.n_layers);
        let mut stw = SpecState::new(&cfg, model.cfg.n_layers);
        let want = model.generate_speculative(&prompt, 20, prec, kvp,
                                              &cfg, &mut sw, &mut stw)
            .unwrap();
        let mut rt = ShardRuntime::new(&model, 2).unwrap();
        let mut sg = DecodeStats::new(model.cfg.n_layers);
        let mut stg = SpecState::new(&cfg, model.cfg.n_layers);
        let got = rt.generate_speculative(&model, &prompt, 20, prec,
                                          kvp, &cfg, &mut sg, &mut stg)
            .unwrap();
        assert_eq!(got, want,
                   "{} KV: sharded speculative output diverged",
                   kvp.label());
        assert_eq!(stg.rounds, stw.rounds);
        assert_eq!(stg.drafted, stw.drafted);
        assert_eq!(stg.accepted, stw.accepted);
        assert_eq!(stg.k, stw.k, "draft window feedback must match");
        assert_eq!(stg.draft_bits, stw.draft_bits);
        assert_eq!(sg.tokens, sw.tokens);
    }
}

// ---------------------------------------------------------------------------
// scheduler-level parity: the pressure ladder over per-shard arenas
// ---------------------------------------------------------------------------

fn mk_req(id: u64, prompt: Vec<u32>, max_new: usize)
          -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    (Request {
        id,
        prompt,
        max_new_tokens: max_new,
        kv_precision: KvPrecision::F32,
        submitted: Instant::now(),
        reply: tx,
    }, rx)
}

fn fixed_controller() -> ElasticController {
    ElasticController::new(ControllerConfig {
        min_bits: 4.0,
        max_bits: 4.0,
        ..ControllerConfig::default()
    })
}

/// The degradation ladder over sharded arenas: with a tiny page budget
/// and lowered bands, a 2-shard scheduler must (a) report exactly the
/// same byte capacity as the single-arena scheduler (occupancy sums
/// across per-shard arenas), (b) walk the same ladder (bands engaged,
/// requants fired, zero drops), and (c) emit bit-identical tokens for
/// every request.
#[test]
fn scheduler_pressure_ladder_parity_across_shards() {
    let model = synth_model_shaped(59, 4, 2, 128);
    let bands = PressureConfig {
        moderate: 0.2,
        high: 0.5,
        critical: 0.99,
        hysteresis: 0.05,
    };
    let run = |shards: usize| {
        let batcher = Batcher::new(4, 16).with_kv_budget(5);
        let mut sched =
            Scheduler::new(&model, batcher, fixed_controller())
                .with_pressure(bands.clone());
        if shards > 1 {
            sched = sched.with_shards(shards).unwrap();
        }
        assert_eq!(sched.n_shards(), shards.max(1));
        let capacity = sched.arena.capacity_bytes();
        let mut rxs = Vec::new();
        for id in 0..8u64 {
            let (req, rx) = mk_req(id, prompt_for(id as usize, 40), 4);
            sched.submit(req);
            rxs.push(rx);
        }
        sched.run_to_completion(|_| 0.0).unwrap();
        assert_eq!(sched.arena.resident_pages(), 0,
                   "retire must return every page on every shard");
        let tokens: Vec<Vec<u32>> = rxs.iter()
            .map(|rx| rx.try_recv()
                .expect("no request may be dropped").tokens)
            .collect();
        (capacity, tokens, sched.metrics.clone())
    };

    let (cap1, tok1, m1) = run(1);
    let (cap2, tok2, m2) = run(2);

    assert_eq!(cap2, cap1,
               "per-shard arena bytes must sum to the unsharded budget");
    assert_eq!(tok2, tok1,
               "sharded scheduling under pressure diverged from the \
                single-arena run");
    assert_eq!(m2.requests_completed, 8);
    assert_eq!(m2.rejected, 0, "the ladder must never drop a request");
    assert_eq!(m2.pressure_ticks, m1.pressure_ticks,
               "summed occupancy must drive the same band per tick");
    assert_eq!(m2.requant_events, m1.requant_events);
    assert_eq!(m2.admissions_degraded, m1.admissions_degraded);
    assert_eq!(m2.preemptions, m1.preemptions);
    assert_eq!(m2.oom_recoveries, m1.oom_recoveries);
    assert!(m2.pressure_ticks[1..].iter().sum::<u64>() > 0,
            "the tiny budget must push the sharded run off Calm");
}
