//! Tab. 8 / Tab. 9 — downstream accuracy substitutes: likelihood-ranked
//! cloze suite (zero-shot commonsense analogue) and templated-arithmetic
//! exact match (GSM8K analogue), FP vs static 4-bit vs elastic MoBiQ.

use mobiquant::bench_support as bs;
use mobiquant::data::{cloze, corpus};
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("tab8_downstream");
    suite.header();
    let dir = mobiquant::artifacts_dir();
    let Ok(text) = corpus::load(&dir, "wiki", corpus::Split::Valid) else {
        suite.note("no corpus");
        suite.finish();
        return;
    };
    let n_items = bs::eval_windows(6) * 4;
    let items = cloze::build_cloze(&text, n_items, 3, 42);
    let arith = cloze::build_arith(n_items, 43);
    suite.note(&format!("{} cloze items (3-way), {} arithmetic items",
                        items.len(), arith.len()));

    for mname in bs::models_available().iter().take(2) {
        let Some(bundle) = bs::try_bundle(mname) else { continue };
        let mut rows: Vec<(String, f64, f64)> = Vec::new();
        let mut eval = |label: &str, model: &Model, prec: Precision,
                        rows: &mut Vec<(String, f64, f64)>| {
            let acc = cloze::eval_cloze(model, &items, prec).unwrap();
            let am = cloze::eval_arith(model, &arith, prec).unwrap();
            rows.push((label.to_string(), acc, am));
        };
        let fp = Model::load(&bundle, BackendKind::Fp32).unwrap();
        eval("FP32", &fp, Precision::Fixed(4), &mut rows);
        if bundle.static_methods().contains(&"omniquant4".to_string()) {
            let m = Model::load(&bundle,
                                BackendKind::Static("omniquant4".into()))
                .unwrap();
            eval("Omni4", &m, Precision::Fixed(4), &mut rows);
        }
        let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        eval("MoBiQ@4", &mobiq, Precision::elastic(4.0), &mut rows);
        eval("MoBiQ@3", &mobiq, Precision::elastic(3.0), &mut rows);

        for (label, acc, am) in rows {
            suite.row(&format!("{mname} {label}"),
                      &[("cloze_acc", acc), ("arith_em", am)]);
        }
    }
    suite.note("paper shape: elastic MoBiQ ~ static 4-bit on downstream \
                tasks, close to FP");
    suite.finish();
}
