//! Tiled attention vs scalar oracle, and block-prefill vs per-token
//! decode parity at prefill chunk boundaries.  All on synthetic
//! models/caches, so no `make artifacts` is needed.
//!
//! Tolerances are 1e-4 absolute: the tiled kernel's online softmax
//! reorders FP accumulation relative to the two-pass oracle, so the
//! results are equal only up to rounding.

use mobiquant::bench_support::{synth_model, synth_model_shaped};
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::attention::{attention_block, attention_step,
                                  AttnScratch};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::transformer::{DecodeStats, MAX_PREFILL_BLOCK};
use mobiquant::model::weights::ModelConfig;
use mobiquant::util::prng::Pcg;
use mobiquant::util::threadpool::ThreadPool;

const TOL: f32 = 1e-4;

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            max_seq: usize) -> ModelConfig {
    ModelConfig {
        name: "parity".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: max_seq,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

fn filled_cache(rng: &mut Pcg, n_kv: usize, hd: usize,
                positions: usize) -> KvCache {
    let mut cache = KvCache::new(positions, n_kv, hd);
    let w = n_kv * hd;
    for _ in 0..positions {
        let k = rng.normal_vec(w, 1.0);
        let v = rng.normal_vec(w, 1.0);
        cache.push(&k, &v);
    }
    cache
}

/// Oracle ctx rows for queries `pos0..pos0 + t` (one scalar
/// `attention_step` per query position).
fn oracle_block(cfg: &ModelConfig, q: &[f32], cache: &KvCache,
                pos0: usize, t: usize) -> Vec<f32> {
    let d = cfg.d_model;
    let mut scores = vec![0f32; cfg.max_seq_len];
    let mut want = vec![0f32; t * d];
    for i in 0..t {
        attention_step(&q[i * d..(i + 1) * d], cache, cfg, pos0 + i,
                       &mut scores, &mut want[i * d..(i + 1) * d]);
    }
    want
}

/// Tiled kernel (serial and head-parallel) vs the scalar oracle across
/// MHA and GQA head configs, tile-boundary-straddling contexts, and
/// block sizes from single-query decode up to a full prefill block.
#[test]
fn tiled_matches_scalar_oracle_across_gqa() {
    let pool = ThreadPool::new(3);
    let hd = 16usize;
    for &(n_heads, n_kv) in &[(4usize, 4usize), (4, 2), (8, 2), (8, 1)] {
        let max_seq = 256; // crosses several ATTN_TILE boundaries
        let cfg = attn_cfg(n_heads, n_kv, hd, max_seq);
        let d = cfg.d_model;
        let mut rng = Pcg::new(100 + n_heads as u64 * 10 + n_kv as u64);
        let cache = filled_cache(&mut rng, n_kv, hd, max_seq);
        // the larger shapes clear ATTN_PARALLEL_MIN_WORK (t*(pos0+t)
        // *hd >= 2^14), so every head config exercises the pooled path
        // too, not just the serial fallback
        for &(pos0, t) in &[(0usize, 1usize), (0, 33), (255, 1),
                            (100, 57), (192, 64)] {
            if pos0 + t > max_seq {
                continue;
            }
            let q = rng.normal_vec(t * d, 1.0);
            let want = oracle_block(&cfg, &q, &cache, pos0, t);

            let mut got = vec![0f32; t * d];
            let mut sc = AttnScratch::new();
            attention_block(&cfg, &q, &cache, pos0, t, &mut sc, None,
                            &mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < TOL,
                        "{n_heads}h/{n_kv}kv pos0={pos0} t={t} serial \
                         ctx[{i}]: tiled {a} vs oracle {b}");
            }

            let mut got_p = vec![0f32; t * d];
            attention_block(&cfg, &q, &cache, pos0, t, &mut sc,
                            Some(&pool), &mut got_p);
            // threading must not change results at all: head order
            // inside each worker is fixed and heads are independent
            assert_eq!(got, got_p,
                       "{n_heads}h/{n_kv}kv pos0={pos0} t={t}: \
                        parallel diverged from serial");
        }
    }
}

/// Above the parallel work gate, the pooled path must engage and stay
/// bit-identical to serial (big enough block to clear
/// ATTN_PARALLEL_MIN_WORK).
#[test]
fn parallel_path_bit_identical_on_large_blocks() {
    let pool = ThreadPool::new(4);
    let (n_heads, n_kv, hd, max_seq) = (8usize, 2usize, 16usize, 256);
    let cfg = attn_cfg(n_heads, n_kv, hd, max_seq);
    let d = cfg.d_model;
    let mut rng = Pcg::new(2024);
    let cache = filled_cache(&mut rng, n_kv, hd, max_seq);
    let (pos0, t) = (max_seq - 64, 64usize);
    let q = rng.normal_vec(t * d, 1.0);

    let mut serial = vec![0f32; t * d];
    let mut sc = AttnScratch::new();
    attention_block(&cfg, &q, &cache, pos0, t, &mut sc, None,
                    &mut serial);
    let mut parallel = vec![0f32; t * d];
    attention_block(&cfg, &q, &cache, pos0, t, &mut sc, Some(&pool),
                    &mut parallel);
    assert_eq!(serial, parallel);

    let want = oracle_block(&cfg, &q, &cache, pos0, t);
    for (i, (a, b)) in serial.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < TOL, "ctx[{i}]: {a} vs oracle {b}");
    }
}

fn per_token_logits(model: &mobiquant::model::Model, tokens: &[u32],
                    prec: Precision) -> Vec<f32> {
    let (mut arena, seq) = model.new_kv();
    let mut scratch = model.new_scratch();
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let mut out = Vec::with_capacity(tokens.len()
        * model.cfg.vocab_size);
    for &tok in tokens {
        model.decode_step(tok, &mut arena, seq, prec, &mut scratch,
                          &mut stats)
            .unwrap();
        out.extend_from_slice(&scratch.logits);
    }
    out
}

fn check_block_vs_per_token(model: &mobiquant::model::Model,
                            n_tokens: usize, label: &str) {
    let tokens: Vec<u32> = (0..n_tokens)
        .map(|i| ((i * 7 + 3) % model.cfg.vocab_size) as u32)
        .collect();
    let prec = Precision::Fixed(2);
    let block = model.forward_logits(&tokens, prec).unwrap();
    let per_tok = per_token_logits(model, &tokens, prec);
    assert_eq!(block.len(), per_tok.len());
    for (i, (a, b)) in block.iter().zip(&per_tok).enumerate() {
        assert!((a - b).abs() < TOL,
                "{label}: logits[{i}] block {a} vs per-token {b}");
    }
}

/// Prefill chunk boundaries: block-prefill logits must match per-token
/// decode right below, at, and past the MAX_PREFILL_BLOCK chunking
/// seam (T = 63 / 64 / 129).
#[test]
fn prefill_chunk_boundary_parity() {
    let model = synth_model_shaped(7, 4, 2, 160);
    for t in [MAX_PREFILL_BLOCK - 1, MAX_PREFILL_BLOCK,
              2 * MAX_PREFILL_BLOCK + 1] {
        check_block_vs_per_token(&model, t, &format!("T={t}"));
    }
}

/// End-to-end GQA sweep (n_kv_heads < n_heads included) on the default
/// synthetic model shape and two others; block length crosses one
/// attention tile boundary.
#[test]
fn gqa_model_block_vs_per_token_parity() {
    check_block_vs_per_token(&synth_model(11), 40, "default 4h/2kv");
    for &(n_heads, n_kv) in &[(4usize, 4usize), (8, 2)] {
        let model = synth_model_shaped(23, n_heads, n_kv, 128);
        check_block_vs_per_token(&model, 40,
                                 &format!("{n_heads}h/{n_kv}kv"));
    }
}
