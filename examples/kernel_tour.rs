//! Low-level API tour: MoBiSlice decomposition, bit-plane packing, the
//! shared-scale LUT GEMV, routing, and the traffic model — everything
//! §4.1/§4.3 of the paper describes, on one toy linear layer.
//!
//!     cargo run --release --example kernel_tour

use mobiquant::mobiq::bitplane::PackedSlice;
use mobiquant::mobiq::gemv::{dequant_gemv, gemv_lut, matvec,
                             permute_by_mask, TokenLut};
use mobiquant::mobiq::quantizer::{decompose, reconstruct, GroupParams};
use mobiquant::util::prng::Pcg;

fn main() {
    let (d_in, d_out, gs) = (128usize, 64usize, 32usize);
    let mut rng = Pcg::new(42);
    let w = rng.normal_vec(d_in * d_out, 0.25);

    // 1. recursive residual decomposition (paper Eq. 2)
    let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
    let codes = decompose(&w, &base, 4);
    println!("decomposed {}x{} weight into {} 2-bit slices",
             d_in, d_out, codes.len());
    for k in 1..=4 {
        let rec = reconstruct(&codes, &base, k);
        let mse: f64 = w.iter().zip(&rec)
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
            / w.len() as f64;
        println!("  {} slices ({} bits): reconstruction mse {:.3e}",
                 k, 2 * k, mse);
    }

    // 2. bit-plane packing (paper §4.3 bit-major layout)
    let slices: Vec<PackedSlice> = codes.iter()
        .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
        .collect();
    println!("\npacked planes: {} bytes/slice vs {} bytes dense f32",
             slices[0].nbytes(), d_in * d_out * 4);

    // 3. the kernel: LUT bit-serial GEMV with shared scales
    let x = rng.normal_vec(d_in, 1.0);
    let mut lut = TokenLut::new(d_in, gs);
    lut.build(&x, gs);
    let active = [true, true, false, false]; // a 4-bit token
    let mut y = vec![0f32; d_out];
    let mut y_oracle = vec![0f32; d_out];
    let mut y_fp = vec![0f32; d_out];
    gemv_lut(&slices, &base, &lut, &active, &mut y);
    dequant_gemv(&slices, &base, &x, &active, &mut y_oracle);
    matvec(&w, &x, &mut y_fp, d_in, d_out);
    let kerr = y.iter().zip(&y_oracle)
        .map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!("\nLUT kernel vs dequant oracle: max diff {:.2e}", kerr);
    let qerr: f32 = y.iter().zip(&y_fp)
        .map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!("4-bit output vs FP output:    max diff {:.3}", qerr);

    // 4. token permutation (contiguous same-precision groups)
    let masks: Vec<Vec<bool>> = (0..8)
        .map(|_| {
            let mut m = vec![true, false, false, false];
            for e in 1..4 {
                m[e] = rng.bool(0.5);
            }
            m
        })
        .collect();
    let perm = permute_by_mask(&masks);
    println!("\ntoken permutation for batched dispatch: {perm:?}");

    // 5. traffic proportionality: bytes fetched per precision
    println!("\non-demand plane fetch (bytes per token):");
    for k in 1..=4 {
        let bytes: usize = slices[..k].iter().map(|s| s.nbytes()).sum();
        println!("  {} bits -> {} plane bytes", 2 * k, bytes);
    }
}
