//! Admission queue + continuous batching.
//!
//! Requests park in a FIFO until the scheduler has a free sequence slot
//! (bounded by `max_active` and the KV budget).  The invariants checked
//! by the property tests: no request is lost or duplicated, admission
//! order is FIFO, and the active count never exceeds the cap.
//!
//! The batcher also owns the tick batching policy the scheduler
//! executes: how many prompt tokens a sequence prefills per tick, and
//! how many sequences a coalesced decode step may fuse into one batched
//! kernel call.

use std::collections::VecDeque;

use super::request::{Request, RequestId};

pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_active: usize,
    pub max_queue: usize,
    /// Prompt tokens fed per tick per sequence during chunked prefill —
    /// each chunk is one whole-block batched kernel call.
    pub prefill_chunk: usize,
    /// Cap on sequences coalesced into one batched decode call; bounds
    /// the kernel's per-token LUT scratch (one TokenLut block each).
    pub max_decode_batch: usize,
    admitted: u64,
    rejected: u64,
}

pub enum Admission {
    Queued,
    Rejected,
}

impl Batcher {
    pub fn new(max_active: usize, max_queue: usize) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            max_active,
            max_queue,
            prefill_chunk: 16,
            max_decode_batch: 32,
            admitted: 0,
            rejected: 0,
        }
    }

    /// Override the tick batching policy (values are clamped to >= 1).
    pub fn with_chunking(mut self, prefill_chunk: usize,
                         max_decode_batch: usize) -> Batcher {
        self.prefill_chunk = prefill_chunk.max(1);
        self.max_decode_batch = max_decode_batch.max(1);
        self
    }

    pub fn submit(&mut self, req: Request) -> Admission {
        if self.queue.len() >= self.max_queue {
            self.rejected += 1;
            return Admission::Rejected;
        }
        self.queue.push_back(req);
        Admission::Queued
    }

    /// Pop as many requests as fit beside `n_active` running sequences.
    pub fn admit(&mut self, n_active: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while n_active + out.len() < self.max_active {
            match self.queue.pop_front() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        self.admitted += out.len() as u64;
        out
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn queued_ids(&self) -> Vec<RequestId> {
        self.queue.iter().map(|r| r.id).collect()
    }

    pub fn counts(&self) -> (u64, u64) {
        (self.admitted, self.rejected)
    }

    /// Queue pressure in [0, 1] — feeds the elastic controller.
    pub fn pressure(&self) -> f64 {
        self.queue.len() as f64 / self.max_queue.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::property;
    use std::sync::mpsc;
    use std::time::Instant;

    fn mk_req(id: u64) -> (Request, mpsc::Receiver<super::super::request::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request {
            id,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            submitted: Instant::now(),
            reply: tx,
        }, rx)
    }

    #[test]
    fn fifo_order_and_cap() {
        let mut b = Batcher::new(2, 100);
        let mut _rxs = Vec::new();
        for id in 0..5 {
            let (r, rx) = mk_req(id);
            _rxs.push(rx);
            b.submit(r);
        }
        let first = b.admit(0);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 1]);
        // one slot busy -> only one more admitted
        let second = b.admit(1);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![2]);
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn rejects_when_full() {
        let mut b = Batcher::new(1, 2);
        let mut _rxs = Vec::new();
        let mut rejected = 0;
        for id in 0..5 {
            let (r, rx) = mk_req(id);
            _rxs.push(rx);
            if matches!(b.submit(r), Admission::Rejected) {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 3);
        assert_eq!(b.counts().1, 3);
    }

    #[test]
    fn no_loss_no_duplication() {
        property(77, 20, |rng, _| {
            let max_active = 1 + rng.below(4);
            let mut b = Batcher::new(max_active, 1000);
            let mut _rxs = Vec::new();
            let n = 20 + rng.below(30);
            for id in 0..n as u64 {
                let (r, rx) = mk_req(id);
                _rxs.push(rx);
                b.submit(r);
            }
            let mut seen = Vec::new();
            let mut active = 0usize;
            while seen.len() < n {
                let batch = b.admit(active);
                assert!(active + batch.len() <= max_active);
                for r in &batch {
                    seen.push(r.id);
                }
                active += batch.len();
                // randomly retire some
                let retire = rng.below(active + 1);
                active -= retire;
            }
            let mut sorted = seen.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), n, "lost or duplicated requests");
            // FIFO: seen must be sorted already
            assert_eq!(seen, {
                let mut s = seen.clone();
                s.sort();
                s
            });
        });
    }
}
