//! Serving metrics: counters + streaming latency histograms.

use crate::model::kvcache::{KvPrecision, KvShards};
use crate::util::stats;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub decode_latencies_ms: Vec<f64>,   // per generated token
    pub request_latencies_ms: Vec<f64>,  // end-to-end
    pub avg_bits_series: Vec<f64>,       // controller trace per tick
    pub target_bits_series: Vec<f64>,
    pub rejected: u64,
    // -- paged KV arena accounting (Fig. 7-style memory view) --------
    /// Arena budget in f32-page equivalents.
    pub kv_pages_capacity: usize,
    /// Pages mapped at the last tick (count across precisions; pages
    /// of different precisions are different sizes — byte-accurate
    /// numbers are below).
    pub kv_pages_resident: usize,
    /// High-water mark of mapped pages over the run.
    pub kv_pages_resident_peak: usize,
    /// Bytes of one f32 KV page (both sides), for report scaling.
    pub kv_page_bytes: usize,
    /// Arena byte budget.
    pub kv_bytes_capacity: usize,
    /// Data bytes mapped at the last tick.
    pub kv_bytes_resident: usize,
    /// High-water mark of mapped bytes over the run.
    pub kv_bytes_resident_peak: usize,
    /// Resident page counts per storage precision at the last tick.
    pub kv_pages_f32: usize,
    pub kv_pages_i8: usize,
    pub kv_pages_u4: usize,
    /// Bytes the resident quantized pages save vs storing them at f32
    /// (4x for i8 pages, 8x for i4).
    pub kv_bytes_saved_vs_f32: usize,
    /// Admissions satisfied (partly) from the shared-prefix cache.
    pub prefix_hits: u64,
    /// Admissions that found no usable shared prefix.
    pub prefix_misses: u64,
    /// Prompt tokens whose prefill was skipped via shared pages.
    pub prefix_tokens_reused: u64,
    /// Prefix-cache entries dropped (LRU / page pressure).
    pub prefix_evictions: u64,
    /// Times admission stalled because the queue head's worst-case
    /// pages did not fit (page backpressure, not slot pressure).
    pub admissions_deferred: u64,
    // -- pressure-ladder accounting (closed-loop elastic control) ----
    /// Ticks spent in each pressure band (calm/moderate/high/critical).
    pub pressure_ticks: [u64; 4],
    /// Admissions whose KV precision was degraded below the request's
    /// ask by the pressure floor.
    pub admissions_degraded: u64,
    /// Requant sweeps that converted at least one resident tail page.
    pub requant_events: u64,
    /// Pages converted in place across all requant sweeps.
    pub requant_pages: u64,
    /// Arena bytes released by in-place requantization.
    pub requant_bytes_freed: u64,
    /// Sequences evicted mid-flight by the Critical rung (each is
    /// parked and later resumed — never dropped).
    pub preemptions: u64,
    /// Preempted sequences re-admitted for their resume prefill.
    pub resumes: u64,
    /// Mid-tick `OutOfPages` faults the degradation ladder absorbed
    /// (none of these escaped `Scheduler::run`).
    pub oom_recoveries: u64,
    // -- host swap tier (O(memcpy) relief instead of O(recompute)) ---
    /// Swap-out sweeps that moved at least one page to the host tier.
    pub swap_out_events: u64,
    /// KV pages copied device→host across all sweeps.
    pub swap_out_pages: u64,
    /// Bytes (codes + scales) copied device→host.
    pub swap_out_bytes: u64,
    /// Swap-in passes that restored at least one page.
    pub swap_in_events: u64,
    /// KV pages copied host→device.
    pub swap_in_pages: u64,
    /// Bytes (codes + scales) copied host→device.
    pub swap_in_bytes: u64,
    /// Host-tier bytes resident at the last tick.
    pub host_bytes_resident: usize,
    /// High-water mark of host-tier bytes over the run.
    pub host_bytes_resident_peak: usize,
    /// Host-tier byte budget (0 = tier disabled).
    pub host_bytes_capacity: usize,
    /// Resumes that fell back to a full re-prefill because the parked
    /// host pages could not be restored (tier exhausted or a failpoint
    /// denied the swap-in).  Each one is a request saved from a drop
    /// at recompute cost — the number the swap tier exists to keep
    /// near zero.
    pub swap_fallback_reprefills: u64,
    // -- self-speculative decoding (draft/verify accounting) ---------
    /// Draft→verify→commit rounds executed (one per member per
    /// speculative group tick).
    pub spec_rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub spec_drafted: u64,
    /// Draft tokens accepted by the full-precision verify pass.
    pub spec_accepted: u64,
    /// Draft tokens the verify pass rejected (rolled back exactly).
    pub spec_rejected: u64,
    /// Tokens committed by verify rounds (accepted prefixes plus their
    /// correction/bonus tokens); divided by `spec_rounds` this is the
    /// headline tokens-per-verify-step.
    pub spec_commit_tokens: u64,
    /// Accept-rate EMA of the most recently observed round (the value
    /// driving that sequence's draft depth and bits).
    pub spec_accept_ema: f64,
    /// Histogram over effective bits per *draft-pass* linear call
    /// (same binning as `DecodeStats::bits_hist`: bin k = k routed
    /// slices active), merged when sequences retire or park.
    pub spec_draft_bits_hist: Vec<u64>,
}

impl Metrics {
    pub fn record_request(&mut self, total_ms: f64, n_tokens: usize) {
        self.requests_completed += 1;
        self.tokens_generated += n_tokens as u64;
        self.request_latencies_ms.push(total_ms);
    }

    pub fn record_token(&mut self, ms: f64) {
        self.decode_latencies_ms.push(ms);
    }

    pub fn record_tick(&mut self, avg_bits: f64, target_bits: f64) {
        self.avg_bits_series.push(avg_bits);
        self.target_bits_series.push(target_bits);
    }

    /// Snapshot the arena's page and byte occupancy (called once per
    /// tick).  Under shards the page-slot numbers come from the
    /// mirrored shard 0 (== unsharded) and byte numbers sum across the
    /// per-shard arenas (== unsharded exactly), so dashboards read the
    /// same regardless of shard count.
    pub fn record_kv(&mut self, arena: &KvShards) {
        self.kv_pages_capacity = arena.capacity_pages();
        self.kv_pages_resident = arena.resident_pages();
        self.kv_pages_resident_peak = arena.peak_resident_pages();
        self.kv_page_bytes = arena.page_bytes();
        self.kv_bytes_capacity = arena.capacity_bytes();
        self.kv_bytes_resident = arena.resident_bytes();
        self.kv_bytes_resident_peak = arena.peak_resident_bytes();
        self.kv_pages_f32 = arena.resident_pages_at(KvPrecision::F32);
        self.kv_pages_i8 = arena.resident_pages_at(KvPrecision::Int8);
        self.kv_pages_u4 = arena.resident_pages_at(KvPrecision::Int4);
        self.kv_bytes_saved_vs_f32 = arena.bytes_saved_vs_f32();
        self.host_bytes_resident = arena.host_resident_bytes();
        self.host_bytes_resident_peak = arena.host_peak_bytes();
        self.host_bytes_capacity = arena.host_capacity_bytes();
    }

    /// Count a tick spent in a pressure band.
    pub fn record_pressure(&mut self, band: usize) {
        if let Some(t) = self.pressure_ticks.get_mut(band) {
            *t += 1;
        }
    }

    /// Fold one speculative round's outcome into the counters.
    pub fn record_spec_round(&mut self, drafted: usize, matched: usize,
                             committed: usize, ema: f64) {
        self.spec_rounds += 1;
        self.spec_drafted += drafted as u64;
        self.spec_accepted += matched as u64;
        self.spec_rejected += (drafted - matched) as u64;
        self.spec_commit_tokens += committed as u64;
        self.spec_accept_ema = ema;
    }

    /// Merge a retiring (or parking) sequence's draft-pass bit
    /// histogram into the run-wide draft-bit histogram.
    pub fn record_spec_hist(&mut self, hist: &[u64]) {
        if self.spec_draft_bits_hist.len() < hist.len() {
            self.spec_draft_bits_hist.resize(hist.len(), 0);
        }
        for (acc, &h) in self.spec_draft_bits_hist.iter_mut().zip(hist) {
            *acc += h;
        }
    }

    /// Lifetime fraction of drafted tokens the verify pass accepted.
    pub fn spec_accept_rate(&self) -> f64 {
        stats::rate(self.spec_accepted, self.spec_drafted)
    }

    /// Mean accepted-prefix length per round (accepted drafts only —
    /// the free correction/bonus token is not counted here).
    pub fn spec_mean_prefix(&self) -> f64 {
        if self.spec_rounds == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_rounds as f64
    }

    /// Tokens committed per verify step; > 1 means speculation pays.
    pub fn spec_tokens_per_round(&self) -> f64 {
        if self.spec_rounds == 0 {
            return 0.0;
        }
        self.spec_commit_tokens as f64 / self.spec_rounds as f64
    }

    /// Draft-bit histogram with trailing empty bins dropped (display).
    pub fn spec_hist_trimmed(&self) -> Vec<u64> {
        let mut h = self.spec_draft_bits_hist.clone();
        while h.last() == Some(&0) {
            h.pop();
        }
        h
    }

    /// Fraction of admissions that reused a shared prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        stats::rate(self.prefix_hits, self.prefix_hits
            + self.prefix_misses)
    }

    /// Peak resident KV bytes (measured — quantized pages count at
    /// their real size, not the f32-page estimate).
    pub fn kv_peak_bytes(&self) -> usize {
        self.kv_bytes_resident_peak
    }

    pub fn p50_token_ms(&self) -> f64 {
        stats::percentile(&self.decode_latencies_ms, 50.0)
    }
    pub fn p99_token_ms(&self) -> f64 {
        stats::percentile(&self.decode_latencies_ms, 99.0)
    }
    pub fn mean_request_ms(&self) -> f64 {
        stats::mean(&self.request_latencies_ms)
    }

    pub fn throughput_tokens_per_s(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / wall_s
    }

    pub fn summary(&self, wall_s: f64) -> String {
        format!(
            "requests={} tokens={} tput={:.1} tok/s p50_tok={:.2}ms \
             p99_tok={:.2}ms mean_req={:.1}ms rejected={} \
             kv_pages_peak={}/{} kv_bytes_peak={}/{} \
             kv_pages_f32/i8/u4={}/{}/{} kv_saved_vs_f32={}B \
             prefix_hit_rate={:.2} prefix_tokens_reused={} deferred={} \
             pressure_ticks={:?} degraded={} requant={}ev/{}pg/{}B \
             preempt={}/{} oom_recovered={} \
             swap_out={}ev/{}pg/{}B swap_in={}ev/{}pg/{}B \
             host_kv_peak={}/{}B swap_fallback_reprefill={} \
             spec_rounds={} spec_drafted={} spec_accepted={} \
             spec_rejected={} spec_accept_ema={:.2} \
             spec_mean_prefix={:.2} spec_tok_per_verify={:.2} \
             spec_draft_bits_hist={:?}",
            self.requests_completed,
            self.tokens_generated,
            self.throughput_tokens_per_s(wall_s),
            self.p50_token_ms(),
            self.p99_token_ms(),
            self.mean_request_ms(),
            self.rejected,
            self.kv_pages_resident_peak,
            self.kv_pages_capacity,
            self.kv_bytes_resident_peak,
            self.kv_bytes_capacity,
            self.kv_pages_f32,
            self.kv_pages_i8,
            self.kv_pages_u4,
            self.kv_bytes_saved_vs_f32,
            self.prefix_hit_rate(),
            self.prefix_tokens_reused,
            self.admissions_deferred,
            self.pressure_ticks,
            self.admissions_degraded,
            self.requant_events,
            self.requant_pages,
            self.requant_bytes_freed,
            self.preemptions,
            self.resumes,
            self.oom_recoveries,
            self.swap_out_events,
            self.swap_out_pages,
            self.swap_out_bytes,
            self.swap_in_events,
            self.swap_in_pages,
            self.swap_in_bytes,
            self.host_bytes_resident_peak,
            self.host_bytes_capacity,
            self.swap_fallback_reprefills,
            self.spec_rounds,
            self.spec_drafted,
            self.spec_accepted,
            self.spec_rejected,
            self.spec_accept_ema,
            self.spec_mean_prefix(),
            self.spec_tokens_per_round(),
            self.spec_hist_trimmed(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = Metrics::default();
        m.record_request(100.0, 10);
        m.record_request(200.0, 20);
        for i in 0..10 {
            m.record_token(i as f64);
        }
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.tokens_generated, 30);
        assert_eq!(m.mean_request_ms(), 150.0);
        assert!((m.p50_token_ms() - 4.5).abs() < 1e-9);
        assert_eq!(m.throughput_tokens_per_s(3.0), 10.0);
    }

    #[test]
    fn spec_accounting_and_summary() {
        let mut m = Metrics::default();
        // two rounds: 4 drafted / 4 accepted, then 4 drafted / 1
        // accepted (commit = accepted prefix + 1 verify token)
        m.record_spec_round(4, 4, 5, 0.60);
        m.record_spec_round(4, 1, 2, 0.55);
        m.record_spec_hist(&[0, 3, 5, 0]);
        m.record_spec_hist(&[0, 1, 0, 0, 2]);
        assert_eq!(m.spec_rounds, 2);
        assert_eq!(m.spec_drafted, 8);
        assert_eq!(m.spec_accepted, 5);
        assert_eq!(m.spec_rejected, 3);
        assert_eq!(m.spec_commit_tokens, 7);
        assert!((m.spec_accept_rate() - 5.0 / 8.0).abs() < 1e-12);
        assert!((m.spec_mean_prefix() - 2.5).abs() < 1e-12);
        assert!((m.spec_tokens_per_round() - 3.5).abs() < 1e-12);
        assert_eq!(m.spec_hist_trimmed(), vec![0, 4, 5, 0, 2]);
        let s = m.summary(1.0);
        assert!(s.contains("spec_rounds=2"));
        assert!(s.contains("spec_accept_ema=0.55"));
        assert!(s.contains("spec_tok_per_verify=3.50"));
        assert!(s.contains("spec_draft_bits_hist=[0, 4, 5, 0, 2]"));
    }

    #[test]
    fn swap_accounting_and_summary() {
        let mut m = Metrics::default();
        m.swap_out_events = 2;
        m.swap_out_pages = 7;
        m.swap_out_bytes = 7 * 1024;
        m.swap_in_events = 1;
        m.swap_in_pages = 4;
        m.swap_in_bytes = 4 * 1024;
        m.host_bytes_resident_peak = 3 * 1024;
        m.host_bytes_capacity = 8 * 1024;
        m.swap_fallback_reprefills = 1;
        let s = m.summary(1.0);
        assert!(s.contains("swap_out=2ev/7pg/7168B"));
        assert!(s.contains("swap_in=1ev/4pg/4096B"));
        assert!(s.contains("host_kv_peak=3072/8192B"));
        assert!(s.contains("swap_fallback_reprefill=1"));
    }
}
