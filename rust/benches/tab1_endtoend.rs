//! Tab. 1 — end-to-end comparison with AnyPrecisionLLM (AP), AnyBCQ
//! (ABCQ), QuIP#/QTIP (VQ) at 2/3/4 bits: WikiText2-analog PPL and
//! decode throughput.
//!
//! Substitutions (DESIGN.md §2): the baselines' CUDA kernels are replaced
//! by CPU simulators reproducing each design's overhead structure; the
//! models are the pretrained tiny-* family.  The reproduced *shape*:
//! MoBiQuant matches/beats the any-precision baselines' PPL at 3-4 bits,
//! avoids AP's 2-bit collapse, and out-throughputs all of them.

use mobiquant::baselines::{AbcqLinear, ApLinear, VqLinear};
use mobiquant::bench_support as bs;
use mobiquant::data::ppl;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::{BackendKind, LINEAR_NAMES};
use mobiquant::model::Model;
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;

fn main() {
    let mut suite = Suite::new("tab1_endtoend");
    suite.header();
    let models = bs::models_available();
    if models.is_empty() {
        suite.note("no bundles; run `make artifacts`");
        suite.finish();
        return;
    }
    let windows = bs::eval_windows(6);

    for mname in models.iter().take(2) {
        let Some(bundle) = bs::try_bundle(mname) else { continue };
        let toks = bs::valid_tokens("wiki").expect("corpus");
        suite.note(&format!("--- model {mname} ---"));

        // ---------------- PPL rows ----------------
        // AP-sim quality == uniform RTN codes at b bits (its codes are a
        // centroid-table view of the same planes).
        for bits in [2usize, 3, 4] {
            let mut cells: Vec<(String, f64)> = Vec::new();
            // AP (rtn at b bits, dense eval)
            let ap = bs::dense_model_with(&bundle, |_, _, w, d_in, d_out| {
                let lin = ApLinear::from_dense(w, d_in, d_out, 32, 8);
                let mut y = vec![0f32; d_in * d_out];
                // reconstruct at `bits` by zeroing dropped planes:
                // reuse its gemv on basis vectors is O(d^3); instead
                // quantize directly at `bits` (same uniform codes).
                let p = mobiquant::mobiq::quantizer::GroupParams::
                    from_minmax(w, d_in, d_out, bits as u32, 32);
                let q = mobiquant::mobiq::quantizer::quantize(w, &p);
                y.copy_from_slice(
                    &mobiquant::mobiq::quantizer::dequantize(&q, &p));
                black_box(lin.nbytes());
                y
            }).unwrap();
            let r = ppl::evaluate(&ap, &toks, Precision::Fixed(4), 128,
                                  windows).unwrap();
            cells.push(("AP".into(), r.ppl));

            // ABCQ (greedy binary-coded, k=bits planes)
            let abcq = bs::dense_model_with(
                &bundle, |_, _, w, d_in, d_out| {
                    let lin = AbcqLinear::from_dense(w, d_in, d_out, 32,
                                                     bits);
                    // dense reconstruction: sum alpha_p * sign_p
                    let mut y = vec![0f32; d_in * d_out];
                    for p in 0..bits {
                        let codes = lin.planes[p].unpack();
                        for g in 0..lin.n_groups {
                            for o in 0..d_out {
                                let a = lin.alphas
                                    [(p * lin.n_groups + g) * d_out + o];
                                for j in 0..lin.group_size {
                                    let idx = (g * lin.group_size + j)
                                        * d_out + o;
                                    let s = if codes[idx] == 1 { a }
                                            else { -a };
                                    y[idx] += s;
                                }
                            }
                        }
                    }
                    y
                }).unwrap();
            let r = ppl::evaluate(&abcq, &toks, Precision::Fixed(4), 128,
                                  windows).unwrap();
            cells.push(("ABCQ".into(), r.ppl));

            // VQ (QuIP#/QTIP-like) only defined at its native rate (~2b)
            if bits == 2 {
                let vq = bs::dense_model_with(
                    &bundle, |_, _, w, d_in, d_out| {
                        let lin = VqLinear::from_dense(w, d_in, d_out);
                        // dense reconstruction via codebook
                        let chunks = d_in / 4;
                        let mut y = vec![0f32; d_in * d_out];
                        for o in 0..d_out {
                            for c in 0..chunks {
                                let e = lin.codes[o * chunks + c] as usize;
                                for j in 0..4 {
                                    y[(c * 4 + j) * d_out + o] =
                                        lin.codebook[e * 4 + j]
                                        * lin.scales[o];
                                }
                            }
                        }
                        y
                    }).unwrap();
                let r = ppl::evaluate(&vq, &toks, Precision::Fixed(4), 128,
                                      windows).unwrap();
                cells.push(("VQ".into(), r.ppl));
            }

            // MoBiQuant elastic at target = bits
            let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
            let r = ppl::evaluate(&mobiq, &toks,
                                  Precision::elastic(bits as f64), 128,
                                  windows).unwrap();
            cells.push(("MoBiQ".into(), r.ppl));
            let named: Vec<(&str, f64)> = cells.iter()
                .map(|(k, v)| (k.as_str(), *v)).collect();
            suite.row(&format!("{mname} PPL @{bits}bit"), &named);
        }

        // ---------------- throughput rows ----------------
        // kernel-level: time one pass over every linear in the model
        // (per-token weight-path cost), per kernel design.
        let cfg = mobiquant::model::weights::ModelConfig::from_bundle(
            &bundle).unwrap();
        let mut rng = Pcg::new(5);
        let mut lin_sets = Vec::new();
        for li in 0..cfg.n_layers {
            for name in LINEAR_NAMES {
                let (w, d_in, d_out) = bs::fp_weight(&bundle, li, name)
                    .unwrap();
                lin_sets.push((w, d_in, d_out));
            }
        }
        for bits in [2usize, 3, 4] {
            let aps: Vec<ApLinear> = lin_sets.iter()
                .map(|(w, i, o)| ApLinear::from_dense(w, *i, *o, 32, 8))
                .collect();
            let abcqs: Vec<AbcqLinear> = lin_sets.iter()
                .map(|(w, i, o)| AbcqLinear::from_dense(w, *i, *o, 32,
                                                        bits))
                .collect();
            let vqs: Vec<VqLinear> = lin_sets.iter()
                .map(|(w, i, o)| VqLinear::from_dense(w, *i, *o))
                .collect();
            let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
            let xs: Vec<Vec<f32>> = lin_sets.iter()
                .map(|(_, i, _)| rng.normal_vec(*i, 1.0))
                .collect();
            let mut out = vec![0f32; 4096];

            let ns_ap = suite.bench(
                &format!("{mname} ap_sim weightpass @{bits}b"), || {
                    for (lin, x) in aps.iter().zip(&xs) {
                        lin.gemv(x, bits, &mut out[..lin.d_out]);
                    }
                    black_box(out[0]);
                });
            let ns_abcq = suite.bench(
                &format!("{mname} abcq_sim weightpass @{bits}b"), || {
                    for (lin, x) in abcqs.iter().zip(&xs) {
                        let gs: Vec<f32> = (0..lin.n_groups).map(|g| {
                            x[g * lin.group_size..(g + 1) * lin.group_size]
                                .iter().sum()
                        }).collect();
                        lin.gemv(x, bits, &gs, &mut out[..lin.d_out]);
                    }
                    black_box(out[0]);
                });
            let ns_vq = suite.bench(
                &format!("{mname} vq_sim weightpass (fixed-rate)"), || {
                    for (lin, x) in vqs.iter().zip(&xs) {
                        lin.gemv(x, &mut out[..lin.d_out]);
                    }
                    black_box(out[0]);
                });
            // MoBiQ weight pass at Fixed(k): route-free lower bound +
            // elastic with router for the honest number.
            let k = (bits + 1) / 2;
            let ns_mobiq = {
                let mut scratch = mobiq.new_scratch();
                suite.bench(
                    &format!("{mname} mobiq weightpass @{bits}b"), || {
                        for (li, lw) in mobiq.layers.iter().enumerate() {
                            let _ = li;
                            for name in LINEAR_NAMES {
                                if let Ok(mobiquant::model::LinearBackend::
                                    Mobiq(m)) = lw.linear(name)
                                {
                                    let x = &xs[0][..m.d_in.min(
                                        xs[0].len())];
                                    // pad x via cycle if needed
                                    let xv: Vec<f32> = (0..m.d_in)
                                        .map(|i| x[i % x.len()]).collect();
                                    m.forward_token(
                                        &xv, Precision::Fixed(k),
                                        &mut scratch.engine,
                                        &mut out[..m.d_out]);
                                }
                            }
                        }
                        black_box(out[0]);
                    })
            };
            suite.row(&format!("{mname} weightpass tok/s @{bits}b"), &[
                ("AP", 1e9 / ns_ap),
                ("ABCQ", 1e9 / ns_abcq),
                ("VQ", 1e9 / ns_vq),
                ("MoBiQ", 1e9 / ns_mobiq),
            ]);
        }

        // end-to-end decode throughput for MoBiQuant (the deployable path)
        let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
        for bits in [2.0, 3.0, 4.0] {
            let (mut arena, seq) = mobiq.new_kv();
            let mut scratch = mobiq.new_scratch();
            let mut stats = mobiquant::model::DecodeStats::new(
                mobiq.cfg.n_layers);
            let mut pos = 0usize;
            let ns = suite.bench(
                &format!("{mname} mobiq e2e decode @{bits}b"), || {
                    if pos + 1 >= mobiq.cfg.max_seq_len {
                        arena.reset_seq(seq);
                        pos = 0;
                    }
                    mobiq.decode_step(65, &mut arena, seq,
                                      Precision::elastic(bits),
                                      &mut scratch, &mut stats).unwrap();
                    pos += 1;
                });
            suite.row(&format!("{mname} e2e decode tok/s @{bits}b"),
                      &[("MoBiQ", 1e9 / ns)]);
        }
    }
    suite.finish();
}
