//! Fixed-size thread pool with scoped parallel-for (tokio/rayon are not
//! vendored; the coordinator and the slice-parallel kernel path use this).
//!
//! The pool holds worker threads fed by an mpsc channel of boxed jobs.
//! `scope_chunks` provides the rayon-like "split a slice into chunks and
//! join" pattern used by the batched GEMV path (the CPU analogue of the
//! paper's CUDA-stream slice overlap).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mobiq-worker-{}", i))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Pool sized to the machine (cores - 0, min 1).
    pub fn default_for_machine() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool alive");
    }

    /// Run `f(chunk_index)` for each index in 0..n, blocking until all
    /// complete.  `f` must be Sync; indices are distributed dynamically.
    /// Uses std::thread::scope (joins on exit), so no extra
    /// synchronisation is needed beyond the work counter.
    pub fn parallel_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.size == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let counter = AtomicUsize::new(0);
        thread::scope(|scope| {
            for _ in 0..self.size.min(n) {
                let counter = &counter;
                let f = &f;
                scope.spawn(move || loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..64 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn parallel_for_covers_all() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0))
            .collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_for_empty() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn drop_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
