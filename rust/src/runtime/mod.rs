//! PJRT runtime — loads the AOT HLO-text modules lowered by
//! python/compile/aot.py and executes them on the XLA CPU client.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).  All modules
//! are lowered with return_tuple=True, so results unwrap via to_tuple1.
//!
//! Used for cross-validation of the native engine (PJRT logits vs Rust
//! logits over the same bundle) and for fixed-precision PPL harnesses;
//! the elastic request path runs the native engine (per-token routing is
//! not expressible in a static HLO module).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

pub struct HloModule {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn load(&self, path: impl AsRef<Path>) -> Result<HloModule> {
        let path = path.as_ref().to_path_buf();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(HloModule { exe, path })
    }
}

impl HloModule {
    /// Execute with literal inputs; returns the first element of the
    /// result tuple as a flat f32 vector.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// tokens (i32) -> logits (T * vocab) — the model_fp / model_q modules.
    pub fn run_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let lit = xla::Literal::vec1(tokens);
        self.run_f32(&[lit])
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Locate a model's HLO module in the artifacts dir.
pub fn hlo_path(artifacts: &Path, model: &str, variant: &str) -> PathBuf {
    artifacts.join("hlo").join(format!("{model}_{variant}.hlo.txt"))
}

/// PPL over a PJRT fixed-precision module (window = the module's T).
pub fn ppl_via_pjrt(module: &HloModule, tokens: &[u32], window: usize,
                    vocab: usize, max_windows: usize) -> Result<f64> {
    let n = ((tokens.len().saturating_sub(1)) / window).min(max_windows);
    anyhow::ensure!(n > 0, "not enough tokens");
    let mut total = 0f64;
    let mut count = 0usize;
    for i in 0..n {
        let chunk = &tokens[i * window..i * window + window + 1];
        let inp: Vec<i32> = chunk[..window].iter().map(|&t| t as i32)
            .collect();
        let logits = module.run_tokens(&inp)
            .context("pjrt window execute")?;
        anyhow::ensure!(logits.len() == window * vocab,
                        "bad logits shape");
        for j in 0..window {
            total += crate::data::ppl::nll_of(
                &logits[j * vocab..(j + 1) * vocab], chunk[j + 1]);
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}
