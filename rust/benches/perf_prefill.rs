//! §Perf — batched weight-stationary prefill study (EXPERIMENTS.md
//! §Perf).
//!
//! Compares three hot paths at production-like dims, T tokens per block:
//!   * per-token `gemv_lut` (the decode kernel run T times — streams
//!     every active plane word T times),
//!   * `gemm_lut_batch` (weight-stationary: T LUT blocks built up
//!     front, each plane word streamed once per mask group),
//!   * the same batched kernel with the `ThreadPool` d_out-parallel
//!     wrapper (`--threads` path).
//!
//! Reports tokens/s, batched/parallel speedups and effective
//! plane-bandwidth; writes `target/bench_reports/BENCH_prefill.json`.

use std::sync::Arc;

use mobiquant::bench_support::synth_mobiq_linear;
use mobiquant::mobiq::engine::{Precision, Scratch};
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;
use mobiquant::util::threadpool::{default_threads, ThreadPool};

fn main() {
    let mut suite = Suite::new("BENCH_prefill");
    suite.header();
    let mut rng = Pcg::new(7);
    let pool = Arc::new(ThreadPool::new(default_threads()));
    suite.note(&format!("parallel rows use {} worker threads",
                        pool.size()));
    // Fixed(2): uniform 4-bit masks -> one mask group, the common
    // prefill shape; routing cost excluded from the comparison.
    let prec = Precision::Fixed(2);

    for (d_in, d_out) in [(1024usize, 1024usize), (4096, 4096)] {
        let lin = synth_mobiq_linear(&mut rng, d_in, d_out);
        let plane_bytes =
            lin.bytes_for_mask(&[true, true, false, false]) as f64;
        for t in [1usize, 8, 32, 128] {
            let xs = rng.normal_vec(d_in * t, 1.0);
            let mut out = vec![0f32; d_out * t];
            let tag = format!("{d_in}x{d_out} T={t}");

            let mut sc = Scratch::new(d_in, 32, 8, 4);
            let ns_tok = suite.bench(&format!("{tag} per-token"), || {
                for i in 0..t {
                    lin.forward_token(&xs[i * d_in..(i + 1) * d_in], prec,
                                      &mut sc,
                                      &mut out[i * d_out..(i + 1) * d_out]);
                }
                black_box(out[0]);
            });
            let ns_batch = suite.bench(&format!("{tag} batched"), || {
                lin.forward_batch(&xs, prec, &mut sc, &mut out);
                black_box(out[0]);
            });
            let mut scp = Scratch::new(d_in, 32, 8, 4)
                .with_pool(Arc::clone(&pool));
            let ns_par = suite.bench(
                &format!("{tag} batched+parallel"), || {
                    lin.forward_batch(&xs, prec, &mut scp, &mut out);
                    black_box(out[0]);
                });

            let toks = t as f64;
            suite.row(&format!("{tag} summary"), &[
                ("tok_s_pertoken", toks / (ns_tok * 1e-9)),
                ("tok_s_batched", toks / (ns_batch * 1e-9)),
                ("tok_s_parallel", toks / (ns_par * 1e-9)),
                ("batched_speedup", ns_tok / ns_batch),
                ("parallel_speedup", ns_tok / ns_par),
                // active plane bytes resolved per wall second; the
                // batched kernel streams them once per mask group, so
                // effective bandwidth scales ~T-fold over per-token
                ("plane_GBps_eff", plane_bytes * toks / ns_batch),
            ]);
        }
    }
    suite.note("targets: batched >= 3x per-token tokens/s at T=32 \
                d=4096; parallel adds further on >= 4 cores \
                (EXPERIMENTS.md §Perf)");
    suite.finish();
}
