//! Tensor-parallel sharded execution behind the [`Communicator`]
//! abstraction (`util/comm.rs`).
//!
//! [`ShardRuntime`] partitions the model across N in-process worker
//! shards: each shard owns a contiguous slice of attention heads (whole
//! GQA groups), FFN channels, d_model output channels and vocab rows,
//! plus its own per-shard KV arena (see [`KvShards`]) holding only its
//! kv heads' pages.  Shards run the full layer stack concurrently and
//! meet at **exactly four barriers per layer** — around the two joins
//! the issue names (the o-proj input/output and the down-proj
//! input/output) — then reassemble logits column-wise at the tail.
//!
//! ## Exactness: column-sharded joins, not reductions
//!
//! The textbook Megatron split row-shards wo/w_down and joins with an
//! `all_reduce_sum`.  That join re-associates f32 addition, so the
//! result depends on the shard count — it can never be bit-identical
//! to the serial kernel, which this codebase's parity contract (and
//! the speculative accept loop, and the golden vectors) requires.  The
//! sharded path therefore **column-shards every linear by output
//! channels**: each output element is produced whole by exactly one
//! shard running the serial per-element kernel over the full (locally
//! recomputed, bit-equal) input, and the joins are *gather barriers*
//! publishing disjoint column spans of a shared buffer.  Stitching
//! column ranges changes which elements a shard computes, never how
//! any element is computed — so N-shard output is bit-identical to
//! 1-shard output, which is bit-identical to the unsharded path
//! (pinned by `tests/shard_parity.rs`).  The reduction-based
//! row-partial entry points ([`crate::mobiq::gemv::gemv_lut_row_partial`]
//! + [`Communicator::all_reduce_sum`]) remain available for backends
//! where exactness is scoped per device; EXPERIMENTS.md §Sharding
//! records the deviation and the cost model.
//!
//! Replicated stages (embedding row, rmsnorm, residual adds) run the
//! identical f32 ops in the identical order on every shard, so every
//! lane's residual stream stays bit-equal without communication; the
//! MoBiRoute router sees the same replicated activations, so every
//! shard routes every token to the same slice count — **bit-plane
//! weights need no cross-shard precision coordination** (shard 0's
//! routing log is replayed into the caller's [`DecodeStats`]).
//!
//! ## Degradation semantics under shards
//!
//! Mirrored per-shard arenas are built with the *same page-slot
//! budget*, so page claims — and therefore `OutOfPages` — fire at the
//! same append on every shard.  A lane that fails an append goes
//! *dead*: it skips its remaining compute but still arrives at every
//! remaining barrier (the per-layer barrier count is fixed, so no lane
//! can deadlock), and the first error by rank order is returned after
//! the dispatch drains.  Callers repair through the mirrored
//! [`KvShards`] ops exactly as the unsharded ladder does.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::attention::{attention_block_range, AttnScratch, RopeCache};
use super::kvcache::{KvArena, KvHandle, KvPrecision, KvShards,
                     OutOfPages, KV_PAGE};
use super::speculative::{SpecCapture, SpecConfig, SpecRound, SpecState};
use super::transformer::{argmax, record_block, record_slots, rmsnorm,
                         DecodeSlot, DecodeStats, Model,
                         MAX_PREFILL_BLOCK};
use super::weights::{LinearBackend, ModelConfig, LINEAR_NAMES};
use crate::mobiq::engine::{Precision, Scratch};
use crate::mobiq::gemv::SharedOut;
use crate::util::comm::{Communicator, InProcComm, InProcGroup};
use crate::util::simd;
use crate::util::threadpool::{SharedMut, ThreadPool};

// ---------------------------------------------------------------------------
// Partition plan
// ---------------------------------------------------------------------------

/// Contiguous range of shard `s` when `total` items are split over `n`
/// shards: every shard gets `total / n`, and the first `total % n`
/// shards carry one extra item — the **remainder rule** every
/// partition in the plan uses (kv heads, FFN channels, d_model
/// columns, vocab rows).  Ranges are contiguous, disjoint, and cover
/// `0..total` for any `n >= 1`.
pub fn shard_range(total: usize, n: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < n);
    let base = total / n;
    let rem = total % n;
    let lo = s * base + s.min(rem);
    let hi = lo + base + usize::from(s < rem);
    (lo, hi)
}

/// Static partition map of one model shape over `n_shards` shards.
/// Attention is split at **kv-head granularity** (whole GQA groups:
/// shard `s` owns kv heads `kv[s]` and therefore query heads
/// `heads[s] = (kv.0 * rep, kv.1 * rep)`), so a query head and the kv
/// head it attends over always live on the same shard.  With
/// `n_kv_heads % n_shards != 0` the remainder rule above applies —
/// e.g. 3 kv heads over 2 shards is `[(0,2), (2,3)]`, and per-shard
/// byte budgets stay proportional while page-slot counts stay mirrored
/// (see [`KvShards`]).  FFN / d_model / vocab columns split
/// independently with the same rule.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub n_shards: usize,
    /// Per-shard kv-head range.
    pub kv: Vec<(usize, usize)>,
    /// Per-shard query-head range (`kv` scaled by the GQA group size).
    pub heads: Vec<(usize, usize)>,
    /// Per-shard output-column range of wo / w_down.
    pub d_model: Vec<(usize, usize)>,
    /// Per-shard output-channel range of w_gate / w_up (and the SwiGLU
    /// combine feeding w_down's shared input).
    pub d_ff: Vec<(usize, usize)>,
    /// Per-shard lm_head row range.
    pub vocab: Vec<(usize, usize)>,
}

impl ShardPlan {
    pub fn new(cfg: &ModelConfig, n_shards: usize) -> Result<ShardPlan> {
        anyhow::ensure!(
            n_shards >= 1 && n_shards <= cfg.n_kv_heads,
            "shard count must be in 1..={} (one whole kv head per \
             shard minimum), got {}",
            cfg.n_kv_heads, n_shards);
        let rep = cfg.n_heads / cfg.n_kv_heads;
        let kv: Vec<_> = (0..n_shards)
            .map(|s| shard_range(cfg.n_kv_heads, n_shards, s))
            .collect();
        let heads = kv.iter().map(|&(a, b)| (a * rep, b * rep)).collect();
        Ok(ShardPlan {
            n_shards,
            heads,
            kv,
            d_model: (0..n_shards)
                .map(|s| shard_range(cfg.d_model, n_shards, s))
                .collect(),
            d_ff: (0..n_shards)
                .map(|s| shard_range(cfg.d_ff, n_shards, s))
                .collect(),
            vocab: (0..n_shards)
                .map(|s| shard_range(cfg.vocab_size, n_shards, s))
                .collect(),
        })
    }

    /// Per-token f32 gather volume of one layer's two joins: join A
    /// publishes the attention context (wo input) and the wo output
    /// columns, join B the SwiGLU output (w_down input) and the w_down
    /// output columns.  The issue's canonical "2 joins x d_model x
    /// tokens" counts the two published d_model outputs; the inputs
    /// add `d_model + d_ff` because the gather join also publishes the
    /// join *inputs* (a reduce join would ship partials instead).
    pub fn join_elems_per_token(&self, cfg: &ModelConfig) -> usize {
        2 * cfg.d_model + cfg.d_model + cfg.d_ff
    }
}

// ---------------------------------------------------------------------------
// Per-lane state
// ---------------------------------------------------------------------------

/// One shard's private working set: replicated residual buffers, the
/// compact per-shard activation slices, its own kernel scratch (no
/// inner pool — the shard lanes *are* the parallelism) and, on rank 0,
/// the routing-bits log the main thread replays into the caller's
/// stats.
struct LaneState {
    engine: Scratch,
    attn: AttnScratch,
    /// Per-shard speculative capture (local kv width).
    cap: SpecCapture,
    /// Replicated residual stream, `(t, d)`.
    xs: Vec<f32>,
    /// Replicated norm output, `(t, d)`.
    xn: Vec<f32>,
    /// Full-width staging for the batched column kernels (`(t, d)` /
    /// `(t, dkv)` / `(t, d_ff)`): `forward_batch_range` writes at full
    /// stride, the compact copies below carve out this shard's span.
    qf: Vec<f32>,
    kf: Vec<f32>,
    vf: Vec<f32>,
    gf: Vec<f32>,
    uf: Vec<f32>,
    /// Compact per-shard slices: q `(t, local_heads * hd)`, k/v
    /// `(t, local_kv * hd)`.
    qc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    /// Rank 0 only: per-(layer, linear) effective bits of every token,
    /// indexed `li * 7 + lin` — routing is replicated, so shard 0's
    /// log equals what the unsharded path would have recorded.
    bits: Vec<Vec<usize>>,
    /// Set when this lane's arena rejected an append; the lane skips
    /// remaining compute but keeps arriving at barriers.
    dead: bool,
    err: Option<OutOfPages>,
}

impl LaneState {
    fn new(cfg: &ModelConfig) -> LaneState {
        LaneState {
            engine: Scratch::new(cfg.d_model.max(cfg.d_ff),
                                 cfg.group_size, cfg.router_hidden,
                                 cfg.n_slices),
            attn: AttnScratch::new(),
            cap: SpecCapture::new(),
            xs: Vec::new(),
            xn: Vec::new(),
            qf: Vec::new(),
            kf: Vec::new(),
            vf: Vec::new(),
            gf: Vec::new(),
            uf: Vec::new(),
            qc: Vec::new(),
            kc: Vec::new(),
            vc: Vec::new(),
            bits: Vec::new(),
            dead: false,
            err: None,
        }
    }

    fn ensure(&mut self, t: usize, cfg: &ModelConfig, lw: usize,
              lkv: usize) {
        let d = cfg.d_model;
        grow(&mut self.xs, t * d);
        grow(&mut self.xn, t * d);
        grow(&mut self.qf, t * d);
        grow(&mut self.kf, t * cfg.kv_dim());
        grow(&mut self.vf, t * cfg.kv_dim());
        grow(&mut self.gf, t * cfg.d_ff);
        grow(&mut self.uf, t * cfg.d_ff);
        grow(&mut self.qc, t * lw);
        grow(&mut self.kc, t * lkv);
        grow(&mut self.vc, t * lkv);
    }
}

fn grow(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

/// How a block forward surfaces logits (mirrors the `all_logits` /
/// `spec` modes of `Model::prefill_inner`).
#[derive(Clone, Copy, PartialEq)]
enum BlockMode {
    /// lm_head on the last token only; row 0 of the shared logits.
    Last,
    /// lm_head on every token; `(t, vocab)` rows in the shared logits.
    All,
    /// Speculative verify: `All` logits plus per-position KV commit
    /// and per-lane pre-RoPE K/V capture.
    Spec,
}

/// Tensor-parallel execution engine: a [`ShardPlan`], one
/// [`LaneState`] per shard, the shared gather buffers the barriers
/// publish, and the [`InProcGroup`] whose [`Communicator`] handles are
/// the only synchronization primitive the forward loops touch.
///
/// The public surface mirrors [`Model`]'s forward entry points
/// (`decode_step` / `prefill` / `decode_batch` / the speculative
/// round) over a [`KvShards`] store; every one is bit-identical to its
/// unsharded counterpart for any shard count (`tests/shard_parity.rs`).
pub struct ShardRuntime {
    group: InProcGroup,
    plan: ShardPlan,
    lanes: Vec<LaneState>,
    /// Shared RoPE tables (read-only inside a dispatch; grown by the
    /// main thread before lanes launch).
    rope: RopeCache,
    // Gather buffers published at the barriers: disjoint column spans
    // written per shard, full-width reads after the join.
    shared_ctx: Vec<f32>,
    shared_attn: Vec<f32>,
    shared_ff: Vec<f32>,
    shared_mlp: Vec<f32>,
    shared_logits: Vec<f32>,
}

impl ShardRuntime {
    /// Build a runtime for `model` over `n_shards` shards.  Reuses the
    /// model's worker pool when it has at least one lane per shard
    /// (ranks block in barriers, so each needs its own lane — see
    /// `util/comm.rs`), otherwise brings up a dedicated pool.
    ///
    /// Static-PTQ backends have no column-range kernels (they are
    /// baseline records, never served sharded) and are rejected here —
    /// which is what lets the range dispatch in `weights.rs` treat
    /// `Static` as unreachable.
    pub fn new(model: &Model, n_shards: usize) -> Result<ShardRuntime> {
        let cfg = &model.cfg;
        let plan = ShardPlan::new(cfg, n_shards)?;
        for (li, layer) in model.layers.iter().enumerate() {
            for name in LINEAR_NAMES {
                if matches!(layer.linear(name)?,
                            LinearBackend::Static(_)) {
                    bail!("layer {li} {name}: static-PTQ backends \
                           cannot run sharded");
                }
            }
        }
        if matches!(model.lm_head, LinearBackend::Static(_)) {
            bail!("lm_head: static-PTQ backends cannot run sharded");
        }
        let pool = match &model.pool {
            Some(p) if p.size() >= n_shards => Arc::clone(p),
            _ => Arc::new(ThreadPool::new(n_shards)),
        };
        Ok(ShardRuntime {
            group: InProcGroup::new(n_shards, pool),
            lanes: (0..n_shards).map(|_| LaneState::new(cfg)).collect(),
            plan,
            rope: RopeCache::new(cfg.head_dim(), cfg.rope_theta),
            shared_ctx: Vec::new(),
            shared_attn: Vec::new(),
            shared_ff: Vec::new(),
            shared_mlp: Vec::new(),
            shared_logits: Vec::new(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Per-shard KV arenas with the given page-slot budget, mirrored
    /// handles, per-shard byte budgets proportional to their kv heads
    /// (the slot counts are identical, so OOM fires at the same append
    /// on every shard).
    pub fn new_shards_with_pages(&self, model: &Model,
                                 capacity_pages: usize) -> KvShards {
        let c = &model.cfg;
        KvShards::new(self.plan.kv.iter()
            .map(|&(k0, k1)| KvArena::new(c.n_layers, c.max_seq_len,
                                          k1 - k0, c.head_dim(),
                                          capacity_pages))
            .collect())
    }

    /// Sharded analogue of [`Model::new_arena`]: budget for `n_seqs`
    /// full-context sequences (same page-slot count per shard as the
    /// unsharded arena, so the byte total is identical too).
    pub fn new_shards_arena(&self, model: &Model, n_seqs: usize)
                            -> KvShards {
        let c = &model.cfg;
        let pages = n_seqs.max(1) * c.n_layers
            * ((c.max_seq_len + KV_PAGE - 1) / KV_PAGE);
        self.new_shards_with_pages(model, pages)
    }

    /// Sharded analogue of [`Model::new_kv_at`].
    pub fn new_kv_at(&self, model: &Model, prec: KvPrecision)
                     -> (KvShards, KvHandle) {
        let mut kv = self.new_shards_arena(model, 1);
        let seq = kv.alloc_seq_at(prec);
        (kv, seq)
    }

    fn ensure_shared(&mut self, t: usize, cfg: &ModelConfig,
                     logit_rows: usize) {
        let d = cfg.d_model;
        grow(&mut self.shared_ctx, t * d);
        grow(&mut self.shared_attn, t * d);
        grow(&mut self.shared_ff, t * cfg.d_ff);
        grow(&mut self.shared_mlp, t * d);
        grow(&mut self.shared_logits, logit_rows * cfg.vocab_size);
    }

    /// Reset per-dispatch lane state (dead flags, rank-0 bits log) and
    /// size every lane's buffers.
    fn arm_lanes(&mut self, t: usize, cfg: &ModelConfig) {
        let n_rec = cfg.n_layers * LINEAR_NAMES.len();
        for (s, lane) in self.lanes.iter_mut().enumerate() {
            let (h0, h1) = self.plan.heads[s];
            let (k0, k1) = self.plan.kv[s];
            lane.ensure(t, cfg, (h1 - h0) * cfg.head_dim(),
                        (k1 - k0) * cfg.head_dim());
            lane.dead = false;
            lane.err = None;
            if s == 0 {
                lane.bits.resize(n_rec, Vec::new());
                for b in &mut lane.bits {
                    b.clear();
                }
            }
        }
    }

    /// First lane error by rank order (all lanes hit the same append
    /// deterministically — mirrored budgets — but rank order makes the
    /// pick well-defined regardless).
    fn take_err(&mut self) -> Option<OutOfPages> {
        self.lanes.iter_mut().find_map(|l| l.err.take())
    }

    /// Replay rank 0's routing log into a single stats accumulator in
    /// the exact order the unsharded path records (layer-major, linear
    /// 0..6, token-minor).
    fn replay_stats(&self, stats: &mut DecodeStats, cfg: &ModelConfig) {
        for li in 0..cfg.n_layers {
            for lin in 0..LINEAR_NAMES.len() {
                record_block(stats,
                             &self.lanes[0].bits[li * 7 + lin], li, lin,
                             cfg.slice_bits);
            }
        }
    }

    // -----------------------------------------------------------------
    // Token path (decode_step mirror)
    // -----------------------------------------------------------------

    /// Sharded [`Model::decode_step`]: logits land in the shared
    /// buffer (`self.shared_logits[..vocab]`), routing stats replay
    /// from shard 0's log.  Bit-identical to the unsharded step.
    fn decode_step_inner(&mut self, m: &Model, token: u32,
                         kv: &mut KvShards, seq: KvHandle,
                         precision: Precision,
                         stats: &mut DecodeStats) -> Result<()> {
        let c = &m.cfg;
        let d = c.d_model;
        let pos = kv.seq_len(seq);
        anyhow::ensure!(pos < c.max_seq_len, "sequence too long");
        anyhow::ensure!((token as usize) < c.vocab_size, "token oob");
        self.rope.ensure(pos + 1);
        self.ensure_shared(1, c, 1);
        self.arm_lanes(1, c);

        let hd = c.head_dim();
        let ctxp = SharedMut(self.shared_ctx.as_mut_ptr());
        let attnp = SharedMut(self.shared_attn.as_mut_ptr());
        let ffp = SharedMut(self.shared_ff.as_mut_ptr());
        let mlpp = SharedMut(self.shared_mlp.as_mut_ptr());
        let logp = SharedMut(self.shared_logits.as_mut_ptr());
        let lanesp = SharedMut(self.lanes.as_mut_ptr());
        let arenasp = SharedMut(kv.arenas_mut().as_mut_ptr());
        let plan = &self.plan;
        let rope = &self.rope;

        self.group.run(|comm: &InProcComm| {
            let r = comm.rank();
            // SAFETY: one rank per lane/arena index; disjoint &mut.
            let lane = unsafe { &mut *lanesp.0.add(r) };
            let arena = unsafe { &mut *arenasp.0.add(r) };
            let (h0, h1) = plan.heads[r];
            let (k0, k1) = plan.kv[r];
            let (m0, m1) = plan.d_model[r];
            let (f0, f1) = plan.d_ff[r];
            let (v0, v1) = plan.vocab[r];
            let (lw, lkv) = ((h1 - h0) * hd, (k1 - k0) * hd);
            let tok = token as usize;
            lane.xs[..d].copy_from_slice(&m.embed[tok * d..(tok + 1) * d]);

            for (li, layer) in m.layers.iter().enumerate() {
                if !lane.dead {
                    rmsnorm(&lane.xs[..d], &layer.attn_norm, c.norm_eps,
                            &mut lane.xn[..d]);
                    let b = layer.wq.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine,
                        h0 * hd, h1 * hd, &mut lane.qc[..lw]);
                    if r == 0 {
                        lane.bits[li * 7].push(b);
                    }
                    let b = layer.wk.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd, &mut lane.kc[..lkv]);
                    if r == 0 {
                        lane.bits[li * 7 + 1].push(b);
                    }
                    let b = layer.wv.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd, &mut lane.vc[..lkv]);
                    if r == 0 {
                        lane.bits[li * 7 + 2].push(b);
                    }
                    rope.apply(&mut lane.qc[..lw], pos);
                    match arena.append_kv_block(seq, li, rope,
                                                &lane.kc[..lkv],
                                                &lane.vc[..lkv], 1) {
                        Ok(_) => {
                            let view = arena.layer(seq, li);
                            attention_block_range(c, &lane.qc[..lw],
                                                  &view, pos, 1, h0, h1,
                                                  k0, &mut lane.attn,
                                                  &ctxp);
                        }
                        Err(e) => {
                            lane.err = Some(e);
                            lane.dead = true;
                        }
                    }
                }
                comm.barrier(); // join A entry: ctx columns published
                if !lane.dead {
                    let ctx_all = unsafe {
                        std::slice::from_raw_parts(ctxp.0, d)
                    };
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(attnp.0.add(m0),
                                                       m1 - m0)
                    };
                    let b = layer.wo.forward_token_range(
                        ctx_all, precision, &mut lane.engine, m0, m1,
                        out);
                    if r == 0 {
                        lane.bits[li * 7 + 3].push(b);
                    }
                }
                comm.barrier(); // join A exit: attn_out published
                if !lane.dead {
                    let attn_all = unsafe {
                        std::slice::from_raw_parts(attnp.0, d)
                    };
                    simd::add_assign(&mut lane.xs[..d], attn_all);
                    rmsnorm(&lane.xs[..d], &layer.mlp_norm, c.norm_eps,
                            &mut lane.xn[..d]);
                    let b = layer.w_gate.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine, f0,
                        f1, &mut lane.gf[..f1 - f0]);
                    if r == 0 {
                        lane.bits[li * 7 + 4].push(b);
                    }
                    let b = layer.w_up.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine, f0,
                        f1, &mut lane.uf[..f1 - f0]);
                    if r == 0 {
                        lane.bits[li * 7 + 5].push(b);
                    }
                    let ff_out = unsafe {
                        std::slice::from_raw_parts_mut(ffp.0.add(f0),
                                                       f1 - f0)
                    };
                    simd::swiglu_row(&lane.gf[..f1 - f0],
                                     &lane.uf[..f1 - f0], ff_out);
                }
                comm.barrier(); // join B entry: ff columns published
                if !lane.dead {
                    let ff_all = unsafe {
                        std::slice::from_raw_parts(ffp.0, c.d_ff)
                    };
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(mlpp.0.add(m0),
                                                       m1 - m0)
                    };
                    let b = layer.w_down.forward_token_range(
                        ff_all, precision, &mut lane.engine, m0, m1,
                        out);
                    if r == 0 {
                        lane.bits[li * 7 + 6].push(b);
                    }
                }
                comm.barrier(); // join B exit: mlp_out published
                if !lane.dead {
                    let mlp_all = unsafe {
                        std::slice::from_raw_parts(mlpp.0, d)
                    };
                    simd::add_assign(&mut lane.xs[..d], mlp_all);
                }
            }
            if !lane.dead {
                rmsnorm(&lane.xs[..d], &m.final_norm, c.norm_eps,
                        &mut lane.xn[..d]);
                let out = unsafe {
                    std::slice::from_raw_parts_mut(logp.0.add(v0),
                                                   v1 - v0)
                };
                m.lm_head.forward_token_range(&lane.xn[..d], precision,
                                              &mut lane.engine, v0, v1,
                                              out);
            }
        });

        self.replay_stats(stats, c);
        if let Some(e) = self.take_err() {
            return Err(e.into());
        }
        stats.tokens += 1;
        Ok(())
    }

    /// Sharded [`Model::decode_step`]; `logits` receives the
    /// vocab-wide row.
    pub fn decode_step(&mut self, m: &Model, token: u32,
                       kv: &mut KvShards, seq: KvHandle,
                       precision: Precision, stats: &mut DecodeStats,
                       logits: &mut [f32]) -> Result<()> {
        self.decode_step_inner(m, token, kv, seq, precision, stats)?;
        logits.copy_from_slice(
            &self.shared_logits[..m.cfg.vocab_size]);
        Ok(())
    }

    /// Sharded [`Model::greedy_step`].
    pub fn greedy_step(&mut self, m: &Model, token: u32,
                       kv: &mut KvShards, seq: KvHandle,
                       precision: Precision, stats: &mut DecodeStats)
                       -> Result<u32> {
        self.decode_step_inner(m, token, kv, seq, precision, stats)?;
        Ok(argmax(&self.shared_logits[..m.cfg.vocab_size]) as u32)
    }

    // -----------------------------------------------------------------
    // Block path (prefill_inner mirror)
    // -----------------------------------------------------------------

    /// Sharded `Model::prefill_inner`: one token block through the
    /// four-barrier layer protocol with batched column kernels.  On
    /// return the shared logits hold the last row (`BlockMode::Last`)
    /// or all `t` rows (`All` / `Spec`); `Spec` additionally commits
    /// KV per position and captures pre-RoPE K/V into each lane's
    /// local-width [`SpecCapture`].
    fn block_forward(&mut self, m: &Model, tokens: &[u32],
                     kv: &mut KvShards, seq: KvHandle,
                     precision: Precision, stats: &mut DecodeStats,
                     mode: BlockMode) -> Result<()> {
        let c = &m.cfg;
        let t = tokens.len();
        if t == 0 {
            return Ok(());
        }
        let d = c.d_model;
        let dkv = c.kv_dim();
        let d_ff = c.d_ff;
        let pos0 = kv.seq_len(seq);
        anyhow::ensure!(pos0 + t <= c.max_seq_len, "sequence too long");
        for &tok in tokens {
            anyhow::ensure!((tok as usize) < c.vocab_size, "token oob");
        }
        self.rope.ensure(pos0 + t);
        let logit_rows = if mode == BlockMode::Last { 1 } else { t };
        self.ensure_shared(t, c, logit_rows);
        self.arm_lanes(t, c);

        let hd = c.head_dim();
        let n_layers = c.n_layers;
        let ctxp = SharedMut(self.shared_ctx.as_mut_ptr());
        let attnp = SharedMut(self.shared_attn.as_mut_ptr());
        let ffp = SharedMut(self.shared_ff.as_mut_ptr());
        let mlpp = SharedMut(self.shared_mlp.as_mut_ptr());
        let logp = SharedMut(self.shared_logits.as_mut_ptr());
        let lanesp = SharedMut(self.lanes.as_mut_ptr());
        let arenasp = SharedMut(kv.arenas_mut().as_mut_ptr());
        let plan = &self.plan;
        let rope = &self.rope;

        self.group.run(|comm: &InProcComm| {
            let r = comm.rank();
            // SAFETY: one rank per lane/arena index; disjoint &mut.
            let lane = unsafe { &mut *lanesp.0.add(r) };
            let arena = unsafe { &mut *arenasp.0.add(r) };
            let (h0, h1) = plan.heads[r];
            let (k0, k1) = plan.kv[r];
            let (m0, m1) = plan.d_model[r];
            let (f0, f1) = plan.d_ff[r];
            let (v0, v1) = plan.vocab[r];
            let (lw, lkv) = ((h1 - h0) * hd, (k1 - k0) * hd);
            if mode == BlockMode::Spec {
                lane.cap.begin(n_layers, t, lkv);
            }
            for (i, &tok) in tokens.iter().enumerate() {
                let e = tok as usize * d;
                lane.xs[i * d..(i + 1) * d]
                    .copy_from_slice(&m.embed[e..e + d]);
            }

            for (li, layer) in m.layers.iter().enumerate() {
                if !lane.dead {
                    for i in 0..t {
                        rmsnorm(&lane.xs[i * d..(i + 1) * d],
                                &layer.attn_norm, c.norm_eps,
                                &mut lane.xn[i * d..(i + 1) * d]);
                    }
                    let qout = SharedOut(lane.qf.as_mut_ptr());
                    layer.wq.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        h0 * hd, h1 * hd, &qout);
                    if r == 0 {
                        lane.bits[li * 7]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    let kout = SharedOut(lane.kf.as_mut_ptr());
                    layer.wk.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd, &kout);
                    if r == 0 {
                        lane.bits[li * 7 + 1]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    let vout = SharedOut(lane.vf.as_mut_ptr());
                    layer.wv.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd, &vout);
                    if r == 0 {
                        lane.bits[li * 7 + 2]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    // carve this shard's compact activation slices out
                    // of the full-stride staging buffers
                    for i in 0..t {
                        lane.qc[i * lw..(i + 1) * lw].copy_from_slice(
                            &lane.qf[i * d + h0 * hd..][..lw]);
                        lane.kc[i * lkv..(i + 1) * lkv].copy_from_slice(
                            &lane.kf[i * dkv + k0 * hd..][..lkv]);
                        lane.vc[i * lkv..(i + 1) * lkv].copy_from_slice(
                            &lane.vf[i * dkv + k0 * hd..][..lkv]);
                    }
                    if mode == BlockMode::Spec {
                        // verify mode: capture pre-RoPE K/V, then
                        // append + attend one position at a time —
                        // decode_step append granularity, so quantized
                        // page scales retrace the straight-line
                        // trajectory (see Model::prefill_inner).
                        lane.cap.save_layer(li, &lane.kc[..t * lkv],
                                            &lane.vc[..t * lkv]);
                        for i in 0..t {
                            let pos = pos0 + i;
                            rope.apply(
                                &mut lane.qc[i * lw..(i + 1) * lw],
                                pos);
                            match arena.append_kv_block(
                                seq, li, rope,
                                &lane.kc[i * lkv..(i + 1) * lkv],
                                &lane.vc[i * lkv..(i + 1) * lkv], 1) {
                                Ok(_) => {
                                    let view = arena.layer(seq, li);
                                    let crow = SharedMut(unsafe {
                                        ctxp.0.add(i * d)
                                    });
                                    attention_block_range(
                                        c,
                                        &lane.qc[i * lw..(i + 1) * lw],
                                        &view, pos, 1, h0, h1, k0,
                                        &mut lane.attn, &crow);
                                }
                                Err(e) => {
                                    lane.err = Some(e);
                                    lane.dead = true;
                                    break;
                                }
                            }
                        }
                    } else {
                        for i in 0..t {
                            rope.apply(
                                &mut lane.qc[i * lw..(i + 1) * lw],
                                pos0 + i);
                        }
                        match arena.append_kv_block(seq, li, rope,
                                                    &lane.kc[..t * lkv],
                                                    &lane.vc[..t * lkv],
                                                    t) {
                            Ok(_) => {
                                let view = arena.layer(seq, li);
                                attention_block_range(
                                    c, &lane.qc[..t * lw], &view, pos0,
                                    t, h0, h1, k0, &mut lane.attn,
                                    &ctxp);
                            }
                            Err(e) => {
                                lane.err = Some(e);
                                lane.dead = true;
                            }
                        }
                    }
                }
                comm.barrier(); // join A entry: ctx columns published
                if !lane.dead {
                    let ctx_all = unsafe {
                        std::slice::from_raw_parts(ctxp.0, t * d)
                    };
                    layer.wo.forward_batch_range(
                        ctx_all, precision, &mut lane.engine, m0, m1,
                        &SharedOut(attnp.0));
                    if r == 0 {
                        lane.bits[li * 7 + 3]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                }
                comm.barrier(); // join A exit: attn_out published
                if !lane.dead {
                    let attn_all = unsafe {
                        std::slice::from_raw_parts(attnp.0, t * d)
                    };
                    simd::add_assign(&mut lane.xs[..t * d], attn_all);
                    for i in 0..t {
                        rmsnorm(&lane.xs[i * d..(i + 1) * d],
                                &layer.mlp_norm, c.norm_eps,
                                &mut lane.xn[i * d..(i + 1) * d]);
                    }
                    let gout = SharedOut(lane.gf.as_mut_ptr());
                    layer.w_gate.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        f0, f1, &gout);
                    if r == 0 {
                        lane.bits[li * 7 + 4]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    let uout = SharedOut(lane.uf.as_mut_ptr());
                    layer.w_up.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        f0, f1, &uout);
                    if r == 0 {
                        lane.bits[li * 7 + 5]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    for i in 0..t {
                        let g = &lane.gf[i * d_ff + f0..][..f1 - f0];
                        let u = &lane.uf[i * d_ff + f0..][..f1 - f0];
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(
                                ffp.0.add(i * d_ff + f0), f1 - f0)
                        };
                        simd::swiglu_row(g, u, out);
                    }
                }
                comm.barrier(); // join B entry: ff columns published
                if !lane.dead {
                    let ff_all = unsafe {
                        std::slice::from_raw_parts(ffp.0, t * d_ff)
                    };
                    layer.w_down.forward_batch_range(
                        ff_all, precision, &mut lane.engine, m0, m1,
                        &SharedOut(mlpp.0));
                    if r == 0 {
                        lane.bits[li * 7 + 6]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                }
                comm.barrier(); // join B exit: mlp_out published
                if !lane.dead {
                    let mlp_all = unsafe {
                        std::slice::from_raw_parts(mlpp.0, t * d)
                    };
                    simd::add_assign(&mut lane.xs[..t * d], mlp_all);
                }
            }
            if !lane.dead {
                if mode == BlockMode::Last {
                    rmsnorm(&lane.xs[(t - 1) * d..t * d],
                            &m.final_norm, c.norm_eps,
                            &mut lane.xn[..d]);
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(logp.0.add(v0),
                                                       v1 - v0)
                    };
                    m.lm_head.forward_token_range(
                        &lane.xn[..d], precision, &mut lane.engine, v0,
                        v1, out);
                } else {
                    for i in 0..t {
                        rmsnorm(&lane.xs[i * d..(i + 1) * d],
                                &m.final_norm, c.norm_eps,
                                &mut lane.xn[i * d..(i + 1) * d]);
                    }
                    m.lm_head.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        v0, v1, &SharedOut(logp.0));
                }
            }
        });

        self.replay_stats(stats, c);
        if let Some(e) = self.take_err() {
            return Err(e.into());
        }
        stats.tokens += t as u64;
        Ok(())
    }

    /// Sharded [`Model::prefill`]; the last token's logits land in
    /// `logits` (vocab-wide; untouched when `tokens` is empty).
    pub fn prefill(&mut self, m: &Model, tokens: &[u32],
                   kv: &mut KvShards, seq: KvHandle,
                   precision: Precision, stats: &mut DecodeStats,
                   logits: &mut [f32]) -> Result<()> {
        for chunk in tokens.chunks(MAX_PREFILL_BLOCK) {
            self.block_forward(m, chunk, kv, seq, precision, stats,
                               BlockMode::Last)?;
        }
        if !tokens.is_empty() {
            logits.copy_from_slice(
                &self.shared_logits[..m.cfg.vocab_size]);
        }
        Ok(())
    }

    /// Sharded [`Model::prefill_logits`]: appends every token's logits
    /// row to `out`.
    pub fn prefill_logits(&mut self, m: &Model, tokens: &[u32],
                          kv: &mut KvShards, seq: KvHandle,
                          precision: Precision, stats: &mut DecodeStats,
                          out: &mut Vec<f32>) -> Result<()> {
        let v = m.cfg.vocab_size;
        for chunk in tokens.chunks(MAX_PREFILL_BLOCK) {
            self.block_forward(m, chunk, kv, seq, precision, stats,
                               BlockMode::All)?;
            out.extend_from_slice(&self.shared_logits[..chunk.len() * v]);
        }
        Ok(())
    }

    /// Sharded [`Model::greedy_prefill`].
    pub fn greedy_prefill(&mut self, m: &Model, tokens: &[u32],
                          kv: &mut KvShards, seq: KvHandle,
                          precision: Precision, stats: &mut DecodeStats)
                          -> Result<u32> {
        anyhow::ensure!(!tokens.is_empty(),
                        "greedy prefill needs at least one token");
        for chunk in tokens.chunks(MAX_PREFILL_BLOCK) {
            self.block_forward(m, chunk, kv, seq, precision, stats,
                               BlockMode::Last)?;
        }
        Ok(argmax(&self.shared_logits[..m.cfg.vocab_size]) as u32)
    }

    /// Sharded [`Model::forward_logits`].
    pub fn forward_logits(&mut self, m: &Model, tokens: &[u32],
                          precision: Precision) -> Result<Vec<f32>> {
        let (mut kv, seq) = self.new_kv_at(m, KvPrecision::F32);
        let mut stats = DecodeStats::new(m.cfg.n_layers);
        let mut out =
            Vec::with_capacity(tokens.len() * m.cfg.vocab_size);
        self.prefill_logits(m, tokens, &mut kv, seq, precision,
                            &mut stats, &mut out)?;
        Ok(out)
    }

    /// Sharded [`Model::resume`].
    pub fn resume(&mut self, m: &Model, tokens: &[u32],
                  kv: &mut KvShards, seq: KvHandle,
                  precision: Precision, stats: &mut DecodeStats)
                  -> Result<u32> {
        anyhow::ensure!(!tokens.is_empty(),
                        "resume needs at least one token");
        anyhow::ensure!(kv.seq_len(seq) == 0,
                        "resume target must be a fresh sequence");
        self.greedy_prefill(m, tokens, kv, seq, precision, stats)
    }

    /// Sharded [`Model::generate`].
    pub fn generate(&mut self, m: &Model, prompt: &[u32], n_new: usize,
                    precision: Precision, stats: &mut DecodeStats)
                    -> Result<Vec<u32>> {
        self.generate_at(m, prompt, n_new, precision, KvPrecision::F32,
                         stats)
    }

    /// Sharded [`Model::generate_at`].
    pub fn generate_at(&mut self, m: &Model, prompt: &[u32],
                       n_new: usize, precision: Precision,
                       kv_prec: KvPrecision, stats: &mut DecodeStats)
                       -> Result<Vec<u32>> {
        let (mut kv, seq) = self.new_kv_at(m, kv_prec);
        let mut toks = prompt.to_vec();
        if n_new == 0 || prompt.is_empty() {
            return Ok(toks);
        }
        let mut last = self.greedy_prefill(m, prompt, &mut kv, seq,
                                           precision, stats)?;
        toks.push(last);
        for _ in 1..n_new {
            last = self.greedy_step(m, last, &mut kv, seq, precision,
                                    stats)?;
            toks.push(last);
        }
        Ok(toks)
    }

    // -----------------------------------------------------------------
    // Coalesced decode (decode_batch mirror)
    // -----------------------------------------------------------------

    /// Sharded [`Model::decode_batch`]: every slot advances one token
    /// through the four-barrier protocol; per-slot logits rows land in
    /// `logits` (`(n_slots, vocab)` row-major, grown as needed) and
    /// per-slot routing stats replay from shard 0's log.
    pub fn decode_batch(&mut self, m: &Model, slots: &mut [DecodeSlot],
                        kv: &mut KvShards, precision: Precision,
                        logits: &mut Vec<f32>) -> Result<()> {
        let c = &m.cfg;
        let t = slots.len();
        if t == 0 {
            return Ok(());
        }
        let d = c.d_model;
        let dkv = c.kv_dim();
        let d_ff = c.d_ff;
        let mut max_pos = 0usize;
        for s in slots.iter() {
            let len = kv.seq_len(s.seq);
            anyhow::ensure!(len < c.max_seq_len, "sequence too long");
            anyhow::ensure!((s.token as usize) < c.vocab_size,
                            "token oob");
            max_pos = max_pos.max(len);
        }
        self.rope.ensure(max_pos + 1);
        self.ensure_shared(t, c, t);
        self.arm_lanes(t, c);
        let ids: Vec<u32> = slots.iter().map(|s| s.token).collect();
        let seqs: Vec<KvHandle> = slots.iter().map(|s| s.seq).collect();

        let hd = c.head_dim();
        let ctxp = SharedMut(self.shared_ctx.as_mut_ptr());
        let attnp = SharedMut(self.shared_attn.as_mut_ptr());
        let ffp = SharedMut(self.shared_ff.as_mut_ptr());
        let mlpp = SharedMut(self.shared_mlp.as_mut_ptr());
        let logp = SharedMut(self.shared_logits.as_mut_ptr());
        let lanesp = SharedMut(self.lanes.as_mut_ptr());
        let arenasp = SharedMut(kv.arenas_mut().as_mut_ptr());
        let plan = &self.plan;
        let rope = &self.rope;

        self.group.run(|comm: &InProcComm| {
            let r = comm.rank();
            // SAFETY: one rank per lane/arena index; disjoint &mut.
            let lane = unsafe { &mut *lanesp.0.add(r) };
            let arena = unsafe { &mut *arenasp.0.add(r) };
            let (h0, h1) = plan.heads[r];
            let (k0, k1) = plan.kv[r];
            let (m0, m1) = plan.d_model[r];
            let (f0, f1) = plan.d_ff[r];
            let (v0, v1) = plan.vocab[r];
            let (lw, lkv) = ((h1 - h0) * hd, (k1 - k0) * hd);
            for (i, &tok) in ids.iter().enumerate() {
                let e = tok as usize * d;
                lane.xs[i * d..(i + 1) * d]
                    .copy_from_slice(&m.embed[e..e + d]);
            }

            for (li, layer) in m.layers.iter().enumerate() {
                if !lane.dead {
                    for i in 0..t {
                        rmsnorm(&lane.xs[i * d..(i + 1) * d],
                                &layer.attn_norm, c.norm_eps,
                                &mut lane.xn[i * d..(i + 1) * d]);
                    }
                    layer.wq.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        h0 * hd, h1 * hd,
                        &SharedOut(lane.qf.as_mut_ptr()));
                    if r == 0 {
                        lane.bits[li * 7]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    layer.wk.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd,
                        &SharedOut(lane.kf.as_mut_ptr()));
                    if r == 0 {
                        lane.bits[li * 7 + 1]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    layer.wv.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        k0 * hd, k1 * hd,
                        &SharedOut(lane.vf.as_mut_ptr()));
                    if r == 0 {
                        lane.bits[li * 7 + 2]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    for i in 0..t {
                        lane.qc[i * lw..(i + 1) * lw].copy_from_slice(
                            &lane.qf[i * d + h0 * hd..][..lw]);
                        lane.kc[i * lkv..(i + 1) * lkv].copy_from_slice(
                            &lane.kf[i * dkv + k0 * hd..][..lkv]);
                        lane.vc[i * lkv..(i + 1) * lkv].copy_from_slice(
                            &lane.vf[i * dkv + k0 * hd..][..lkv]);
                    }
                    // land every slot's fresh K/V (the slot's position
                    // at this layer is the layer's own table length —
                    // see Model::decode_batch), then attend per slot
                    // over this shard's heads
                    for i in 0..t {
                        let pos = arena.layer_len(seqs[i], li);
                        rope.apply(&mut lane.qc[i * lw..(i + 1) * lw],
                                   pos);
                        if let Err(e) = arena.append_kv_block(
                            seqs[i], li, rope,
                            &lane.kc[i * lkv..(i + 1) * lkv],
                            &lane.vc[i * lkv..(i + 1) * lkv], 1) {
                            lane.err = Some(e);
                            lane.dead = true;
                            break;
                        }
                    }
                    if !lane.dead {
                        for i in 0..t {
                            let view = arena.layer(seqs[i], li);
                            let pos = arena.layer_len(seqs[i], li) - 1;
                            let crow =
                                SharedMut(unsafe { ctxp.0.add(i * d) });
                            attention_block_range(
                                c, &lane.qc[i * lw..(i + 1) * lw],
                                &view, pos, 1, h0, h1, k0,
                                &mut lane.attn, &crow);
                        }
                    }
                }
                comm.barrier(); // join A entry
                if !lane.dead {
                    let ctx_all = unsafe {
                        std::slice::from_raw_parts(ctxp.0, t * d)
                    };
                    layer.wo.forward_batch_range(
                        ctx_all, precision, &mut lane.engine, m0, m1,
                        &SharedOut(attnp.0));
                    if r == 0 {
                        lane.bits[li * 7 + 3]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                }
                comm.barrier(); // join A exit
                if !lane.dead {
                    let attn_all = unsafe {
                        std::slice::from_raw_parts(attnp.0, t * d)
                    };
                    simd::add_assign(&mut lane.xs[..t * d], attn_all);
                    for i in 0..t {
                        rmsnorm(&lane.xs[i * d..(i + 1) * d],
                                &layer.mlp_norm, c.norm_eps,
                                &mut lane.xn[i * d..(i + 1) * d]);
                    }
                    layer.w_gate.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        f0, f1, &SharedOut(lane.gf.as_mut_ptr()));
                    if r == 0 {
                        lane.bits[li * 7 + 4]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    layer.w_up.forward_batch_range(
                        &lane.xn[..t * d], precision, &mut lane.engine,
                        f0, f1, &SharedOut(lane.uf.as_mut_ptr()));
                    if r == 0 {
                        lane.bits[li * 7 + 5]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                    for i in 0..t {
                        let g = &lane.gf[i * d_ff + f0..][..f1 - f0];
                        let u = &lane.uf[i * d_ff + f0..][..f1 - f0];
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(
                                ffp.0.add(i * d_ff + f0), f1 - f0)
                        };
                        simd::swiglu_row(g, u, out);
                    }
                }
                comm.barrier(); // join B entry
                if !lane.dead {
                    let ff_all = unsafe {
                        std::slice::from_raw_parts(ffp.0, t * d_ff)
                    };
                    layer.w_down.forward_batch_range(
                        ff_all, precision, &mut lane.engine, m0, m1,
                        &SharedOut(mlpp.0));
                    if r == 0 {
                        lane.bits[li * 7 + 6]
                            .extend_from_slice(&lane.engine.batch.bits);
                    }
                }
                comm.barrier(); // join B exit
                if !lane.dead {
                    let mlp_all = unsafe {
                        std::slice::from_raw_parts(mlpp.0, t * d)
                    };
                    simd::add_assign(&mut lane.xs[..t * d], mlp_all);
                }
            }
            if !lane.dead {
                for i in 0..t {
                    rmsnorm(&lane.xs[i * d..(i + 1) * d],
                            &m.final_norm, c.norm_eps,
                            &mut lane.xn[i * d..(i + 1) * d]);
                }
                m.lm_head.forward_batch_range(
                    &lane.xn[..t * d], precision, &mut lane.engine, v0,
                    v1, &SharedOut(logp.0));
            }
        });

        // replay shard 0's per-token bits into each slot's own stats
        for li in 0..c.n_layers {
            for lin in 0..LINEAR_NAMES.len() {
                record_slots(slots, &self.lanes[0].bits[li * 7 + lin],
                             li, lin, c.slice_bits);
            }
        }
        if let Some(e) = self.take_err() {
            return Err(e.into());
        }
        for s in slots.iter_mut() {
            s.stats.tokens += 1;
        }
        let v = c.vocab_size;
        if logits.len() < t * v {
            logits.resize(t * v, 0.0);
        }
        logits[..t * v].copy_from_slice(&self.shared_logits[..t * v]);
        Ok(())
    }

    // -----------------------------------------------------------------
    // Speculative decoding under shards
    // -----------------------------------------------------------------

    /// Sharded [`Model::verify_logits`]: batched linears,
    /// per-position KV commit, per-lane pre-RoPE K/V capture; appends
    /// every row's logits to `out`.
    pub fn verify_logits(&mut self, m: &Model, tokens: &[u32],
                         kv: &mut KvShards, seq: KvHandle,
                         precision: Precision, stats: &mut DecodeStats,
                         out: &mut Vec<f32>) -> Result<()> {
        anyhow::ensure!(tokens.len() <= MAX_PREFILL_BLOCK,
                        "verify block exceeds MAX_PREFILL_BLOCK");
        self.block_forward(m, tokens, kv, seq, precision, stats,
                           BlockMode::Spec)?;
        out.extend_from_slice(
            &self.shared_logits[..tokens.len() * m.cfg.vocab_size]);
        Ok(())
    }

    /// Sharded [`Model::verify_commit`]: same accept loop, same
    /// rollback discipline, with mirrored checkpoints and each lane
    /// re-committing accepted rows from its own local-width capture in
    /// the identical position-outer / layer-inner order.
    pub fn verify_commit(&mut self, m: &Model, last: u32,
                         drafts: &[u32], kv: &mut KvShards,
                         seq: KvHandle, precision: Precision,
                         stats: &mut DecodeStats) -> Result<SpecRound> {
        let c = &m.cfg;
        let k = drafts.len();
        anyhow::ensure!(k + 1 <= MAX_PREFILL_BLOCK,
                        "draft window exceeds MAX_PREFILL_BLOCK");
        let len0 = kv.seq_len(seq);
        anyhow::ensure!(len0 + k + 1 <= c.max_seq_len,
                        "speculative window exceeds the context");
        let cks = kv.checkpoint_seq(seq);
        let mut fed = Vec::with_capacity(k + 1);
        fed.push(last);
        fed.extend_from_slice(drafts);
        if let Err(e) = self.block_forward(m, &fed, kv, seq, precision,
                                           stats, BlockMode::Spec) {
            kv.rollback_seq(seq, &cks);
            return Err(e);
        }
        let vocab = c.vocab_size;
        let logits = &self.shared_logits;
        let mut matched = 0usize;
        while matched < k {
            let next =
                argmax(&logits[matched * vocab..(matched + 1) * vocab]);
            if next as u32 == drafts[matched] {
                matched += 1;
            } else {
                break;
            }
        }
        let mut tokens = Vec::with_capacity(matched + 1);
        tokens.extend_from_slice(&drafts[..matched]);
        tokens.push(
            argmax(&logits[matched * vocab..(matched + 1) * vocab])
                as u32,
        );
        if matched < k {
            // roll every shard back, then re-commit the accepted
            // positions from each lane's capture — position-outer,
            // layer-inner, exactly the unsharded append order per
            // arena
            kv.rollback_seq(seq, &cks);
            for i in 0..=matched {
                for li in 0..c.n_layers {
                    for (s, arena) in
                        kv.arenas_mut().iter_mut().enumerate() {
                        let cap = &self.lanes[s].cap;
                        if let Err(e) = arena.append_kv_block(
                            seq, li, &self.rope, cap.k_row(li, i),
                            cap.v_row(li, i), 1) {
                            kv.rollback_seq(seq, &cks);
                            return Err(e.into());
                        }
                    }
                }
            }
        }
        Ok(SpecRound { drafted: k, matched, tokens })
    }

    /// Sharded [`Model::speculate_round`].
    pub fn speculate_round(&mut self, m: &Model, last: u32,
                           kv: &mut KvShards, seq: KvHandle,
                           precision: Precision,
                           draft_precision: Precision, k: usize,
                           stats: &mut DecodeStats,
                           draft_stats: &mut DecodeStats)
                           -> Result<SpecRound> {
        let len0 = kv.seq_len(seq);
        let k = k
            .min(m.cfg.max_seq_len.saturating_sub(len0 + 1))
            .min(MAX_PREFILL_BLOCK - 1);
        let mut drafts = Vec::with_capacity(k);
        if k > 0 {
            let cks = kv.checkpoint_seq(seq);
            let mut cur = last;
            for _ in 0..k {
                match self.greedy_step(m, cur, kv, seq,
                                       draft_precision, draft_stats) {
                    Ok(next) => {
                        drafts.push(next);
                        cur = next;
                    }
                    Err(e) => {
                        kv.rollback_seq(seq, &cks);
                        return Err(e);
                    }
                }
            }
            kv.rollback_seq(seq, &cks);
        }
        self.verify_commit(m, last, &drafts, kv, seq, precision, stats)
    }

    /// Sharded [`Model::generate_speculative`].
    pub fn generate_speculative(&mut self, m: &Model, prompt: &[u32],
                                n_new: usize, precision: Precision,
                                kv_prec: KvPrecision, cfg: &SpecConfig,
                                stats: &mut DecodeStats,
                                state: &mut SpecState)
                                -> Result<Vec<u32>> {
        let (mut kv, seq) = self.new_kv_at(m, kv_prec);
        let mut toks = prompt.to_vec();
        if n_new == 0 || prompt.is_empty() {
            return Ok(toks);
        }
        let mut last = self.greedy_prefill(m, prompt, &mut kv, seq,
                                           precision, stats)?;
        toks.push(last);
        let mut generated = 1usize;
        while generated < n_new {
            let k = state.k.min(n_new - generated - 1);
            let draft_precision = state.draft_precision(cfg);
            let round = self.speculate_round(
                m, last, &mut kv, seq, precision, draft_precision, k,
                stats, &mut state.draft_stats)?;
            debug_assert_eq!(round.tokens.len(), round.matched + 1);
            toks.extend_from_slice(&round.tokens);
            generated += round.tokens.len();
            last = *round.tokens.last().expect("round commits >= 1");
            state.observe(cfg, round.drafted, round.matched,
                          round.tokens.len());
        }
        debug_assert_eq!(toks.len(), prompt.len() + n_new);
        Ok(toks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::synth_model_shaped;

    #[test]
    fn shard_range_covers_contiguously() {
        for total in [1usize, 2, 3, 5, 7, 8, 64, 100] {
            for n in 1..=total.min(9) {
                let mut next = 0usize;
                for s in 0..n {
                    let (lo, hi) = shard_range(total, n, s);
                    assert_eq!(lo, next, "gap at shard {s}/{n}");
                    assert!(hi > lo, "empty shard {s}/{n} of {total}");
                    // remainder rule: first `total % n` shards get one
                    // extra
                    let want = total / n + usize::from(s < total % n);
                    assert_eq!(hi - lo, want);
                    next = hi;
                }
                assert_eq!(next, total);
            }
        }
    }

    #[test]
    fn plan_keeps_gqa_groups_whole() {
        let m = synth_model_shaped(7, 6, 3, 64);
        let plan = ShardPlan::new(&m.cfg, 2).unwrap();
        // 3 kv heads over 2 shards: remainder shard 0 takes 2
        assert_eq!(plan.kv, vec![(0, 2), (2, 3)]);
        // rep = 2 query heads per kv head, scaled ranges
        assert_eq!(plan.heads, vec![(0, 4), (4, 6)]);
        let elems = plan.join_elems_per_token(&m.cfg);
        assert_eq!(elems, 3 * m.cfg.d_model + m.cfg.d_ff);
    }

    #[test]
    fn plan_rejects_bad_shard_counts() {
        let m = synth_model_shaped(7, 4, 2, 64);
        assert!(ShardPlan::new(&m.cfg, 0).is_err());
        assert!(ShardPlan::new(&m.cfg, 3).is_err(), "3 > n_kv_heads");
        assert!(ShardPlan::new(&m.cfg, 1).is_ok());
        assert!(ShardPlan::new(&m.cfg, 2).is_ok());
    }

    #[test]
    fn static_backend_rejected() {
        let m = synth_model_shaped(3, 4, 2, 64);
        assert!(ShardRuntime::new(&m, 2).is_ok());
        // (static backends only come from bundles; synth models are
        // Mobiq + Dense, so the accept path is what's checkable here)
    }

    /// One shard == the unsharded model, bit for bit: the sharded
    /// protocol with N = 1 runs the same kernels over full ranges.
    #[test]
    fn single_shard_matches_unsharded() {
        let m = synth_model_shaped(11, 4, 2, 96);
        let prec = Precision::elastic(4.0);
        let toks: Vec<u32> = (0..40u32).map(|i| (i * 7 + 3) % 256)
            .collect();
        let want = m.forward_logits(&toks, prec).unwrap();
        let mut rt = ShardRuntime::new(&m, 1).unwrap();
        let got = rt.forward_logits(&m, &toks, prec).unwrap();
        assert_eq!(want, got, "single-shard logits must be bitwise \
                               equal to the unsharded path");

        let mut st_a = DecodeStats::new(m.cfg.n_layers);
        let mut st_b = DecodeStats::new(m.cfg.n_layers);
        let a = m.generate(&toks[..9], 12, prec, &mut st_a).unwrap();
        let b = rt.generate(&m, &toks[..9], 12, prec, &mut st_b)
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(st_a.total_bits, st_b.total_bits,
                   "stats replay must match direct recording");
        assert_eq!(st_a.bits_hist, st_b.bits_hist);
        assert_eq!(st_a.tokens, st_b.tokens);
    }

    /// Two shards == one shard (== unsharded), including a GQA
    /// remainder split (3 kv heads over 2 shards).
    #[test]
    fn two_shards_match_single() {
        for (nh, nkv) in [(4usize, 2usize), (6, 3)] {
            let m = synth_model_shaped(13, nh, nkv, 96);
            let prec = Precision::elastic(4.0);
            let toks: Vec<u32> = (0..33u32).map(|i| (i * 11 + 5) % 256)
                .collect();
            let want = m.forward_logits(&toks, prec).unwrap();
            let mut rt = ShardRuntime::new(&m, 2).unwrap();
            let got = rt.forward_logits(&m, &toks, prec).unwrap();
            assert_eq!(want, got,
                       "{nh}/{nkv} heads over 2 shards diverged");
        }
    }
}
