//! Substrate utilities built from scratch (no external crates beyond
//! `xla`/`anyhow` are vendored in this environment).

pub mod bench;
pub mod cli;
pub mod comm;
pub mod json;
pub mod prng;
pub mod simd;
pub mod stats;
pub mod threadpool;
pub mod tunable;
