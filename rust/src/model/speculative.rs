//! Self-speculative decoding from residual bit-slices.
//!
//! MoBiQuant's recursive residual quantization means a low-bit prefix
//! of the *same* packed weights is a free draft model: draft k tokens
//! with 1–2 residual bit-planes (`Precision::elastic` + the router's
//! threshold shift), verify all k in one batched full-precision pass,
//! accept the longest matching greedy prefix, and roll the KV arena
//! back for the rejected tail.  No second model, no extra weight
//! memory — the elastic representation *is* the draft/verify
//! hierarchy, which turns the §4 token-aware router into a latency
//! feature rather than only a quality knob.
//!
//! ## The invariant, and why rollback is not just `truncate_seq`
//!
//! Speculative greedy output must be **token-for-token identical** to
//! [`Model::generate`].  Two hazards on quantized KV pools would break
//! that with the naive truncate-and-batched-verify design:
//!
//! 1. Draft rows appended into a partially filled i8/u4 tail page can
//!    widen its absmax scale, lossily re-coding the rows *before* them
//!    — `truncate_seq` drops the rows but cannot narrow the scale
//!    back, so every later append would quantize differently from a
//!    straight-line run.  The draft/verify loop therefore brackets
//!    every burst with [`KvArena::checkpoint_seq`] /
//!    [`KvArena::rollback_seq`], which snapshot and restore the tail
//!    page's raw codes and scales exactly.
//! 2. A block append takes its absmax over the whole block, which is
//!    not the scale trajectory t single-token appends produce.  The
//!    verify pass ([`Model::verify_logits`]) keeps the seven linears
//!    batched but commits KV one position at a time with per-position
//!    attention — `decode_step` granularity — so the verify logits are
//!    bit-identical to a run of decode steps.
//!
//! On acceptance of a proper prefix, the loop rolls back to the
//! checkpoint and re-commits only the accepted positions' K/V rows
//! (captured pre-RoPE during the verify) one at a time, in the same
//! position-outer order as `decode_step` — reproducing the
//! straight-line bytes and scales exactly.  The parity suite
//! (`rust/tests/speculative.rs`) pins this across GQA configs,
//! page-seam lengths and all three KV precisions, including
//! forced-rejection rounds with garbage drafts.
//!
//! ## Feedback loop
//!
//! A per-sequence accept-rate EMA ([`SpecState`]) adapts the draft
//! depth k and the draft's elastic bit-width: sustained full
//! acceptance deepens the draft window and sheds draft bits; sustained
//! rejection shallows it and gives the router more residual slices
//! (via [`draft_delta`]'s Eq. 10 threshold shift — sensitive tokens
//! draft with more slices).  The adaptation rule is pure integer/f64
//! arithmetic on observed accept counts, so benches and tests can
//! simulate a trajectory exactly.

use anyhow::Result;

use super::kvcache::{KvArena, KvHandle, KvPrecision};
use super::transformer::{argmax, DecodeScratch, DecodeStats, Model,
                         MAX_PREFILL_BLOCK};
use crate::mobiq::engine::Precision;
use crate::mobiq::router::draft_delta;

/// Tuning knobs of the speculative loop.  Defaults are conservative:
/// the window starts at `k_min` and only deepens on sustained
/// acceptance.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Smallest / largest draft window (tokens drafted per round).
    pub k_min: usize,
    pub k_max: usize,
    /// Draft elastic target-bit band: the draft starts cheap at
    /// `draft_bits_min` and the feedback loop walks it up toward
    /// `draft_bits_max` in `bits_step` increments when drafts keep
    /// missing.
    pub draft_bits_min: f64,
    pub draft_bits_max: f64,
    pub bits_step: f64,
    /// Accept-rate EMA smoothing (weight of the newest round).
    pub ema_alpha: f64,
    /// EMA band: at or above `accept_hi` (with a fully accepted round)
    /// the window deepens and the draft sheds bits; at or below
    /// `accept_lo` the window shallows and the draft gains bits.
    pub accept_hi: f64,
    pub accept_lo: f64,
    /// Magnitude of the router threshold shift [`draft_delta`] feeds
    /// the draft precision (Eq. 10 delta at the band edges).
    pub max_delta: f32,
}

impl Default for SpecConfig {
    fn default() -> SpecConfig {
        SpecConfig {
            k_min: 1,
            k_max: 4,
            draft_bits_min: 2.0,
            draft_bits_max: 4.0,
            bits_step: 2.0,
            ema_alpha: 0.25,
            accept_hi: 0.75,
            accept_lo: 0.35,
            max_delta: 0.25,
        }
    }
}

/// Per-sequence speculative state: the adaptive knobs (window depth,
/// draft bits, accept-rate EMA) plus lifetime counters and the draft
/// pass's own routing stats (kept separate from the request's stats —
/// draft tokens are scaffolding, not output).
#[derive(Debug, Clone)]
pub struct SpecState {
    /// Current draft window depth.
    pub k: usize,
    /// Current draft elastic target bits.
    pub draft_bits: f64,
    /// Accept-rate EMA (fraction of drafted tokens accepted), seeded
    /// neutrally at 0.5.
    pub ema: f64,
    pub rounds: u64,
    pub drafted: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Tokens committed by verify rounds (accepted prefixes plus their
    /// correction/bonus tokens).
    pub commit_tokens: u64,
    /// Routing stats of the draft passes (bits histogram feeds the
    /// metrics summary's draft-bit histogram).
    pub draft_stats: DecodeStats,
}

impl SpecState {
    pub fn new(cfg: &SpecConfig, n_layers: usize) -> SpecState {
        SpecState {
            k: cfg.k_min,
            draft_bits: cfg.draft_bits_min,
            ema: 0.5,
            rounds: 0,
            drafted: 0,
            accepted: 0,
            rejected: 0,
            commit_tokens: 0,
            draft_stats: DecodeStats::new(n_layers),
        }
    }

    /// Precision of the next draft pass: elastic at the current draft
    /// bits, with the router threshold shifted by the accept-rate EMA
    /// ([`draft_delta`] — a struggling draft gives sensitive tokens
    /// more residual slices).
    pub fn draft_precision(&self, cfg: &SpecConfig) -> Precision {
        Precision::elastic(self.draft_bits).with_delta(draft_delta(
            self.ema,
            cfg.accept_lo,
            cfg.accept_hi,
            cfg.max_delta,
        ))
    }

    /// Fold one round's outcome into the EMA and walk the adaptive
    /// knobs.  Deterministic arithmetic only — benches simulate
    /// trajectories with exactly this rule.
    pub fn observe(&mut self, cfg: &SpecConfig, drafted: usize,
                   matched: usize, committed: usize) {
        self.rounds += 1;
        self.drafted += drafted as u64;
        self.accepted += matched as u64;
        self.rejected += (drafted - matched) as u64;
        self.commit_tokens += committed as u64;
        if drafted == 0 {
            // end-of-request degenerate round (pure verify step):
            // nothing was risked, nothing to learn
            return;
        }
        let rate = matched as f64 / drafted as f64;
        self.ema += cfg.ema_alpha * (rate - self.ema);
        if matched == drafted && self.ema >= cfg.accept_hi {
            self.k = (self.k + 1).min(cfg.k_max);
            self.draft_bits =
                (self.draft_bits - cfg.bits_step).max(cfg.draft_bits_min);
        } else if self.ema <= cfg.accept_lo {
            self.k = self.k.saturating_sub(1).max(cfg.k_min);
            self.draft_bits =
                (self.draft_bits + cfg.bits_step).min(cfg.draft_bits_max);
        }
    }

    /// Lifetime fraction of drafted tokens accepted.
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }

    /// Mean tokens committed per verify round (the headline
    /// tokens-per-verify-step number; > 1 means speculation pays).
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.commit_tokens as f64 / self.rounds as f64
    }
}

/// Scratch the verify forward fills per round: each position's
/// pre-RoPE K/V linear outputs for every layer (so a rejection can
/// roll back to the checkpoint and re-commit only the accepted rows),
/// plus reusable token/logits buffers.  Grow-only, reused across
/// rounds and sequences.
pub struct SpecCapture {
    k: Vec<f32>,
    v: Vec<f32>,
    t: usize,
    dkv: usize,
    /// Verify logits buffer ((t, vocab) row-major), recycled between
    /// rounds.
    pub(crate) logits: Vec<f32>,
    /// Fed-token staging buffer (pending token + drafts).
    fed: Vec<u32>,
}

impl SpecCapture {
    pub fn new() -> SpecCapture {
        SpecCapture {
            k: Vec::new(),
            v: Vec::new(),
            t: 0,
            dkv: 0,
            logits: Vec::new(),
            fed: Vec::new(),
        }
    }

    /// Size the capture for one verify pass (`prefill_inner` calls
    /// this when running in spec mode).
    pub(crate) fn begin(&mut self, n_layers: usize, t: usize,
                        dkv: usize) {
        self.t = t;
        self.dkv = dkv;
        let n = n_layers * t * dkv;
        if self.k.len() < n {
            self.k.resize(n, 0.0);
            self.v.resize(n, 0.0);
        }
    }

    /// Stash one layer's pre-RoPE K/V linear outputs ((t, kv_dim)
    /// row-major).
    pub(crate) fn save_layer(&mut self, li: usize, k: &[f32],
                             v: &[f32]) {
        let n = self.t * self.dkv;
        let lo = li * n;
        self.k[lo..lo + n].copy_from_slice(&k[..n]);
        self.v[lo..lo + n].copy_from_slice(&v[..n]);
    }

    pub(crate) fn k_row(&self, li: usize, i: usize) -> &[f32] {
        &self.k[(li * self.t + i) * self.dkv..][..self.dkv]
    }

    pub(crate) fn v_row(&self, li: usize, i: usize) -> &[f32] {
        &self.v[(li * self.t + i) * self.dkv..][..self.dkv]
    }
}

/// Outcome of one draft→verify→commit round.
#[derive(Debug, Clone)]
pub struct SpecRound {
    /// Draft tokens fed to the verify pass.
    pub drafted: usize,
    /// Length of the accepted draft prefix.
    pub matched: usize,
    /// Tokens committed to the sequence: the accepted drafts plus one
    /// verify token (the correction where the first draft missed, or
    /// the bonus token after a fully accepted window).  Always
    /// `matched + 1` long, and always exactly what straight-line
    /// greedy decode would have produced.
    pub tokens: Vec<u32>,
}

impl Model {
    /// One full speculative round for a sequence whose pending (not
    /// yet fed) token is `last`: draft up to `k` tokens at
    /// `draft_precision` with single-token greedy steps, roll the
    /// arena back, then verify and commit via
    /// [`Model::verify_commit`].  On any error (e.g. `OutOfPages`
    /// surfaced mid-draft) the sequence is rolled back to its
    /// pre-round state before the error propagates, so the caller can
    /// run recovery and retry the round.
    ///
    /// `k` is clamped so the verify pass (k+1 tokens) fits both the
    /// context window and `MAX_PREFILL_BLOCK`; `k = 0` degenerates to
    /// a plain decode step through the verify path.
    pub fn speculate_round(&self, last: u32, arena: &mut KvArena,
                           seq: KvHandle, precision: Precision,
                           draft_precision: Precision, k: usize,
                           scratch: &mut DecodeScratch,
                           cap: &mut SpecCapture,
                           stats: &mut DecodeStats,
                           draft_stats: &mut DecodeStats)
                           -> Result<SpecRound> {
        let len0 = arena.seq_len(seq);
        let k = k
            .min(self.cfg.max_seq_len.saturating_sub(len0 + 1))
            .min(MAX_PREFILL_BLOCK - 1);
        let mut drafts = std::mem::take(&mut cap.fed);
        drafts.clear();
        if k > 0 {
            let ck = arena.checkpoint_seq(seq);
            let mut cur = last;
            for _ in 0..k {
                match self.greedy_step(cur, arena, seq, draft_precision,
                                       scratch, draft_stats) {
                    Ok(next) => {
                        drafts.push(next);
                        cur = next;
                    }
                    Err(e) => {
                        arena.rollback_seq(seq, &ck);
                        cap.fed = drafts;
                        return Err(e);
                    }
                }
            }
            arena.rollback_seq(seq, &ck);
        }
        let res = self.verify_commit(last, &drafts, arena, seq,
                                     precision, scratch, cap, stats);
        cap.fed = drafts;
        res
    }

    /// Verify `drafts` against the full-precision model and commit the
    /// longest matching greedy prefix (plus the verify's own
    /// correction/bonus token).  The sequence must be at its committed
    /// length with `last` pending; on return it has advanced by
    /// `matched + 1` positions whose KV bytes are identical to a
    /// straight-line run, whatever the drafts were — the parity
    /// invariant holds for *arbitrary* draft tokens, which is what
    /// lets the tests force rejections with garbage drafts.
    ///
    /// On error the sequence is rolled back to its pre-call state.
    /// `stats` accumulates the verify pass's routing stats (it feeds
    /// `drafts.len() + 1` tokens — a superset of the committed ones).
    pub fn verify_commit(&self, last: u32, drafts: &[u32],
                         arena: &mut KvArena, seq: KvHandle,
                         precision: Precision,
                         scratch: &mut DecodeScratch,
                         cap: &mut SpecCapture,
                         stats: &mut DecodeStats) -> Result<SpecRound> {
        let k = drafts.len();
        anyhow::ensure!(k + 1 <= MAX_PREFILL_BLOCK,
                        "draft window exceeds MAX_PREFILL_BLOCK");
        let len0 = arena.seq_len(seq);
        anyhow::ensure!(len0 + k + 1 <= self.cfg.max_seq_len,
                        "speculative window exceeds the context");
        let ck = arena.checkpoint_seq(seq);
        let mut logits = std::mem::take(&mut cap.logits);
        logits.clear();
        let mut fed = Vec::with_capacity(k + 1);
        fed.push(last);
        fed.extend_from_slice(drafts);
        if let Err(e) = self.verify_logits(&fed, arena, seq, precision,
                                           scratch, stats, cap,
                                           &mut logits) {
            arena.rollback_seq(seq, &ck);
            cap.logits = logits;
            return Err(e);
        }
        // Greedy accept: row i is the full-precision distribution
        // after feeding fed[..=i], so drafts[i] is accepted iff it is
        // row i's argmax.  First-max tie-breaking on both sides (see
        // `transformer::argmax`) keeps ties from diverging.
        let vocab = self.cfg.vocab_size;
        let mut matched = 0usize;
        while matched < k {
            let next =
                argmax(&logits[matched * vocab..(matched + 1) * vocab]);
            if next as u32 == drafts[matched] {
                matched += 1;
            } else {
                break;
            }
        }
        let mut tokens = Vec::with_capacity(matched + 1);
        tokens.extend_from_slice(&drafts[..matched]);
        tokens.push(
            argmax(&logits[matched * vocab..(matched + 1) * vocab])
                as u32,
        );
        if matched < k {
            // Rejection: roll back to the checkpoint, then re-commit
            // the accepted positions' captured K/V rows one position
            // at a time (position-outer, layer-inner — the exact
            // append order of a run of decode_steps, so quantized
            // page scales retrace the straight-line trajectory).
            arena.rollback_seq(seq, &ck);
            for i in 0..=matched {
                for li in 0..self.cfg.n_layers {
                    if let Err(e) = arena.append_kv_block(
                        seq, li, &scratch.rope, cap.k_row(li, i),
                        cap.v_row(li, i), 1)
                    {
                        arena.rollback_seq(seq, &ck);
                        cap.logits = logits;
                        return Err(e.into());
                    }
                }
            }
        }
        // matched == k: every appended position is an accepted one —
        // the serial verify commit already left the straight-line
        // bytes in place, no rollback needed.
        cap.logits = logits;
        Ok(SpecRound { drafted: k, matched, tokens })
    }

    /// Greedy continuation of a prompt through the speculative loop —
    /// the self-contained counterpart of [`Model::generate_at`], and
    /// guaranteed to return exactly its output.  `state` carries the
    /// adaptive knobs and counters across calls (pass a fresh
    /// [`SpecState`] for a fresh sequence).
    pub fn generate_speculative(&self, prompt: &[u32], n_new: usize,
                                precision: Precision,
                                kv_prec: KvPrecision, cfg: &SpecConfig,
                                stats: &mut DecodeStats,
                                state: &mut SpecState)
                                -> Result<Vec<u32>> {
        let (mut arena, seq) = self.new_kv_at(kv_prec);
        let mut scratch = self.new_scratch();
        let mut cap = SpecCapture::new();
        let mut toks = prompt.to_vec();
        if n_new == 0 || prompt.is_empty() {
            return Ok(toks);
        }
        let mut last = self.greedy_prefill(prompt, &mut arena, seq,
                                           precision, &mut scratch,
                                           stats)?;
        toks.push(last);
        let mut generated = 1usize;
        while generated < n_new {
            // a round commits at most k + 1 tokens; never overshoot
            // the request
            let k = state.k.min(n_new - generated - 1);
            let draft_precision = state.draft_precision(cfg);
            let round = self.speculate_round(
                last, &mut arena, seq, precision, draft_precision, k,
                &mut scratch, &mut cap, stats,
                &mut state.draft_stats)?;
            debug_assert_eq!(round.tokens.len(), round.matched + 1);
            toks.extend_from_slice(&round.tokens);
            generated += round.tokens.len();
            last = *round.tokens.last().expect("round commits >= 1");
            state.observe(cfg, round.drafted, round.matched,
                          round.tokens.len());
        }
        debug_assert_eq!(toks.len(), prompt.len() + n_new);
        Ok(toks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_state_adapts_on_acceptance() {
        let cfg = SpecConfig::default();
        let mut st = SpecState::new(&cfg, 2);
        assert_eq!(st.k, cfg.k_min);
        // sustained full acceptance: EMA climbs, window deepens
        for _ in 0..8 {
            let k = st.k;
            st.observe(&cfg, k, k, k + 1);
        }
        assert_eq!(st.k, cfg.k_max);
        assert!(st.ema > cfg.accept_hi);
        assert_eq!(st.accept_rate(), 1.0);
        assert!(st.tokens_per_round() > 1.0);
        // sustained total rejection: EMA falls, window shallows,
        // draft gains bits
        for _ in 0..12 {
            let k = st.k;
            st.observe(&cfg, k, 0, 1);
        }
        assert_eq!(st.k, cfg.k_min);
        assert!(st.ema < cfg.accept_lo);
        assert_eq!(st.draft_bits, cfg.draft_bits_max);
    }

    #[test]
    fn spec_state_draft_precision_tracks_ema() {
        let cfg = SpecConfig::default();
        let mut st = SpecState::new(&cfg, 2);
        st.ema = 0.0;
        let lo = st.draft_precision(&cfg);
        st.ema = 1.0;
        let hi = st.draft_precision(&cfg);
        match (lo, hi) {
            (Precision::Elastic { delta: dl, .. },
             Precision::Elastic { delta: dh, .. }) => {
                assert_eq!(dl, -cfg.max_delta, "low EMA -> more slices");
                assert_eq!(dh, cfg.max_delta, "high EMA -> fewer");
            }
            _ => panic!("draft precision must be elastic"),
        }
    }

    #[test]
    fn zero_draft_round_is_neutral() {
        let cfg = SpecConfig::default();
        let mut st = SpecState::new(&cfg, 2);
        let (k0, ema0) = (st.k, st.ema);
        st.observe(&cfg, 0, 0, 1);
        assert_eq!((st.k, st.ema), (k0, ema0));
        assert_eq!(st.commit_tokens, 1);
        assert_eq!(st.rounds, 1);
    }
}
