"""MoBiRoute gating / scheduling / threshold properties (paper §4.2)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quant import router as R
from compile.quant import schedules as S


def test_temperature_schedule_endpoints():
    assert S.gate_temperature(1, 100) == 1.0
    assert math.isinf(S.gate_temperature(100, 100))
    ts = [S.gate_temperature(t, 100) for t in range(1, 100)]
    assert ts == sorted(ts)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(list(S.SCHEDULES)), st.integers(2, 500))
def test_budget_schedules_decay(kind, total):
    b0 = S.budget(1, total, 8.0, 3.0, kind)
    bl = S.budget(total, total, 8.0, 3.0, kind)
    # starts near b_init (exactly for log; within the first step's decay
    # for the others since t starts at 1), ends exactly at the target
    assert 3.0 - 1e-9 <= b0 <= 8.0 + 1e-9
    assert abs(bl - 3.0) < 1e-6
    if kind == "log":
        assert abs(b0 - 8.0) < 1e-6
    vals = [S.budget(t, total, 8.0, 3.0, kind) for t in
            range(1, total + 1)]
    assert all(vals[i] + 1e-9 >= vals[i + 1] for i in
               range(len(vals) - 1)), kind


def test_gate_hardens_to_indicator():
    s = jnp.asarray([-0.5, 0.01, 2.0])
    g_final = R.gate(s, 100, 100)
    np.testing.assert_array_equal(np.asarray(g_final),
                                  np.asarray([0.0, 1.0, 1.0]))
    g_start = R.gate(s, 1, 100)
    assert 0.1 < float(g_start[0]) < 0.5 < float(g_start[2]) < 1.0


def test_avg_bits_counts_base():
    g = jnp.asarray([[0.9, 0.1, 0.9], [0.1, 0.1, 0.1]])
    # token0 activates 2 residuals, token1 none; base 2 bits always
    ab = float(R.avg_bits(g, 2, 2))
    assert abs(ab - (2 + 2 * (2 + 0) / 2)) < 1e-6


def test_reg_loss_sign():
    g_over = jnp.full((8, 3), 0.9)   # everything on -> over budget
    over = float(R.reg_loss_bt(g_over, 3.0, 2, 2))
    assert over > 0  # positive -> pressure to prune
    g_under = jnp.full((8, 3), 0.1)
    under = float(R.reg_loss_bt(g_under, 3.0, 2, 2))
    assert under < 0  # promotes activation


def test_router_init_neutral():
    rp = R.init_router(jax.random.PRNGKey(0), 16, 8, 3)
    s = R.scores(rp, jnp.ones((5, 16)))
    np.testing.assert_allclose(np.asarray(s), 0.0, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_threshold_ratio_roundtrip(seed, rho):
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal(4000).astype(np.float32)
    q = R.score_quantiles(scores)
    thr = R.threshold_for_ratio(q, rho)
    realized = float((scores > thr).mean())
    assert abs(realized - rho) < 0.05


def test_ratio_for_target_bits():
    assert R.ratio_for_target_bits(2.0, 2, 2, 3) == 0.0
    assert R.ratio_for_target_bits(8.0, 2, 2, 3) == 1.0
    assert abs(R.ratio_for_target_bits(3.0, 2, 2, 3) - 1 / 6) < 1e-9


def test_hard_gate_threshold_shift():
    s = jnp.asarray([[0.2, -0.1, 0.5]])
    m0 = np.asarray(R.hard_gate(s, 0.0))
    m1 = np.asarray(R.hard_gate(s, 0.3))
    assert m0.sum() > m1.sum()
