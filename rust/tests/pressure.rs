//! Closed-loop elastic degradation under memory pressure (ISSUE 6).
//!
//! The bar: the scheduler never hard-fails on memory.  Under a tiny
//! page budget (and, with `--features failpoints`, an injected
//! allocation-failure schedule) every submitted request completes —
//! degraded, deferred, or preempted-and-resumed, but never dropped —
//! and no `OutOfPages` error escapes the tick loop.  Requantized tails
//! stay within the PR 5 oracle bounds (i8 <= 1e-2, u4 <= 0.3 rel err
//! vs the f32 slab), and a preempt->resume sequence produces
//! token-for-token the same greedy output as an unpressured run.
//!
//! All on synthetic models, so no `make artifacts` is needed.  The
//! fault-injection tests are compiled only under
//! `--features failpoints` (CI's stress lane); the proactive-ladder
//! and requant-bound tests run in plain tier-1 too.

use std::sync::mpsc;
use std::time::Instant;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::coordinator::batcher::Batcher;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::coordinator::request::{Request, Response};
use mobiquant::coordinator::scheduler::Scheduler;
use mobiquant::coordinator::PressureConfig;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::attention::{append_kv_block, attention_block,
                                  AttnScratch, RopeCache};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::transformer::{argmax, DecodeStats};
use mobiquant::model::weights::ModelConfig;
use mobiquant::model::{KvArena, KvPrecision, KV_PAGE};
use mobiquant::util::prng::Pcg;

fn mk_req(id: u64, prompt: Vec<u32>, max_new: usize)
          -> (Request, mpsc::Receiver<Response>) {
    mk_req_at(id, prompt, max_new, KvPrecision::F32)
}

fn mk_req_at(id: u64, prompt: Vec<u32>, max_new: usize,
             kv_precision: KvPrecision)
             -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    (Request {
        id,
        prompt,
        max_new_tokens: max_new,
        kv_precision,
        submitted: Instant::now(),
        reply: tx,
    }, rx)
}

fn fixed_controller() -> ElasticController {
    ElasticController::new(ControllerConfig {
        min_bits: 4.0,
        max_bits: 4.0,
        ..ControllerConfig::default()
    })
}

fn prompt_for(id: u64, len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 5 + 11 * id as usize) % 256) as u32).collect()
}

/// High band, no injected faults: with lowered thresholds and one
/// page of headroom, occupancy alone must drive in-place tail
/// requantization of resident sequences plus admission degradation —
/// and every request still completes with its full token count.
#[test]
fn high_band_requantizes_tails_and_degrades_admissions() {
    let model = synth_model_shaped(59, 4, 2, 128);
    assert_eq!(model.cfg.n_layers, 2);
    // 5-page budget: two resident 2-page f32 sequences put occupancy
    // at 0.8 with one free page of requant headroom
    let batcher = Batcher::new(4, 16).with_kv_budget(5);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller())
        .with_pressure(PressureConfig {
            moderate: 0.2,
            high: 0.5,
            critical: 0.99,
            hysteresis: 0.05,
        });
    let mut rxs = Vec::new();
    for id in 0..8u64 {
        // 40-token prompts + 4 new: worst case 2 f32 pages per request
        let (req, rx) = mk_req(id, prompt_for(id, 40), 4);
        sched.submit(req);
        rxs.push(rx);
    }
    sched.run_to_completion(|_| 0.0).unwrap();

    for rx in rxs {
        let resp = rx.try_recv().expect("no request may be dropped");
        assert_eq!(resp.metrics.generated_tokens, 4);
    }
    let m = &sched.metrics;
    assert_eq!(m.requests_completed, 8);
    assert_eq!(m.rejected, 0);
    assert!(m.pressure_ticks[2] > 0,
            "the tiny budget must reach the High band");
    assert!(m.requant_events >= 1, "resident tails must requantize");
    assert!(m.requant_pages >= 1);
    assert!(m.requant_bytes_freed > 0);
    assert!(m.admissions_degraded >= 1,
            "High-band admissions must floor KV precision");
    assert_eq!(m.oom_recoveries, 0,
               "the proactive ladder must act before faults happen");
    assert_eq!(sched.arena.resident_pages(), 0,
               "retire must return every page");
}

/// Critical band, no injected faults: a 4-page budget packs to 100%
/// occupancy (no requant headroom), so the ladder's last rung —
/// preempt the youngest, park its tokens, resume it later — must
/// carry the load, with zero drops and every preemption resumed.
#[test]
fn critical_band_preempts_youngest_and_resumes() {
    let model = synth_model_shaped(61, 4, 2, 128);
    let batcher = Batcher::new(4, 16).with_kv_budget(4);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let mut rxs = Vec::new();
    for id in 0..8u64 {
        let (req, rx) = mk_req(id, prompt_for(id, 40), 4);
        sched.submit(req);
        rxs.push(rx);
    }
    sched.run_to_completion(|_| 0.0).unwrap();

    for rx in rxs {
        let resp = rx.try_recv().expect("no request may be dropped");
        assert_eq!(resp.metrics.generated_tokens, 4,
                   "preempt/resume must finish the full token budget");
    }
    let m = &sched.metrics;
    assert_eq!(m.requests_completed, 8);
    assert_eq!(m.rejected, 0);
    assert!(m.pressure_ticks[3] > 0,
            "two resident f32 prefills must fill the arena -> Critical");
    assert!(m.preemptions >= 1, "Critical must preempt the youngest");
    assert_eq!(m.preemptions, m.resumes,
               "every preempted sequence must resume (none dropped)");
    assert!(m.admissions_degraded >= 1,
            "resume under Critical must floor KV precision to i4");
    assert_eq!(m.oom_recoveries, 0,
               "the proactive ladder must act before faults happen");
    assert_eq!(sched.arena.resident_pages(), 0,
               "retire must return every page");
}

/// Proactive host-tier swap, no injected faults: sequences whose KV
/// already stores at i4 leave the requant rung nothing to convert, so
/// under a one-f32-page budget the High band's only gentle relief is
/// moving cold pages to the host tier.  Swapped sequences stall for
/// the tick and the swap-in pass (including the all-stalled deadlock
/// guard — here every High tick stalls the lone active sequence and
/// must force it back) restores them, so the run both completes with
/// zero drops AND reproduces the unpressured token stream bit for bit
/// (host pages round-trip byte-exactly).
#[test]
fn high_band_swaps_cold_pages_and_output_stays_bit_identical() {
    let model = synth_model_shaped(67, 4, 2, 256);
    let run = |budget: Option<usize>, host_swap: usize| {
        let mut batcher = Batcher::new(4, 16);
        if let Some(p) = budget {
            batcher = batcher.with_kv_budget(p);
        }
        if host_swap > 0 {
            batcher = batcher.with_host_swap(host_swap);
        }
        let mut sched =
            Scheduler::new(&model, batcher, fixed_controller());
        if budget.is_some() {
            sched = sched.with_pressure(PressureConfig {
                moderate: 0.2,
                high: 0.5,
                critical: 0.99,
                hysteresis: 0.05,
            });
        }
        let mut rxs = Vec::new();
        for id in 0..4u64 {
            // 150-token prompts: two+ full pages per layer, so cold
            // pages exist once prefill crosses the second page seam
            let (req, rx) = mk_req_at(id, prompt_for(id, 150), 4,
                                      KvPrecision::Int4);
            sched.submit(req);
            rxs.push(rx);
        }
        sched.run_to_completion(|_| 0.0).unwrap();
        let resps: Vec<Response> = rxs.iter()
            .map(|rx| rx.try_recv().expect("no request may be dropped"))
            .collect();
        let dev = sched.arena.resident_pages();
        let host = sched.arena.host_resident_bytes();
        (resps, sched.metrics.clone(), dev, host)
    };

    // unpressured oracle: ample budget, no host tier
    let (base, m0, _, _) = run(None, 0);
    assert_eq!(m0.preemptions, 0);
    assert_eq!(m0.swap_out_pages, 0);

    // one f32-page budget = eight i4 pages: a single 150-token i4
    // sequence alone crosses the lowered High threshold mid-prefill
    let (tight, m, dev, host) = run(Some(1), 1 << 20);
    for (a, b) in base.iter().zip(&tight) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "swap-out -> stall -> swap-in -> continue must be \
                    bit-identical to the unpressured run");
        assert_eq!(b.metrics.generated_tokens, 4);
    }
    assert!(m.pressure_ticks[2] > 0,
            "the tight budget must reach the High band");
    assert!(m.swap_out_pages >= 1,
            "High must move cold pages to the host tier");
    assert!(m.swap_in_pages >= 1,
            "stalled sequences must be restored");
    assert_eq!(m.swap_fallback_reprefills, 0,
               "no preemption happened: nothing may re-prefill");
    assert_eq!(m.preemptions, 0,
               "swap relief must keep the run below Critical");
    assert_eq!(m.oom_recoveries, 0,
               "the proactive ladder must act before faults happen");
    assert_eq!(dev, 0, "retire must return every device page");
    assert_eq!(host, 0, "retire must drain the host tier too");
}

/// Requantized-tail attention against the f32 slab oracle: after
/// `requant_seq_tail`, full-block and decode-shape attention over the
/// mixed arena stay within the PR 5 bounds (i8 <= 1e-2, u4 <= 0.3).
#[test]
fn requant_tail_attention_within_oracle_bounds() {
    let cfg = attn_cfg(4, 2, 16, 3 * KV_PAGE);
    let d = cfg.d_model;
    for &(target, bound) in &[(KvPrecision::Int8, 1e-2f32),
                              (KvPrecision::Int4, 0.3f32)] {
        let t = 2 * KV_PAGE + 1;
        let (slab, mut arena, seq) = paired_fill(&cfg, t, 900, KvPrecision::F32);
        let sum = arena.requant_seq_tail(seq, target);
        assert_eq!(sum.pages, 3,
                   "all exclusively-owned pages must convert");
        assert!(sum.bytes_freed > 0);

        let mut rng = Pcg::new(901);
        let mut sc = AttnScratch::new();
        // whole-block shape
        let q = rng.normal_vec(t * d, 1.0);
        let mut want = vec![0f32; t * d];
        attention_block(&cfg, &q, &slab, 0, t, &mut sc, None, &mut want);
        let mut got = vec![0f32; t * d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q, &view, 0, t, &mut sc, None, &mut got);
        let e = rel_err(&got, &want);
        assert!(e <= bound,
                "{}: block rel err {e} > {bound}", target.label());

        // decode shape at the last position
        let q1 = rng.normal_vec(d, 1.0);
        let mut want1 = vec![0f32; d];
        attention_block(&cfg, &q1, &slab, t - 1, 1, &mut sc, None,
                        &mut want1);
        let mut got1 = vec![0f32; d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q1, &view, t - 1, 1, &mut sc, None,
                        &mut got1);
        let e1 = rel_err(&got1, &want1);
        assert!(e1 <= bound,
                "{}: decode rel err {e1} > {bound}", target.label());
        arena.free_seq(seq);
        assert_eq!(arena.resident_pages(), 0);
    }
}

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            max_seq: usize) -> ModelConfig {
    ModelConfig {
        name: "pressure".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: max_seq,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

/// Append the same random K/V stream (uneven chunks crossing page
/// seams) to a slab and an arena sequence at `kvp`; returns both.
fn paired_fill(cfg: &ModelConfig, t: usize, seed: u64,
               kvp: KvPrecision) -> (KvCache, KvArena,
                                     mobiquant::model::KvHandle) {
    let hd = cfg.head_dim();
    let n_kv = cfg.n_kv_heads;
    let w = n_kv * hd;
    let mut rng = Pcg::new(seed);
    let k_block = rng.normal_vec(t * w, 1.0);
    let v_block = rng.normal_vec(t * w, 1.0);
    let mut rope = RopeCache::new(hd, cfg.rope_theta);
    rope.ensure(t);

    let mut slab = KvCache::new(cfg.max_seq_len, n_kv, hd);
    let mut arena = KvArena::new(1, cfg.max_seq_len, n_kv, hd, 8);
    let seq = arena.alloc_seq_at(kvp);
    let mut fed = 0usize;
    for chunk in [50usize, 31, 64, 64] {
        let n = chunk.min(t - fed);
        if n == 0 {
            break;
        }
        let lo = fed * w;
        append_kv_block(&mut slab, &rope, &k_block[lo..(fed + n) * w],
                        &v_block[lo..(fed + n) * w], n);
        arena.append_kv_block(seq, 0, &rope,
                              &k_block[lo..(fed + n) * w],
                              &v_block[lo..(fed + n) * w], n)
            .unwrap();
        fed += n;
    }
    assert_eq!(fed, t);
    (slab, arena, seq)
}

/// Relative error of `got` vs the oracle `want`, normalised by the
/// oracle's largest magnitude (guarded for all-zero oracles).
fn rel_err(got: &[f32], want: &[f32]) -> f32 {
    let mut max_err = 0f32;
    let mut max_abs = 0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
        max_abs = max_abs.max(b.abs());
    }
    max_err / max_abs.max(1e-6)
}

/// `Model::resume` parity, driven directly at the model layer: an
/// interrupted run (prefill + a few decode steps, sequence freed,
/// prompt-plus-generated re-prefilled through `resume` on a fresh
/// handle, then decode continues) must reproduce `generate`'s
/// uninterrupted greedy output token for token.
#[test]
fn model_resume_matches_uninterrupted_generate() {
    let model = synth_model_shaped(77, 4, 2, 256);
    let prec = Precision::Fixed(2);
    let prompt = prompt_for(5, 20);

    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let base = model.generate(&prompt, 6, prec, &mut stats).unwrap();
    assert_eq!(base.len(), prompt.len() + 6);

    // interrupted: three tokens, preempt (free the sequence), resume
    let (mut arena, seq) = model.new_kv();
    let mut scratch = model.new_scratch();
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let mut toks = prompt.clone();
    model.prefill(&toks, &mut arena, seq, prec, &mut scratch,
                  &mut stats).unwrap();
    toks.push(argmax(&scratch.logits) as u32);
    for _ in 0..2 {
        let last = *toks.last().unwrap();
        model.decode_step(last, &mut arena, seq, prec, &mut scratch,
                          &mut stats).unwrap();
        toks.push(argmax(&scratch.logits) as u32);
    }
    arena.free_seq(seq); // the preemption: KV state is gone

    let seq = arena.alloc_seq();
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let next = model.resume(&toks, &mut arena, seq, prec,
                            &mut scratch, &mut stats).unwrap();
    toks.push(next);
    for _ in 0..2 {
        let last = *toks.last().unwrap();
        model.decode_step(last, &mut arena, seq, prec, &mut scratch,
                          &mut stats).unwrap();
        toks.push(argmax(&scratch.logits) as u32);
    }
    assert_eq!(toks, base,
               "resume must reproduce the uninterrupted greedy run");
}

/// `Model::resume` from host-parked KV: instead of freeing the
/// interrupted sequence, park its cold pages in the host tier and
/// truncate to the parked prefix — `resume` must restore the pages by
/// memcpy, re-feed only the unparked suffix at its absolute positions,
/// and still reproduce `generate`'s uninterrupted greedy output.
#[test]
fn model_resume_from_host_parked_kv_matches_generate() {
    let model = synth_model_shaped(83, 4, 2, 256);
    let prec = Precision::Fixed(2);
    // > KV_PAGE prompt so the interrupted sequence owns a cold page
    let prompt = prompt_for(9, 100);

    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let base = model.generate(&prompt, 6, prec, &mut stats).unwrap();

    let (mut arena, seq) = model.new_kv();
    arena.set_host_budget_pages(8);
    let mut scratch = model.new_scratch();
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let mut toks = prompt.clone();
    model.prefill(&toks, &mut arena, seq, prec, &mut scratch,
                  &mut stats).unwrap();
    toks.push(argmax(&scratch.logits) as u32);
    for _ in 0..2 {
        let last = *toks.last().unwrap();
        model.decode_step(last, &mut arena, seq, prec, &mut scratch,
                          &mut stats).unwrap();
        toks.push(argmax(&scratch.logits) as u32);
    }
    // the preemption: cold pages park in the host tier and the
    // sequence truncates to the page-aligned host prefix
    let sum = arena.swap_out_seq_cold(seq);
    assert!(sum.pages >= 1, "a 103-token sequence has cold pages");
    let kept = arena.seq_host_prefix_len(seq);
    assert_eq!(kept, KV_PAGE);
    arena.truncate_seq(seq, kept);
    assert!(arena.seq_swapped_pages(seq) > 0);

    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let next = model.resume(&toks, &mut arena, seq, prec,
                            &mut scratch, &mut stats).unwrap();
    assert_eq!(arena.seq_swapped_pages(seq), 0,
               "resume must restore the parked pages first");
    toks.push(next);
    for _ in 0..2 {
        let last = *toks.last().unwrap();
        model.decode_step(last, &mut arena, seq, prec, &mut scratch,
                          &mut stats).unwrap();
        toks.push(argmax(&scratch.logits) as u32);
    }
    assert_eq!(toks, base,
               "resume from host-parked KV must reproduce the \
                uninterrupted greedy run");
}

// ---------------------------------------------------------------------------
// fault injection (compiled only under --features failpoints)
// ---------------------------------------------------------------------------

/// The acceptance workload: 32 requests through a 4-page arena with a
/// deterministic allocation-denial schedule.  Zero `OutOfPages` may
/// escape the tick loop (the `unwrap` on `run_to_completion` is the
/// assertion), zero requests may be dropped, and every preemption must
/// pair with a resume.
#[cfg(feature = "failpoints")]
#[test]
fn injected_faults_recover_32_requests_zero_drops() {
    use mobiquant::model::kvcache::FailPlan;

    let model = synth_model_shaped(97, 4, 2, 128);
    let batcher = Batcher::new(4, 64).with_kv_budget(4);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    sched.arena.set_fail_plan(Some(FailPlan::deny_every(3, 5, 25)));
    let mut rxs = Vec::new();
    for id in 0..32u64 {
        let (req, rx) = mk_req(id, prompt_for(id, 40), 4);
        sched.submit(req);
        rxs.push(rx);
    }
    // zero OutOfPages escaping Scheduler::run is this unwrap
    sched.run_to_completion(|_| 0.0).unwrap();

    for rx in rxs {
        let resp = rx.try_recv().expect("no request may be dropped");
        assert_eq!(resp.metrics.generated_tokens, 4);
    }
    let m = &sched.metrics;
    assert_eq!(m.requests_completed, 32);
    assert_eq!(m.rejected, 0);
    assert!(m.oom_recoveries > 0,
            "the denial schedule must actually fire mid-tick");
    assert_eq!(m.preemptions, m.resumes,
               "every preempted sequence must resume");
    assert_eq!(sched.arena.resident_pages(), 0);
}

/// Preempt->resume parity: a run whose decode is interrupted by an
/// injected allocation fault (forcing a preemption and a later resume)
/// must produce token-for-token the same greedy output as the same
/// workload with no fault — both when the resume re-prefills from
/// scratch (no host tier) and when it restores host-parked KV by
/// memcpy.  The arena budget is ample, so the only difference between
/// the runs is the injected fault itself.
#[cfg(feature = "failpoints")]
#[test]
fn preempt_resume_output_bit_identical_to_unpressured_run() {
    use mobiquant::model::kvcache::FailPlan;

    let model = synth_model_shaped(41, 4, 2, 256);
    let run = |plan: Option<FailPlan>, host_swap: usize| {
        let mut batcher = Batcher::new(2, 16);
        if host_swap > 0 {
            batcher = batcher.with_host_swap(host_swap);
        }
        let mut sched =
            Scheduler::new(&model, batcher, fixed_controller());
        sched.arena.set_fail_plan(plan);
        let mut rxs = Vec::new();
        for id in 0..2u64 {
            // 150-token prompts: three pages per layer, so a sequence
            // preempted past the second seam owns cold (parkable) KV
            let (req, rx) = mk_req(id, prompt_for(id, 150), 8);
            sched.submit(req);
            rxs.push(rx);
        }
        sched.run_to_completion(|_| 0.0).unwrap();
        let resps: Vec<Response> = rxs.iter()
            .map(|rx| rx.try_recv().expect("response"))
            .collect();
        let attempts = sched.arena.alloc_attempts();
        (resps, attempts, sched.metrics.clone())
    };

    let (base, attempts, m0) = run(None, 0);
    assert_eq!(m0.preemptions, 0,
               "ample budget: baseline must not preempt");
    assert!(attempts >= 4, "workload must allocate several pages");

    // deny one mid-run allocation: the synthetic fault reports real
    // free bytes, so recovery skips the gentle rungs and preempts
    let (faulted, _, m1) = run(Some(FailPlan::deny_at(
        &[attempts / 2])), 0);
    assert!(m1.oom_recoveries >= 1,
            "the denial must surface as an OOM recovery");
    assert!(m1.preemptions >= 1, "recovery must preempt");
    assert_eq!(m1.preemptions, m1.resumes,
               "every preemption must resume");
    for (a, b) in base.iter().zip(&faulted) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "preempt->resume output must be bit-identical to \
                    the unpressured greedy run");
        assert_eq!(a.metrics.generated_tokens,
                   b.metrics.generated_tokens);
    }

    // same fault class with the host tier armed, denied late enough
    // that the preempted sequence owns cold pages: preemption parks
    // its KV in host memory and the resume restores it by memcpy
    // instead of re-prefilling — the output must STILL be
    // bit-identical, because swapped pages round-trip byte-exactly
    let (swapped, _, m2) = run(Some(FailPlan::deny_at(
        &[attempts - 2])), 1 << 20);
    assert!(m2.preemptions >= 1, "recovery must preempt");
    assert_eq!(m2.preemptions, m2.resumes,
               "every preemption must resume");
    assert!(m2.swap_out_pages >= 1,
            "preemption must park cold KV in the host tier");
    assert!(m2.swap_in_pages >= 1,
            "the resume must restore the parked pages");
    assert_eq!(m2.swap_in_pages, m2.swap_out_pages,
               "every parked page must come back");
    assert_eq!(m2.swap_fallback_reprefills, 0,
               "the host tier had room: no resume may fall back");
    for (a, b) in base.iter().zip(&swapped) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "preempt->swap->resume output must be bit-identical \
                    to the unpressured greedy run");
        assert_eq!(a.metrics.generated_tokens,
                   b.metrics.generated_tokens);
    }
}

/// The acceptance fallback: the 32-request/4-page stress run with the
/// host tier *armed but failpoint-exhausted* (every host-page claim
/// denied).  Preemptions find no host room, park nothing, and every
/// resume must carry its request through the full re-prefill fallback
/// — zero drops, zero pages in either tier at the end, and the
/// fallback counter accounts for every resume.
#[cfg(feature = "failpoints")]
#[test]
fn host_tier_exhausted_falls_back_to_reprefill_zero_drops() {
    use mobiquant::model::kvcache::FailPlan;

    let model = synth_model_shaped(97, 4, 2, 128);
    let batcher = Batcher::new(4, 64)
        .with_kv_budget(4)
        .with_host_swap(1 << 20);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    sched.arena.set_fail_plan(Some(
        FailPlan::deny_every(3, 5, 25).and_host_all()));
    let mut rxs = Vec::new();
    for id in 0..32u64 {
        let (req, rx) = mk_req(id, prompt_for(id, 40), 4);
        sched.submit(req);
        rxs.push(rx);
    }
    sched.run_to_completion(|_| 0.0).unwrap();

    for rx in rxs {
        let resp = rx.try_recv().expect("no request may be dropped");
        assert_eq!(resp.metrics.generated_tokens, 4);
    }
    let m = &sched.metrics;
    assert_eq!(m.requests_completed, 32);
    assert_eq!(m.rejected, 0);
    assert!(m.oom_recoveries > 0,
            "the denial schedule must actually fire mid-tick");
    assert_eq!(m.preemptions, m.resumes,
               "every preempted sequence must resume");
    assert_eq!(m.swap_in_pages, 0,
               "a denied host tier can never restore pages");
    assert_eq!(m.swap_fallback_reprefills, m.resumes,
               "with the tier armed but exhausted, every resume must \
                go through the re-prefill fallback");
    assert_eq!(sched.arena.resident_pages(), 0);
    assert_eq!(sched.arena.host_resident_bytes(), 0);
}
