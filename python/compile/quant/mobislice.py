"""MoBiSlice — many-in-one recursive residual quantization (paper §4.1, App. B).

    R_1 = W
    W_e = Q(R_e | Theta_q, b_e)        (floor-aligned quantizer)
    R_{e+1} = R_e - W_e

Slice 1 (the shared-expert MSB slice) uses the calibrated (s_1, z_1); every
residual slice e >= 2 derives its parameters from the shared set:

    s_{e+1} = s_e / 2^{b_e}        (App. B scale refinement)
    z_e     = 2^{b_e - 1}          (centred residual zero point)

so only ONE set of scales/zeros is stored — the paper's key storage/runtime
advantage over AnyBCQ's per-precision scales.  A b-bit weight is
reconstructed by summing the first k slices, b = sum b_e (Eq. 3).

Note: §4.1 of the main text says the next scale divides by 2^{b_e - 1} while
App. B (the authoritative formulation, Eq. 14) divides by 2^{b_e}; with
centred dequantization only 2^{b_e} gives exact residual coverage
(residual after a centred b-bit bin lies in [-s/2, s/2) = s/2^{b} * [-2^{b-1},
2^{b-1})), so we follow App. B.
"""

from __future__ import annotations

from typing import List, NamedTuple

import jax.numpy as jnp
import numpy as np

from .quantizer import (GroupQuantParams, calc_params, dequantize,
                        group_view, flat_view, quantize, quantize_ste)


class SlicedWeight(NamedTuple):
    """MoBiSlice decomposition of one linear layer's weight."""
    codes: List[jnp.ndarray]      # E x (d_in, d_out) int32, values < 2^slice_bits
    base: GroupQuantParams        # (s_1, z_1); residual params are derived
    slice_bits: int

    @property
    def n_slices(self) -> int:
        return len(self.codes)


def residual_params(base: GroupQuantParams, e: int,
                    slice_bits: int) -> GroupQuantParams:
    """Derived parameters of slice e (1-indexed; e=1 is the base slice)."""
    if e == 1:
        return base
    s = base.scale / float(2 ** (slice_bits * (e - 1)))
    z = jnp.full_like(base.zero, float(2 ** (slice_bits - 1)))
    return GroupQuantParams(s, z, slice_bits, base.group_size)


def decompose(w: jnp.ndarray, base: GroupQuantParams, n_slices: int,
              slice_bits: int) -> SlicedWeight:
    """Recursive residual quantization (Eq. 2)."""
    codes: List[jnp.ndarray] = []
    r = w
    for e in range(1, n_slices + 1):
        p = residual_params(base, e, slice_bits)
        q = quantize(r, p)
        codes.append(q)
        r = r - dequantize(q, p)
    return SlicedWeight(codes, base, slice_bits)


def slice_deq(sw: SlicedWeight, e: int) -> jnp.ndarray:
    """Dequantized contribution of slice e (1-indexed)."""
    p = residual_params(sw.base, e, sw.slice_bits)
    return dequantize(sw.codes[e - 1], p)


def reconstruct(sw: SlicedWeight, k: int) -> jnp.ndarray:
    """W^(b) = sum of the first k slices (Eq. 3); b = k * slice_bits."""
    acc = slice_deq(sw, 1)
    for e in range(2, k + 1):
        acc = acc + slice_deq(sw, e)
    return acc


def reconstruct_masked(sw: SlicedWeight, mask) -> jnp.ndarray:
    """Reconstruction from an arbitrary slice subset (Eq. 6 semantics).

    mask: length-E boolean; mask[0] must be True (shared-expert slice).
    """
    assert mask[0], "slice 1 is the always-on shared expert"
    acc = slice_deq(sw, 1)
    for e in range(2, sw.n_slices + 1):
        if mask[e - 1]:
            acc = acc + slice_deq(sw, e)
    return acc


def decompose_ste(w: jnp.ndarray, base: GroupQuantParams, n_slices: int,
                  slice_bits: int) -> List[jnp.ndarray]:
    """Differentiable decomposition: per-slice dequantized contributions
    with straight-through gradients w.r.t. (w, s_1, z_1).  Used during
    stage-2 joint optimisation (Alg. 1)."""
    outs: List[jnp.ndarray] = []
    r = w
    for e in range(1, n_slices + 1):
        p = residual_params(base, e, slice_bits)
        deq = quantize_ste(r, p)
        outs.append(deq)
        r = r - deq
    return outs


# ---------------------------------------------------------------------------
# Bit-plane packing (kernel interchange format, §4.3)
# ---------------------------------------------------------------------------

def pack_bitplanes(codes: np.ndarray, slice_bits: int) -> np.ndarray:
    """Pack integer codes (d_in, d_out) into bit-major planes.

    Returns uint64 array of shape (slice_bits, d_out, ceil(d_in/64)): plane p
    holds bit p of every code, packed along the *input* dimension so a GEMV
    kernel streams contiguous words per output channel.  Bit j of word w of
    plane p = bit p of codes[w*64 + j, o].
    """
    codes = np.asarray(codes, dtype=np.uint64)
    d_in, d_out = codes.shape
    n_words = (d_in + 63) // 64
    planes = np.zeros((slice_bits, d_out, n_words), dtype=np.uint64)
    for p in range(slice_bits):
        bits = ((codes >> np.uint64(p)) & np.uint64(1)).T  # (d_out, d_in)
        padded = np.zeros((d_out, n_words * 64), dtype=np.uint64)
        padded[:, :d_in] = bits
        chunks = padded.reshape(d_out, n_words, 64)
        shifts = np.arange(64, dtype=np.uint64)
        planes[p] = np.sum(chunks << shifts[None, None, :], axis=2,
                           dtype=np.uint64)
    return planes


def unpack_bitplanes(planes: np.ndarray, d_in: int) -> np.ndarray:
    """Inverse of pack_bitplanes -> (d_in, d_out) integer codes."""
    slice_bits, d_out, n_words = planes.shape
    codes = np.zeros((d_out, n_words * 64), dtype=np.uint64)
    shifts = np.arange(64, dtype=np.uint64)
    for p in range(slice_bits):
        bits = (planes[p][:, :, None] >> shifts[None, None, :]) & np.uint64(1)
        codes |= bits.reshape(d_out, n_words * 64) << np.uint64(p)
    return codes[:, :d_in].T.astype(np.int32)
