"""AOT build orchestrator — `make artifacts` entry point.

Runs ONCE at build time (never on the request path):

  1. generate synthetic corpora (corpus.py)
  2. pretrain the substitute model family (pretrain.py)
  3. calibrate MoBiQuant (Alg. 1) + every static-PTQ baseline
  4. export self-contained .mobiq bundles for the Rust engine
  5. lower AOT HLO-text modules for the Rust PJRT runtime
     (HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
     instruction ids that xla_extension 0.5.1 rejects — see
     /opt/xla-example/README.md)

Usage:
    python -m compile.aot --out-dir ../artifacts [--models tiny-s,tiny-m]
                          [--ablations] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, export, model as model_mod, pretrain
from .config import MODEL_ZOO, PRETRAIN_STEPS, QuantConfig
from .kernels import ref as kref
from .kernels.mobislice_matmul import mobislice_matmul
from .quant import awq, gptq, mobislice, rotation, smoothquant
from .quant.calibrate import LINEARS, calibrate, clipped_params, _linear_input
from .quant.schedules import SCHEDULES


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})` and xla_extension 0.5.1's HLO text
    # parser silently fills them with ZEROS (we found model weights
    # zeroed on the Rust side; see DESIGN.md gotchas).
    try:
        return comp.as_hlo_text(print_large_constants=True)
    except TypeError:
        options = xc._xla.HloPrintOptions.default()
        options.print_large_constants = True
        return comp.as_hlo_module().to_string(options)


def lower_to_file(fn, args, path: str) -> None:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# Activation capture for the static-PTQ baselines
# ---------------------------------------------------------------------------

def capture_linear_inputs(params, cfg, tokens: np.ndarray):
    """FP activations feeding every linear: {(layer, name): (n_tok, d_in)}."""
    h = params["embed"][jnp.asarray(tokens.astype(np.int32))]
    outs = {}
    for li, bp in enumerate(params["layers"]):
        for name in LINEARS:
            x = _linear_input(bp, cfg, h, name)
            outs[(li, name)] = np.asarray(x).reshape(-1, x.shape[-1])
        h = jax.vmap(lambda xb, bp=bp: model_mod.block(
            xb, bp, cfg, 0, lambda l, n, xi, w: xi @ w))(h)
    return outs


# ---------------------------------------------------------------------------
# Static baseline calibration (per method, per bit-width)
# ---------------------------------------------------------------------------

def build_static_records(params, cfg, qcfg, acts, calib_omni, bits_list,
                         verbose=True):
    """Returns {method_key: {"meta":..., "records": {(l,n): rec}}}."""
    out = {}
    t0 = time.time()
    for bits in bits_list:
        for method in ("rtn", "gptq", "awq", "smoothquant", "quarot",
                       "spinquant"):
            key = f"{method}{bits}"
            recs = {}
            for li, bp in enumerate(params["layers"]):
                for name in LINEARS:
                    w = np.asarray(bp[name])
                    x = acts[(li, name)]
                    if method == "rtn":
                        r = gptq.rtn_record(w, bits, qcfg.group_size)
                    elif method == "gptq":
                        r = gptq.gptq_quantize(w, x, bits, qcfg.group_size)
                    elif method == "awq":
                        r = awq.awq_quantize(w, x, bits, qcfg.group_size)
                    elif method == "smoothquant":
                        r = smoothquant.smooth_quantize(w, x, bits,
                                                        qcfg.group_size)
                    elif method == "quarot":
                        r = rotation.quarot_quantize(w, bits,
                                                     qcfg.group_size)
                    else:
                        r = rotation.spinquant_quantize(w, x, bits,
                                                        qcfg.group_size,
                                                        n_signs=8)
                    recs[(li, name)] = r
            tf = next(iter(recs.values())).transform
            out[key] = {"meta": export.static_meta(method, bits, tf),
                        "records": recs}
            if verbose:
                print(f"  [static] {key} done ({time.time() - t0:.1f}s)",
                      flush=True)
    # OmniQuant-lite records come from the LWC calibration results
    for bits, cres in calib_omni.items():
        key = f"omniquant{bits}"
        recs = {}
        for li, bp in enumerate(params["layers"]):
            for name in LINEARS:
                w = np.asarray(bp[name])
                cal = cres.layers[li][name]
                p = clipped_params(w, cal.clip_lo, cal.clip_hi, bits,
                                   qcfg.group_size)
                from .quant import quantizer
                codes = np.asarray(quantizer.quantize(jnp.asarray(w), p),
                                   np.uint8)
                recs[(li, name)] = gptq.StaticQuantLinear(
                    codes=codes, scale=np.asarray(p.scale, np.float32),
                    zero=np.asarray(p.zero, np.float32), bits=bits,
                    group_size=qcfg.group_size,
                    act_scale=np.ones(w.shape[0], np.float32),
                    transform="none")
        out[key] = {"meta": export.static_meta("omniquant", bits, "none"),
                    "records": recs}
    return out


# ---------------------------------------------------------------------------
# Bundle assembly
# ---------------------------------------------------------------------------

def build_bundle(path, params, cfg, qcfg, calib_mobiq, statics,
                 pretrain_summary, golden_tokens):
    w = export.BundleWriter()
    w.meta.update(export.model_meta(cfg, qcfg))
    w.meta["pretrain"] = {k: v for k, v in pretrain_summary.items()
                          if k != "curve"}
    w.meta["pretrain"]["curve"] = [[int(s), float(l)] for s, l in
                                   pretrain_summary["curve"]]
    w.meta["static_methods"] = {k: v["meta"] for k, v in statics.items()}
    export.add_fp_params(w, params)
    export.add_mobiq(w, params, calib_mobiq, qcfg)
    for key, entry in statics.items():
        for (li, name), rec in entry["records"].items():
            export.add_static_record(w, key, li, name, rec)

    # golden vectors: FP logits + fixed-k MoBiSlice logits for Rust parity
    logits = {}
    tok = jnp.asarray(golden_tokens.astype(np.int32))
    logits["logits_fp"] = np.asarray(
        model_mod.forward(params, tok, cfg))

    for k in range(1, qcfg.n_slices + 1):
        qparams = _reconstructed_params(params, cfg, qcfg, calib_mobiq, k)
        logits[f"logits_q{k * qcfg.slice_bits}"] = np.asarray(
            model_mod.forward(qparams, tok, cfg))
    export.add_golden(w, golden_tokens, logits)
    w.write(path)
    return logits


def _reconstructed_params(params, cfg, qcfg, calib, k):
    """Model params with every linear replaced by its k-slice reconstruction."""
    new_layers = []
    for lp, lc in zip(params["layers"], calib.layers):
        nlp = dict(lp)
        for name in LINEARS:
            wmat = lp[name]
            cal = lc[name]
            base = clipped_params(wmat, cal.clip_lo, cal.clip_hi,
                                  qcfg.slice_bits, qcfg.group_size)
            sw = mobislice.decompose(wmat, base, qcfg.n_slices,
                                     qcfg.slice_bits)
            nlp[name] = mobislice.reconstruct(sw, k)
        new_layers.append(nlp)
    return {**params, "layers": new_layers}


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------

def lower_model_hlos(out_dir, name, params, cfg, qcfg, calib_mobiq,
                     seq_len=128):
    os.makedirs(out_dir, exist_ok=True)
    spec = jax.ShapeDtypeStruct((seq_len,), jnp.int32)

    def fp_fn(tokens):
        return (model_mod.forward(params, tokens, cfg),)
    lower_to_file(fp_fn, (spec,), os.path.join(out_dir, f"{name}_fp.hlo.txt"))

    for k in range(1, qcfg.n_slices + 1):
        qp = _reconstructed_params(params, cfg, qcfg, calib_mobiq, k)

        def q_fn(tokens, qp=qp):
            return (model_mod.forward(qp, tokens, cfg),)
        bits = k * qcfg.slice_bits
        lower_to_file(q_fn, (spec,),
                      os.path.join(out_dir, f"{name}_q{bits}.hlo.txt"))

    # standalone Pallas kernel module (layer-0 wq shapes)
    d_in = cfg.d_model
    d_out = cfg.d_model
    t = 16
    xspec = jax.ShapeDtypeStruct((t, d_in), jnp.float32)
    pspec = jax.ShapeDtypeStruct(
        (qcfg.n_slices, qcfg.slice_bits, d_in // 32, d_out), jnp.int32)
    sspec = jax.ShapeDtypeStruct((d_in // qcfg.group_size, d_out),
                                 jnp.float32)
    mspec = jax.ShapeDtypeStruct((t, qcfg.n_slices), jnp.float32)

    def kernel_fn(x, planes, scale, zero, mask):
        return (mobislice_matmul(x, planes, scale, zero, mask,
                                 slice_bits=qcfg.slice_bits,
                                 group_size=qcfg.group_size,
                                 tile_m=t, tile_n=d_out),)
    lower_to_file(kernel_fn, (xspec, pspec, sspec, sspec, mspec),
                  os.path.join(out_dir, f"{name}_kernel.hlo.txt"))

    # layer-0 wq router module
    cal = calib_mobiq.layers[0]["wq"]
    w1, b1 = jnp.asarray(cal.router["w1"]), jnp.asarray(cal.router["b1"])
    w2, b2 = jnp.asarray(cal.router["w2"]), jnp.asarray(cal.router["b2"])

    def router_fn(x):
        return (jax.nn.relu(x @ w1 + b1) @ w2 + b2,)
    lower_to_file(router_fn, (jax.ShapeDtypeStruct((t, d_in), jnp.float32),),
                  os.path.join(out_dir, f"{name}_router.hlo.txt"))


# ---------------------------------------------------------------------------
# Main pipeline
# ---------------------------------------------------------------------------

def run(out_dir: str, models, ablations: bool, force: bool,
        fast: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    corpus_dir = os.path.join(out_dir, "corpus")
    marker = os.path.join(out_dir, "manifest.json")
    if os.path.exists(marker) and not force:
        existing = json.load(open(marker))
        if set(models) <= set(existing.get("models", [])) and (
                not ablations or existing.get("ablations")):
            print("[aot] artifacts up to date; skipping (use --force)")
            return

    t0 = time.time()
    print("[aot] generating corpora", flush=True)
    corpus.write_corpora(corpus_dir,
                         train_chars=120_000 if fast else 900_000,
                         valid_chars=30_000 if fast else 60_000)

    manifest = {"models": [], "ablations": ablations, "hlo": {},
                "elapsed": {}}
    qcfg = QuantConfig()
    golden_tokens = corpus.tokenize(
        corpus.generate("wiki", 4096, seed=1234))[:64].astype(np.int32)

    for mname in models:
        cfg = MODEL_ZOO[mname]
        steps = 60 if fast else PRETRAIN_STEPS[mname]
        ckpt = os.path.join(out_dir, f"ckpt_{mname}.npz")
        bundle_done = os.path.join(out_dir, f"{mname}.mobiq")
        hlo_done = os.path.join(out_dir, "hlo", f"{mname}_router.hlo.txt")
        if os.path.exists(bundle_done) and os.path.exists(hlo_done) \
                and not force:
            print(f"[aot] {mname} bundle up to date; skipping", flush=True)
            manifest["models"].append(mname)
            continue
        print(f"[aot] pretraining {mname} ({steps} steps)", flush=True)
        if os.path.exists(ckpt) and not force:
            params = pretrain.load_params(ckpt)
            summary = json.load(open(ckpt + ".json"))
        else:
            params, summary = pretrain.pretrain(cfg, corpus_dir, steps)
            pretrain.save_params(params, ckpt)
            json.dump(summary, open(ckpt + ".json", "w"))

        calib_tokens = _calib_tokens(corpus_dir, "wiki", qcfg, fast)

        print(f"[aot] calibrating MoBiQuant on {mname}", flush=True)
        s1, s2 = (8, 20) if fast else (30, 90)
        calib_mobiq = calibrate(params, cfg, qcfg, calib_tokens,
                                mode="mobiq", stage1_steps=s1,
                                stage2_steps=s2)
        calib_omni = {}
        for bits in ((3,) if fast else (2, 3, 4)):
            print(f"[aot] calibrating OmniQuant-lite @{bits}b", flush=True)
            calib_omni[bits] = calibrate(params, cfg, qcfg, calib_tokens,
                                         mode="omniquant", bits=bits,
                                         stage1_steps=s1, stage2_steps=0)

        print(f"[aot] static baselines on {mname}", flush=True)
        acts = capture_linear_inputs(params, cfg,
                                     calib_tokens[:16 if fast else 32])
        statics = build_static_records(params, cfg, qcfg, acts, calib_omni,
                                       (3,) if fast else (3, 4))

        bundle_path = os.path.join(out_dir, f"{mname}.mobiq")
        print(f"[aot] writing {bundle_path}", flush=True)
        build_bundle(bundle_path, params, cfg, qcfg, calib_mobiq, statics,
                     summary, golden_tokens)

        hlo_dir = os.path.join(out_dir, "hlo")
        print(f"[aot] lowering HLO modules for {mname}", flush=True)
        lower_model_hlos(hlo_dir, mname, params, cfg, qcfg, calib_mobiq)
        manifest["models"].append(mname)
        manifest["elapsed"][mname] = time.time() - t0

    if ablations:
        run_ablations(out_dir, corpus_dir, qcfg, fast)

    # compatibility alias expected by the Makefile dependency rule
    first_hlo = os.path.join(out_dir, "hlo", f"{models[0]}_fp.hlo.txt")
    alias = os.path.join(out_dir, "model.hlo.txt")
    if os.path.exists(first_hlo):
        with open(first_hlo) as src, open(alias, "w") as dst:
            dst.write(src.read())

    json.dump(manifest, open(marker, "w"), indent=1)
    print(f"[aot] DONE in {time.time() - t0:.0f}s", flush=True)


def _calib_tokens(corpus_dir, domain, qcfg, fast):
    with open(os.path.join(corpus_dir, f"{domain}.train.txt")) as f:
        stream = corpus.tokenize(f.read())
    n = 24 if fast else qcfg.nsamples
    seq = 64 if fast else qcfg.seq_len
    rng = np.random.default_rng(7)
    starts = rng.integers(0, len(stream) - seq - 1, size=n)
    return np.stack([stream[s:s + seq] for s in starts])


def run_ablations(out_dir, corpus_dir, qcfg, fast):
    """App. D ablations on tiny-s: schedules x target bits x calib set."""
    abl_dir = os.path.join(out_dir, "ablations")
    os.makedirs(abl_dir, exist_ok=True)
    cfg = MODEL_ZOO["tiny-s"]
    ckpt = os.path.join(out_dir, "ckpt_tiny-s.npz")
    params = pretrain.load_params(ckpt)
    summary = json.load(open(ckpt + ".json"))
    golden_tokens = corpus.tokenize(
        corpus.generate("wiki", 4096, seed=1234))[:64].astype(np.int32)
    # ablations retrain the router 13x on tiny-s: keep each job short
    s1, s2 = (8, 20) if fast else (12, 40)

    jobs = []
    for sched in SCHEDULES:                       # Fig. 8
        jobs.append((f"sched_{sched}", dict(schedule=sched), "wiki"))
    for tb in (2.5, 3.0, 3.5, 4.0, 5.0):          # Fig. 9
        jobs.append((f"target_{tb}", dict(target_bits=tb), "wiki"))
    for dom in ("wiki", "web", "news", "mix"):    # Tab. 3
        jobs.append((f"calib_{dom}", dict(), dom))

    for tag, kwargs, dom in jobs:
        path = os.path.join(abl_dir, f"tiny-s_{tag}.mobiq")
        if os.path.exists(path):
            continue
        print(f"[aot] ablation {tag}", flush=True)
        if dom == "mix":
            toks = np.concatenate([
                _calib_tokens(corpus_dir, d, qcfg, fast)[:qcfg.nsamples // 3]
                for d in ("wiki", "web", "news")])
        else:
            toks = _calib_tokens(corpus_dir, dom, qcfg, fast)
        cres = calibrate(params, cfg, qcfg, toks, mode="mobiq",
                         stage1_steps=s1, stage2_steps=s2, verbose=False,
                         **kwargs)
        build_bundle(path, params, cfg, qcfg, cres, {}, summary,
                     golden_tokens)


def relower_from_bundle(out_dir: str, mname: str, seq_len: int = 128):
    """Re-lower all HLO modules for a model from its existing bundle
    (no recalibration): used after fixing the HLO printer and whenever
    only the lowering code changes."""
    from .export import read_bundle
    from .quant.mobislice import unpack_bitplanes, residual_params
    from .quant.quantizer import GroupQuantParams, dequantize

    cfg = MODEL_ZOO[mname]
    qcfg = QuantConfig()
    params = pretrain.load_params(os.path.join(out_dir,
                                               f"ckpt_{mname}.npz"))
    _, tensors = read_bundle(os.path.join(out_dir, f"{mname}.mobiq"))
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    spec = jax.ShapeDtypeStruct((seq_len,), jnp.int32)

    def fp_fn(tokens):
        return (model_mod.forward(params, tokens, cfg),)
    lower_to_file(fp_fn, (spec,),
                  os.path.join(hlo_dir, f"{mname}_fp.hlo.txt"))

    def recon(li, name, k):
        pre = f"mobiq.layers.{li}.{name}"
        d_in = params["layers"][li][name].shape[0]
        base = GroupQuantParams(jnp.asarray(tensors[f"{pre}.scale"]),
                                jnp.asarray(tensors[f"{pre}.zero"]),
                                qcfg.slice_bits, qcfg.group_size)
        acc = None
        for e in range(k):
            codes = unpack_bitplanes(
                tensors[f"{pre}.slice{e}.planes"].astype(np.uint64), d_in)
            deq = dequantize(jnp.asarray(codes),
                             residual_params(base, e + 1, qcfg.slice_bits))
            acc = deq if acc is None else acc + deq
        return acc

    for k in range(1, qcfg.n_slices + 1):
        qp = {**params, "layers": [
            {**lp, **{n: recon(li, n, k) for n in LINEARS}}
            for li, lp in enumerate(params["layers"])]}

        def q_fn(tokens, qp=qp):
            return (model_mod.forward(qp, tokens, cfg),)
        bits = k * qcfg.slice_bits
        lower_to_file(q_fn, (spec,),
                      os.path.join(hlo_dir, f"{mname}_q{bits}.hlo.txt"))

    # kernel + router modules
    d = cfg.d_model
    t = 16
    xspec = jax.ShapeDtypeStruct((t, d), jnp.float32)
    pspec = jax.ShapeDtypeStruct(
        (qcfg.n_slices, qcfg.slice_bits, d // 32, d), jnp.int32)
    sspec = jax.ShapeDtypeStruct((d // qcfg.group_size, d), jnp.float32)
    mspec = jax.ShapeDtypeStruct((t, qcfg.n_slices), jnp.float32)

    def kernel_fn(x, planes, scale, zero, mask):
        return (mobislice_matmul(x, planes, scale, zero, mask,
                                 slice_bits=qcfg.slice_bits,
                                 group_size=qcfg.group_size,
                                 tile_m=t, tile_n=d),)
    lower_to_file(kernel_fn, (xspec, pspec, sspec, sspec, mspec),
                  os.path.join(hlo_dir, f"{mname}_kernel.hlo.txt"))

    pre = "mobiq.layers.0.wq"
    w1 = jnp.asarray(tensors[f"{pre}.router.w1"])
    b1 = jnp.asarray(tensors[f"{pre}.router.b1"])
    w2 = jnp.asarray(tensors[f"{pre}.router.w2"])
    b2 = jnp.asarray(tensors[f"{pre}.router.b2"])

    def router_fn(x):
        return (jax.nn.relu(x @ w1 + b1) @ w2 + b2,)
    lower_to_file(router_fn, (jax.ShapeDtypeStruct((t, d), jnp.float32),),
                  os.path.join(hlo_dir, f"{mname}_router.hlo.txt"))
    print(f"[aot] relowered HLO modules for {mname}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="(legacy) single-HLO alias path; implied by out-dir")
    ap.add_argument("--models", default="tiny-s,tiny-m")
    ap.add_argument("--ablations", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="smoke-scale build for CI/tests")
    ap.add_argument("--relower", action="store_true",
                    help="re-lower HLO modules from existing bundles only")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or out_dir
    if args.relower:
        for m in args.models.split(","):
            relower_from_bundle(out_dir, m)
        return
    run(out_dir, args.models.split(","), args.ablations, args.force,
        args.fast)


if __name__ == "__main__":
    main()
