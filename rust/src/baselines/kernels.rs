//! Baseline kernel implementations (see module docs in mod.rs).

use crate::mobiq::bitplane::PackedSlice;
use crate::mobiq::quantizer::{quantize, GroupParams};

// ---------------------------------------------------------------------------
// AnyPrecisionLLM-like: bit-planes + centroid table per (group, channel)
// ---------------------------------------------------------------------------

pub struct ApLinear {
    /// Merged integer codes at max precision, packed per bit: planes[p]
    /// over d_in, per output channel (same layout as PackedSlice).
    pub planes: PackedSlice,
    /// Centroid tables: (n_groups, d_out, 2^max_bits) dequantized values.
    pub centroids: Vec<f32>,
    pub max_bits: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub n_groups: usize,
    pub group_size: usize,
}

impl ApLinear {
    /// Build from dense weights with uniform codes (structurally faithful:
    /// the overhead is the per-weight table gather, not the centroids'
    /// values).
    pub fn from_dense(w: &[f32], d_in: usize, d_out: usize,
                      group_size: usize, max_bits: usize) -> ApLinear {
        let p = GroupParams::from_minmax(w, d_in, d_out, max_bits as u32,
                                         group_size);
        let codes = quantize(w, &p);
        let planes = PackedSlice::from_codes(&codes, d_in, d_out, max_bits);
        let levels = 1usize << max_bits;
        let n_groups = p.n_groups;
        let mut centroids = vec![0f32; n_groups * d_out * levels];
        for g in 0..n_groups {
            for o in 0..d_out {
                let (s, z) = p.at(g, o);
                for c in 0..levels {
                    centroids[(g * d_out + o) * levels + c] =
                        s * (c as f32 - z + 0.5);
                }
            }
        }
        ApLinear { planes, centroids, max_bits, d_in, d_out, n_groups,
                   group_size }
    }

    /// GEMV at `bits` effective precision: unpack the top `bits` planes
    /// (bit-plane fetch, like ours) then dequantize each weight through
    /// the centroid table — the AnyPrecisionLLM overhead.
    pub fn gemv(&self, x: &[f32], bits: usize, out: &mut [f32]) {
        let levels = 1usize << self.max_bits;
        let drop = self.max_bits - bits.min(self.max_bits);
        for o in 0..self.d_out {
            let mut acc = 0f32;
            for g in 0..self.n_groups {
                let tab = &self.centroids[(g * self.d_out + o) * levels..];
                for j in 0..self.group_size {
                    let row = g * self.group_size + j;
                    // gather the code bit-by-bit from the top planes
                    let mut code = 0usize;
                    for p in drop..self.max_bits {
                        let w = self.planes.plane(p, o)[row / 64];
                        code |= (((w >> (row % 64)) & 1) as usize) << p;
                    }
                    acc += tab[code] * x[row];
                }
            }
            out[o] = acc;
        }
    }

    pub fn nbytes(&self) -> usize {
        self.planes.nbytes() + self.centroids.len() * 4
    }
}

// ---------------------------------------------------------------------------
// AnyBCQ-like: binary planes with per-plane scale sets
// ---------------------------------------------------------------------------

pub struct AbcqLinear {
    /// One binary (+-1) plane per bit of precision.
    pub planes: Vec<PackedSlice>, // each slice_bits = 1
    /// Per-plane scales: (n_planes, n_groups, d_out).
    pub alphas: Vec<f32>,
    pub n_planes: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub n_groups: usize,
    pub group_size: usize,
}

impl AbcqLinear {
    /// Greedy binary-coded quantization: plane p takes sign(residual),
    /// alpha = mean |residual| per (group, channel).
    pub fn from_dense(w: &[f32], d_in: usize, d_out: usize,
                      group_size: usize, n_planes: usize) -> AbcqLinear {
        let n_groups = d_in / group_size;
        let mut resid = w.to_vec();
        let mut planes = Vec::with_capacity(n_planes);
        let mut alphas = vec![0f32; n_planes * n_groups * d_out];
        for p in 0..n_planes {
            let mut bits = vec![0u8; d_in * d_out];
            for g in 0..n_groups {
                for o in 0..d_out {
                    let mut mean_abs = 0f32;
                    for j in 0..group_size {
                        mean_abs += resid[(g * group_size + j) * d_out + o]
                            .abs();
                    }
                    mean_abs /= group_size as f32;
                    alphas[(p * n_groups + g) * d_out + o] = mean_abs;
                    for j in 0..group_size {
                        let idx = (g * group_size + j) * d_out + o;
                        let sign = if resid[idx] >= 0.0 { 1f32 } else { -1f32 };
                        bits[idx] = (sign > 0.0) as u8;
                        resid[idx] -= sign * mean_abs;
                    }
                }
            }
            planes.push(PackedSlice::from_codes(&bits, d_in, d_out, 1));
        }
        AbcqLinear { planes, alphas, n_planes, d_in, d_out, n_groups,
                     group_size }
    }

    /// GEMV using the first `k` planes.  Per-plane scale multiply — the
    /// AnyBCQ dequantization overhead (paper Fig. 3b).
    pub fn gemv(&self, x: &[f32], k: usize, group_sums: &[f32],
                out: &mut [f32]) {
        let k = k.min(self.n_planes);
        for o in 0..self.d_out {
            let mut acc = 0f32;
            for g in 0..self.n_groups {
                let gsum = group_sums[g];
                for p in 0..k {
                    // masked sum over set bits (+1) vs unset (-1):
                    // sum = 2*masked - gsum
                    let plane = self.planes[p].plane(0, o);
                    let mut masked = 0f32;
                    let lo = g * self.group_size;
                    let hi = lo + self.group_size;
                    let mut row = lo;
                    while row < hi {
                        let word = plane[row / 64];
                        let base_bit = row % 64;
                        let span = (hi - row).min(64 - base_bit);
                        let mut m = (word >> base_bit)
                            & mask_lo(span);
                        while m != 0 {
                            masked += x[row + m.trailing_zeros() as usize];
                            m &= m - 1;
                        }
                        row += span;
                    }
                    let alpha =
                        self.alphas[(p * self.n_groups + g) * self.d_out + o];
                    acc += alpha * (2.0 * masked - gsum);
                }
            }
            out[o] = acc;
        }
    }

    pub fn nbytes(&self) -> usize {
        self.planes.iter().map(|p| p.nbytes()).sum::<usize>()
            + self.alphas.len() * 4
    }
}

// ---------------------------------------------------------------------------
// QuIP#/QTIP-like vector quantization
// ---------------------------------------------------------------------------

pub struct VqLinear {
    /// 8-bit code per 4-weight chunk along d_in, per output channel:
    /// (d_out, d_in/4).
    pub codes: Vec<u8>,
    /// Codebook: (256, 4).
    pub codebook: Vec<f32>,
    /// Per-output scale.
    pub scales: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

impl VqLinear {
    /// K-means-free codebook: fixed E8-like lattice of 256 sign/magnitude
    /// patterns; each chunk maps to its nearest entry.  Structurally
    /// faithful (gather per 4 weights); fitting quality is secondary.
    pub fn from_dense(w: &[f32], d_in: usize, d_out: usize) -> VqLinear {
        assert_eq!(d_in % 4, 0);
        // codebook: all sign patterns x 16 magnitude shapes
        let mut codebook = vec![0f32; 256 * 4];
        for i in 0..256 {
            for j in 0..4 {
                let sign = if (i >> j) & 1 == 1 { 1f32 } else { -1f32 };
                let mag = 0.4 + 0.4 * (((i >> 4) & 0xF) as f32 / 15.0)
                    * ((j % 2) as f32 + 1.0);
                codebook[i * 4 + j] = sign * mag;
            }
        }
        let mut codes = vec![0u8; d_out * d_in / 4];
        let mut scales = vec![0f32; d_out];
        for o in 0..d_out {
            // per-output scale: rms of the column
            let mut rms = 0f32;
            for r in 0..d_in {
                rms += w[r * d_out + o] * w[r * d_out + o];
            }
            let s = (rms / d_in as f32).sqrt().max(1e-8);
            scales[o] = s;
            for c in 0..d_in / 4 {
                let chunk: Vec<f32> = (0..4)
                    .map(|j| w[(c * 4 + j) * d_out + o] / s)
                    .collect();
                let mut best = (f32::INFINITY, 0usize);
                for e in 0..256 {
                    let mut d2 = 0f32;
                    for j in 0..4 {
                        let diff = chunk[j] - codebook[e * 4 + j];
                        d2 += diff * diff;
                    }
                    if d2 < best.0 {
                        best = (d2, e);
                    }
                }
                codes[o * (d_in / 4) + c] = best.1 as u8;
            }
        }
        VqLinear { codes, codebook, scales, d_in, d_out }
    }

    /// GEMV: codebook gather per 4 weights (the QuIP#/QTIP decode cost).
    pub fn gemv(&self, x: &[f32], out: &mut [f32]) {
        let chunks = self.d_in / 4;
        for o in 0..self.d_out {
            let mut acc = 0f32;
            let row = &self.codes[o * chunks..(o + 1) * chunks];
            for (c, &code) in row.iter().enumerate() {
                let entry = &self.codebook[code as usize * 4..];
                let xs = &x[c * 4..c * 4 + 4];
                acc += entry[0] * xs[0] + entry[1] * xs[1]
                    + entry[2] * xs[2] + entry[3] * xs[3];
            }
            out[o] = acc * self.scales[o];
        }
    }

    pub fn nbytes(&self) -> usize {
        self.codes.len() + self.codebook.len() * 4 + self.scales.len() * 4
    }
}

// ---------------------------------------------------------------------------
// ABQ-LLM-like static low-bit dense kernel
// ---------------------------------------------------------------------------

pub struct AbqLinear {
    pub weights: Vec<f32>, // dequantized at fixed bits
    pub bits: usize,
    pub d_in: usize,
    pub d_out: usize,
}

impl AbqLinear {
    pub fn from_dense(w: &[f32], d_in: usize, d_out: usize,
                      group_size: usize, bits: usize) -> AbqLinear {
        let p = GroupParams::from_minmax(w, d_in, d_out, bits as u32,
                                         group_size);
        let codes = quantize(w, &p);
        let weights = crate::mobiq::quantizer::dequantize(&codes, &p);
        AbqLinear { weights, bits, d_in, d_out }
    }

    pub fn gemv(&self, x: &[f32], out: &mut [f32]) {
        crate::mobiq::gemv::matvec(&self.weights, x, out, self.d_in,
                                   self.d_out);
    }
}

#[inline]
fn mask_lo(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobiq::gemv::matvec;
    use crate::util::prng::Pcg;

    fn setup(seed: u64, d_in: usize, d_out: usize)
             -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        (rng.normal_vec(d_in * d_out, 0.2), rng.normal_vec(d_in, 1.0))
    }

    fn rel_err(y: &[f32], y_ref: &[f32]) -> f32 {
        let num: f32 = y.iter().zip(y_ref)
            .map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = y_ref.iter().map(|b| b * b).sum();
        (num / den.max(1e-12)).sqrt()
    }

    #[test]
    fn ap_sim_accuracy_improves_with_bits() {
        let (w, x) = setup(1, 64, 16);
        let ap = ApLinear::from_dense(&w, 64, 16, 32, 8);
        let mut y_ref = vec![0f32; 16];
        matvec(&w, &x, &mut y_ref, 64, 16);
        let mut prev = f32::INFINITY;
        for bits in [2, 4, 8] {
            let mut y = vec![0f32; 16];
            ap.gemv(&x, bits, &mut y);
            let e = rel_err(&y, &y_ref);
            assert!(e < prev, "bits={bits}: {e} !< {prev}");
            prev = e;
        }
        assert!(prev < 0.02, "8-bit AP error {prev}");
    }

    #[test]
    fn abcq_sim_accuracy_improves_with_planes() {
        let (w, x) = setup(2, 64, 16);
        let q = AbcqLinear::from_dense(&w, 64, 16, 32, 8);
        let gsums: Vec<f32> = (0..2)
            .map(|g| x[g * 32..(g + 1) * 32].iter().sum())
            .collect();
        let mut y_ref = vec![0f32; 16];
        matvec(&w, &x, &mut y_ref, 64, 16);
        let mut prev = f32::INFINITY;
        for k in [1, 2, 4, 8] {
            let mut y = vec![0f32; 16];
            q.gemv(&x, k, &gsums, &mut y);
            let e = rel_err(&y, &y_ref);
            assert!(e < prev + 1e-6, "k={k}: {e} !< {prev}");
            prev = e;
        }
        assert!(prev < 0.1, "8-plane BCQ error {prev}");
    }

    #[test]
    fn vq_sim_roughly_reconstructs() {
        let (w, x) = setup(3, 64, 16);
        let vq = VqLinear::from_dense(&w, 64, 16);
        let mut y_ref = vec![0f32; 16];
        matvec(&w, &x, &mut y_ref, 64, 16);
        let mut y = vec![0f32; 16];
        vq.gemv(&x, &mut y);
        // coarse 2-bit-equivalent quality: just require correlation
        let c = crate::util::stats::pearson(
            &y.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &y_ref.iter().map(|&v| v as f64).collect::<Vec<_>>());
        assert!(c > 0.5, "vq corr {c}");
    }

    #[test]
    fn abq_matches_rtn_dequant() {
        let (w, x) = setup(4, 64, 16);
        let abq = AbqLinear::from_dense(&w, 64, 16, 32, 4);
        let mut y = vec![0f32; 16];
        abq.gemv(&x, &mut y);
        let mut y_ref = vec![0f32; 16];
        matvec(&abq.weights, &x, &mut y_ref, 64, 16);
        assert_eq!(y, y_ref);
    }
}
