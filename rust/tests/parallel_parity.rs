//! Parallel == serial parity for the persistent fork-join rewiring:
//! the pooled forward pass (batched linears + tiled attention + the
//! block-parallel elementwise stages) must be *bit-identical* to the
//! serial path — workers only partition which rows they compute, never
//! the per-row math or its accumulation order — and the cross-slot
//! `decode_batch` attention must match a per-slot decode oracle.
//! All on synthetic models, so no `make artifacts` is needed.

use std::sync::Arc;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::transformer::{argmax, DecodeSlot, DecodeStats};
use mobiquant::util::threadpool::ThreadPool;

const TOL: f32 = 1e-4;

/// Whole-prompt block forward with an attached pool vs the same-seed
/// model without one: logits must be exactly equal, across GQA shapes,
/// a prompt long enough to cross prefill-chunk and attention-tile
/// boundaries, and both fixed and elastic routing.
#[test]
fn pooled_forward_logits_bit_identical_to_serial() {
    for &(n_heads, n_kv) in &[(4usize, 2usize), (8, 2)] {
        let mut pooled = synth_model_shaped(31, n_heads, n_kv, 160);
        let serial = synth_model_shaped(31, n_heads, n_kv, 160);
        pooled.set_pool(Arc::new(ThreadPool::new(3)));
        let tokens: Vec<u32> = (0..130)
            .map(|i| ((i * 7 + 3) % 256) as u32)
            .collect();
        for prec in [Precision::Fixed(2), Precision::elastic(4.0)] {
            let a = pooled.forward_logits(&tokens, prec).unwrap();
            let b = serial.forward_logits(&tokens, prec).unwrap();
            assert_eq!(a, b,
                       "{n_heads}h/{n_kv}kv {prec:?}: pooled forward \
                        diverged from serial");
        }
    }
}

/// Drive one sequence end-to-end (prefill + decode) on a pooled model
/// and a serial model: generated tokens must be identical.
#[test]
fn pooled_generate_matches_serial() {
    let mut pooled = synth_model_shaped(37, 4, 2, 128);
    let serial = synth_model_shaped(37, 4, 2, 128);
    pooled.set_pool(Arc::new(ThreadPool::new(4)));
    let prompt: Vec<u32> = "the elastic pool".bytes()
        .map(|b| b as u32).collect();
    let mut sa = DecodeStats::new(pooled.cfg.n_layers);
    let mut sb = DecodeStats::new(serial.cfg.n_layers);
    let prec = Precision::elastic(4.0);
    let a = pooled.generate(&prompt, 24, prec, &mut sa).unwrap();
    let b = serial.generate(&prompt, 24, prec, &mut sb).unwrap();
    assert_eq!(a, b, "pooled generation diverged from serial");
    assert_eq!(sa.tokens, sb.tokens);
    assert_eq!(sa.total_bits, sb.total_bits,
               "routing must be unaffected by the pool");
}

/// Cross-slot `decode_batch` vs the per-slot oracle (`decode_step`
/// sequence by sequence) at 1 / 2 / 5 concurrent slots with ragged
/// prompt lengths: every decoded token must agree and every logits row
/// must match within FP-reordering tolerance.
#[test]
fn cross_slot_decode_matches_per_slot_oracle() {
    for &n_slots in &[1usize, 2, 5] {
        let mut model = synth_model_shaped(57, 4, 2, 256);
        model.set_pool(Arc::new(ThreadPool::new(3)));
        let oracle_model = synth_model_shaped(57, 4, 2, 256);
        let prec = Precision::Fixed(2);
        let n_new = 6usize;
        // ragged contexts; at 5 slots the batch clears
        // ATTN_PARALLEL_MIN_WORK (hd * total_positions: 5 x ~215 x 16
        // >= 2^14) and takes the parallel cross-slot branch, while the
        // 1- and 2-slot cases exercise the serial-gate fallback
        let prompts: Vec<Vec<u32>> = (0..n_slots)
            .map(|s| (0..205 + 11 * s)
                .map(|i| ((i * 5 + 7 * s + 2) % 256) as u32)
                .collect())
            .collect();

        // oracle: each sequence advanced alone through per-token
        // decode on the pool-free model
        let mut want_tokens: Vec<Vec<u32>> = Vec::new();
        let mut want_logits: Vec<Vec<f32>> = Vec::new();
        for prompt in &prompts {
            let (mut arena, seq) = oracle_model.new_kv();
            let mut scratch = oracle_model.new_scratch();
            let mut stats = DecodeStats::new(oracle_model.cfg.n_layers);
            let mut toks = Vec::new();
            let mut logits = Vec::new();
            for &tok in prompt {
                oracle_model.decode_step(tok, &mut arena, seq, prec,
                                         &mut scratch, &mut stats)
                    .unwrap();
            }
            let mut last = argmax(&scratch.logits) as u32;
            toks.push(last);
            for _ in 1..=n_new {
                oracle_model.decode_step(last, &mut arena, seq, prec,
                                         &mut scratch, &mut stats)
                    .unwrap();
                logits.extend_from_slice(&scratch.logits);
                last = argmax(&scratch.logits) as u32;
                toks.push(last);
            }
            want_tokens.push(toks);
            want_logits.push(logits);
        }

        // subject: all slots coalesced through decode_batch on the
        // pooled model, all in ONE shared paged arena (prefill via
        // per-token decode so both paths enter decode with identical
        // KV content)
        let mut scratch = model.new_scratch();
        let mut arena = model.new_arena(n_slots);
        let seqs: Vec<_> = (0..n_slots).map(|_| arena.alloc_seq())
            .collect();
        let mut stats: Vec<DecodeStats> = (0..n_slots)
            .map(|_| DecodeStats::new(model.cfg.n_layers))
            .collect();
        let mut next: Vec<u32> = Vec::new();
        for (s, prompt) in prompts.iter().enumerate() {
            for &tok in prompt {
                model.decode_step(tok, &mut arena, seqs[s], prec,
                                  &mut scratch, &mut stats[s]).unwrap();
            }
            next.push(argmax(&scratch.logits) as u32);
        }
        let vocab = model.cfg.vocab_size;
        let mut got_tokens: Vec<Vec<u32>> = next.iter()
            .map(|&t| vec![t]).collect();
        for step in 0..n_new {
            {
                let mut slots: Vec<DecodeSlot> = Vec::new();
                for ((&seq, st), &tok) in seqs.iter()
                    .zip(stats.iter_mut()).zip(&next) {
                    slots.push(DecodeSlot { token: tok, seq,
                                            stats: st });
                }
                model.decode_batch(&mut slots, &mut arena, prec,
                                   &mut scratch)
                    .unwrap();
            }
            for s in 0..n_slots {
                let row = &scratch.block.logits[s * vocab
                    ..(s + 1) * vocab];
                let want = &want_logits[s][step * vocab
                    ..(step + 1) * vocab];
                for (i, (a, b)) in row.iter().zip(want).enumerate() {
                    assert!((a - b).abs() < TOL,
                            "slots={n_slots} slot {s} step {step} \
                             logit[{i}]: batched {a} vs oracle {b}");
                }
                let tok = argmax(row) as u32;
                got_tokens[s].push(tok);
                next[s] = tok;
            }
        }
        for (s, (got, want)) in got_tokens.iter().zip(&want_tokens)
            .enumerate() {
            assert_eq!(got, want,
                       "slots={n_slots} slot {s}: cross-slot decode \
                        diverged from the per-slot oracle");
        }
    }
}
