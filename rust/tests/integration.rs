//! Integration tests over the real artifact bundle (tiny-s).
//!
//! Require `make artifacts` (MODELS at least tiny-s).  They are skipped
//! gracefully when the bundle is missing so `cargo test` stays green on a
//! fresh checkout.

use mobiquant::coordinator::{Server, ServerConfig};
use mobiquant::data::{corpus, ppl};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;

fn bundle() -> Option<Bundle> {
    let path = mobiquant::artifacts_dir().join("tiny-s.mobiq");
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)",
                  path.display());
        return None;
    }
    Some(Bundle::load(path).expect("bundle loads"))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn golden_fp_logits_match_jax() {
    let Some(b) = bundle() else { return };
    let model = Model::load(&b, BackendKind::Fp32).unwrap();
    let tokens: Vec<u32> = b.tensor("golden.tokens").unwrap()
        .i32().unwrap().iter().map(|&t| t as u32).collect();
    let (_, want) = b.f32("golden.logits_fp").unwrap();
    let got = model.forward_logits(&tokens, Precision::Fixed(4)).unwrap();
    assert_eq!(got.len(), want.len());
    let d = max_abs_diff(&got, want);
    assert!(d < 2e-2, "fp logits diverge from JAX: max abs diff {d}");
}

#[test]
fn golden_quantized_logits_match_jax() {
    let Some(b) = bundle() else { return };
    let tokens: Vec<u32> = b.tensor("golden.tokens").unwrap()
        .i32().unwrap().iter().map(|&t| t as u32).collect();
    for k in 1..=4usize {
        let bits = 2 * k;
        let name = format!("golden.logits_q{bits}");
        let (_, want) = b.f32(&name).unwrap();
        // dense reconstruction path (exactly what JAX lowered)
        let model = Model::load(&b, BackendKind::MobiqDenseK(k)).unwrap();
        let got = model.forward_logits(&tokens, Precision::Fixed(k))
            .unwrap();
        let d = max_abs_diff(&got, want);
        assert!(d < 2e-2, "q{bits} dense logits diverge: {d}");
        // bit-plane LUT kernel path must agree with the dense path
        let model_bp = Model::load(&b, BackendKind::Mobiq).unwrap();
        let got_bp = model_bp.forward_logits(&tokens, Precision::Fixed(k))
            .unwrap();
        let d2 = max_abs_diff(&got_bp, &got);
        assert!(d2 < 2e-2, "q{bits} LUT kernel vs dense: {d2}");
    }
}

#[test]
fn ppl_improves_with_slices() {
    let Some(b) = bundle() else { return };
    let model = Model::load(&b, BackendKind::Mobiq).unwrap();
    let dir = mobiquant::artifacts_dir();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
        .unwrap();
    let mut prev = f64::INFINITY;
    for k in 1..=4 {
        let r = ppl::evaluate(&model, &toks, Precision::Fixed(k), 128, 4)
            .unwrap();
        assert!(r.ppl.is_finite() && r.ppl > 1.0);
        assert!(r.ppl < prev * 1.02,
                "k={k}: ppl {} should not regress vs {prev}", r.ppl);
        prev = r.ppl;
    }
}

#[test]
fn elastic_precision_tracks_target() {
    let Some(b) = bundle() else { return };
    let model = Model::load(&b, BackendKind::Mobiq).unwrap();
    let dir = mobiquant::artifacts_dir();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
        .unwrap();
    let mut prev_bits = 0.0;
    for target in [2.0, 3.0, 5.0, 8.0] {
        let r = ppl::evaluate(&model, &toks, Precision::elastic(target),
                              128, 2).unwrap();
        assert!(r.avg_bits >= prev_bits - 1e-9,
                "avg bits must rise with target");
        // within a slice of the requested budget (threshold quantiles
        // were calibrated on a different token set)
        assert!((r.avg_bits - target).abs() < 2.1,
                "target {target}: avg {}", r.avg_bits);
        prev_bits = r.avg_bits;
    }
}

#[test]
fn static_methods_load_and_eval() {
    let Some(b) = bundle() else { return };
    let dir = mobiquant::artifacts_dir();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
        .unwrap();
    for method in b.static_methods() {
        let model = Model::load(&b, BackendKind::Static(method.clone()))
            .unwrap();
        let r = ppl::evaluate(&model, &toks, Precision::Fixed(4), 128, 2)
            .unwrap();
        assert!(r.ppl.is_finite() && r.ppl > 1.0 && r.ppl < 300.0,
                "{method}: ppl {}", r.ppl);
    }
}

#[test]
fn serving_end_to_end() {
    let Some(b) = bundle() else { return };
    let model = Model::load(&b, BackendKind::Mobiq).unwrap();
    let server = Server::start(model, ServerConfig::default());
    let mut rxs = Vec::new();
    for i in 0..3u32 {
        let prompt: Vec<u32> = format!("The settlement {i} ")
            .bytes().map(|c| c as u32).collect();
        rxs.push(server.submit(prompt, 6));
    }
    server.set_pressure(0.5);
    for (_, rx) in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(120))
            .expect("response");
        assert_eq!(resp.metrics.generated_tokens, 6);
        assert!(resp.metrics.avg_bits >= 2.0);
        assert!(resp.generated.len() == 6);
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_completed, 3);
}

#[test]
fn pjrt_fp_module_matches_native() {
    let Some(b) = bundle() else { return };
    if !mobiquant::runtime::PjrtRuntime::available() {
        eprintln!("SKIP: built without the `pjrt` feature");
        return;
    }
    let dir = mobiquant::artifacts_dir();
    let path = mobiquant::runtime::hlo_path(&dir, "tiny-s", "fp");
    if !path.exists() {
        eprintln!("SKIP: {} missing", path.display());
        return;
    }
    let rt = mobiquant::runtime::PjrtRuntime::cpu().unwrap();
    let module = rt.load(&path).unwrap();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
        .unwrap();
    let window = 128;
    let vocab = 256;
    let inp: Vec<i32> = toks[..window].iter().map(|&t| t as i32).collect();
    let logits_pjrt = module.run_tokens(&inp).unwrap();
    assert_eq!(logits_pjrt.len(), window * vocab);

    let model = Model::load(&b, BackendKind::Fp32).unwrap();
    let logits_native = model
        .forward_logits(&toks[..window].to_vec(), Precision::Fixed(4))
        .unwrap();
    let d = max_abs_diff(&logits_pjrt, &logits_native);
    assert!(d < 2e-2, "PJRT vs native fp logits: max diff {d}");
}

#[test]
fn pjrt_quantized_modules_eval() {
    let Some(_b) = bundle() else { return };
    if !mobiquant::runtime::PjrtRuntime::available() {
        eprintln!("SKIP: built without the `pjrt` feature");
        return;
    }
    let dir = mobiquant::artifacts_dir();
    let rt = mobiquant::runtime::PjrtRuntime::cpu().unwrap();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)
        .unwrap();
    let mut prev = f64::INFINITY;
    for bits in [2usize, 4, 6, 8] {
        let path = mobiquant::runtime::hlo_path(
            &dir, "tiny-s", &format!("q{bits}"));
        if !path.exists() {
            return;
        }
        let module = rt.load(&path).unwrap();
        let p = mobiquant::runtime::ppl_via_pjrt(&module, &toks, 128, 256,
                                                 2).unwrap();
        assert!(p.is_finite());
        assert!(p < prev * 1.02, "q{bits} ppl {p} vs prev {prev}");
        prev = p;
    }
}
