//! Request/response types for the serving API.

use std::sync::mpsc;
use std::time::Instant;

use crate::model::kvcache::{KvHandle, KvPrecision};
use crate::model::transformer::DecodeStats;

pub type RequestId = u64;

#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Storage precision of this sequence's KV pages — an i8 request
    /// reserves a quarter of an f32 request's bytes at admission (and
    /// only matches prefix-cache entries written at i8).  Defaults to
    /// `ServerConfig::kv_precision` when submitted through the server.
    pub kv_precision: KvPrecision,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// A sequence evicted mid-flight by the pressure ladder's Critical
/// rung: everything needed to finish the request later is parked
/// here.  `tokens` holds the prompt *plus every token generated so
/// far* — decoding is greedy (argmax, no sampling state), so KV
/// content is a pure function of the token prefix and re-prefilling
/// `tokens` reproduces exactly the logits the preempted decode would
/// have seen next.  That is the preempt→resume parity guarantee
/// `tests/pressure.rs` pins.
///
/// With a host swap tier configured, preemption first moves the
/// sequence's cold KV pages to host memory and parks the (truncated)
/// arena handle in `host_kv` — the resume then restores those pages
/// by memcpy and re-feeds only `tokens[len..]`, which is bit-identical
/// to the full re-prefill because the swapped pages round-trip
/// byte-exactly.
#[derive(Debug)]
pub struct PreemptedSeq {
    pub req: Request,
    /// Prompt + generated-so-far (the resume re-prefill input).
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    /// KV parked in the host tier: the still-live arena handle whose
    /// remaining pages are all host-resident, plus the token count
    /// those pages cover (page-aligned).  `None` when the host tier
    /// is disabled, exhausted, or denied — the resume then rebuilds
    /// the whole context through the re-prefill fallback.
    pub host_kv: Option<(KvHandle, usize)>,
    /// Tokens already generated (counts against `max_new_tokens`).
    pub generated: usize,
    /// KV storage precision the request *asked* for; the resume
    /// admission re-applies the pressure floor freshly, so a sequence
    /// preempted under Critical is not pinned to i4 forever.
    pub kv_prec: KvPrecision,
    /// Routing stats carried across the gap so the final response
    /// reports bits over the whole request, not just the resumed half.
    pub stats: DecodeStats,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub admitted_at: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Generated suffix only (excludes the prompt).
    pub generated: Vec<u32>,
    pub metrics: RequestMetrics,
}

#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub total_ms: f64,
    pub generated_tokens: usize,
    /// Average effective weight bits over the request's routed linears.
    pub avg_bits: f64,
}

impl Response {
    pub fn text(&self) -> String {
        crate::data::tokenizer::decode(&self.generated)
    }
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.metrics.decode_ms <= 0.0 {
            return 0.0;
        }
        self.metrics.generated_tokens as f64
            / (self.metrics.decode_ms / 1000.0)
    }
}
