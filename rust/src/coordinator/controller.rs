//! Elastic precision controller.
//!
//! Maps a resource-pressure signal (plus queue backpressure) to the
//! runtime precision knobs of Eq. 10: a target average bit-width and a
//! global threshold shift delta.  Hysteresis prevents oscillation when
//! the pressure hovers near a band edge — precision changes are free
//! (no repacking), but PPL jitter is still undesirable.

use crate::mobiq::engine::Precision;

#[derive(Debug, Clone)]
pub struct ControllerConfig {
    pub min_bits: f64,
    pub max_bits: f64,
    /// Pressure weight of queue depth vs the external signal.
    pub queue_weight: f64,
    /// Pressure weight of KV arena occupancy — couples the weight-bits
    /// loop to the memory ladder: a full arena pulls weight precision
    /// down too, shortening residency (fewer high-bit decode ticks).
    pub memory_weight: f64,
    /// Minimum change in computed target before switching (hysteresis).
    pub hysteresis_bits: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            min_bits: 2.0,
            max_bits: 8.0,
            queue_weight: 0.5,
            memory_weight: 0.25,
            hysteresis_bits: 0.45,
        }
    }
}

#[derive(Debug)]
pub struct ElasticController {
    cfg: ControllerConfig,
    current_bits: f64,
    switches: u64,
}

impl ElasticController {
    pub fn new(cfg: ControllerConfig) -> ElasticController {
        let start = cfg.max_bits;
        ElasticController { cfg, current_bits: start, switches: 0 }
    }

    /// Update with external pressure and queue pressure, both in [0, 1].
    /// Returns the precision to use for the next scheduling tick.
    pub fn update(&mut self, external: f64, queue: f64) -> Precision {
        self.update_with_memory(external, queue, 0.0)
    }

    /// [`update`](Self::update) with an additional KV-occupancy term
    /// (the scheduler feeds the arena's resident/capacity ratio).
    pub fn update_with_memory(&mut self, external: f64, queue: f64,
                              memory: f64) -> Precision {
        let p = (external + self.cfg.queue_weight * queue
                 + self.cfg.memory_weight * memory)
            .clamp(0.0, 1.0);
        let raw = self.cfg.max_bits
            - (self.cfg.max_bits - self.cfg.min_bits) * p;
        if (raw - self.current_bits).abs() >= self.cfg.hysteresis_bits {
            self.current_bits = raw;
            self.switches += 1;
        }
        self.precision()
    }

    pub fn precision(&self) -> Precision {
        Precision::elastic(self.current_bits)
    }

    pub fn target_bits(&self) -> f64 {
        self.current_bits
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Ceiling for the *draft* pass's elastic bits under the current
    /// system pressure: half the serving precision, floored at 2 bits
    /// (the MSB plane is not divisible).  Speculation only pays when
    /// the draft is meaningfully cheaper than the verify pass, so as
    /// the controller degrades the serving bits toward the draft's
    /// band, the draft budget shrinks with it instead of converging on
    /// a draft that costs as much as the model it is drafting for.
    pub fn draft_bits_ceiling(&self) -> f64 {
        (0.5 * self.current_bits).max(2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pressure_lowers_bits() {
        let mut c = ElasticController::new(ControllerConfig::default());
        let _ = c.update(0.0, 0.0);
        let calm = c.target_bits();
        let _ = c.update(1.0, 0.0);
        let loaded = c.target_bits();
        assert!(loaded < calm);
        assert!((2.0..=8.0).contains(&loaded));
        assert_eq!(calm, 8.0);
        assert_eq!(loaded, 2.0);
    }

    #[test]
    fn hysteresis_suppresses_jitter() {
        let mut c = ElasticController::new(ControllerConfig::default());
        let _ = c.update(0.5, 0.0);
        let s0 = c.switches();
        // tiny oscillation around the same pressure: no switch
        for p in [0.51, 0.49, 0.505, 0.495] {
            let _ = c.update(p, 0.0);
        }
        assert_eq!(c.switches(), s0);
        // large move: switch
        let _ = c.update(1.0, 0.0);
        assert_eq!(c.switches(), s0 + 1);
    }

    #[test]
    fn queue_pressure_contributes() {
        let mut a = ElasticController::new(ControllerConfig::default());
        let mut b = ElasticController::new(ControllerConfig::default());
        let _ = a.update(0.3, 0.0);
        let _ = b.update(0.3, 1.0);
        assert!(b.target_bits() < a.target_bits());
    }

    #[test]
    fn memory_pressure_contributes() {
        let mut a = ElasticController::new(ControllerConfig::default());
        let mut b = ElasticController::new(ControllerConfig::default());
        let _ = a.update_with_memory(0.3, 0.0, 0.0);
        let _ = b.update_with_memory(0.3, 0.0, 1.0);
        assert!(b.target_bits() < a.target_bits());
    }

    #[test]
    fn clamped_to_band() {
        let mut c = ElasticController::new(ControllerConfig::default());
        let _ = c.update(5.0, 5.0); // silly inputs
        assert!(c.target_bits() >= 2.0);
    }
}
