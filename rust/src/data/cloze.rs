//! Synthetic downstream evaluation suites (Tab. 8 / Tab. 9 substitutes).
//!
//! * **Cloze suite** — zero-shot commonsense analogue: the model picks the
//!   true continuation of a corpus sentence among distractors sampled from
//!   other sentences, scored by likelihood (the same measurement as
//!   BoolQ/PIQA/ARC accuracy via LM scoring).
//! * **Arithmetic suite** — GSM8K analogue: templated sum/difference word
//!   problems in the corpus style; exact-match of the greedy-decoded
//!   answer digits.

use anyhow::Result;

use super::corpus::sentences;
use super::ppl::continuation_logprob;
use super::tokenizer::encode;
use crate::mobiq::engine::Precision;
use crate::model::transformer::DecodeStats;
use crate::model::Model;
use crate::util::prng::Pcg;

#[derive(Debug, Clone)]
pub struct ClozeItem {
    pub prompt: String,
    pub choices: Vec<String>, // choices[0] is correct
}

/// Build cloze items from corpus text: split each eligible sentence at a
/// word boundary ~60% in; distractor completions come from other
/// sentences' tails.
pub fn build_cloze(text: &str, n_items: usize, n_choices: usize,
                   seed: u64) -> Vec<ClozeItem> {
    let sents: Vec<&str> = sentences(text);
    let mut rng = Pcg::new(seed);
    let mut items = Vec::new();
    if sents.len() < n_choices + 1 {
        return items;
    }
    let mut splits: Vec<(String, String)> = Vec::new();
    for s in &sents {
        let cut = (s.len() * 3 / 5).min(s.len() - 8);
        // snap to a space so the continuation starts at a word boundary
        if let Some(sp) = s[..cut].rfind(' ') {
            if sp > 10 {
                splits.push((s[..sp].to_string(), s[sp..].to_string()));
            }
        }
    }
    for _ in 0..n_items {
        if splits.len() < n_choices + 1 {
            break;
        }
        let i = rng.below(splits.len());
        let (prompt, correct) = splits[i].clone();
        let mut choices = vec![correct];
        while choices.len() < n_choices {
            let j = rng.below(splits.len());
            if j != i && splits[j].1 != choices[0] {
                choices.push(splits[j].1.clone());
            }
        }
        items.push(ClozeItem { prompt, choices });
    }
    items
}

/// Accuracy of likelihood-ranked choice (choice 0 is gold).  Length-
/// normalised log-prob, as standard for multiple-choice LM eval.
pub fn eval_cloze(model: &Model, items: &[ClozeItem],
                  precision: Precision) -> Result<f64> {
    let mut correct = 0usize;
    for item in items {
        let prompt = encode(&item.prompt);
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in item.choices.iter().enumerate() {
            let cont = encode(choice);
            let lp = continuation_logprob(model, &prompt, &cont,
                                          precision)?
                / cont.len().max(1) as f64;
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        if best.1 == 0 {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

#[derive(Debug, Clone)]
pub struct ArithItem {
    pub prompt: String,
    pub answer: String,
}

/// Templated arithmetic word problems in the news-corpus register.
pub fn build_arith(n_items: usize, seed: u64) -> Vec<ArithItem> {
    let mut rng = Pcg::new(seed);
    let goods = ["grain", "copper", "timber", "salt", "wool"];
    (0..n_items)
        .map(|_| {
            let a = 2 + rng.below(8);
            let b = 1 + rng.below(8);
            let g = goods[rng.below(goods.len())];
            let sum = a + b;
            ArithItem {
                prompt: format!(
                    "The exchange sold {a} tons of {g} and then {b} more \
                     tons. In total it sold "),
                answer: format!("{sum}"),
            }
        })
        .collect()
}

/// Exact-match accuracy of greedy decode on the answer digits.
pub fn eval_arith(model: &Model, items: &[ArithItem],
                  precision: Precision) -> Result<f64> {
    let mut correct = 0usize;
    for item in items {
        let prompt = encode(&item.prompt);
        let mut stats = DecodeStats::new(model.cfg.n_layers);
        let out = model.generate(&prompt, item.answer.len() + 1,
                                 precision, &mut stats)?;
        let gen = super::tokenizer::decode(&out[prompt.len()..]);
        if gen.trim_start().starts_with(&item.answer) {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = "The ancient settlement was founded near the \
        river and became a center of trade. Officials in Ostia reported \
        that the reservoir would require forty million to restore. The \
        fortified structure was completed during the medieval period and \
        flourished. Early records describe the coastal province as \
        devoted to navigation and weaving. Trading in copper closed up \
        four points in Kessel yesterday evening.";

    #[test]
    fn cloze_items_wellformed() {
        let items = build_cloze(TEXT, 8, 3, 42);
        assert!(!items.is_empty());
        for it in &items {
            assert_eq!(it.choices.len(), 3);
            assert!(it.prompt.len() >= 10);
            // gold continuation differs from distractors
            assert_ne!(it.choices[0], it.choices[1]);
        }
    }

    #[test]
    fn cloze_deterministic() {
        let a = build_cloze(TEXT, 4, 2, 7);
        let b = build_cloze(TEXT, 4, 2, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn arith_answers_correct() {
        for it in build_arith(20, 3) {
            // parse back the numbers from the prompt and check the answer
            let nums: Vec<usize> = it.prompt
                .split_whitespace()
                .filter_map(|w| w.parse().ok())
                .collect();
            assert_eq!(nums.len(), 2);
            assert_eq!(format!("{}", nums[0] + nums[1]), it.answer);
        }
    }
}
