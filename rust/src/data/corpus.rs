//! Corpus loading.  The synthetic corpora (wiki/web/news — stand-ins for
//! WikiText2/C4/PTB, DESIGN.md §2) are generated deterministically by
//! python/compile/corpus.py at `make artifacts`; Rust reads the files so
//! both languages see byte-identical data.

use std::path::Path;

use anyhow::{Context, Result};

pub const DOMAINS: [&str; 3] = ["wiki", "web", "news"];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
}

impl Split {
    fn name(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Valid => "valid",
        }
    }
}

pub fn load(artifacts: &Path, domain: &str, split: Split) -> Result<String> {
    let path = artifacts
        .join("corpus")
        .join(format!("{domain}.{}.txt", split.name()));
    std::fs::read_to_string(&path)
        .with_context(|| format!("reading corpus {}", path.display()))
}

pub fn load_tokens(artifacts: &Path, domain: &str, split: Split)
                   -> Result<Vec<u32>> {
    Ok(super::tokenizer::encode(&load(artifacts, domain, split)?))
}

/// Split a token stream into non-overlapping (input, target) windows.
pub fn windows(tokens: &[u32], window: usize, max_windows: usize)
               -> Vec<(&[u32], &[u32])> {
    let n = ((tokens.len().saturating_sub(1)) / window).min(max_windows);
    (0..n)
        .map(|i| {
            let lo = i * window;
            (&tokens[lo..lo + window], &tokens[lo + 1..lo + window + 1])
        })
        .collect()
}

/// Sentence segmentation for the cloze suite (period/newline boundaries).
pub fn sentences(text: &str) -> Vec<&str> {
    text.split(|c| c == '.' || c == '\n')
        .map(str::trim)
        .filter(|s| s.len() >= 20 && s.len() <= 240)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_shapes() {
        let toks: Vec<u32> = (0..100).collect();
        let w = windows(&toks, 10, 100);
        assert_eq!(w.len(), 9);
        assert_eq!(w[0].0, &toks[0..10]);
        assert_eq!(w[0].1, &toks[1..11]);
        let w = windows(&toks, 10, 3);
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn sentences_filters_short() {
        let s = sentences("Tiny. This sentence is long enough to keep \
                           around for a test. x.\nAnother usable sentence \
                           that is fine too");
        assert_eq!(s.len(), 2);
    }
}
