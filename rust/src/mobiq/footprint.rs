//! Memory footprint accounting — Fig. 7 (right) and the §5.2 "3.5x memory
//! savings vs separate multi-precision deployment" claim.
//!
//! Deployment scenarios compared at equal *served precisions*
//! {2, 4, 6, 8}-bit:
//!
//! * `multi_static`  — one statically packed model per precision, each
//!   with its own scales (what a MatQuant/offline-repack deployment
//!   stores).
//! * `anybcq_like`   — single bit-plane model but per-precision scale
//!   sets (AnyBCQ).
//! * `mobiq`         — single bit-plane model, ONE shared scale set, plus
//!   routers and threshold tables.
//! * `fp16`          — the unquantized comparator.

/// Per-linear dimensions needed for the accounting.
#[derive(Debug, Clone, Copy)]
pub struct LinearDims {
    pub d_in: usize,
    pub d_out: usize,
}

#[derive(Debug, Clone)]
pub struct FootprintInputs {
    pub linears: Vec<LinearDims>,
    pub group_size: usize,
    pub n_slices: usize,
    pub slice_bits: usize,
    pub router_hidden: usize,
    /// Non-quantized residue: embeddings, norms, lm_head (bytes, fp32).
    pub fp_other_bytes: usize,
}

impl FootprintInputs {
    fn weights(&self) -> usize {
        self.linears.iter().map(|l| l.d_in * l.d_out).sum()
    }

    fn scale_entries(&self) -> usize {
        self.linears.iter()
            .map(|l| (l.d_in / self.group_size) * l.d_out)
            .sum()
    }

    pub fn fp16_bytes(&self) -> usize {
        self.weights() * 2 + self.fp_other_bytes
    }

    /// One statically packed model at `bits` (codes + scale/zero f32).
    pub fn static_bytes(&self, bits: usize) -> usize {
        self.weights() * bits / 8 + self.scale_entries() * 8
            + self.fp_other_bytes
    }

    /// Separate deployment of every served precision.
    pub fn multi_static_bytes(&self, precisions: &[usize]) -> usize {
        precisions.iter().map(|&b| self.static_bytes(b)).sum()
    }

    /// AnyBCQ-like: shared bit-planes but per-precision scales.
    pub fn anybcq_bytes(&self, precisions: &[usize]) -> usize {
        self.weights() * (self.n_slices * self.slice_bits) / 8
            + self.scale_entries() * 8 * precisions.len()
            + self.fp_other_bytes
    }

    pub fn router_bytes(&self) -> usize {
        self.linears.iter()
            .map(|l| {
                4 * (l.d_in * self.router_hidden
                    + self.router_hidden * (self.n_slices - 1)
                    + self.router_hidden + (self.n_slices - 1))
                    + 129 * 4 // threshold quantile grid
            })
            .sum()
    }

    /// MoBiQuant: all planes + ONE scale set + routers.
    pub fn mobiq_bytes(&self) -> usize {
        self.weights() * (self.n_slices * self.slice_bits) / 8
            + self.scale_entries() * 8
            + self.router_bytes()
            + self.fp_other_bytes
    }

    /// Headline ratio: multi-precision deployment vs MoBiQuant.
    pub fn savings_vs_multi(&self, precisions: &[usize]) -> f64 {
        self.multi_static_bytes(precisions) as f64
            / self.mobiq_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_scale_inputs() -> FootprintInputs {
        // LLaMA-2-7B-like dims to sanity check against the paper's 3.5x
        let d = 4096;
        let f = 11008;
        let per_layer = vec![
            LinearDims { d_in: d, d_out: d },   // q
            LinearDims { d_in: d, d_out: d },   // k
            LinearDims { d_in: d, d_out: d },   // v
            LinearDims { d_in: d, d_out: d },   // o
            LinearDims { d_in: d, d_out: f },   // gate
            LinearDims { d_in: d, d_out: f },   // up
            LinearDims { d_in: f, d_out: d },   // down
        ];
        let linears: Vec<LinearDims> = (0..32)
            .flat_map(|_| per_layer.clone())
            .collect();
        FootprintInputs {
            linears,
            group_size: 128,
            n_slices: 4,
            slice_bits: 2,
            router_hidden: 16,
            fp_other_bytes: 32000 * d * 4 * 2,
        }
    }

    #[test]
    fn savings_in_paper_ballpark() {
        let fi = paper_scale_inputs();
        let s = fi.savings_vs_multi(&[2, 4, 6, 8]);
        // paper reports up to 3.5x; exact value depends on what the
        // multi-deployment duplicates. Require the right order.
        assert!(s > 2.0 && s < 4.0, "savings {s}");
    }

    #[test]
    fn mobiq_smaller_than_fp16() {
        let fi = paper_scale_inputs();
        assert!(fi.mobiq_bytes() < fi.fp16_bytes());
    }

    #[test]
    fn anybcq_larger_than_mobiq() {
        let fi = paper_scale_inputs();
        assert!(fi.anybcq_bytes(&[2, 4, 6, 8]) > fi.mobiq_bytes());
    }

    #[test]
    fn router_overhead_small() {
        let fi = paper_scale_inputs();
        let frac = fi.router_bytes() as f64 / fi.mobiq_bytes() as f64;
        assert!(frac < 0.05, "router overhead {frac}");
    }
}
