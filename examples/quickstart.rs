//! Quickstart: load a MoBiQuant bundle, inspect it, generate text at two
//! precisions, and evaluate perplexity across the elastic range.
//!
//!     make artifacts          # once (pretrain + calibrate + export)
//!     cargo run --release --example quickstart

use anyhow::Result;
use mobiquant::data::{corpus, ppl, tokenizer};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;

fn main() -> Result<()> {
    let dir = mobiquant::artifacts_dir();
    let bundle = Bundle::load(dir.join("tiny-s.mobiq"))?;
    let model = Model::load(&bundle, BackendKind::Mobiq)?;
    println!("loaded {} ({} layers, d={}, E={} x {}-bit slices)",
             model.cfg.name, model.cfg.n_layers, model.cfg.d_model,
             model.cfg.n_slices, model.cfg.slice_bits);

    // --- generation at low vs high precision --------------------------
    let prompt = tokenizer::encode("The ancient settlement ");
    for target in [2.5, 6.0] {
        let mut stats = DecodeStats::new(model.cfg.n_layers);
        let out = model.generate(&prompt, 64, Precision::elastic(target),
                                 &mut stats)?;
        println!("\n--- target {target} bits (avg used {:.2}) ---\n{}",
                 stats.avg_bits(), tokenizer::decode(&out));
    }

    // --- elastic PPL sweep --------------------------------------------
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)?;
    println!("\nelastic perplexity sweep (wiki valid):");
    for target in [2.0, 3.0, 4.0, 6.0, 8.0] {
        let r = ppl::evaluate(&model, &toks, Precision::elastic(target),
                              128, 8)?;
        println!("  target {target:>3} bits -> ppl {:.4} (avg bits {:.2})",
                 r.ppl, r.avg_bits);
    }
    Ok(())
}
