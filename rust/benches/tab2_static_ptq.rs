//! Tab. 2 (+ App. E.5 context) — elastic MoBiQuant vs static scalar PTQ
//! baselines at matched average bits (3 and 4), across the model family.
//! Also covers the QuaRot/SpinQuant rows used by Tab. 6 context.
//!
//! Reproduced shape: MoBiQuant (one calibration, elastic) matches or
//! beats the per-bit-width calibrated static baselines.

use mobiquant::bench_support as bs;
use mobiquant::data::ppl;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("tab2_static_ptq");
    suite.header();
    let windows = bs::eval_windows(6);
    let models = bs::models_available();
    if models.is_empty() {
        suite.note("no bundles; run `make artifacts`");
        suite.finish();
        return;
    }
    let toks = bs::valid_tokens("wiki").expect("corpus");

    for mname in &models {
        let Some(bundle) = bs::try_bundle(mname) else { continue };
        // FP reference row
        let fp = Model::load(&bundle, BackendKind::Fp32).unwrap();
        let r = ppl::evaluate(&fp, &toks, Precision::Fixed(4), 128,
                              windows).unwrap();
        suite.row(&format!("{mname} FP32"), &[("ppl", r.ppl)]);

        for bits in [3usize, 4] {
            let mut cells: Vec<(String, f64)> = Vec::new();
            for method in ["rtn", "smoothquant", "awq", "gptq", "quarot",
                           "spinquant", "omniquant"] {
                let key = format!("{method}{bits}");
                if !bundle.static_methods().contains(&key) {
                    continue;
                }
                let model = Model::load(
                    &bundle, BackendKind::Static(key.clone())).unwrap();
                let r = ppl::evaluate(&model, &toks, Precision::Fixed(4),
                                      128, windows).unwrap();
                cells.push((method.to_string(), r.ppl));
            }
            // MoBiQuant, elastic, budgeted to the same average bits
            let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
            let r = ppl::evaluate(&mobiq, &toks,
                                  Precision::elastic(bits as f64), 128,
                                  windows).unwrap();
            cells.push(("MoBiQ".to_string(), r.ppl));
            cells.push(("MoBiQ_avg_bits".to_string(), r.avg_bits));
            let named: Vec<(&str, f64)> = cells.iter()
                .map(|(k, v)| (k.as_str(), *v)).collect();
            suite.row(&format!("{mname} @{bits}bit"), &named);
        }
    }
    suite.note("paper Tab.2 shape: MoBiQ ~= best static at 3/4-bit while \
                staying elastic (single calibration)");
    suite.finish();
}
