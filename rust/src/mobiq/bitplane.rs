//! Bit-major (bit-plane) packed weight slices — the kernel interchange
//! format of §4.3.  Layout matches python/compile/quant/mobislice.py
//! `pack_bitplanes`: planes[p][o][w] is a u64 whose bit j is bit p of
//! code[(w*64 + j), o] — packed along the *input* dimension so a GEMV
//! kernel streams contiguous words per output channel.

/// One bit-slice of one linear layer, packed as bit-planes.
#[derive(Debug, Clone)]
pub struct PackedSlice {
    /// (slice_bits, d_out, n_words) row-major.
    pub planes: Vec<u64>,
    pub slice_bits: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub n_words: usize,
}

impl PackedSlice {
    pub fn from_codes(codes: &[u8], d_in: usize, d_out: usize,
                      slice_bits: usize) -> PackedSlice {
        assert_eq!(codes.len(), d_in * d_out);
        let n_words = (d_in + 63) / 64;
        let mut planes = vec![0u64; slice_bits * d_out * n_words];
        for row in 0..d_in {
            let word = row / 64;
            let bit = row % 64;
            for o in 0..d_out {
                let c = codes[row * d_out + o];
                for p in 0..slice_bits {
                    if (c >> p) & 1 == 1 {
                        planes[(p * d_out + o) * n_words + word] |=
                            1u64 << bit;
                    }
                }
            }
        }
        PackedSlice { planes, slice_bits, d_in, d_out, n_words }
    }

    /// Raw plane words of (plane p, output channel o).
    #[inline]
    pub fn plane(&self, p: usize, o: usize) -> &[u64] {
        let base = (p * self.d_out + o) * self.n_words;
        &self.planes[base..base + self.n_words]
    }

    /// Load from the artifact tensor layout (slice_bits, d_out, n_words).
    pub fn from_tensor(words: &[u64], shape: &[usize], d_in: usize)
                       -> PackedSlice {
        assert_eq!(shape.len(), 3);
        let (slice_bits, d_out, n_words) = (shape[0], shape[1], shape[2]);
        assert_eq!(words.len(), slice_bits * d_out * n_words);
        assert!(n_words * 64 >= d_in);
        PackedSlice { planes: words.to_vec(), slice_bits, d_in, d_out,
                      n_words }
    }

    /// Unpack back to integer codes (d_in * d_out) — tests / slow path.
    pub fn unpack(&self) -> Vec<u8> {
        let mut codes = vec![0u8; self.d_in * self.d_out];
        for o in 0..self.d_out {
            for p in 0..self.slice_bits {
                let plane = self.plane(p, o);
                for row in 0..self.d_in {
                    if (plane[row / 64] >> (row % 64)) & 1 == 1 {
                        codes[row * self.d_out + o] |= 1 << p;
                    }
                }
            }
        }
        codes
    }

    pub fn nbytes(&self) -> usize {
        self.planes.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::property;

    #[test]
    fn pack_unpack_roundtrip() {
        property(10, 30, |rng, _| {
            let d_in = 64 * (1 + rng.below(3));
            let d_out = 1 + rng.below(20);
            let bits = 1 + rng.below(3);
            let codes: Vec<u8> = (0..d_in * d_out)
                .map(|_| rng.below(1 << bits) as u8)
                .collect();
            let packed = PackedSlice::from_codes(&codes, d_in, d_out, bits);
            assert_eq!(packed.unpack(), codes);
        });
    }

    #[test]
    fn pack_matches_reference_bit_positions() {
        // code at row 65, col 2, value 0b10 -> plane 1, word 1, bit 1
        let d_in = 128;
        let d_out = 4;
        let mut codes = vec![0u8; d_in * d_out];
        codes[65 * d_out + 2] = 0b10;
        let packed = PackedSlice::from_codes(&codes, d_in, d_out, 2);
        assert_eq!(packed.plane(1, 2)[1], 1u64 << 1);
        assert_eq!(packed.plane(0, 2)[1], 0);
    }

    #[test]
    fn nonmultiple_of_64_padding() {
        let d_in = 96; // 2 words, 32 bits padding
        let d_out = 3;
        let codes: Vec<u8> = (0..d_in * d_out).map(|i| (i % 4) as u8)
            .collect();
        let packed = PackedSlice::from_codes(&codes, d_in, d_out, 2);
        assert_eq!(packed.n_words, 2);
        assert_eq!(packed.unpack(), codes);
        // padding bits must be zero
        for o in 0..d_out {
            for p in 0..2 {
                assert_eq!(packed.plane(p, o)[1] >> 32, 0);
            }
        }
    }
}
