"""Temperature / budget schedules (paper Eq. 5, Eq. 7; App. D.2).

All schedules map training progress t in [1, L] to a value; the budget
schedule decays b_init -> b_target, the temperature schedule grows 1 -> inf.
The paper adopts the *logarithmic* budget schedule because it matches the
log temperature annealing of the gate (App. D.2); we implement all four
ablated variants for the Fig. 8 bench.
"""

from __future__ import annotations

import math


def gate_temperature(t: int, total: int) -> float:
    """tau(t) = ln(L) / (ln(L) - ln(t)); tau(1)=1, tau(L)=inf (Eq. 5)."""
    t = max(1, min(t, total))
    if t >= total:
        return float("inf")
    ln_l = math.log(max(total, 2))
    return ln_l / (ln_l - math.log(t))


def budget(t: int, total: int, b_init: float, b_target: float,
           kind: str = "log") -> float:
    """b(t) schedules: b_init -> b_target as t: 1 -> L (Eq. 7 + App. D.2)."""
    t = max(1, min(t, total))
    frac = _frac(t, total, kind)
    return b_init - (b_init - b_target) * frac


def _frac(t: int, total: int, kind: str) -> float:
    x = t / total
    if kind == "log":
        # ln(t)/ln(L) — the paper's Eq. 7 form.
        return math.log(t) / math.log(max(total, 2)) if t > 1 else 0.0
    if kind == "linear":
        return x
    if kind == "cosine":
        return 0.5 * (1.0 - math.cos(math.pi * x))
    if kind == "exp":
        # fast early decay, mirroring exp annealing in App. D.2.
        k = 5.0
        return (1.0 - math.exp(-k * x)) / (1.0 - math.exp(-k))
    raise ValueError(f"unknown schedule {kind!r}")


SCHEDULES = ("log", "linear", "cosine", "exp")
