"""L2 — LLaMA-style decoder-only transformer in JAX.

This is the compute graph the paper quantizes: RMSNorm, rotary attention
(optionally grouped-query), SwiGLU MLP.  Every linear goes through the
``linear_fn`` hook so the quantization stack (python/compile/quant) and the
Pallas kernel path (python/compile/kernels) can intercept it without
rewriting the model.

Used at build time only: pretraining (pretrain.py), calibration activations
(quant/calibrate.py), and AOT lowering (aot.py).  The Rust engine
re-implements the same forward natively for the request path; golden vectors
exported by export.py pin the two implementations together.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, object]
# linear_fn(layer_idx, name, x, W) -> y   with x: (..., d_in), W: (d_in, d_out)
LinearFn = Callable[[int, str, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _default_linear(layer: int, name: str, x: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    del layer, name
    return x @ w


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Scaled-normal init (GPT-2 style residual scaling)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    dkv = cfg.n_kv_heads * cfg.head_dim
    keys = iter(jax.random.split(key, 4 + 7 * cfg.n_layers))

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    resid_scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    layers: List[Dict[str, jnp.ndarray]] = []
    for _ in range(cfg.n_layers):
        layers.append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "wq": nrm(next(keys), (d, d), 0.02),
            "wk": nrm(next(keys), (d, dkv), 0.02),
            "wv": nrm(next(keys), (d, dkv), 0.02),
            "wo": nrm(next(keys), (d, d), resid_scale),
            "w_gate": nrm(next(keys), (d, f), 0.02),
            "w_up": nrm(next(keys), (d, f), 0.02),
            "w_down": nrm(next(keys), (f, d), resid_scale),
        })
    return {
        "embed": nrm(next(keys), (v, d), 0.02),
        "layers": layers,
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": nrm(next(keys), (d, v), 0.02),
    }


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(seq_len: int, head_dim: int, theta: float,
                offset: int = 0) -> tuple:
    """cos/sin tables; pairs (2i, 2i+1) rotated as in LLaMA."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]          # (T, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (T, H, head_dim) with even/odd interleaved pairs."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[:, None, :]
    s = sin[:, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def attention(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], cfg: ModelConfig,
              layer: int, linear_fn: LinearFn) -> jnp.ndarray:
    """Causal self-attention over the full sequence.  x: (T, d)."""
    T = x.shape[0]
    hd = cfg.head_dim
    q = linear_fn(layer, "wq", x, lp["wq"]).reshape(T, cfg.n_heads, hd)
    k = linear_fn(layer, "wk", x, lp["wk"]).reshape(T, cfg.n_kv_heads, hd)
    v = linear_fn(layer, "wv", x, lp["wv"]).reshape(T, cfg.n_kv_heads, hd)
    cos, sin = rope_tables(T, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if cfg.n_kv_heads != cfg.n_heads:
        rep = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    # (H, T, T)
    scores = jnp.einsum("thd,shd->hts", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((T, T), jnp.bool_))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,shd->thd", probs, v).reshape(T, cfg.d_model)
    return linear_fn(layer, "wo", ctx, lp["wo"])


def mlp(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], cfg: ModelConfig,
        layer: int, linear_fn: LinearFn) -> jnp.ndarray:
    g = linear_fn(layer, "w_gate", x, lp["w_gate"])
    u = linear_fn(layer, "w_up", x, lp["w_up"])
    return linear_fn(layer, "w_down", jax.nn.silu(g) * u, lp["w_down"])


def block(x: jnp.ndarray, lp: Dict[str, jnp.ndarray], cfg: ModelConfig,
          layer: int, linear_fn: LinearFn) -> jnp.ndarray:
    x = x + attention(rmsnorm(x, lp["attn_norm"], cfg.norm_eps), lp, cfg,
                      layer, linear_fn)
    x = x + mlp(rmsnorm(x, lp["mlp_norm"], cfg.norm_eps), lp, cfg, layer,
                linear_fn)
    return x


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------

def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            linear_fn: Optional[LinearFn] = None) -> jnp.ndarray:
    """tokens: (T,) int32 -> logits (T, V)."""
    linear_fn = linear_fn or _default_linear
    x = params["embed"][tokens]
    for i, lp in enumerate(params["layers"]):
        x = block(x, lp, cfg, i, linear_fn)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"]


def forward_batch(params: Params, tokens: jnp.ndarray,
                  cfg: ModelConfig) -> jnp.ndarray:
    """tokens: (B, T) -> logits (B, T, V); pretraining path."""
    return jax.vmap(lambda t: forward(params, t, cfg))(tokens)


def loss_fn(params: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Next-token cross-entropy; tokens: (B, T+1)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward_batch(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def perplexity(params: Params, tokens, cfg: ModelConfig,
               linear_fn: Optional[LinearFn] = None,
               window: int = 128, max_windows: int = 64) -> float:
    """Sliding non-overlapping window PPL over a 1-D token stream."""
    import numpy as np
    tokens = np.asarray(tokens)
    n = min((tokens.shape[0] - 1) // window, max_windows)
    total, count = 0.0, 0
    fwd = jax.jit(lambda t: forward(params, t, cfg, linear_fn))
    for i in range(n):
        chunk = jnp.asarray(tokens[i * window:(i + 1) * window + 1].astype("int32"))
        logits = fwd(chunk[:-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, chunk[1:, None], axis=-1)[:, 0]
        total += float(jnp.sum(nll))
        count += window
    return float(jnp.exp(total / max(count, 1)))


def capture_block_inputs(params: Params, tokens: jnp.ndarray,
                         cfg: ModelConfig):
    """Per-block residual-stream inputs for layer-wise calibration (Alg. 1).

    tokens: (B, T) int32 -> list over layers of (B, T, d) block inputs.
    """
    def single(t):
        x = params["embed"][t]
        xs = []
        for i, lp in enumerate(params["layers"]):
            xs.append(x)
            x = block(x, lp, cfg, i, _default_linear)
        return xs
    return jax.vmap(single, out_axes=0)(tokens)
