//! The decode scheduler: continuous batching with elastic precision.
//!
//! Each tick the scheduler (1) admits queued requests into free sequence
//! slots, (2) asks the elastic controller for the tick's precision given
//! external + queue pressure, (3) advances every active sequence by one
//! token — prefilling sequences consume a whole prompt chunk through one
//! batched kernel call, and all decoding sequences are **coalesced into
//! one batched call per layer** (`Model::decode_batch`) so plane words
//! stream once per mask group instead of once per sequence — and
//! (4) retires finished sequences.  The structure mirrors a vLLM-style
//! continuous batcher.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{Admission, Batcher};
use super::controller::ElasticController;
use super::metrics::Metrics;
use super::request::{Request, RequestMetrics, Response};
use crate::mobiq::engine::Precision;
use crate::model::kvcache::SequenceKv;
use crate::model::transformer::{argmax, DecodeScratch, DecodeSlot,
                                DecodeStats};
use crate::model::Model;

struct ActiveSeq {
    req: Request,
    kv: SequenceKv,
    tokens: Vec<u32>,
    prompt_len: usize,
    fed: usize,          // how many tokens have entered the model
    generated: usize,
    stats: DecodeStats,
    prefill_ms: f64,
    decode_ms: f64,
    admitted_at: Instant,
}

pub struct Scheduler<'m> {
    pub model: &'m Model,
    pub batcher: Batcher,
    pub controller: ElasticController,
    pub metrics: Metrics,
    active: Vec<ActiveSeq>,
    scratch: DecodeScratch,
    started: Instant,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, batcher: Batcher,
               controller: ElasticController) -> Scheduler<'m> {
        let mut scratch = model.new_scratch();
        // Pre-warm the RoPE sin/cos tables over the whole context
        // budget: the cache grows on demand, but growing it mid-tick
        // would show up as a latency blip on whichever request first
        // reaches a new position.  One-off cost at server start.
        scratch.rope.ensure(model.cfg.max_seq_len);
        // Same for the fork-join workers: they normally spawn lazily
        // on the first parallel dispatch, which would charge thread
        // creation to the first request's tick.
        if let Some(pool) = &model.pool {
            pool.warm();
        }
        Scheduler {
            scratch,
            model,
            batcher,
            controller,
            metrics: Metrics::default(),
            active: Vec::new(),
            started: Instant::now(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        if matches!(self.batcher.submit(req), Admission::Rejected) {
            self.metrics.rejected += 1;
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.batcher.queued() == 0
    }

    /// One scheduling tick under the given external pressure.
    /// Returns the number of model steps executed.
    pub fn tick(&mut self, external_pressure: f64) -> Result<usize> {
        // 1. admission
        for req in self.batcher.admit(self.active.len()) {
            let max_prompt = self.model.cfg.max_seq_len
                .saturating_sub(req.max_new_tokens + 1);
            let mut tokens = req.prompt.clone();
            tokens.truncate(max_prompt.max(1));
            self.active.push(ActiveSeq {
                kv: self.model.new_kv(),
                prompt_len: tokens.len(),
                tokens,
                fed: 0,
                generated: 0,
                stats: DecodeStats::new(self.model.cfg.n_layers),
                prefill_ms: 0.0,
                decode_ms: 0.0,
                admitted_at: Instant::now(),
                req,
            });
        }

        // 2. precision for this tick
        let precision = self.controller
            .update(external_pressure, self.batcher.pressure());

        // 3. advance sequences: prefill chunks first (one batched call
        // per chunk), then one coalesced decode step across every
        // sequence that was already past prefill at tick start.
        let model = self.model;
        let mut steps = 0usize;
        let decode_ready: Vec<bool> = self.active.iter()
            .map(|s| s.fed >= s.prompt_len)
            .collect();
        let prefill_chunk = self.batcher.prefill_chunk;

        // 3a. chunked prefill — a whole prompt chunk per tick through
        // the weight-stationary kernel instead of per-token decodes.
        for (seq, &ready) in self.active.iter_mut().zip(&decode_ready) {
            if ready {
                continue;
            }
            let t0 = Instant::now();
            let end = (seq.fed + prefill_chunk).min(seq.prompt_len);
            model.prefill(&seq.tokens[seq.fed..end], &mut seq.kv,
                          precision, &mut self.scratch, &mut seq.stats)?;
            steps += end - seq.fed;
            seq.fed = end;
            seq.prefill_ms += t0.elapsed().as_secs_f64() * 1000.0;
            if seq.fed == seq.prompt_len {
                // emit first generated token right after prefill
                let next = argmax(&self.scratch.logits) as u32;
                seq.tokens.push(next);
                seq.generated = 1;
            }
        }

        // 3b. coalesced decode: fuse ready sequences (up to
        // max_decode_batch per group) into one batched call per layer.
        let vocab = model.cfg.vocab_size;
        let cap = self.batcher.max_decode_batch;
        let mut ready: Vec<&mut ActiveSeq> = self.active.iter_mut()
            .zip(&decode_ready)
            .filter_map(|(s, &r)| if r { Some(s) } else { None })
            .collect();
        for group in ready.chunks_mut(cap) {
            let t0 = Instant::now();
            {
                let mut slots: Vec<DecodeSlot> = group.iter_mut()
                    .map(|seq| DecodeSlot {
                        token: seq.tokens[seq.fed],
                        kv: &mut seq.kv,
                        stats: &mut seq.stats,
                    })
                    .collect();
                model.decode_batch(&mut slots, precision,
                                   &mut self.scratch)?;
            }
            // per-token latency attribution: the batch advanced every
            // member one token in one wall interval
            let ms = t0.elapsed().as_secs_f64() * 1000.0
                / group.len() as f64;
            for (row, seq) in group.iter_mut().enumerate() {
                let lo = row * vocab;
                let next = argmax(
                    &self.scratch.block.logits[lo..lo + vocab]) as u32;
                seq.fed += 1;
                seq.tokens.push(next);
                seq.generated += 1;
                seq.decode_ms += ms;
                self.metrics.record_token(ms);
                steps += 1;
            }
        }
        drop(ready);

        let mut finished: Vec<usize> = Vec::new();
        for (i, seq) in self.active.iter().enumerate() {
            let kv_full = seq.kv.len() + 1 >= self.model.cfg.max_seq_len;
            if seq.generated >= seq.req.max_new_tokens || kv_full {
                finished.push(i);
            }
        }

        // 4. retire
        for &i in finished.iter().rev() {
            let seq = self.active.swap_remove(i);
            let total_ms =
                seq.req.submitted.elapsed().as_secs_f64() * 1000.0;
            let queue_ms =
                (seq.admitted_at - seq.req.submitted).as_secs_f64() * 1000.0;
            let prompt_len = seq.prompt_len;
            let resp = Response {
                id: seq.req.id,
                generated: seq.tokens[prompt_len..].to_vec(),
                tokens: seq.tokens,
                metrics: RequestMetrics {
                    queue_ms,
                    prefill_ms: seq.prefill_ms,
                    decode_ms: seq.decode_ms,
                    total_ms,
                    generated_tokens: seq.generated,
                    avg_bits: seq.stats.avg_bits(),
                },
            };
            self.metrics.record_request(total_ms, seq.generated);
            let _ = seq.req.reply.send(resp); // receiver may have gone away
        }

        let avg_bits = if self.active.is_empty() {
            self.controller.target_bits()
        } else {
            self.active.iter().map(|s| s.stats.avg_bits()).sum::<f64>()
                / self.active.len() as f64
        };
        self.metrics.record_tick(avg_bits, self.controller.target_bits());
        Ok(steps)
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(
        &mut self,
        pressure_at: impl Fn(f64) -> f64,
    ) -> Result<()> {
        while !self.idle() {
            let t_ms = self.started.elapsed().as_secs_f64() * 1000.0;
            self.tick(pressure_at(t_ms))?;
        }
        Ok(())
    }

    pub fn current_precision(&self) -> Precision {
        self.controller.precision()
    }

    pub fn wall_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}
