//! Request/response types for the serving API.

use std::sync::mpsc;
use std::time::Instant;

use crate::model::kvcache::KvPrecision;

pub type RequestId = u64;

#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Storage precision of this sequence's KV pages — an i8 request
    /// reserves a quarter of an f32 request's bytes at admission (and
    /// only matches prefix-cache entries written at i8).  Defaults to
    /// `ServerConfig::kv_precision` when submitted through the server.
    pub kv_precision: KvPrecision,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Generated suffix only (excludes the prompt).
    pub generated: Vec<u32>,
    pub metrics: RequestMetrics,
}

#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub total_ms: f64,
    pub generated_tokens: usize,
    /// Average effective weight bits over the request's routed linears.
    pub avg_bits: f64,
}

impl Response {
    pub fn text(&self) -> String {
        crate::data::tokenizer::decode(&self.generated)
    }
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.metrics.decode_ms <= 0.0 {
            return 0.0;
        }
        self.metrics.generated_tokens as f64
            / (self.metrics.decode_ms / 1000.0)
    }
}
