//! MoBiRoute inference — per-linear 2-layer MLP scoring tokens for each
//! residual slice (paper Eq. 4), hard threshold gating (Eq. 10), and the
//! quantile-based layer threshold calibration of App. C.2.
//!
//! Runtime elasticity: each linear stores a pooled score-quantile grid
//! collected at calibration time.  A target average bit-width maps to an
//! activation ratio rho (App. C.2); the layer threshold is the
//! (1 - rho)-quantile, shifted by a *global* delta for runtime control
//! (Eq. 10).  Increasing delta lowers the effective precision and vice
//! versa, with no repacking or extra scales.

/// 2-layer MLP: relu(x W1 + b1) W2 + b2 — mirror of
/// python/compile/quant/router.py::scores.
#[derive(Debug, Clone)]
pub struct RouterMlp {
    pub w1: Vec<f32>, // (d_in, hidden) row-major
    pub b1: Vec<f32>, // (hidden)
    pub w2: Vec<f32>, // (hidden, n_residual)
    pub b2: Vec<f32>, // (n_residual)
    pub d_in: usize,
    pub hidden: usize,
    pub n_residual: usize,
}

impl RouterMlp {
    /// Scores for one token; `scratch` must have length `hidden`.
    pub fn scores_into(&self, x: &[f32], scratch: &mut [f32],
                       out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(scratch.len(), self.hidden);
        debug_assert_eq!(out.len(), self.n_residual);
        scratch.copy_from_slice(&self.b1);
        for (row, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &self.w1[row * self.hidden..(row + 1) * self.hidden];
            for (h, wv) in wrow.iter().enumerate() {
                scratch[h] += xv * wv;
            }
        }
        out.copy_from_slice(&self.b2);
        for (h, &hv) in scratch.iter().enumerate() {
            let a = hv.max(0.0); // relu
            if a == 0.0 {
                continue;
            }
            let wrow = &self.w2[h * self.n_residual
                ..(h + 1) * self.n_residual];
            for (o, wv) in wrow.iter().enumerate() {
                out[o] += a * wv;
            }
        }
    }

    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = vec![0f32; self.hidden];
        let mut out = vec![0f32; self.n_residual];
        self.scores_into(x, &mut scratch, &mut out);
        out
    }

    /// FLOPs of one routed token (latency-breakdown accounting, Fig. 7).
    pub fn flops(&self) -> usize {
        2 * self.d_in * self.hidden + 2 * self.hidden * self.n_residual
    }
}

/// Pooled score quantiles collected at calibration (App. C.2).
#[derive(Debug, Clone)]
pub struct ThresholdTable {
    /// Monotone grid of len >= 2 covering quantiles 0..=1.
    pub quantiles: Vec<f32>,
}

impl ThresholdTable {
    /// rho = fraction of (token, slice) scores that should activate.
    pub fn threshold_for_ratio(&self, rho: f64) -> f32 {
        let rho = rho.clamp(0.0, 1.0);
        let n = self.quantiles.len();
        let pos = (1.0 - rho) * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = (lo + 1).min(n - 1);
        let frac = (pos - lo as f64) as f32;
        self.quantiles[lo] * (1.0 - frac) + self.quantiles[hi] * frac
    }
}

/// rho for a target average bit-width (App. C.2):
/// rho = (b_target - b_msb) / sum residual bits.
pub fn ratio_for_target_bits(target_bits: f64, base_bits: usize,
                             slice_bits: usize, n_residual: usize) -> f64 {
    ((target_bits - base_bits as f64)
        / (slice_bits * n_residual) as f64)
        .clamp(0.0, 1.0)
}

/// Hard gate (Eq. 10): active_e = score_e > threshold + delta.
/// `mask[0]` (shared expert) is always set; mask has n_residual+1 entries.
pub fn hard_mask(scores: &[f32], threshold: f32, delta: f32,
                 mask: &mut [bool]) {
    mask[0] = true;
    for (e, &s) in scores.iter().enumerate() {
        mask[e + 1] = s - (threshold + delta) > 0.0;
    }
}

/// Effective bits of a mask under uniform slice_bits.
pub fn mask_bits(mask: &[bool], slice_bits: usize) -> usize {
    mask.iter().filter(|&&b| b).count() * slice_bits
}

/// Map a speculative accept-rate EMA into the Eq. 10 global threshold
/// shift for the **draft** pass.  [`hard_mask`] activates a slice when
/// `score > threshold + delta`, so a *negative* delta admits more
/// slices.  A struggling draft (`ema <= lo`) therefore gets
/// `-max_shift` — sensitive tokens pick up extra residual slices and
/// the draft tracks the verify model more closely — while a draft
/// that's already matching (`ema >= hi`) gets `+max_shift` and sheds
/// slices it evidently doesn't need.  Linear ramp in between, zero at
/// the band midpoint; degenerate bands (`hi <= lo`) shift nothing.
pub fn draft_delta(ema: f64, lo: f64, hi: f64, max_shift: f32) -> f32 {
    if hi <= lo {
        return 0.0;
    }
    let mid = 0.5 * (lo + hi);
    let half = 0.5 * (hi - lo);
    let t = ((ema - mid) / half).clamp(-1.0, 1.0);
    t as f32 * max_shift
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn mk_router(rng: &mut Pcg, d_in: usize, hidden: usize,
                 nr: usize) -> RouterMlp {
        RouterMlp {
            w1: rng.normal_vec(d_in * hidden, 0.3),
            b1: rng.normal_vec(hidden, 0.1),
            w2: rng.normal_vec(hidden * nr, 0.3),
            b2: rng.normal_vec(nr, 0.1),
            d_in, hidden, n_residual: nr,
        }
    }

    #[test]
    fn mlp_matches_manual() {
        let r = RouterMlp {
            w1: vec![1.0, 0.0, 0.0, 1.0], // identity 2x2
            b1: vec![0.0, -1.0],
            w2: vec![1.0, 2.0],           // (2 hidden, 1 out)... row-major
            b2: vec![0.5],
            d_in: 2, hidden: 2, n_residual: 1,
        };
        // x = [2, 3]: h = relu([2, 2]) = [2, 2]; out = 2*1 + 2*2 + 0.5
        let s = r.scores(&[2.0, 3.0]);
        assert!((s[0] - 6.5).abs() < 1e-6);
        // negative pre-activation is clamped
        let s = r.scores(&[-5.0, 0.5]);
        assert!((s[0] - 0.5).abs() < 1e-6); // both hidden units negative
    }

    #[test]
    fn threshold_monotone_in_rho() {
        let t = ThresholdTable {
            quantiles: (0..129).map(|i| i as f32 * 0.01 - 0.5).collect(),
        };
        let mut prev = f32::INFINITY;
        for rho in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let d = t.threshold_for_ratio(rho);
            assert!(d <= prev, "threshold must fall as rho rises");
            prev = d;
        }
        // rho=0 -> max quantile (nothing activates)
        assert_eq!(t.threshold_for_ratio(0.0), 0.78);
        assert_eq!(t.threshold_for_ratio(1.0), -0.5);
    }

    #[test]
    fn ratio_mapping() {
        // E=4, 2-bit slices: target 3 bits -> rho = 1/6
        let r = ratio_for_target_bits(3.0, 2, 2, 3);
        assert!((r - 1.0 / 6.0).abs() < 1e-9);
        assert_eq!(ratio_for_target_bits(2.0, 2, 2, 3), 0.0);
        assert_eq!(ratio_for_target_bits(8.0, 2, 2, 3), 1.0);
        assert_eq!(ratio_for_target_bits(99.0, 2, 2, 3), 1.0);
    }

    #[test]
    fn hard_mask_and_bits() {
        let mut m = vec![false; 4];
        hard_mask(&[0.5, -0.5, 0.1], 0.0, 0.0, &mut m);
        assert_eq!(m, vec![true, true, false, true]);
        assert_eq!(mask_bits(&m, 2), 6);
        // raising delta prunes slices (Eq. 10 elasticity)
        hard_mask(&[0.5, -0.5, 0.1], 0.0, 0.4, &mut m);
        assert_eq!(m, vec![true, true, false, false]);
    }

    #[test]
    fn draft_delta_ramp() {
        // low accept rate -> negative shift (more slices in the draft)
        assert_eq!(draft_delta(0.0, 0.35, 0.75, 0.25), -0.25);
        assert_eq!(draft_delta(0.35, 0.35, 0.75, 0.25), -0.25);
        // high accept rate -> positive shift (fewer slices)
        assert_eq!(draft_delta(0.75, 0.35, 0.75, 0.25), 0.25);
        assert_eq!(draft_delta(1.0, 0.35, 0.75, 0.25), 0.25);
        // band midpoint is neutral, ramp is monotone
        assert!(draft_delta(0.55, 0.35, 0.75, 0.25).abs() < 1e-6);
        assert!(draft_delta(0.45, 0.35, 0.75, 0.25)
                    < draft_delta(0.65, 0.35, 0.75, 0.25));
        // degenerate band never shifts
        assert_eq!(draft_delta(0.9, 0.5, 0.5, 0.25), 0.0);
    }

    #[test]
    fn scores_into_no_alloc_path_matches() {
        let mut rng = Pcg::new(3);
        let r = mk_router(&mut rng, 16, 8, 3);
        let x = rng.normal_vec(16, 1.0);
        let a = r.scores(&x);
        let mut scratch = vec![0f32; 8];
        let mut b = vec![0f32; 3];
        r.scores_into(&x, &mut scratch, &mut b);
        assert_eq!(a, b);
    }
}
