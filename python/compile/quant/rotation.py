"""Rotation-based PTQ baselines: QuaRot-lite and SpinQuant-lite.

QuaRot (ref. [27]) multiplies activations by an orthogonal (Hadamard)
matrix and weights by its transpose, flattening outliers before scalar
quantization:  y = (x H) (H^T W) = x W  exactly in FP, but HW is much
friendlier to quantize.

For d_in not a power of two we use a *block* Walsh-Hadamard transform on
the largest power-of-two block size dividing d_in; the Rust engine applies
the same block FWHT to activations at runtime (transform = "hadamard").

SpinQuant-lite adds a searched diagonal +-1 sign vector D (H' = D H),
picking the best of ``n_signs`` random draws by layer output error — a
cheap stand-in for SpinQuant's learned rotations (ref. [14]).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .gptq import StaticQuantLinear, dequantize, rtn_record


def hadamard_block_size(d: int, max_block: int = 64) -> int:
    """Largest power of two <= max_block dividing d."""
    b = 1
    while b * 2 <= max_block and d % (b * 2) == 0:
        b *= 2
    return b


def fwht(v: np.ndarray) -> np.ndarray:
    """In-place-style fast Walsh-Hadamard transform along the last axis
    (unnormalised)."""
    v = np.array(v, dtype=np.float64)
    n = v.shape[-1]
    h = 1
    while h < n:
        v = v.reshape(*v.shape[:-1], n // (2 * h), 2, h)
        a = v[..., 0, :].copy()
        b = v[..., 1, :].copy()
        v[..., 0, :] = a + b
        v[..., 1, :] = a - b
        v = v.reshape(*v.shape[:-3], n)
        h *= 2
    return v


def block_hadamard(x: np.ndarray, block: int,
                   signs: np.ndarray = None) -> np.ndarray:
    """Apply a normalised block-FWHT along the last axis; optional
    per-channel sign flips applied *before* the transform."""
    d = x.shape[-1]
    assert d % block == 0
    if signs is not None:
        x = x * signs
    xb = np.asarray(x, np.float64).reshape(*x.shape[:-1], d // block, block)
    yb = fwht(xb) / np.sqrt(block)
    return yb.reshape(*x.shape)


def quarot_quantize(w: np.ndarray, bits: int, group_size: int,
                    block: int = None) -> StaticQuantLinear:
    """Rotate W rows by the block Hadamard, then RTN-quantize.

    Runtime contract: y = FWHT_block(x) @ deq(codes); act_scale stores the
    signs (all +1 for plain QuaRot).
    """
    d_in = w.shape[0]
    block = block or hadamard_block_size(d_in)
    # x H corresponds to rotating the input axis of W by H^T = H (symmetric).
    w_rot = block_hadamard(np.asarray(w, np.float64).T, block).T
    rec = rtn_record(w_rot.astype(np.float32), bits, group_size)
    return rec._replace(transform="hadamard",
                        act_scale=np.ones(d_in, np.float32))


def spinquant_quantize(w: np.ndarray, x: np.ndarray, bits: int,
                       group_size: int, n_signs: int = 16,
                       seed: int = 0) -> StaticQuantLinear:
    """QuaRot + searched diagonal signs (SpinQuant-lite)."""
    d_in = w.shape[0]
    block = hadamard_block_size(d_in)
    rng = np.random.default_rng(seed)
    w64 = np.asarray(w, np.float64)
    x64 = np.asarray(x, np.float64)
    y_ref = x64 @ w64
    best_err, best = np.inf, None
    for trial in range(n_signs):
        signs = (rng.integers(0, 2, size=d_in) * 2 - 1).astype(np.float64)
        if trial == 0:
            signs[:] = 1.0      # always include plain QuaRot
        w_rot = block_hadamard((w64 * signs[:, None]).T, block).T
        rec = rtn_record(w_rot.astype(np.float32), bits, group_size)
        xq = block_hadamard(x64, block, signs=signs)
        err = float(np.mean((xq @ dequantize(rec) - y_ref) ** 2))
        if err < best_err:
            best_err = err
            best = rec._replace(transform="hadamard",
                                act_scale=signs.astype(np.float32))
    return best


def apply_transform(rec: StaticQuantLinear, x: np.ndarray) -> np.ndarray:
    """Apply the record's activation-side transform (python oracle for the
    Rust engine's runtime path)."""
    if rec.transform == "none":
        return np.asarray(x, np.float64)
    if rec.transform == "chan_scale":
        return np.asarray(x, np.float64) / rec.act_scale.astype(np.float64)
    if rec.transform == "hadamard":
        block = hadamard_block_size(rec.codes.shape[0])
        return block_hadamard(np.asarray(x, np.float64), block,
                              signs=rec.act_scale.astype(np.float64))
    raise ValueError(rec.transform)
