"""MoBiRoute — token-adaptive bit-slice router (paper §4.2).

A per-linear 2-layer MLP scores each token for each *residual* slice
(slice 1 is the always-on shared expert, Alg. 1):

    S = R(X, Theta_r)                        (Eq. 4), S: (T, E-1)
    G = sigmoid(tau(t) * S)                  (Eq. 5) annealed gate
    AvgBits = (1/T) sum_i [b_1 + sum_j 1(G_ij > .5) * b_j]   (Eq. 8)
    L_reg = (AvgBits - b(t)) * ||G||_1       (Eq. 7)

At inference the gate hardens to 1(S - delta > 0) (Eq. 10); per-layer base
thresholds come from score quantiles (App. C.2) and a *global* delta shift
implements runtime elasticity.
"""

from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .schedules import budget, gate_temperature


class RouterParams(NamedTuple):
    w1: jnp.ndarray   # (d_in, hidden)
    b1: jnp.ndarray   # (hidden,)
    w2: jnp.ndarray   # (hidden, n_residual)
    b2: jnp.ndarray   # (n_residual,)


def init_router(key: jax.Array, d_in: int, hidden: int,
                n_residual: int) -> RouterParams:
    """w2 starts at zero so S=0 (gate 0.5, maximal exploration)."""
    k1, _ = jax.random.split(key)
    return RouterParams(
        w1=jax.random.normal(k1, (d_in, hidden)) * (1.0 / np.sqrt(d_in)),
        b1=jnp.zeros((hidden,)),
        w2=jnp.zeros((hidden, n_residual)),
        b2=jnp.zeros((n_residual,)),
    )


def scores(rp: RouterParams, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d_in) -> S: (..., n_residual).  Matches the Rust engine."""
    h = jax.nn.relu(x @ rp.w1 + rp.b1)
    return h @ rp.w2 + rp.b2


def gate(s: jnp.ndarray, t: int, total: int) -> jnp.ndarray:
    """Annealed sigmoid gate (Eq. 5)."""
    tau = gate_temperature(t, total)
    if np.isinf(tau):
        return (s > 0).astype(s.dtype)
    return jax.nn.sigmoid(tau * s)


def gate_tau(s: jnp.ndarray, tau) -> jnp.ndarray:
    """Gate with the temperature passed as a runtime scalar (jit-friendly:
    avoids one recompilation per training step)."""
    return jax.nn.sigmoid(tau * s)


def hard_gate(s: jnp.ndarray, delta) -> jnp.ndarray:
    """Inference-time binary mask 1(S - delta > 0) (Eq. 10)."""
    return (s > delta).astype(s.dtype)


def avg_bits(g: jnp.ndarray, base_bits: int, slice_bits: int) -> jnp.ndarray:
    """Eq. 8 with the shared base slice counted for every token."""
    active = (g > 0.5).astype(jnp.float32)
    return base_bits + slice_bits * jnp.mean(jnp.sum(active, axis=-1))


def reg_loss(g: jnp.ndarray, t: int, total: int, base_bits: int,
             slice_bits: int, b_init: float, b_target: float,
             kind: str = "log") -> jnp.ndarray:
    """Budget-aware regularisation (Eq. 7).

    The (AvgBits - b(t)) factor is treated as a constant multiplier (stop
    gradient): it sets the *sign and strength* of the pressure on ||G||_1,
    pruning when over budget and promoting slices when under.
    """
    b_t = budget(t, total, b_init, b_target, kind)
    ab = jax.lax.stop_gradient(avg_bits(g, base_bits, slice_bits))
    return (ab - b_t) * jnp.mean(jnp.abs(g))


def reg_loss_bt(g: jnp.ndarray, b_t, base_bits: int,
                slice_bits: int) -> jnp.ndarray:
    """Eq. 7 with the scheduled budget b(t) passed as a runtime scalar."""
    ab = jax.lax.stop_gradient(avg_bits(g, base_bits, slice_bits))
    return (ab - b_t) * jnp.mean(jnp.abs(g))


def score_quantiles(all_scores: np.ndarray, n_points: int = 129) -> np.ndarray:
    """Pooled score quantile grid for layer-wise threshold calibration
    (App. C.2).  Rust picks delta = quantile(1 - rho) for a target ratio."""
    qs = np.linspace(0.0, 1.0, n_points)
    return np.quantile(all_scores.reshape(-1), qs).astype(np.float32)


def threshold_for_ratio(quantiles: np.ndarray, rho: float) -> float:
    """delta such that ~rho of (token, slice) scores exceed it."""
    rho = float(np.clip(rho, 0.0, 1.0))
    pos = (1.0 - rho) * (len(quantiles) - 1)
    lo = int(np.floor(pos))
    hi = min(lo + 1, len(quantiles) - 1)
    frac = pos - lo
    return float(quantiles[lo] * (1 - frac) + quantiles[hi] * frac)


def ratio_for_target_bits(target_bits: float, base_bits: int,
                          slice_bits: int, n_residual: int) -> float:
    """rho = (b_target - b_msb) / sum residual bits (App. C.2)."""
    return float(np.clip(
        (target_bits - base_bits) / (slice_bits * n_residual), 0.0, 1.0))


def export_arrays(rp: RouterParams) -> Dict[str, np.ndarray]:
    return {
        "w1": np.asarray(rp.w1, np.float32),
        "b1": np.asarray(rp.b1, np.float32),
        "w2": np.asarray(rp.w2, np.float32),
        "b2": np.asarray(rp.b2, np.float32),
    }
