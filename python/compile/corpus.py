"""Synthetic corpora standing in for WikiText2 / C4 / PTB (DESIGN.md §2).

Three stochastic grammars with distinct token statistics:

  * ``wiki`` — clean encyclopedic declaratives (WikiText2 analogue).
  * ``web``  — noisy web text with urls, fragments, casing noise (C4).
  * ``news`` — templated newswire with numbers and quotes (PTB).

All generation is deterministic given the seed, so `make artifacts` is
reproducible and the Rust side can rely on byte-identical files.
"""

from __future__ import annotations

import os
import random
from typing import List

# ---------------------------------------------------------------------------
# Shared vocabulary pools
# ---------------------------------------------------------------------------

_ENTITIES = [
    "the river", "the valley", "the observatory", "the republic", "the canal",
    "the archive", "the cathedral", "the railway", "the glacier", "the harbor",
    "the parliament", "the reactor", "the telescope", "the monastery",
    "the festival", "the dynasty", "the plateau", "the aqueduct",
]
_PROPER = [
    "Avaria", "Borun", "Cadell", "Doriath", "Elmsworth", "Farrow", "Galdin",
    "Hale", "Istria", "Jorvik", "Kessel", "Lorane", "Mirefold", "Norwind",
    "Ostia", "Peralt", "Quillon", "Ravenna", "Solmere", "Tarvos",
]
_VERBS_PAST = [
    "was founded", "was completed", "was abandoned", "was restored",
    "was documented", "expanded", "declined", "flourished", "was surveyed",
    "was rebuilt", "was annexed", "was electrified",
]
_ADJ = [
    "ancient", "remote", "industrial", "coastal", "fortified", "celebrated",
    "obscure", "prosperous", "arid", "volcanic", "medieval", "northern",
]
_NOUNS = [
    "settlement", "region", "institution", "structure", "expedition",
    "province", "network", "tradition", "reservoir", "manuscript",
    "observatory", "census", "trade route", "irrigation system",
]
_YEARS = list(range(1201, 1999, 7))
_TOPICS = [
    "trade", "astronomy", "agriculture", "navigation", "metallurgy",
    "cartography", "weaving", "printing", "shipbuilding", "medicine",
]
_CITIES = ["Avaria", "Borun", "Ostia", "Tarvos", "Kessel", "Lorane"]
_AGENCIES = ["the ministry", "the council", "the bureau", "the commission",
             "the exchange", "the port authority"]
_COMMODITIES = ["grain", "copper", "timber", "salt", "wool", "amber", "tin"]


def _wiki_sentence(rng: random.Random) -> str:
    p = rng.random()
    if p < 0.25:
        return (f"{rng.choice(_PROPER)} is a {rng.choice(_ADJ)} "
                f"{rng.choice(_NOUNS)} near {rng.choice(_ENTITIES)}.")
    if p < 0.5:
        return (f"{rng.choice(_ENTITIES).capitalize()} of "
                f"{rng.choice(_PROPER)} {rng.choice(_VERBS_PAST)} in "
                f"{rng.choice(_YEARS)}.")
    if p < 0.7:
        return (f"The {rng.choice(_NOUNS)} {rng.choice(_VERBS_PAST)} during "
                f"the {rng.choice(_ADJ)} period and became a center of "
                f"{rng.choice(_TOPICS)}.")
    if p < 0.85:
        return (f"In {rng.choice(_YEARS)}, {rng.choice(_PROPER)} "
                f"{rng.choice(_VERBS_PAST)}, linking {rng.choice(_ENTITIES)} "
                f"with {rng.choice(_ENTITIES)}.")
    return (f"Early records describe the {rng.choice(_ADJ)} "
            f"{rng.choice(_NOUNS)} as devoted to {rng.choice(_TOPICS)} "
            f"and {rng.choice(_TOPICS)}.")


def _wiki_doc(rng: random.Random) -> str:
    title = f"= {rng.choice(_PROPER)} {rng.choice(_NOUNS).title()} ="
    body = " ".join(_wiki_sentence(rng) for _ in range(rng.randint(4, 9)))
    return f"{title}\n{body}\n"


_URL_BITS = ["shop", "blog", "forum", "wiki", "news", "app", "dev", "mail"]
_WEB_FRAGS = [
    "click here to read more", "sign up for the newsletter",
    "posted by admin", "leave a comment below", "terms and conditions apply",
    "free shipping on orders over 50", "updated last tuesday",
    "this post has been archived", "error 404 page not found",
    "cookies are required to continue",
]


def _web_doc(rng: random.Random) -> str:
    parts: List[str] = []
    for _ in range(rng.randint(3, 7)):
        p = rng.random()
        if p < 0.2:
            parts.append(
                f"www.{rng.choice(_URL_BITS)}{rng.randint(1, 99)}."
                f"{rng.choice(['com', 'net', 'org'])}/"
                f"{rng.choice(_URL_BITS)}")
        elif p < 0.45:
            frag = rng.choice(_WEB_FRAGS)
            parts.append(frag.upper() if rng.random() < 0.15 else frag)
        elif p < 0.7:
            parts.append(
                f"{rng.choice(_COMMODITIES)} {rng.choice(['sale', 'review', 'guide'])}"
                f" {rng.randint(2, 9)} stars rated by {rng.randint(3, 900)} users")
        else:
            s = _wiki_sentence(rng).lower()
            parts.append(s.rstrip(".") + rng.choice(["...", "!!", ".", " >>"]))
    return " | ".join(parts) + "\n"


def _news_sentence(rng: random.Random) -> str:
    p = rng.random()
    if p < 0.3:
        return (f"{rng.choice(_AGENCIES).capitalize()} of "
                f"{rng.choice(_CITIES)} said {rng.choice(_COMMODITIES)} "
                f"prices rose {rng.randint(1, 19)} percent.")
    if p < 0.55:
        return (f"Officials in {rng.choice(_CITIES)} reported that the "
                f"{rng.choice(_NOUNS)} would require "
                f"{rng.randint(2, 80)} million to restore.")
    if p < 0.8:
        return (f"\"The {rng.choice(_NOUNS)} remains {rng.choice(_ADJ)},\" "
                f"a spokesman for {rng.choice(_AGENCIES)} said.")
    return (f"Trading in {rng.choice(_COMMODITIES)} closed "
            f"{rng.choice(['up', 'down'])} {rng.randint(1, 9)}."
            f"{rng.randint(0, 9)} points in {rng.choice(_CITIES)}.")


def _news_doc(rng: random.Random) -> str:
    dateline = f"{rng.choice(_CITIES).upper()} -- "
    return dateline + " ".join(
        _news_sentence(rng) for _ in range(rng.randint(3, 6))) + "\n"


_GENERATORS = {"wiki": _wiki_doc, "web": _web_doc, "news": _news_doc}


def _stable_seed(domain: str, seed: int) -> int:
    """Deterministic across processes (python's hash() is salted)."""
    h = 2166136261
    for b in f"{domain}:{seed}".encode():
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h


def generate(domain: str, n_chars: int, seed: int = 0) -> str:
    """Generate at least ``n_chars`` characters of ``domain`` text."""
    rng = random.Random(_stable_seed(domain, seed))
    gen = _GENERATORS[domain]
    out: List[str] = []
    total = 0
    while total < n_chars:
        doc = gen(rng)
        out.append(doc)
        total += len(doc)
    return "".join(out)


def write_corpora(out_dir: str, train_chars: int = 900_000,
                  valid_chars: int = 60_000, seed: int = 0) -> None:
    """Write {wiki,web,news}.{train,valid}.txt under ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    for domain in _GENERATORS:
        for split, n in (("train", train_chars), ("valid", valid_chars)):
            path = os.path.join(out_dir, f"{domain}.{split}.txt")
            text = generate(domain, n, seed=seed + (1 if split == "valid" else 0) * 7919)
            with open(path, "w") as f:
                f.write(text)


def tokenize(text: str) -> "np.ndarray":  # noqa: F821 - forward numpy ref
    """Byte-level tokenization: vocab = 256 raw bytes."""
    import numpy as np
    return np.frombuffer(text.encode("utf-8", errors="replace"), dtype=np.uint8)
