"""L1 — Pallas kernel: token-adaptive MoBiSlice bit-sliced matmul (§4.3).

CUDA -> TPU rethink (DESIGN.md §Hardware-Adaptation): the paper's A100
kernel does warp-level BMMA on bit-planes with shared-memory staging and
CUDA-stream slice overlap.  Here:

  * bit-planes live in HBM as int32 words packed along d_in; each grid step
    stages only the planes of ONE slice into VMEM (BlockSpec index map on
    the slice axis == the paper's "fetch only the required slices"),
  * the VPU unpacks words to {0,1} lanes with shift/AND and reconstructs the
    slice's integer codes, then a single MXU matmul x_tile @ deq_tile
    replaces tensor-core WMMA,
  * the slice axis is the innermost grid dimension, so Pallas double-buffers
    consecutive slices — the TPU analogue of overlapping CUDA streams,
  * per-token routing enters as a (T, E) mask multiplying the accumulated
    partial product; token permutation happens host-side (L3) exactly as
    the paper permutes before kernel launch.

interpret=True always: real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute.  Numerics are pinned to kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, planes_ref, scale_ref, zero_ref, mask_ref, o_ref, *,
            slice_bits: int, group_size: int, n_slices: int):
    """One grid step: accumulate slice e's masked partial product.

    Block shapes (leading slice axis is blocked to 1):
      x_ref:      (TM, K)            f32
      planes_ref: (1, slice_bits, K // 32, TN) int32
      scale_ref:  (K // group_size, TN) f32   (base slice scale)
      zero_ref:   (K // group_size, TN) f32   (base slice zero)
      mask_ref:   (TM, 1)            f32      (this slice's token gates)
      o_ref:      (TM, TN)           f32      (revisited across slices)
    """
    e = pl.program_id(2)
    x = x_ref[...]
    words = planes_ref[0].astype(jnp.uint32)       # (B, K//32, TN)
    n_words = words.shape[1]
    tn = words.shape[2]
    k = n_words * 32

    # --- VPU unpack: words -> integer codes (K, TN) ----------------------
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :, None]
    bits = (words[:, :, None, :] >> shifts) & jnp.uint32(1)
    codes = jnp.zeros((n_words, 32, tn), jnp.uint32)
    for p in range(slice_bits):
        codes = codes | (bits[p] << jnp.uint32(p))
    q = codes.reshape(k, tn).astype(jnp.float32)

    # --- shared-scale dequantization (Eq. 14): s_e = s_1 / 2^{b e} -------
    s1 = scale_ref[...]
    z1 = zero_ref[...]
    z_resid = jnp.full_like(z1, float(2 ** (slice_bits - 1)))
    shift = jnp.exp2(-(slice_bits * e).astype(jnp.float32))
    s_e = s1 * shift
    z_e = jnp.where(e == 0, z1, z_resid)
    qg = q.reshape(k // group_size, group_size, tn)
    w = (s_e[:, None, :] * (qg - z_e[:, None, :] + 0.5)).reshape(k, tn)

    # --- MXU matmul + token gating + cross-slice accumulate --------------
    partial = jnp.dot(x, w, preferred_element_type=jnp.float32)
    partial = partial * mask_ref[...]

    @pl.when(e == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(e != 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("slice_bits", "group_size",
                                             "tile_m", "tile_n"))
def mobislice_matmul(x: jnp.ndarray, planes: jnp.ndarray,
                     base_scale: jnp.ndarray, base_zero: jnp.ndarray,
                     mask: jnp.ndarray, *, slice_bits: int = 2,
                     group_size: int = 32, tile_m: int = 128,
                     tile_n: int = 128) -> jnp.ndarray:
    """Token-adaptive bit-sliced matmul.

    x:          (T, K) f32 activations
    planes:     (E, slice_bits, K // 32, N) int32 packed bit-planes
    base_scale: (K // group_size, N) f32 shared slice-1 scale
    base_zero:  (K // group_size, N) f32 shared slice-1 zero
    mask:       (T, E) f32 router gates, mask[:, 0] == 1
    -> y: (T, N) f32
    """
    t, k = x.shape
    n_slices, sb, n_words, n = planes.shape
    assert sb == slice_bits and n_words * 32 == k
    tm = min(tile_m, t)
    tn = min(tile_n, n)
    assert t % tm == 0 and n % tn == 0, "pad T/N to tile multiples host-side"
    # slice axis innermost: consecutive revisits of the same output block
    # accumulate while Pallas double-buffers the next slice's planes.
    grid = (t // tm, n // tn, n_slices)

    return pl.pallas_call(
        functools.partial(_kernel, slice_bits=slice_bits,
                          group_size=group_size, n_slices=n_slices),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j, e: (i, 0)),
            pl.BlockSpec((1, slice_bits, n_words, tn),
                         lambda i, j, e: (e, 0, 0, j)),
            pl.BlockSpec((k // group_size, tn), lambda i, j, e: (0, j)),
            pl.BlockSpec((k // group_size, tn), lambda i, j, e: (0, j)),
            pl.BlockSpec((tm, 1), lambda i, j, e: (i, e)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, e: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(x, planes, base_scale, base_zero, mask)


def vmem_footprint_bytes(k: int, tile_m: int, tile_n: int, slice_bits: int,
                         group_size: int) -> int:
    """Static VMEM footprint estimate for DESIGN.md/EXPERIMENTS.md §Perf.

    Counts the resident blocks of one grid step (x tile, one slice's plane
    words, scale/zero tiles, mask column, output tile) plus the unpacked
    code tile the kernel materialises.
    """
    f32 = 4
    x_tile = tile_m * k * f32
    planes = slice_bits * (k // 32) * tile_n * 4
    scales = 2 * (k // group_size) * tile_n * f32
    maskb = tile_m * f32
    out = tile_m * tile_n * f32
    unpacked = k * tile_n * f32
    return x_tile + planes + scales + maskb + out + unpacked


def mxu_utilization_estimate(k: int, tile_m: int, tile_n: int,
                             slice_bits: int) -> float:
    """Fraction of a grid step spent on MXU-shaped work vs VPU unpack.

    MXU: tm*k*tn MACs; VPU unpack: ~32 ops per word * (slice_bits * k/32
    * tn) words-lanes => k*tn*slice_bits.  Utilization ~ MXU/(MXU + VPU/8)
    with the VPU's 8-wide disadvantage folded in.
    """
    mxu = tile_m * k * tile_n
    vpu = k * tile_n * slice_bits * 4.0
    return mxu / (mxu + vpu / 8.0)
