"""Pallas kernel vs pure-jnp oracle — the CORE L1 correctness signal.

hypothesis sweeps shapes/masks; interpret=True throughout (CPU PJRT).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mobislice_matmul import (mobislice_matmul,
                                              mxu_utilization_estimate,
                                              vmem_footprint_bytes)


def make_case(seed, t, k, n, e=4, slice_bits=2, gs=32, mask_p=0.5):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** slice_bits, size=(e, k, n)).astype(
        np.int32)
    scale = (rng.random((k // gs, n)).astype(np.float32) + 0.3) * 0.1
    zero = rng.random((k // gs, n)).astype(np.float32) * (2 ** slice_bits)
    x = rng.standard_normal((t, k)).astype(np.float32)
    mask = (rng.random((t, e)) < mask_p).astype(np.float32)
    mask[:, 0] = 1.0
    return codes, scale, zero, x, mask


def run_both(codes, scale, zero, x, mask, slice_bits=2, gs=32,
             tile_m=None, tile_n=None):
    t, k = x.shape
    n = codes.shape[2]
    y_ref = ref.ref_matmul(jnp.asarray(x), jnp.asarray(codes),
                           jnp.asarray(scale), jnp.asarray(zero),
                           jnp.asarray(mask), slice_bits, gs)
    planes = ref.pack_words(codes, slice_bits)
    y = mobislice_matmul(jnp.asarray(x), jnp.asarray(planes),
                         jnp.asarray(scale), jnp.asarray(zero),
                         jnp.asarray(mask), slice_bits=slice_bits,
                         group_size=gs, tile_m=tile_m or t,
                         tile_n=tile_n or n)
    return np.asarray(y), np.asarray(y_ref)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([(4, 32, 32), (8, 64, 32), (2, 96, 64)]),
       st.floats(0.0, 1.0))
def test_kernel_matches_ref(seed, shape, mask_p):
    t, k, n = shape
    case = make_case(seed, t, k, n, mask_p=mask_p)
    y, y_ref = run_both(*case)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_tiled_grid():
    case = make_case(7, 16, 64, 64)
    y, y_ref = run_both(*case, tile_m=8, tile_n=32)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_all_slices_equals_sum():
    codes, scale, zero, x, _ = make_case(3, 4, 32, 32)
    mask = np.ones((4, 4), np.float32)
    y, y_ref = run_both(codes, scale, zero, x, mask)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_base_only_mask():
    codes, scale, zero, x, _ = make_case(4, 4, 32, 32)
    mask = np.zeros((4, 4), np.float32)
    mask[:, 0] = 1.0
    y, y_ref = run_both(codes, scale, zero, x, mask)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_pack_words_layout():
    codes = np.zeros((1, 64, 2), np.int32)
    codes[0, 33, 1] = 0b10
    planes = ref.pack_words(codes, 2)
    # plane 1 (bit index 1), word 1, col 1, bit 1 of second word
    assert planes.shape == (1, 2, 2, 2)
    word = np.asarray(planes)[0, 1, 1, 1]
    assert np.uint32(word) == np.uint32(1 << 1)


def test_unpack_words_inverse():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 4, size=(4, 96, 8)).astype(np.int32)
    planes = ref.pack_words(codes, 2)
    back = np.asarray(ref.unpack_words(jnp.asarray(planes)))
    np.testing.assert_array_equal(back, codes)


def test_vmem_footprint_fits_budget():
    # d=4096 tiles must fit a 16 MB VMEM with double buffering
    b = vmem_footprint_bytes(4096, 128, 128, 2, 128)
    assert 2 * b < 16 * 1024 * 1024


def test_mxu_estimate_monotone_in_tile_m():
    a = mxu_utilization_estimate(4096, 8, 128, 2)
    b = mxu_utilization_estimate(4096, 128, 128, 2)
    assert b > a


def test_kernel_composes_under_jit():
    """The kernel participates in larger jitted L2 graphs (inference
    path; backward uses the STE dequant path, not the packed kernel)."""
    codes, scale, zero, x, mask = make_case(5, 4, 32, 32)
    planes = ref.pack_words(codes, 2)

    @jax.jit
    def f(xv):
        y = mobislice_matmul(xv * 2.0, jnp.asarray(planes),
                             jnp.asarray(scale), jnp.asarray(zero),
                             jnp.asarray(mask), slice_bits=2,
                             group_size=32, tile_m=4, tile_n=32)
        return jnp.tanh(y).sum()

    v = float(f(jnp.asarray(x)))
    assert np.isfinite(v)
