"""GPTQ baseline — Hessian-based error-compensating quantization.

Frantar et al., "GPTQ: Accurate post-training quantization for generative
pre-trained transformers" (ref. [12] in the paper).  Classic column-wise
algorithm with Cholesky inverse-Hessian back-substitution and group-wise
scales, adapted to this repo's floor-aligned quantizer so the exported
codes dequantize identically in the Rust engine:

    deq = s * (q - z + 0.5)

W is (d_in, d_out) with y = x @ W; the Hessian is over the d_in axis.
Pure numpy — runs at build time on tiny-model scale in milliseconds.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class StaticQuantLinear(NamedTuple):
    """Exported static-PTQ linear (shared with AWQ/SmoothQuant/RTN)."""
    codes: np.ndarray        # (d_in, d_out) uint8
    scale: np.ndarray        # (n_groups, d_out) f32
    zero: np.ndarray         # (n_groups, d_out) f32
    bits: int
    group_size: int
    act_scale: np.ndarray    # (d_in,) f32 per-channel input divisor (or ones)
    transform: str           # "none" | "chan_scale" | "hadamard"


def _group_params(wblk: np.ndarray, bits: int):
    """Min/max floor-quant params for one group block (gs, d_out)."""
    wmin = np.minimum(wblk.min(axis=0), -1e-8)
    wmax = np.maximum(wblk.max(axis=0), 1e-8)
    scale = np.maximum((wmax - wmin) / float(2 ** bits), 1e-8)
    zero = -wmin / scale
    return scale.astype(np.float32), zero.astype(np.float32)


def _quant_row(w: np.ndarray, s: np.ndarray, z: np.ndarray, bits: int):
    q = np.clip(np.floor(w / s + z), 0, 2 ** bits - 1)
    deq = s * (q - z + 0.5)
    return q.astype(np.uint8), deq


def gptq_quantize(w: np.ndarray, x: np.ndarray, bits: int, group_size: int,
                  percdamp: float = 0.01) -> StaticQuantLinear:
    """Quantize one linear with GPTQ.

    w: (d_in, d_out) float32; x: (n_tokens, d_in) calibration activations.
    """
    w = np.array(w, dtype=np.float64)
    d_in, d_out = w.shape
    assert d_in % group_size == 0
    n_groups = d_in // group_size

    h = x.T.astype(np.float64) @ x.astype(np.float64)   # (d_in, d_in)
    damp = percdamp * float(np.mean(np.diag(h)) + 1e-8)
    h[np.diag_indices(d_in)] += damp

    # Upper Cholesky factor of H^{-1}: the standard GPTQ trick.
    hinv = np.linalg.inv(h)
    # Symmetrise against numerical drift before Cholesky.
    hinv = 0.5 * (hinv + hinv.T)
    l = np.linalg.cholesky(hinv)
    u = l.T            # hinv = l @ l.T ; we consume u rows top-down

    codes = np.zeros((d_in, d_out), dtype=np.uint8)
    scales = np.zeros((n_groups, d_out), dtype=np.float32)
    zeros = np.zeros((n_groups, d_out), dtype=np.float32)

    for g in range(n_groups):
        lo, hi = g * group_size, (g + 1) * group_size
        s, z = _group_params(w[lo:hi], bits)
        scales[g], zeros[g] = s, z
        for i in range(lo, hi):
            d = u[i, i]
            q, deq = _quant_row(w[i], s, z, bits)
            codes[i] = q
            err = (w[i] - deq) / d
            if i + 1 < d_in:
                w[i + 1:] -= np.outer(u[i, i + 1:], err)

    return StaticQuantLinear(codes=codes, scale=scales, zero=zeros,
                             bits=bits, group_size=group_size,
                             act_scale=np.ones(d_in, np.float32),
                             transform="none")


def dequantize(rec: StaticQuantLinear) -> np.ndarray:
    """Reconstruct the (transformed-space) weight matrix."""
    d_in, d_out = rec.codes.shape
    q = rec.codes.astype(np.float32).reshape(-1, rec.group_size, d_out)
    deq = rec.scale[:, None, :] * (q - rec.zero[:, None, :] + 0.5)
    return deq.reshape(d_in, d_out)


def rtn_record(w: np.ndarray, bits: int, group_size: int) -> StaticQuantLinear:
    """Plain round(floor)-to-nearest record, same container."""
    d_in, d_out = w.shape
    n_groups = d_in // group_size
    codes = np.zeros((d_in, d_out), dtype=np.uint8)
    scales = np.zeros((n_groups, d_out), dtype=np.float32)
    zeros = np.zeros((n_groups, d_out), dtype=np.float32)
    for g in range(n_groups):
        lo, hi = g * group_size, (g + 1) * group_size
        s, z = _group_params(np.asarray(w[lo:hi], np.float64), bits)
        scales[g], zeros[g] = s, z
        for i in range(lo, hi):
            codes[i], _ = _quant_row(np.asarray(w[i], np.float64), s, z, bits)
    return StaticQuantLinear(codes=codes, scale=scales, zero=zeros,
                             bits=bits, group_size=group_size,
                             act_scale=np.ones(d_in, np.float32),
                             transform="none")
