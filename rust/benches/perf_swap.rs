//! §Perf §Swap — host-tier KV page swap study (EXPERIMENTS.md §Swap).
//!
//! Questions, all on the synthetic model (no `make artifacts`):
//!
//! 1. **Exact byte accounting** of a swap-out/swap-in round trip at
//!    f32 / i8 / u4 page storage: the pass must move exactly the
//!    cold-page bytes (full pages strictly before the tail page, both
//!    layers), restore exactly the same bytes, and leave the host
//!    tier empty afterwards.  These rows are exact and asserted — a
//!    regenerated report can never silently regress them.
//! 2. **Swap vs recompute**: wall time of a full round trip
//!    (device→host→device memcpy of the cold pages) vs re-prefilling
//!    the same token prefix through the model — the crossover the
//!    ladder's swap rung exists to exploit.  Timing rows vary by
//!    machine; the acceptance bar is the ratio, not the absolute ns.
//!
//! Writes `target/bench_reports/BENCH_swap.json`.

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::{DecodeStats, KvPrecision, KV_PAGE};
use mobiquant::util::bench::{black_box, Suite};

const KV_PRECS: [KvPrecision; 3] =
    [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4];

fn main() {
    let mut suite = Suite::new("BENCH_swap");
    suite.header();
    let prec = Precision::Fixed(2);

    // 4h/2kv, head_dim 16, 2 layers — the shape the pressure tests use
    let model = synth_model_shaped(301, 4, 2, 1024);
    let cfg = &model.cfg;
    let n_layers = cfg.n_layers;

    // ---------------- exact byte accounting x precision ---------------
    // 2.5 pages per layer: exactly two cold pages each, tail stays hot
    let t = 2 * KV_PAGE + KV_PAGE / 2;
    let prompt: Vec<u32> = (0..t).map(|i| ((i * 5 + 2) % 256) as u32)
        .collect();
    for &kvp in &KV_PRECS {
        let mut arena = model.new_arena(1);
        arena.set_host_budget_pages(16);
        let mut scratch = model.new_scratch();
        let mut dstats = DecodeStats::new(n_layers);
        let seq = arena.alloc_seq_at(kvp);
        model.prefill(&prompt, &mut arena, seq, prec, &mut scratch,
                      &mut dstats).unwrap();
        let pb = arena.page_bytes_at(kvp);
        let dev0 = arena.resident_bytes();

        let out = arena.swap_out_seq_cold(seq);
        let cold_pages = 2 * n_layers; // 2 cold pages per layer
        assert_eq!(out.pages, cold_pages,
                   "{}: every cold page must move", kvp.label());
        assert_eq!(out.bytes, cold_pages * pb,
                   "{}: swap-out bytes must be exact", kvp.label());
        assert_eq!(arena.host_resident_bytes(), cold_pages * pb);
        assert_eq!(arena.resident_bytes(), dev0 - cold_pages * pb,
                   "{}: device bytes must return to the budget",
                   kvp.label());

        let back = arena.swap_in_seq(seq).unwrap();
        assert_eq!(back.pages, out.pages);
        assert_eq!(back.bytes, out.bytes,
                   "{}: the restore must move the same bytes back",
                   kvp.label());
        assert_eq!(arena.host_resident_bytes(), 0);
        assert_eq!(arena.resident_bytes(), dev0);

        suite.row(&format!("swap bytes {} @len {t}", kvp.label()), &[
            ("cold_pages", out.pages as f64),
            ("swap_out_bytes", out.bytes as f64),
            ("page_bytes", pb as f64),
            ("bytes_vs_f32_ratio",
             out.bytes as f64
                 / (cold_pages * arena.page_bytes()) as f64),
        ]);
        arena.free_seq(seq);
    }

    // ---------------- swap round trip vs re-prefill -------------------
    // the rung's economics: restoring a parked prefix is O(memcpy) in
    // the cold bytes; the fallback recomputes the same prefix through
    // every layer.  Measure both over the identical token prefix.
    for &ctx in &[2 * KV_PAGE, 8 * KV_PAGE] {
        let prompt: Vec<u32> = (0..ctx + KV_PAGE / 2)
            .map(|i| ((i * 7 + 3) % 256) as u32)
            .collect();
        let mut arena = model.new_arena(2);
        arena.set_host_budget_pages(2 * (ctx / KV_PAGE) * n_layers);
        let mut scratch = model.new_scratch();
        let mut dstats = DecodeStats::new(n_layers);
        let seq = arena.alloc_seq();
        model.prefill(&prompt, &mut arena, seq, prec, &mut scratch,
                      &mut dstats).unwrap();
        let cold_bytes = arena.seq_bytes(seq)
            - n_layers * arena.page_bytes(); // tail pages stay hot

        let ns_swap = suite.bench(
            &format!("swap round trip ctx {ctx}"), || {
                let out = arena.swap_out_seq_cold(seq);
                black_box(out.bytes);
                let back = arena.swap_in_seq(seq).unwrap();
                black_box(back.bytes);
            });
        let ns_reprefill = suite.bench(
            &format!("re-prefill {ctx} cold tokens"), || {
                let h = arena.alloc_seq();
                model.prefill(&prompt[..ctx], &mut arena, h, prec,
                              &mut scratch, &mut dstats).unwrap();
                black_box(scratch.logits[0]);
                arena.free_seq(h);
            });
        suite.row(&format!("swap vs recompute ctx {ctx}"), &[
            ("ns_swap_roundtrip", ns_swap),
            ("ns_reprefill", ns_reprefill),
            ("reprefill_over_swap", ns_reprefill / ns_swap),
            ("cold_bytes", cold_bytes as f64),
        ]);
        arena.free_seq(seq);
    }

    suite.note(&format!(
        "targets: swap bytes rows are exact (cold_pages = 2 per layer \
         x {n_layers} layers; bytes_vs_f32_ratio = 1 / 0.25 / 0.125 \
         for f32/i8/u4 — scales are side metadata); swap vs recompute: \
         reprefill_over_swap must stay >> 1 and grow with ctx (a \
         memcpy round trip vs {n_layers} transformer layers per \
         token), which is the whole case for the ladder's swap rung \
         ahead of preemption"));
    suite.finish();
}
