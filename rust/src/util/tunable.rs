//! Runtime-overridable performance gates.
//!
//! The three parallel-dispatch thresholds (`PARALLEL_MIN_DOUT`,
//! `ATTN_PARALLEL_MIN_WORK`, `ELEMENTWISE_PARALLEL_MIN`) were derived
//! analytically for the persistent fork-join pool and have never been
//! validated on real hardware (no container since the seed has carried
//! a Rust toolchain — see ROADMAP "toolchain debt").  Baking them in as
//! `const`s means the first cargo-equipped session would need a
//! rebuild per candidate value to tune them from measured `perf_pool`
//! dispatch latency.  A [`TunableGate`] keeps the compiled-in constant
//! as the default but lets it be overridden at process start (env var)
//! or at runtime (`ServerConfig` / tests), no rebuild required.
//!
//! Resolution order: programmatic [`TunableGate::set`] beats the
//! environment variable beats the compiled-in default.  The env lookup
//! is cached on first read (gates sit on kernel hot paths; a `getenv`
//! per GEMV would be absurd), so exported overrides must be in place
//! before the first forward pass — which is how deployment knobs work
//! anyway.  The programmatic setter is plumbed for tests and for
//! `ServerConfig`, where it is applied before the scheduler starts.
//!
//! Gates only move the serial/parallel dispatch decision, never the
//! arithmetic: serial and pooled kernels are pinned bit-identical
//! (`tests/parallel_parity.rs`), so a concurrently flipped gate can
//! change wall time but not one output bit.
//!
//! The SIMD kernel dispatch ([`crate::util::simd`], `MOBIQ_SIMD`)
//! follows the same resolution order — programmatic override
//! (`ServerConfig.simd` / tests) beats the cached env var beats the
//! default — but is *not* a `TunableGate`: its value is an enum (off /
//! auto / level cap) rather than a threshold, and unlike these gates
//! flipping it can reassociate f32 reductions, which is why the parity
//! suites pin each mode separately (`tests/simd_parity.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Sentinel meaning "no programmatic override installed".
const UNSET: usize = usize::MAX;

/// One runtime-overridable threshold: a compiled-in default, an
/// optional environment override (read once), and an optional
/// programmatic override (atomic, takes precedence).
pub struct TunableGate {
    env_name: &'static str,
    default: usize,
    /// Programmatic override; [`UNSET`] when absent.
    set: AtomicUsize,
    /// Cached result of the env lookup (`None` = unset or unparsable).
    env: OnceLock<Option<usize>>,
}

impl TunableGate {
    /// `const`-constructible so gates can live in `static`s next to
    /// the constants they wrap.
    pub const fn new(env_name: &'static str, default: usize)
                     -> TunableGate {
        TunableGate {
            env_name,
            default,
            set: AtomicUsize::new(UNSET),
            env: OnceLock::new(),
        }
    }

    /// Current effective value: programmatic override, else env var
    /// (first read wins, cached), else the compiled-in default.
    #[inline]
    pub fn get(&self) -> usize {
        let s = self.set.load(Ordering::Relaxed);
        if s != UNSET {
            return s;
        }
        self.env
            .get_or_init(|| {
                std::env::var(self.env_name)
                    .ok()
                    .and_then(|v| v.trim().parse().ok())
            })
            .unwrap_or(self.default)
    }

    /// Install a programmatic override (beats env and default).
    /// `usize::MAX` is reserved as the unset sentinel and clamps down
    /// one — at that magnitude both values mean "never parallel".
    pub fn set(&self, v: usize) {
        self.set.store(v.min(UNSET - 1), Ordering::Relaxed);
    }

    /// Drop the programmatic override, falling back to env/default.
    pub fn clear(&self) {
        self.set.store(UNSET, Ordering::Relaxed);
    }

    /// The compiled-in default (what `get` returns with no overrides).
    pub fn default_value(&self) -> usize {
        self.default
    }

    /// The environment variable this gate reads at first use.
    pub fn env_var(&self) -> &'static str {
        self.env_name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_when_untouched() {
        let g = TunableGate::new("MOBIQ_TEST_GATE_UNSET_XYZ", 128);
        assert_eq!(g.get(), 128);
        assert_eq!(g.default_value(), 128);
        assert_eq!(g.env_var(), "MOBIQ_TEST_GATE_UNSET_XYZ");
    }

    #[test]
    fn programmatic_override_and_clear() {
        let g = TunableGate::new("MOBIQ_TEST_GATE_SET_XYZ", 128);
        g.set(7);
        assert_eq!(g.get(), 7);
        g.set(0);
        assert_eq!(g.get(), 0, "zero (always parallel) is a valid value");
        g.clear();
        assert_eq!(g.get(), 128, "clear falls back to the default");
    }

    #[test]
    fn env_override_read_once() {
        // Fresh gate instances so the global statics are untouched and
        // this test cannot race the parity suites.
        std::env::set_var("MOBIQ_TEST_GATE_ENV_XYZ", "4096");
        let g = TunableGate::new("MOBIQ_TEST_GATE_ENV_XYZ", 128);
        assert_eq!(g.get(), 4096);
        // the lookup is cached: later env changes do not move the gate
        std::env::set_var("MOBIQ_TEST_GATE_ENV_XYZ", "1");
        assert_eq!(g.get(), 4096);
        std::env::remove_var("MOBIQ_TEST_GATE_ENV_XYZ");
    }

    #[test]
    fn set_beats_env() {
        std::env::set_var("MOBIQ_TEST_GATE_PREC_XYZ", "4096");
        let g = TunableGate::new("MOBIQ_TEST_GATE_PREC_XYZ", 128);
        g.set(9);
        assert_eq!(g.get(), 9, "programmatic override beats env");
        g.clear();
        assert_eq!(g.get(), 4096, "clearing falls back to env");
        std::env::remove_var("MOBIQ_TEST_GATE_PREC_XYZ");
    }

    #[test]
    fn garbage_env_falls_back_to_default() {
        std::env::set_var("MOBIQ_TEST_GATE_BAD_XYZ", "not-a-number");
        let g = TunableGate::new("MOBIQ_TEST_GATE_BAD_XYZ", 128);
        assert_eq!(g.get(), 128);
        std::env::remove_var("MOBIQ_TEST_GATE_BAD_XYZ");
    }

    #[test]
    fn max_clamps_below_sentinel() {
        let g = TunableGate::new("MOBIQ_TEST_GATE_MAX_XYZ", 128);
        g.set(usize::MAX);
        assert_eq!(g.get(), usize::MAX - 1);
    }
}
