//! Communicator abstraction for tensor-parallel sharded execution.
//!
//! The sharded forward path (`model/shard.rs`) partitions attention
//! heads, FFN slices and the KV arena across N worker shards.  All
//! cross-shard coordination goes through the [`Communicator`] trait —
//! the sharded transformer code never touches the threadpool directly —
//! so the in-process backend here can later be swapped for a
//! multi-process or PJRT-device backend behind the same three
//! primitives (the `CommunicatorGroup`/`ReduceType` shape InfiniLM
//! uses for its NVIDIA distributed llama; see ROADMAP).
//!
//! ## First backend: in-process shards on the persistent pool
//!
//! [`InProcGroup`] owns N rank handles ([`InProcComm`]) and dispatches
//! one closure per rank onto the existing persistent fork-join
//! [`ThreadPool`] ([`InProcGroup::run`] — the single point where
//! sharded execution meets the pool).  Ranks coordinate through a
//! sense-counting barrier (mutex + condvar; a dispatch-heavy fanout
//! would want a spinning tree barrier, but a decode layer crosses the
//! barrier 4 times per layer against ~10⁵-FLOP phases, so the condvar
//! cost is noise at current shapes and trivially correct).
//!
//! **Determinism note.**  `all_reduce_sum` is rank-count-dependent by
//! construction: it folds partials in rank order, which re-associates
//! f32 addition relative to a serial kernel, so a reduction-based join
//! cannot be bit-identical across shard counts.  The sharded
//! transformer therefore joins by *gather* — every output element is
//! computed whole by exactly one shard and barriers publish the
//! columns (see `model/shard.rs` and EXPERIMENTS.md §Sharding) —
//! and `all_reduce_sum`/`broadcast` are provided (and unit-tested)
//! for the approximate row-partial GEMM path and for future backends
//! where exactness is already scoped per device.
//!
//! ## Pool-capacity contract
//!
//! Ranks block inside barriers mid-closure, so every rank must run on
//! its own pool lane for the lifetime of one `run` dispatch:
//! `parallel_for(n_shards, ..)` with `pool.size() >= n_shards` wakes
//! exactly `n_shards - 1` workers and runs the last rank on the
//! caller, and a lane blocked in a barrier cannot claim a second rank
//! before every rank has been claimed (the barrier only opens once all
//! ranks reach it).  [`InProcGroup::new`] enforces the capacity bound
//! at construction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::threadpool::ThreadPool;

/// Collective-communication surface of one shard (one "rank").  The
/// sharded forward path is written against this trait only.
pub trait Communicator {
    /// Number of shards in the group.
    fn n_shards(&self) -> usize;

    /// This shard's index, `0..n_shards`.
    fn rank(&self) -> usize;

    /// Block until every rank in the group has called `barrier`.
    /// This is the primitive the exact gather joins are built on.
    fn barrier(&self);

    /// Element-wise sum of every rank's `buf` across the group; all
    /// ranks return with identical contents.  Partials are folded in
    /// rank order (deterministic for a fixed shard count, not
    /// bit-stable across shard counts — see module docs).  All ranks
    /// must pass equal-length buffers.
    fn all_reduce_sum(&self, buf: &mut [f32]);

    /// Copy `root`'s `buf` into every rank's `buf`.  All ranks must
    /// pass equal-length buffers.
    fn broadcast(&self, root: usize, buf: &mut [f32]);
}

/// Shared state of one in-process group.
struct InProcShared {
    n: usize,
    /// Sense-counting barrier: arrivals in the current generation,
    /// plus the generation counter that releases waiters.
    gate: Mutex<(usize, u64)>,
    cv: Condvar,
    /// Exchange slab for `all_reduce_sum`/`broadcast`: `n` rank slots
    /// of the call's buffer length, grown on demand under the lock.
    slots: Mutex<Vec<f32>>,
    /// Length every rank passed to the current collective (validated:
    /// ragged collectives are a protocol bug, caught loudly).
    slot_len: AtomicUsize,
}

impl InProcShared {
    fn barrier(&self) {
        let mut g = self.gate.lock().unwrap();
        let gen = g.1;
        g.0 += 1;
        if g.0 == self.n {
            g.0 = 0;
            g.1 = g.1.wrapping_add(1);
            self.cv.notify_all();
            return;
        }
        while g.1 == gen {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// One rank's handle onto an in-process shard group.  Cheap to clone
/// conceptually (all state is behind an `Arc`), but ranks are handed
/// out by [`InProcGroup::run`] — user code never fabricates one.
pub struct InProcComm {
    rank: usize,
    shared: Arc<InProcShared>,
}

impl InProcComm {
    /// Stage this rank's buffer into its exchange slot.  Returns the
    /// per-rank slot stride (== `buf.len()`).
    fn stage(&self, buf: &[f32]) {
        let mut slots = self.shared.slots.lock().unwrap();
        let need = self.shared.n * buf.len();
        if slots.len() < need {
            slots.resize(need, 0.0);
        }
        let prev = self.shared.slot_len.swap(buf.len(), Ordering::Relaxed);
        debug_assert!(prev == 0 || prev == buf.len(),
                      "ragged collective: ranks passed different lengths");
        let lo = self.rank * buf.len();
        slots[lo..lo + buf.len()].copy_from_slice(buf);
    }
}

impl Communicator for InProcComm {
    fn n_shards(&self) -> usize {
        self.shared.n
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn barrier(&self) {
        self.shared.barrier();
    }

    fn all_reduce_sum(&self, buf: &mut [f32]) {
        if self.shared.n == 1 {
            return;
        }
        self.stage(buf);
        // all slots staged after this
        self.shared.barrier();
        {
            let slots = self.shared.slots.lock().unwrap();
            let w = buf.len();
            // fold in rank order so every rank computes the identical
            // (shard-count-dependent) association
            buf.copy_from_slice(&slots[..w]);
            for r in 1..self.shared.n {
                for (o, &x) in buf.iter_mut().zip(&slots[r * w..(r + 1) * w])
                {
                    *o += x;
                }
            }
        }
        // all ranks done reading before slots can be restaged
        self.shared.slot_len.store(0, Ordering::Relaxed);
        self.shared.barrier();
    }

    fn broadcast(&self, root: usize, buf: &mut [f32]) {
        if self.shared.n == 1 {
            return;
        }
        debug_assert!(root < self.shared.n, "broadcast root out of range");
        if self.rank == root {
            self.stage(buf);
        } else {
            // non-roots still publish their length for the ragged check
            let mut slots = self.shared.slots.lock().unwrap();
            let need = self.shared.n * buf.len();
            if slots.len() < need {
                slots.resize(need, 0.0);
            }
        }
        self.shared.barrier();
        if self.rank != root {
            let slots = self.shared.slots.lock().unwrap();
            let w = buf.len();
            buf.copy_from_slice(&slots[root * w..root * w + w]);
        }
        self.shared.barrier();
    }
}

/// An in-process shard group: N rank handles plus the pool that runs
/// them.  This is the only type in the sharded path that talks to the
/// [`ThreadPool`]; everything above it sees [`Communicator`]s.
pub struct InProcGroup {
    comms: Vec<InProcComm>,
    pool: Arc<ThreadPool>,
}

impl InProcGroup {
    /// Build a group of `n_shards` ranks on `pool`.
    ///
    /// # Panics
    /// If `n_shards == 0` or `pool.size() < n_shards` — ranks block in
    /// barriers, so each needs a dedicated lane (see module docs).
    pub fn new(n_shards: usize, pool: Arc<ThreadPool>) -> InProcGroup {
        assert!(n_shards > 0, "shard group needs at least one rank");
        assert!(pool.size() >= n_shards,
                "pool of {} lanes cannot run {} blocking shard ranks",
                pool.size(), n_shards);
        let shared = Arc::new(InProcShared {
            n: n_shards,
            gate: Mutex::new((0, 0)),
            cv: Condvar::new(),
            slots: Mutex::new(Vec::new()),
            slot_len: AtomicUsize::new(0),
        });
        let comms = (0..n_shards)
            .map(|rank| InProcComm { rank, shared: Arc::clone(&shared) })
            .collect();
        InProcGroup { comms, pool }
    }

    pub fn n_shards(&self) -> usize {
        self.comms.len()
    }

    /// Run `f` once per rank, concurrently, returning when every rank
    /// has finished.  The closure may call barriers/collectives on its
    /// rank handle; it must make the same sequence of collective calls
    /// on every rank (the usual SPMD contract).
    pub fn run(&self, f: impl Fn(&InProcComm) + Sync) {
        let n = self.comms.len();
        if n == 1 {
            f(&self.comms[0]);
            return;
        }
        self.pool.parallel_for(n, |i| f(&self.comms[i]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn group(n: usize) -> InProcGroup {
        InProcGroup::new(n, Arc::new(ThreadPool::new(n)))
    }

    #[test]
    fn runs_every_rank_once() {
        let g = group(4);
        let seen = AtomicU64::new(0);
        g.run(|c| {
            assert_eq!(c.n_shards(), 4);
            seen.fetch_or(1 << c.rank(), Ordering::SeqCst);
        });
        assert_eq!(seen.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn barrier_orders_phases() {
        // every rank writes its slot in phase 1; after the barrier,
        // every rank must observe all phase-1 writes
        let g = group(3);
        let phase1 = [AtomicU64::new(0), AtomicU64::new(0),
                      AtomicU64::new(0)];
        g.run(|c| {
            phase1[c.rank()].store(c.rank() as u64 + 1, Ordering::SeqCst);
            c.barrier();
            for (r, slot) in phase1.iter().enumerate() {
                assert_eq!(slot.load(Ordering::SeqCst), r as u64 + 1,
                           "rank {} missed rank {}'s phase-1 write",
                           c.rank(), r);
            }
            c.barrier();
        });
    }

    #[test]
    fn barrier_reusable_many_generations() {
        let g = group(2);
        let counter = AtomicU64::new(0);
        g.run(|c| {
            for i in 0..64u64 {
                if c.rank() == 0 {
                    counter.fetch_add(1, Ordering::SeqCst);
                }
                c.barrier();
                assert_eq!(counter.load(Ordering::SeqCst), i + 1);
                c.barrier();
            }
        });
    }

    #[test]
    fn all_reduce_sums_in_rank_order() {
        let g = group(3);
        let ok = AtomicU64::new(0);
        g.run(|c| {
            let mut buf = vec![c.rank() as f32 + 1.0; 5];
            buf[0] = (c.rank() as f32 + 1.0) * 10.0;
            c.all_reduce_sum(&mut buf);
            // ranks contribute 1+2+3 (tail) and 10+20+30 (head)
            assert_eq!(buf[0], 60.0);
            assert!(buf[1..].iter().all(|&x| x == 6.0));
            ok.fetch_add(1, Ordering::SeqCst);
            // back-to-back reductions must not see stale slots
            let mut buf2 = vec![1.0f32; 2];
            c.all_reduce_sum(&mut buf2);
            assert!(buf2.iter().all(|&x| x == 3.0));
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn all_reduce_single_rank_is_identity() {
        let g = group(1);
        g.run(|c| {
            let mut buf = vec![4.0f32, 5.0];
            c.all_reduce_sum(&mut buf);
            assert_eq!(buf, vec![4.0, 5.0]);
        });
    }

    #[test]
    fn broadcast_copies_root() {
        let g = group(4);
        g.run(|c| {
            let mut buf = if c.rank() == 2 {
                vec![7.0f32, 8.0, 9.0]
            } else {
                vec![0.0f32; 3]
            };
            c.broadcast(2, &mut buf);
            assert_eq!(buf, vec![7.0, 8.0, 9.0], "rank {}", c.rank());
            // a second broadcast from a different root reuses the slab
            let mut buf2 = if c.rank() == 0 {
                vec![-1.0f32]
            } else {
                vec![0.0f32]
            };
            c.broadcast(0, &mut buf2);
            assert_eq!(buf2, vec![-1.0]);
        });
    }

    #[test]
    fn oversized_pool_is_fine() {
        // more lanes than ranks: parallel_for(n) wakes only n-1
        let g = InProcGroup::new(2, Arc::new(ThreadPool::new(5)));
        let hits = AtomicU64::new(0);
        g.run(|c| {
            c.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    #[should_panic(expected = "cannot run")]
    fn undersized_pool_rejected() {
        // 2 lanes cannot host 3 ranks that block in barriers
        let _ = InProcGroup::new(3, Arc::new(ThreadPool::new(2)));
    }
}
