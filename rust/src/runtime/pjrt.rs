//! Real PJRT backend (`--features pjrt`): loads AOT HLO-text modules
//! lowered by python/compile/aot.py and executes them on the XLA CPU
//! client via the vendored `xla` bindings.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).  All modules
//! are lowered with return_tuple=True, so results unwrap via to_tuple1.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

pub use xla::Literal;

pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

pub struct HloModule {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl PjrtRuntime {
    /// Whether this build carries a real PJRT backend (callers that
    /// would otherwise `unwrap` a client should skip when false).
    pub fn available() -> bool {
        true
    }

    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn load(&self, path: impl AsRef<Path>) -> Result<HloModule> {
        let path = path.as_ref().to_path_buf();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(HloModule { exe, path })
    }
}

impl HloModule {
    /// Execute with literal inputs; returns the first element of the
    /// result tuple as a flat f32 vector.
    pub fn run_f32(&self, inputs: &[Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// tokens (i32) -> logits (T * vocab) — the model_fp / model_q modules.
    pub fn run_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let lit = Literal::vec1(tokens);
        self.run_f32(&[lit])
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}
