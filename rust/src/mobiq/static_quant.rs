//! Static-PTQ baseline records (Tab. 2 / Fig. 1 / App. E comparators).
//!
//! One record per linear per (method, bits): integer codes + group
//! scales/zeros + an activation-side transform:
//!
//! * `None`        — RTN / GPTQ / OmniQuant-lite
//! * `ChanScale`   — AWQ / SmoothQuant (x'_j = x_j / s_j, weights folded)
//! * `Hadamard`    — QuaRot-lite / SpinQuant-lite (x' = FWHT_block(D x))
//!
//! The fast Walsh-Hadamard transform runs on the activation at request
//! time; the math is exactly the python oracle in quant/rotation.py.

use anyhow::{bail, Result};

use super::artifact::Bundle;
use super::gemv::matvec;
use super::quantizer::{dequantize, GroupParams};

#[derive(Debug, Clone, PartialEq)]
pub enum Transform {
    None,
    /// Per-channel divisor on the activation.
    ChanScale(Vec<f32>),
    /// Block FWHT with per-channel pre-signs (+-1) and block size.
    Hadamard { signs: Vec<f32>, block: usize },
}

#[derive(Debug, Clone)]
pub struct StaticLinear {
    pub weights: Vec<f32>, // dequantized (d_in, d_out); hot path is dense
    pub codes: Vec<u8>,
    pub params: GroupParams,
    pub transform: Transform,
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u32,
}

impl StaticLinear {
    pub fn from_bundle(bundle: &Bundle, method: &str, layer: usize,
                       name: &str) -> Result<StaticLinear> {
        let pre = format!("static.{method}.layers.{layer}.{name}");
        let codes_t = bundle.tensor(&format!("{pre}.codes"))?;
        let (d_in, d_out) = (codes_t.shape[0], codes_t.shape[1]);
        let codes = codes_t.u8()?.to_vec();
        let (sshape, scale) = bundle.f32(&format!("{pre}.scale"))?;
        let (_, zero) = bundle.f32(&format!("{pre}.zero"))?;
        let (_, act_scale) = bundle.f32(&format!("{pre}.act_scale"))?;
        let n_groups = sshape[0];
        let meta = bundle.manifest
            .path(&["static_methods", method])
            .ok_or_else(|| anyhow::anyhow!("no meta for {method}"))?;
        let bits = meta.get("bits").and_then(|v| v.as_usize())
            .unwrap_or(3) as u32;
        let tf = meta.get("transform").and_then(|v| v.as_str())
            .unwrap_or("none");
        let transform = match tf {
            "none" => Transform::None,
            "chan_scale" => Transform::ChanScale(act_scale.to_vec()),
            "hadamard" => Transform::Hadamard {
                signs: act_scale.to_vec(),
                block: hadamard_block_size(d_in, 64),
            },
            other => bail!("unknown transform {other}"),
        };
        let params = GroupParams {
            scale: scale.to_vec(),
            zero: zero.to_vec(),
            n_groups,
            d_out,
            bits,
            group_size: d_in / n_groups,
        };
        let weights = dequantize(&codes, &params);
        Ok(StaticLinear { weights, codes, params, transform, d_in, d_out,
                          bits })
    }

    /// y = transform(x) @ deq(codes); scratch must be d_in long.
    pub fn forward(&self, x: &[f32], scratch: &mut [f32],
                   out: &mut [f32]) {
        apply_transform(&self.transform, x, scratch);
        matvec(&self.weights, scratch, out, self.d_in, self.d_out);
    }

    pub fn nbytes_packed(&self) -> usize {
        // codes at `bits` per weight + scales/zeros
        self.codes.len() * self.bits as usize / 8
            + self.params.scale.len() * 8
    }
}

/// Largest power of two <= max_block dividing d (mirror of rotation.py).
pub fn hadamard_block_size(d: usize, max_block: usize) -> usize {
    let mut b = 1;
    while b * 2 <= max_block && d % (b * 2) == 0 {
        b *= 2;
    }
    b
}

/// Normalised in-place FWHT over blocks of `block` along x.
pub fn block_fwht(x: &mut [f32], block: usize) {
    debug_assert_eq!(x.len() % block, 0);
    let norm = 1.0 / (block as f32).sqrt();
    for chunk in x.chunks_exact_mut(block) {
        let mut h = 1;
        while h < block {
            let mut i = 0;
            while i < block {
                for j in i..i + h {
                    let a = chunk[j];
                    let b = chunk[j + h];
                    chunk[j] = a + b;
                    chunk[j + h] = a - b;
                }
                i += 2 * h;
            }
            h *= 2;
        }
        for v in chunk.iter_mut() {
            *v *= norm;
        }
    }
}

pub fn apply_transform(t: &Transform, x: &[f32], out: &mut [f32]) {
    out.copy_from_slice(x);
    match t {
        Transform::None => {}
        Transform::ChanScale(s) => {
            for (o, sv) in out.iter_mut().zip(s) {
                *o /= sv;
            }
        }
        Transform::Hadamard { signs, block } => {
            for (o, sg) in out.iter_mut().zip(signs) {
                *o *= sg;
            }
            block_fwht(out, *block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{property, Pcg};

    #[test]
    fn fwht_is_orthonormal() {
        property(31, 20, |rng, _| {
            let block = [2, 4, 8, 16, 32][rng.below(5)];
            let n = block * (1 + rng.below(3));
            let x = rng.normal_vec(n, 1.0);
            let mut y = x.clone();
            block_fwht(&mut y, block);
            // norm preserved
            let nx: f32 = x.iter().map(|v| v * v).sum();
            let ny: f32 = y.iter().map(|v| v * v).sum();
            assert!((nx - ny).abs() < 1e-3 * nx.max(1.0));
            // involution: H(Hx) = x for normalised Hadamard
            block_fwht(&mut y, block);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn fwht_matches_matrix_h2() {
        let mut x = vec![3.0, 5.0];
        block_fwht(&mut x, 2);
        let s = 1.0 / 2f32.sqrt();
        assert!((x[0] - 8.0 * s).abs() < 1e-6);
        assert!((x[1] + 2.0 * s).abs() < 1e-6);
    }

    #[test]
    fn chan_scale_transform() {
        let t = Transform::ChanScale(vec![2.0, 4.0]);
        let mut out = vec![0.0; 2];
        apply_transform(&t, &[8.0, 8.0], &mut out);
        assert_eq!(out, vec![4.0, 2.0]);
    }

    #[test]
    fn rotation_preserves_linear_output() {
        // (x H)(H^T W) == x W: quantization-free invariance check.
        let mut rng = Pcg::new(4);
        let (d_in, d_out, block) = (16, 6, 16);
        let w = rng.normal_vec(d_in * d_out, 0.5);
        let x = rng.normal_vec(d_in, 1.0);
        // rotate W rows: each column of W transformed by FWHT
        let mut w_rot = vec![0f32; d_in * d_out];
        for o in 0..d_out {
            let mut col: Vec<f32> = (0..d_in).map(|r| w[r * d_out + o])
                .collect();
            block_fwht(&mut col, block);
            for r in 0..d_in {
                w_rot[r * d_out + o] = col[r];
            }
        }
        let mut xr = x.clone();
        block_fwht(&mut xr, block);
        let mut y1 = vec![0f32; d_out];
        let mut y2 = vec![0f32; d_out];
        matvec(&w, &x, &mut y1, d_in, d_out);
        matvec(&w_rot, &xr, &mut y2, d_in, d_out);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
