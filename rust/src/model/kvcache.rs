//! KV storage: the process-wide paged arena (serving path) and the
//! contiguous per-sequence slab (oracle/test path).
//!
//! Until PR 4 every sequence slot eagerly allocated
//! `n_layers x 2 x n_kv_heads x max_seq_len x head_dim` floats up
//! front, so KV memory was budgeted for worst-case context even for a
//! 30-token request, and admission had to assume the worst case.  The
//! [`KvArena`] replaces those slabs with one vLLM-style pool of
//! fixed-size pages ([`KV_PAGE`] positions each):
//!
//! * each sequence x layer holds a page table ([`LayerTable`]) instead
//!   of a slab, and pages are allocated lazily as positions are
//!   appended — resident bytes track actual context, not `max_seq_len`;
//! * pages are refcounted, so a detected shared prompt prefix maps the
//!   same physical pages into many sequences ([`KvArena::fork_prefix`]);
//!   the first append into a shared partial page copies it
//!   (copy-on-write), full shared pages are never copied;
//! * the free list makes retire-then-readmit reuse pages without
//!   touching the allocator, and the scheduler admits against real
//!   free-byte counts (`coordinator/scheduler.rs`).
//!
//! ## Precision-tagged pages (PR 5)
//!
//! At serving scale the KV cache — not the weights — dominates resident
//! bytes and decode memory bandwidth, so pages now come in three
//! storage precisions ([`KvPrecision`]): the original f32 slabs, int8
//! (4x smaller) and bit-packed int4 (8x smaller).  Each sequence picks
//! its precision at [`KvArena::alloc_seq_at`] time (plumbed from
//! `ServerConfig` / per-request) and fresh pages land in that
//! precision's pool; since PR 6 tables tag the precision per *page*,
//! because [`KvArena::requant_seq_tail`] converts exclusively-owned
//! pages down the ladder in place under memory pressure while shared
//! prefix pages keep the precision their other owners expect.  Quantization is symmetric absmax with **one scale
//! per (page, kv head, side)**: `x ~= code * step` where
//! `step = absmax / qmax` (qmax 127 for i8, 7 for i4).  The scale is
//! updated incrementally on append — when a fresh row's absmax exceeds
//! the page's current range, the page-head's existing codes are
//! re-coded to the wider step (a pure integer rescale over at most
//! `KV_PAGE` rows) before the new rows land.  Quantization happens at
//! scatter time, fused with the K-side RoPE rotation — no staging
//! buffer ever holds a dequantized page.
//!
//! The **byte budget** replaces the page budget: a quantized page costs
//! proportionally less of the arena's capacity, so an i8 deployment
//! admits ~4x the sequences under the same `kv_page_budget`
//! (expressed in f32-page equivalents).  Per-page-head scales live in
//! their own side tables — like the page tables themselves they are
//! O(pages) metadata, not counted against the data budget.
//!
//! Page layout: within a page, `[kv_head][pos_in_page][head_dim]` —
//! the same head-major order as the slab, so one head's K (or V) rows
//! for any run of positions inside a page are contiguous (int4 packs
//! two codes per byte, low nibble first, so a row is `head_dim / 2`
//! bytes).  [`KV_PAGE`] is a multiple of the attention kernel's
//! `ATTN_TILE`, so a position tile never straddles a page: a tile's
//! rows always share one page and therefore **one scale**, which is
//! what lets the kernels fuse dequantization into the dot product with
//! the scale hoisted out of the inner loop (`model/attention.rs`).
//!
//! The [`KvSource`] trait is the read interface the attention kernels
//! stream through; runs come back as [`KvRun`] — an f32 slice, or a
//! quantized slice + its page-uniform scale.  Both [`KvCache`] (slab)
//! and [`KvLayerView`] (one sequence x layer of the arena) implement
//! it; the f32 paged path is bit-identical to the slab under the same
//! kernel (pinned by tests).
//!
//! ## Host swap tier (PR 10)
//!
//! Preempting a sequence under Critical pressure used to throw its KV
//! away and pay a full prefix re-prefill at resume — O(context) of
//! recompute for exactly the long-context requests that cause
//! pressure.  The arena now carries a second, host-side byte budget
//! ([`KvArena::set_host_budget_pages`]): [`KvArena::swap_out_seq_cold`]
//! moves a sequence's exclusively-owned **cold** pages (every full
//! page before the tail page) into host-tier pools byte-for-byte and
//! returns their device bytes to the budget, and
//! [`KvArena::swap_in_seq`] restores them — so parking and resuming a
//! sequence is O(memcpy), with re-prefill demoted to the fallback for
//! a full (or failpoint-denied) host tier.  Page tables tag each
//! entry with its tier ([`PageLocation`]); a host-tagged page must be
//! swapped back in before the kernels read it ([`KvLayerView`] treats
//! a host-tier run as a dispatch bug and panics).

use super::attention::RopeCache;

/// Positions per KV page.  A multiple of `attention::ATTN_TILE` (32)
/// so tiles never straddle a page; at head_dim 64 one page side is
/// 16 KB per kv head at f32, 4 KB at i8, 2 KB at i4.
pub const KV_PAGE: usize = 64;

// ---------------------------------------------------------------------------
// Storage precision
// ---------------------------------------------------------------------------

/// Storage precision of KV pages.  Chosen per sequence at allocation
/// (`ServerConfig::kv_precision` / per-request) and inherited by
/// forks; online requantization ([`KvArena::requant_seq_tail`]) can
/// later move a sequence's exclusively-owned pages down the ladder, so
/// tables track the precision per page and shared pages are always
/// read at the precision they were written.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvPrecision {
    /// Exact f32 rows — the oracle path and the default.
    #[default]
    F32,
    /// Symmetric int8, one absmax scale per (page, kv head, side): 4x
    /// smaller than f32 at ~0.4% absmax-relative rounding error.
    Int8,
    /// Bit-packed int4 (two codes per byte): 8x smaller, ~7% step.
    Int4,
}

impl KvPrecision {
    /// Storage bytes of one position x head row (one side).
    pub fn row_bytes(self, head_dim: usize) -> usize {
        match self {
            KvPrecision::F32 => head_dim * 4,
            KvPrecision::Int8 => head_dim,
            KvPrecision::Int4 => head_dim / 2,
        }
    }

    /// Bytes of one page's K + V data.  Per-page-head scales are
    /// O(pages) side metadata (like the page tables) and not counted.
    pub fn page_bytes(self, n_kv_heads: usize, head_dim: usize) -> usize {
        2 * n_kv_heads * KV_PAGE * self.row_bytes(head_dim)
    }

    pub fn label(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::Int8 => "i8",
            KvPrecision::Int4 => "u4",
        }
    }

    /// Coarseness rank along the degradation ladder: f32 < i8 < u4.
    /// Online requantization only ever moves pages to a higher rank
    /// (lossy, irreversible without recompute), so ladder logic
    /// compares ranks instead of enumerating pairs.
    pub fn rank(self) -> u8 {
        match self {
            KvPrecision::F32 => 0,
            KvPrecision::Int8 => 1,
            KvPrecision::Int4 => 2,
        }
    }

    /// The next coarser precision down the ladder (None at the bottom).
    pub fn degrade(self) -> Option<KvPrecision> {
        match self {
            KvPrecision::F32 => Some(KvPrecision::Int8),
            KvPrecision::Int8 => Some(KvPrecision::Int4),
            KvPrecision::Int4 => None,
        }
    }
}

/// Decode code `i` of a bit-packed int4 run (low nibble first).
#[inline]
pub fn u4_code(data: &[u8], i: usize) -> i8 {
    let nib = (data[i >> 1] >> ((i & 1) * 4)) & 0xF;
    // sign-extend the 4-bit two's-complement nibble
    ((nib << 4) as i8) >> 4
}

// ---------------------------------------------------------------------------
// Read interface shared by slab and paged storage
// ---------------------------------------------------------------------------

/// One contiguous head-major run of K or V rows, in whatever precision
/// the backing pages store.  Quantized runs carry the page-uniform
/// dequant step (`x ~= code * scale`); because `KV_PAGE % ATTN_TILE ==
/// 0` a kernel tile always resolves to exactly one run with exactly
/// one scale, so the kernels hoist it out of their inner loops.
#[derive(Debug, Clone, Copy)]
pub enum KvRun<'a> {
    /// `positions x head_dim` floats.
    F32(&'a [f32]),
    /// `positions x head_dim` symmetric int8 codes.
    I8 { data: &'a [i8], scale: f32 },
    /// `positions x head_dim / 2` bytes of packed int4 codes (two per
    /// byte, low nibble first).
    U4 { data: &'a [u8], scale: f32 },
}

impl<'a> KvRun<'a> {
    /// The f32 slice of an exact run, `None` on quantized storage
    /// (oracle/test accessor — kernels match on the variant instead).
    /// Non-panicking so a routing bug surfaces as a handleable error,
    /// not a tick abort.
    pub fn as_f32(&self) -> Option<&'a [f32]> {
        match *self {
            KvRun::F32(s) => Some(s),
            _ => None,
        }
    }

    /// Dequant step of the run (1.0 for exact f32).
    pub fn scale(&self) -> f32 {
        match self {
            KvRun::F32(_) => 1.0,
            KvRun::I8 { scale, .. } | KvRun::U4 { scale, .. } => *scale,
        }
    }

    /// Number of positions in the run.
    pub fn positions(&self, head_dim: usize) -> usize {
        match self {
            KvRun::F32(s) => s.len() / head_dim,
            KvRun::I8 { data, .. } => data.len() / head_dim,
            KvRun::U4 { data, .. } => 2 * data.len() / head_dim,
        }
    }

    /// Dequantized copy (tests/diagnostics; the kernels fuse dequant
    /// into their tiles instead of materialising this).
    pub fn dequant(&self, head_dim: usize) -> Vec<f32> {
        match self {
            KvRun::F32(s) => s.to_vec(),
            KvRun::I8 { data, scale } => {
                data.iter().map(|&c| c as f32 * scale).collect()
            }
            KvRun::U4 { data, scale } => {
                let n = self.positions(head_dim) * head_dim;
                (0..n).map(|i| u4_code(data, i) as f32 * scale).collect()
            }
        }
    }
}

/// Read access to one sequence x layer of K/V, in head-major runs.
/// The attention kernels are generic over this, so the tiled
/// online-softmax math is literally the same code over the slab oracle
/// and the paged arena, at every storage precision.
pub trait KvSource: Sync {
    /// Number of positions stored.
    fn len(&self) -> usize;
    /// Contiguous K rows for positions `[p0, p1)` of kv head `h`.
    /// For paged sources the range must not straddle a page boundary;
    /// `ATTN_TILE`-aligned tiles always satisfy this because
    /// `KV_PAGE % ATTN_TILE == 0` — which also makes the returned
    /// run's scale uniform over the tile.
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_>;
    /// Contiguous V rows for positions `[p0, p1)` of kv head `h`.
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_>;
}

// ---------------------------------------------------------------------------
// Slab cache (oracle / kernel-test path)
// ---------------------------------------------------------------------------

/// KV tensors of one sequence, one layer, as contiguous
/// `(n_kv_heads, max_seq, head_dim)` f32 slabs for K and V.  This is
/// the eager layout the arena replaced on the serving path; it stays as
/// the exactness oracle the paged views (quantized ones included) are
/// pinned against, and as the simplest harness for kernel tests/benches.
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, n_kv_heads: usize,
               head_dim: usize) -> KvCache {
        KvCache {
            k: vec![0f32; n_kv_heads * max_seq * head_dim],
            v: vec![0f32; n_kv_heads * max_seq * head_dim],
            len: 0,
            n_kv_heads,
            head_dim,
            max_seq,
        }
    }

    /// Row width of one position across all kv heads.
    pub fn width(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Claim `t` fresh positions; returns the first.  Callers write the
    /// claimed rows through the `*_row_mut` accessors — this is what
    /// lets block writers land results in the slab directly.
    pub fn reserve(&mut self, t: usize) -> usize {
        assert!(self.len + t <= self.max_seq, "kv cache overflow");
        let pos = self.len;
        self.len += t;
        pos
    }

    /// Append one position's head-interleaved `(n_kv_heads * head_dim)`
    /// K/V rows (the scalar-oracle path); returns the position index.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        let hd = self.head_dim;
        debug_assert_eq!(k_row.len(), self.width());
        debug_assert_eq!(v_row.len(), self.width());
        let pos = self.reserve(1);
        for h in 0..self.n_kv_heads {
            let base = self.slab_off(h, pos);
            self.k[base..base + hd]
                .copy_from_slice(&k_row[h * hd..(h + 1) * hd]);
            self.v[base..base + hd]
                .copy_from_slice(&v_row[h * hd..(h + 1) * hd]);
        }
        pos
    }

    #[inline]
    fn slab_off(&self, h: usize, pos: usize) -> usize {
        (h * self.max_seq + pos) * self.head_dim
    }

    /// Head `h`'s contiguous `(len, head_dim)` key slab.
    #[inline]
    pub fn k_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.k[lo..lo + self.len * self.head_dim]
    }

    /// Head `h`'s contiguous `(len, head_dim)` value slab.
    #[inline]
    pub fn v_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.v[lo..lo + self.len * self.head_dim]
    }

    #[inline]
    pub fn k_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.v[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn k_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.v[lo..lo + self.head_dim]
    }

    pub fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

impl KvSource for KvCache {
    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_> {
        debug_assert!(p0 < p1 && p1 <= self.len);
        let lo = self.slab_off(h, p0);
        KvRun::F32(&self.k[lo..lo + (p1 - p0) * self.head_dim])
    }

    #[inline]
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_> {
        debug_assert!(p0 < p1 && p1 <= self.len);
        let lo = self.slab_off(h, p0);
        KvRun::F32(&self.v[lo..lo + (p1 - p0) * self.head_dim])
    }
}

// ---------------------------------------------------------------------------
// Quantized page storage
// ---------------------------------------------------------------------------

/// Element-level codec of one quantized pool: how rows quantize into
/// the backing element type and how existing codes re-code when a
/// page's absmax range widens.
trait QuantStore: Copy + Default {
    const QMAX: f32;
    /// Storage elements of one `head_dim` row.
    fn row_elems(head_dim: usize) -> usize;
    /// Quantize one f32 row into `dst` (`row_elems` long) with step
    /// `step` (`step == 0` stores zeros — an all-zero page-head).
    fn store_row(dst: &mut [Self], src: &[f32], step: f32);
    /// Re-code `data` in place from step `old` to step `new >= old`
    /// (pure integer rescale; no float round-trip through the rows).
    fn rescale(data: &mut [Self], old: f32, new: f32);
}

#[inline]
fn qcode(x: f32, step: f32, qmax: f32) -> f32 {
    if step == 0.0 {
        return 0.0;
    }
    (x / step).round().clamp(-qmax, qmax)
}

impl QuantStore for i8 {
    const QMAX: f32 = 127.0;

    fn row_elems(head_dim: usize) -> usize {
        head_dim
    }

    fn store_row(dst: &mut [i8], src: &[f32], step: f32) {
        for (d, &x) in dst.iter_mut().zip(src) {
            *d = qcode(x, step, Self::QMAX) as i8;
        }
    }

    fn rescale(data: &mut [i8], old: f32, new: f32) {
        let r = old / new;
        for d in data.iter_mut() {
            *d = (*d as f32 * r).round()
                .clamp(-Self::QMAX, Self::QMAX) as i8;
        }
    }
}

impl QuantStore for u8 {
    const QMAX: f32 = 7.0;

    fn row_elems(head_dim: usize) -> usize {
        head_dim / 2
    }

    fn store_row(dst: &mut [u8], src: &[f32], step: f32) {
        for (d, pair) in dst.iter_mut().zip(src.chunks_exact(2)) {
            let lo = qcode(pair[0], step, Self::QMAX) as i8 as u8 & 0xF;
            let hi = qcode(pair[1], step, Self::QMAX) as i8 as u8 & 0xF;
            *d = lo | (hi << 4);
        }
    }

    fn rescale(data: &mut [u8], old: f32, new: f32) {
        let r = old / new;
        for d in data.iter_mut() {
            let lo = (((((*d << 4) as i8) >> 4) as f32) * r).round()
                .clamp(-Self::QMAX, Self::QMAX) as i8 as u8 & 0xF;
            let hi = (((((*d & 0xF0) as i8) >> 4) as f32) * r).round()
                .clamp(-Self::QMAX, Self::QMAX) as i8 as u8 & 0xF;
            *d = lo | (hi << 4);
        }
    }
}

/// One precision's page pool: K/V data slabs indexed by page id, the
/// per-page-head scales (empty for f32), refcounts and the free list.
/// Page ids are pool-local; a sequence's tables always resolve in its
/// own precision's pool.
#[derive(Default)]
struct PagePool<T: Copy + Default> {
    /// Page `p`'s per-side data is `[p * page_elems, (p+1) * page_elems)`.
    /// The backing grows lazily with the page high-water mark (the free
    /// list hands freed ids back first), so process RSS tracks peak
    /// *used* pages, not the byte budget.
    k: Vec<T>,
    v: Vec<T>,
    /// `(page, kv_head)` absmax steps per side; empty in the f32 pool.
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<u32>,
}

impl<T: Copy + Default> PagePool<T> {
    /// Pages currently mapped by at least one sequence.
    fn resident(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    /// Claim a page with refcount 1, growing the backing (and the
    /// scale tables when `scale_elems > 0`) if the id is fresh.
    fn alloc(&mut self, page_elems: usize, scale_elems: usize) -> u32 {
        let p = match self.free.pop() {
            Some(p) => p,
            None => {
                self.refcount.push(0);
                (self.refcount.len() - 1) as u32
            }
        };
        debug_assert_eq!(self.refcount[p as usize], 0);
        self.refcount[p as usize] = 1;
        let end = (p as usize + 1) * page_elems;
        if self.k.len() < end {
            self.k.resize(end, T::default());
            self.v.resize(end, T::default());
        }
        let send = (p as usize + 1) * scale_elems;
        if scale_elems > 0 && self.k_scale.len() < send {
            self.k_scale.resize(send, 0.0);
            self.v_scale.resize(send, 0.0);
        }
        p
    }

    /// Decrement a page's refcount; returns true when it became free.
    fn decref(&mut self, page: u32) -> bool {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "decref of a free page");
        *rc -= 1;
        if *rc == 0 {
            // a freed quantized page's scales reset with it: the next
            // owner starts from an empty absmax range
            let n_kv = if self.k_scale.is_empty() {
                0
            } else {
                self.k_scale.len() / self.refcount.len()
            };
            for s in 0..n_kv {
                self.k_scale[page as usize * n_kv + s] = 0.0;
                self.v_scale[page as usize * n_kv + s] = 0.0;
            }
            self.free.push(page);
            return true;
        }
        false
    }

    /// Copy the first `rows` positions of every head from page `src`
    /// to page `dst` (the COW body), scales included — the copy reads
    /// back at exactly the bytes the source wrote.
    fn copy_page_prefix(&mut self, src: u32, dst: u32, rows: usize,
                        n_kv: usize, row_elems: usize) {
        let cap = KV_PAGE * row_elems;
        for head in 0..n_kv {
            let s = src as usize * n_kv * cap + head * cap;
            let d = dst as usize * n_kv * cap + head * cap;
            self.k.copy_within(s..s + rows * row_elems, d);
            self.v.copy_within(s..s + rows * row_elems, d);
        }
        if !self.k_scale.is_empty() {
            let s = src as usize * n_kv;
            let d = dst as usize * n_kv;
            self.k_scale.copy_within(s..s + n_kv, d);
            self.v_scale.copy_within(s..s + n_kv, d);
        }
    }
}

/// The host memory tier: byte-budgeted page pools (one per precision,
/// same geometry as the device pools) that hold cold KV pages swapped
/// out under pressure.  Host pages are always exclusively owned —
/// [`KvArena::swap_out_seq_cold`] only takes refcount-1 pages — so the
/// pools' refcounts are only ever 0 or 1 and the free lists recycle
/// slots the moment a page swaps back in or its sequence dies.  A zero
/// budget (the default) disables the tier entirely.
#[derive(Default)]
struct HostArena {
    pool_f32: PagePool<f32>,
    pool_i8: PagePool<i8>,
    pool_u4: PagePool<u8>,
    budget_bytes: usize,
    used_bytes: usize,
    peak_bytes: usize,
}

/// Copy one full page (both sides + scales) between two pools of the
/// same precision — the swap-out / swap-in body.  A byte-for-byte move
/// of codes and absmax steps, so a swapped-then-restored page reads
/// back bit-identical to one that never left the device.
fn copy_page_across<T: Copy + Default>(src: &PagePool<T>, sp: u32,
                                       dst: &mut PagePool<T>, dp: u32,
                                       page_elems: usize, n_kv: usize) {
    let s0 = sp as usize * page_elems;
    let d0 = dp as usize * page_elems;
    dst.k[d0..d0 + page_elems]
        .copy_from_slice(&src.k[s0..s0 + page_elems]);
    dst.v[d0..d0 + page_elems]
        .copy_from_slice(&src.v[s0..s0 + page_elems]);
    if !src.k_scale.is_empty() {
        let ss = sp as usize * n_kv;
        let ds = dp as usize * n_kv;
        dst.k_scale[ds..ds + n_kv]
            .copy_from_slice(&src.k_scale[ss..ss + n_kv]);
        dst.v_scale[ds..ds + n_kv]
            .copy_from_slice(&src.v_scale[ss..ss + n_kv]);
    }
}

/// Widening hysteresis: when a fresh row outgrows a page-head's step,
/// the new step is at least this multiple of the old one.  Each
/// re-code of a row adds at most half its (then-current) step of
/// error, and with every widening multiplying the step by >= 3/2 the
/// accumulated error forms a geometric series bounded by
/// `0.5 * step_final * sum_k (2/3)^k = 1.5 * step_final` — the bound
/// the round-trip tests pin — no matter how many times single-token
/// decode appends push the running absmax record up.  The cost is a
/// step inflated at most 1.5x past the true absmax, well inside the
/// i8 attention tolerance.
const SCALE_GROW: f32 = 1.5;

/// Quantize `n` source rows (`src[i * stride..][..head_dim]` — the
/// RoPE'd staging scratch for the K side, the strided linear output
/// for V) into one page-head starting at row `off0`, widening the
/// page's absmax step first if the fresh rows exceed it (existing
/// codes re-code in place — the page is exclusively owned, COW ran).
#[allow(clippy::too_many_arguments)]
fn quant_append_side<T: QuantStore>(data: &mut [T], scale: &mut f32,
                                    head_base: usize, off0: usize,
                                    src: &[f32], stride: usize,
                                    n: usize, head_dim: usize) {
    let re = T::row_elems(head_dim);
    let mut amax = 0f32;
    for i in 0..n {
        for &x in &src[i * stride..i * stride + head_dim] {
            amax = amax.max(x.abs());
        }
    }
    let need = amax / T::QMAX;
    let mut step = *scale;
    if need > step {
        let new = if step > 0.0 {
            need.max(SCALE_GROW * step)
        } else {
            need
        };
        if step > 0.0 && off0 > 0 {
            T::rescale(&mut data[head_base..head_base + off0 * re],
                       step, new);
        }
        *scale = new;
        step = new;
    }
    for i in 0..n {
        T::store_row(&mut data[head_base + (off0 + i) * re..][..re],
                     &src[i * stride..i * stride + head_dim], step);
    }
}

// ---------------------------------------------------------------------------
// Paged arena
// ---------------------------------------------------------------------------

/// Opaque handle to one sequence's KV state inside a [`KvArena`].
/// Obtained from [`KvArena::alloc_seq`] / [`KvArena::fork_prefix`];
/// invalid after [`KvArena::free_seq`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvHandle(u32);

impl KvHandle {
    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Error returned when an append needs more bytes than the arena's
/// budget has free.  The scheduler's admission accounting is sized so
/// this never fires mid-flight; hitting it means the caller
/// over-admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfPages {
    pub needed_bytes: usize,
    pub free_bytes: usize,
}

impl std::fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv arena out of memory: need {} bytes but only {} \
                   free", self.needed_bytes, self.free_bytes)
    }
}

impl std::error::Error for OutOfPages {}

/// Which memory tier a page-table entry's bytes live in.  `Device`
/// pages are resident in the arena's budgeted pools and readable by
/// the attention kernels; `Host` pages were swapped out by
/// [`KvArena::swap_out_seq_cold`] into the host arena — their codes
/// and scales are preserved byte-exactly, but they must come back
/// through [`KvArena::swap_in_seq`] before any kernel touches them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageLocation {
    Device,
    Host,
}

/// One page-table entry: a pool-local page id tagged with the pool it
/// lives in.  Until PR 6 a whole sequence shared one precision; online
/// requantization ([`KvArena::requant_seq_tail`]) now converts
/// exclusively owned pages down the ladder in place, so a table can
/// mix precisions — shared prefix pages keep the precision they were
/// written at while the tail migrates to a coarser pool.  Since PR 10
/// an entry also records its tier: `id` indexes the device pool of
/// `prec` when `loc` is `Device`, the host pool of `prec` when `Host`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PageRef {
    id: u32,
    prec: KvPrecision,
    loc: PageLocation,
}

impl PageRef {
    /// A device-resident entry (the common case — every page starts
    /// life on device; only the swap path mints `Host` refs).
    fn device(id: u32, prec: KvPrecision) -> PageRef {
        PageRef { id, prec, loc: PageLocation::Device }
    }
}

/// Page table of one sequence x layer: precision-tagged physical page
/// refs covering positions `[0, len)`.  Invariant: `pages.len() ==
/// ceil(len / KV_PAGE)` between appends (the final page may be
/// partially filled).
#[derive(Debug, Clone, Default)]
pub struct LayerTable {
    pages: Vec<PageRef>,
    len: usize,
}

struct SeqState {
    layers: Vec<LayerTable>,
    /// Precision fresh appends land at.  Pages already in the tables
    /// keep their own tags; requantization moves this down the ladder
    /// so the sequence keeps growing at the degraded precision.
    prec: KvPrecision,
}

/// Process-wide paged KV pool: all sequences' K/V for all layers live
/// in per-precision page pools under one byte budget, with refcounted
/// pages, free lists, lazy allocation and copy-on-write (see module
/// docs).
pub struct KvArena {
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    pool_f32: PagePool<f32>,
    pool_i8: PagePool<i8>,
    pool_u4: PagePool<u8>,
    /// Data-byte budget shared by the three pools; constructed from an
    /// f32-page-equivalent count so existing deployments keep their
    /// numbers, but quantized pages draw proportionally less of it.
    budget_bytes: usize,
    used_bytes: usize,
    peak_bytes: usize,
    peak_pages: usize,
    /// The host swap tier (separate byte budget; disabled at 0 — see
    /// [`Self::set_host_budget_pages`]).
    host: HostArena,
    seqs: Vec<Option<SeqState>>,
    free_seqs: Vec<usize>,
    /// Staging row scratch for quantized appends (rope'd K rows, then
    /// gathered V rows); grow-only, reused across calls.
    rot: Vec<f32>,
    /// Deterministic fault-injection plan (tests only; see [`FailPlan`]).
    #[cfg(feature = "failpoints")]
    fail_plan: Option<FailPlan>,
    /// Append-path page-claim attempts so far (failpoint schedule index).
    #[cfg(feature = "failpoints")]
    alloc_attempts: u64,
    /// Host-tier page-claim attempts so far (swap-out failpoint index).
    #[cfg(feature = "failpoints")]
    host_attempts: u64,
    /// Swap-in page-restore attempts so far (swap-in failpoint index).
    #[cfg(feature = "failpoints")]
    swap_in_attempts: u64,
}

/// Deterministic fault-injection plan (`--features failpoints`): the
/// arena counts append-path page-claim attempts, and any attempt whose
/// 0-based index is in the plan fails with a synthetic [`OutOfPages`]
/// as if the byte budget were exhausted at that instant.  The attempt
/// counter advances on denied attempts too, so a rolled-back append
/// that retries consumes its denial and then proceeds — every finite
/// schedule terminates.  Synthetic faults report the arena's *real*
/// free bytes, so recovery code can tell them from a genuine shortage.
///
/// The host swap tier has two independent denial axes on their own
/// attempt counters: `deny_host` makes a host-page claim behave as if
/// the host budget were exhausted (the swap-out pass stops and reports
/// what it did move — exactly the full-tier behaviour, so `host_all()`
/// proves the re-prefill fallback end to end), and `deny_swap_in`
/// fails a page restore with a synthetic [`OutOfPages`] so the
/// resume-side fallback paths execute under test.
#[cfg(feature = "failpoints")]
#[derive(Debug, Clone, Default)]
pub struct FailPlan {
    deny: std::collections::BTreeSet<u64>,
    deny_host: std::collections::BTreeSet<u64>,
    deny_host_all: bool,
    deny_swap_in: std::collections::BTreeSet<u64>,
}

#[cfg(feature = "failpoints")]
impl FailPlan {
    /// Deny exactly the listed page-claim attempt indices.
    pub fn deny_at(indices: &[u64]) -> FailPlan {
        FailPlan {
            deny: indices.iter().copied().collect(),
            ..FailPlan::default()
        }
    }

    /// Deny `n` attempts spaced `every` apart starting at `start`
    /// (a periodic pressure schedule).
    pub fn deny_every(start: u64, every: u64, n: u64) -> FailPlan {
        assert!(every > 0);
        FailPlan {
            deny: (0..n).map(|i| start + i * every).collect(),
            ..FailPlan::default()
        }
    }

    /// Deny every host-tier page claim — the host arena behaves as
    /// permanently exhausted, forcing the ladder's re-prefill fallback.
    pub fn host_all() -> FailPlan {
        FailPlan { deny_host_all: true, ..FailPlan::default() }
    }

    /// Deny the listed host-tier page-claim attempt indices (the
    /// swap-out pass treats a denial as budget exhaustion and stops).
    pub fn host_at(indices: &[u64]) -> FailPlan {
        FailPlan {
            deny_host: indices.iter().copied().collect(),
            ..FailPlan::default()
        }
    }

    /// Compose this plan with a host-tier deny-all: the device-alloc
    /// schedule keeps firing AND every host-page claim fails, so a
    /// stress run exercises preemption with the swap tier armed but
    /// useless — the re-prefill fallback must carry every resume.
    pub fn and_host_all(mut self) -> FailPlan {
        self.deny_host_all = true;
        self
    }

    /// Deny the listed swap-in page-restore attempt indices (each
    /// fails with a synthetic [`OutOfPages`] reporting real free
    /// bytes, like the append-path denials).
    pub fn swap_in_at(indices: &[u64]) -> FailPlan {
        FailPlan {
            deny_swap_in: indices.iter().copied().collect(),
            ..FailPlan::default()
        }
    }

    fn denies(&self, attempt: u64) -> bool {
        self.deny.contains(&attempt)
    }

    fn denies_host(&self, attempt: u64) -> bool {
        self.deny_host_all || self.deny_host.contains(&attempt)
    }

    fn denies_swap_in(&self, attempt: u64) -> bool {
        self.deny_swap_in.contains(&attempt)
    }
}

impl KvArena {
    /// `capacity_pages` is the budget in **f32-page equivalents**: the
    /// byte budget is `capacity_pages * page_bytes_at(F32)`, of which
    /// an i8 page consumes a quarter and an i4 page an eighth.
    pub fn new(n_layers: usize, max_seq: usize, n_kv_heads: usize,
               head_dim: usize, capacity_pages: usize) -> KvArena {
        let budget_bytes = capacity_pages
            * KvPrecision::F32.page_bytes(n_kv_heads, head_dim);
        KvArena {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            pool_f32: PagePool::default(),
            pool_i8: PagePool::default(),
            pool_u4: PagePool::default(),
            budget_bytes,
            used_bytes: 0,
            peak_bytes: 0,
            peak_pages: 0,
            host: HostArena::default(),
            seqs: Vec::new(),
            free_seqs: Vec::new(),
            rot: Vec::new(),
            #[cfg(feature = "failpoints")]
            fail_plan: None,
            #[cfg(feature = "failpoints")]
            alloc_attempts: 0,
            #[cfg(feature = "failpoints")]
            host_attempts: 0,
            #[cfg(feature = "failpoints")]
            swap_in_attempts: 0,
        }
    }

    /// Install (or clear) a fault-injection plan.  The attempt counter
    /// keeps running across plans so schedules compose within one run.
    #[cfg(feature = "failpoints")]
    pub fn set_fail_plan(&mut self, plan: Option<FailPlan>) {
        self.fail_plan = plan;
    }

    /// Append-path page-claim attempts seen so far (failpoint index
    /// space — lets tests aim a denial at "the Nth claim from now").
    #[cfg(feature = "failpoints")]
    pub fn alloc_attempts(&self) -> u64 {
        self.alloc_attempts
    }

    /// Host-tier page-claim attempts seen so far (swap-out failpoint
    /// index space).
    #[cfg(feature = "failpoints")]
    pub fn host_attempts(&self) -> u64 {
        self.host_attempts
    }

    /// Swap-in page-restore attempts seen so far (swap-in failpoint
    /// index space).
    #[cfg(feature = "failpoints")]
    pub fn swap_in_attempts(&self) -> u64 {
        self.swap_in_attempts
    }

    /// Pages needed to hold `positions` KV rows of one layer.
    pub fn pages_for(positions: usize) -> usize {
        (positions + KV_PAGE - 1) / KV_PAGE
    }

    /// Worst-case pages a sequence reaching `positions` total context
    /// needs across all layers (what eager slab allocation always paid
    /// at `positions = max_seq_len`).
    pub fn seq_worst_pages(&self, positions: usize) -> usize {
        self.n_layers * Self::pages_for(positions.min(self.max_seq))
    }

    /// Worst-case budget bytes the same sequence needs at a given
    /// storage precision — what admission reserves.
    pub fn seq_worst_bytes(&self, positions: usize,
                           prec: KvPrecision) -> usize {
        self.seq_worst_pages(positions) * self.page_bytes_at(prec)
    }

    /// Budget capacity in f32-page equivalents.
    pub fn capacity_pages(&self) -> usize {
        self.budget_bytes / self.page_bytes()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Pages currently mapped by at least one sequence (count across
    /// all precisions — pages of different precisions are different
    /// sizes; byte-accurate numbers come from [`Self::resident_bytes`]).
    pub fn resident_pages(&self) -> usize {
        self.pool_f32.resident() + self.pool_i8.resident()
            + self.pool_u4.resident()
    }

    /// Resident pages of one precision's pool.
    pub fn resident_pages_at(&self, prec: KvPrecision) -> usize {
        match prec {
            KvPrecision::F32 => self.pool_f32.resident(),
            KvPrecision::Int8 => self.pool_i8.resident(),
            KvPrecision::Int4 => self.pool_u4.resident(),
        }
    }

    pub fn peak_resident_pages(&self) -> usize {
        self.peak_pages
    }

    pub fn free_bytes(&self) -> usize {
        self.budget_bytes - self.used_bytes
    }

    /// Bytes of one f32 page (K + V sides) — the budget's unit.
    pub fn page_bytes(&self) -> usize {
        self.page_bytes_at(KvPrecision::F32)
    }

    /// Bytes of one page at a given storage precision.
    pub fn page_bytes_at(&self, prec: KvPrecision) -> usize {
        prec.page_bytes(self.n_kv_heads, self.head_dim)
    }

    pub fn resident_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Budget bytes the resident quantized pages would additionally
    /// consume if they were stored at f32 — the headline KV savings.
    pub fn bytes_saved_vs_f32(&self) -> usize {
        let pb = self.page_bytes();
        self.pool_i8.resident()
            * (pb - self.page_bytes_at(KvPrecision::Int8))
            + self.pool_u4.resident()
                * (pb - self.page_bytes_at(KvPrecision::Int4))
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    // -- host swap tier (PR 10) ---------------------------------------

    /// Size the host swap tier in **f32-page equivalents** — the same
    /// unit as the device budget, so a quantized page draws
    /// proportionally less of it.  0 (the default) disables swapping:
    /// [`Self::swap_out_seq_cold`] becomes a no-op and the pressure
    /// ladder falls straight through to preemption + re-prefill.
    pub fn set_host_budget_pages(&mut self, pages: usize) {
        self.host.budget_bytes = pages * self.page_bytes();
    }

    /// The host tier's byte budget (0 = tier disabled).
    pub fn host_capacity_bytes(&self) -> usize {
        self.host.budget_bytes
    }

    /// Bytes of swapped-out pages currently parked in the host tier.
    pub fn host_resident_bytes(&self) -> usize {
        self.host.used_bytes
    }

    /// High-water mark of [`Self::host_resident_bytes`].
    pub fn host_peak_bytes(&self) -> usize {
        self.host.peak_bytes
    }

    /// Host-tier bytes still free for swap-outs.
    pub fn host_free_bytes(&self) -> usize {
        self.host.budget_bytes - self.host.used_bytes
    }

    /// Pages currently parked in the host tier (count across all
    /// precision pools, like [`Self::resident_pages`]).
    pub fn host_resident_pages(&self) -> usize {
        self.host.pool_f32.resident() + self.host.pool_i8.resident()
            + self.host.pool_u4.resident()
    }

    /// Park a sequence state in a (possibly recycled) handle slot.
    fn insert_seq(&mut self, state: SeqState) -> KvHandle {
        let idx = match self.free_seqs.pop() {
            Some(i) => {
                self.seqs[i] = Some(state);
                i
            }
            None => {
                self.seqs.push(Some(state));
                self.seqs.len() - 1
            }
        };
        KvHandle(idx as u32)
    }

    /// Allocate an empty f32 sequence (no pages yet — pages are
    /// claimed lazily as positions are appended).
    pub fn alloc_seq(&mut self) -> KvHandle {
        self.alloc_seq_at(KvPrecision::F32)
    }

    /// Allocate an empty sequence whose pages will live in `prec`'s
    /// pool.  All layers share the precision; forks inherit it.
    pub fn alloc_seq_at(&mut self, prec: KvPrecision) -> KvHandle {
        assert!(prec != KvPrecision::Int4 || self.head_dim % 2 == 0,
                "int4 KV needs an even head_dim");
        let state = SeqState {
            layers: vec![LayerTable::default(); self.n_layers],
            prec,
        };
        self.insert_seq(state)
    }

    /// Precision a sequence's fresh appends land at.  Individual pages
    /// already in its tables may sit higher up the ladder (e.g. an f32
    /// shared prefix after the tail was requantized) — see
    /// [`KvLayerView::page_precision`] for per-page tags.
    pub fn seq_precision(&self, h: KvHandle) -> KvPrecision {
        self.seqs[h.idx()].as_ref().expect("stale handle").prec
    }

    /// Fork a new sequence sharing `src`'s first `len` positions: page
    /// tables are cloned up to `ceil(len / KV_PAGE)` entries with every
    /// shared page's refcount bumped — no K/V bytes are copied, and the
    /// fork reads the pages at the precision they were written (it
    /// inherits `src`'s).  A partially filled shared tail page is
    /// copied lazily on the fork's (or the source's) first append into
    /// it (COW).  `len` must not exceed `src`'s current length on any
    /// layer.
    pub fn fork_prefix(&mut self, src: KvHandle, len: usize) -> KvHandle {
        let n_pages = Self::pages_for(len);
        let mut layers = Vec::with_capacity(self.n_layers);
        let prec = {
            let s = self.seqs[src.idx()].as_ref().expect("stale handle");
            for t in &s.layers {
                assert!(t.len >= len, "fork_prefix past source length");
                layers.push(LayerTable {
                    pages: t.pages[..n_pages].to_vec(),
                    len,
                });
            }
            s.prec
        };
        for t in &layers {
            for &p in &t.pages {
                // the scheduler never registers a swapped sequence as a
                // prefix-cache source, and host pages are refcount-1 by
                // construction — sharing one would break both tiers'
                // accounting
                assert_eq!(p.loc, PageLocation::Device,
                           "fork_prefix across a swapped-out page");
                self.refcount_mut(p.prec)[p.id as usize] += 1;
            }
        }
        self.insert_seq(SeqState { layers, prec })
    }

    /// Fork sharing the source's whole current length.
    pub fn fork_seq(&mut self, src: KvHandle) -> KvHandle {
        let len = self.seq_len(src);
        self.fork_prefix(src, len)
    }

    fn refcount_mut(&mut self, prec: KvPrecision) -> &mut Vec<u32> {
        match prec {
            KvPrecision::F32 => &mut self.pool_f32.refcount,
            KvPrecision::Int8 => &mut self.pool_i8.refcount,
            KvPrecision::Int4 => &mut self.pool_u4.refcount,
        }
    }

    /// Current owner count of one table entry's physical page
    /// (device-tier entries only — host pages are always refcount 1).
    fn refcount_of(&self, p: PageRef) -> u32 {
        debug_assert_eq!(p.loc, PageLocation::Device,
                         "refcount_of on a host-tier page");
        match p.prec {
            KvPrecision::F32 => self.pool_f32.refcount[p.id as usize],
            KvPrecision::Int8 => self.pool_i8.refcount[p.id as usize],
            KvPrecision::Int4 => self.pool_u4.refcount[p.id as usize],
        }
    }

    /// Decref one page of `prec`'s pool, returning its bytes to the
    /// budget when the last owner dropped it.
    fn decref_at(&mut self, prec: KvPrecision, page: u32) {
        let freed = match prec {
            KvPrecision::F32 => self.pool_f32.decref(page),
            KvPrecision::Int8 => self.pool_i8.decref(page),
            KvPrecision::Int4 => self.pool_u4.decref(page),
        };
        if freed {
            self.used_bytes -= self.page_bytes_at(prec);
        }
    }

    /// Drop one table entry's page whichever tier it lives in:
    /// device pages decref (and may free), host pages always free.
    fn release_page(&mut self, p: PageRef) {
        match p.loc {
            PageLocation::Device => self.decref_at(p.prec, p.id),
            PageLocation::Host => self.host_release(p.prec, p.id),
        }
    }

    /// Claim one host-tier page of `prec`'s pool (caller has already
    /// checked the host budget) and charge the host accountant.
    fn host_alloc(&mut self, prec: KvPrecision) -> u32 {
        let pb = self.page_bytes_at(prec);
        debug_assert!(self.host.used_bytes + pb
                          <= self.host.budget_bytes,
                      "host_alloc past budget check");
        let (page_elems, scale_elems) = self.pool_geom(prec);
        let p = match prec {
            KvPrecision::F32 => self.host.pool_f32.alloc(page_elems, 0),
            KvPrecision::Int8 => {
                self.host.pool_i8.alloc(page_elems, scale_elems)
            }
            KvPrecision::Int4 => {
                self.host.pool_u4.alloc(page_elems, scale_elems)
            }
        };
        self.host.used_bytes += pb;
        self.host.peak_bytes =
            self.host.peak_bytes.max(self.host.used_bytes);
        p
    }

    /// Return one host-tier page's bytes to the host budget.
    fn host_release(&mut self, prec: KvPrecision, page: u32) {
        let freed = match prec {
            KvPrecision::F32 => self.host.pool_f32.decref(page),
            KvPrecision::Int8 => self.host.pool_i8.decref(page),
            KvPrecision::Int4 => self.host.pool_u4.decref(page),
        };
        debug_assert!(freed, "host pages are exclusively owned");
        self.host.used_bytes -= self.page_bytes_at(prec);
    }

    /// Claim one page of `prec`'s pool (caller has already checked the
    /// byte budget) and charge its bytes.
    fn alloc_page_at(&mut self, prec: KvPrecision) -> u32 {
        let pb = self.page_bytes_at(prec);
        debug_assert!(self.used_bytes + pb <= self.budget_bytes,
                      "alloc_page past budget check");
        let (page_elems, scale_elems) = self.pool_geom(prec);
        let p = match prec {
            KvPrecision::F32 => self.pool_f32.alloc(page_elems, 0),
            KvPrecision::Int8 => {
                self.pool_i8.alloc(page_elems, scale_elems)
            }
            KvPrecision::Int4 => {
                self.pool_u4.alloc(page_elems, scale_elems)
            }
        };
        self.used_bytes += pb;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.peak_pages = self.peak_pages.max(self.resident_pages());
        p
    }

    /// (per-side storage elements, per-side scale entries) of one page
    /// in `prec`'s pool.
    fn pool_geom(&self, prec: KvPrecision) -> (usize, usize) {
        let re = match prec {
            KvPrecision::F32 => self.head_dim,
            KvPrecision::Int8 => self.head_dim,
            KvPrecision::Int4 => self.head_dim / 2,
        };
        (self.n_kv_heads * KV_PAGE * re, self.n_kv_heads)
    }

    /// Drop all of a sequence's pages (refcounts decremented, pages
    /// with no remaining owner return to the free list and the budget)
    /// and recycle the handle slot.  The handle must not be used
    /// afterwards.
    pub fn free_seq(&mut self, h: KvHandle) {
        let state = self.seqs[h.idx()].take().expect("double free_seq");
        for t in &state.layers {
            for &p in &t.pages {
                self.release_page(p);
            }
        }
        self.free_seqs.push(h.idx());
    }

    /// Drop a sequence's pages but keep the handle alive at length 0
    /// (the window-reset idiom of the PPL evaluator and probes).
    pub fn reset_seq(&mut self, h: KvHandle) {
        let mut tables = Vec::new();
        {
            let s = self.seqs[h.idx()].as_mut().expect("stale handle");
            for t in &mut s.layers {
                tables.push(std::mem::take(&mut t.pages));
                t.len = 0;
            }
        }
        for pages in tables {
            for p in pages {
                self.release_page(p);
            }
        }
    }

    /// Sequence length (layer 0; all layers agree between forward
    /// calls — they only diverge transiently inside a layer loop).
    pub fn seq_len(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers[0].len
    }

    /// Length of one layer's table (differs from [`Self::seq_len`]
    /// only mid-tick, while a layer loop appends layer by layer).
    pub fn layer_len(&self, h: KvHandle, layer: usize) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers[layer].len
    }

    /// Total pages mapped by this sequence across all layers (shared
    /// pages count once per mapping — this is the table size, not
    /// exclusive ownership).
    pub fn seq_pages(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers.iter().map(|t| t.pages.len()).sum()
    }

    /// **Device**-budget bytes this sequence's mapped pages occupy,
    /// each page at its own storage precision (shared pages count once
    /// per mapping, like [`Self::seq_pages`]).  Host-tier pages are
    /// excluded — their bytes left the device budget at swap-out, and
    /// this number feeds the scheduler's device reservation math.
    pub fn seq_bytes(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers.iter()
            .flat_map(|t| t.pages.iter())
            .filter(|p| p.loc == PageLocation::Device)
            .map(|p| self.page_bytes_at(p.prec))
            .sum()
    }

    /// Read view of one sequence x layer for the attention kernels.
    /// The view carries all three pools so a mixed-precision table
    /// (f32 shared prefix + requantized tail) resolves every run at
    /// the precision its own page stores.
    pub fn layer(&self, h: KvHandle, layer: usize) -> KvLayerView<'_> {
        let s = self.seqs[h.idx()].as_ref().expect("stale handle");
        let t = &s.layers[layer];
        KvLayerView {
            pages: &t.pages,
            len: t.len,
            head_dim: self.head_dim,
            n_kv_heads: self.n_kv_heads,
            append_prec: s.prec,
            pools: PoolViews {
                f32k: &self.pool_f32.k,
                f32v: &self.pool_f32.v,
                i8k: &self.pool_i8.k,
                i8v: &self.pool_i8.v,
                i8ks: &self.pool_i8.k_scale,
                i8vs: &self.pool_i8.v_scale,
                u4k: &self.pool_u4.k,
                u4v: &self.pool_u4.v,
                u4ks: &self.pool_u4.k_scale,
                u4vs: &self.pool_u4.v_scale,
            },
        }
    }

    /// Append a `(t, n_kv_heads * head_dim)` row-major K/V block to one
    /// sequence x layer, applying RoPE to the K rows from the cached
    /// tables while scattering into the head-major page layout — the
    /// paged equivalent of `attention::append_kv_block`, with identical
    /// per-row rotation math (an f32 sequence stores floats
    /// bit-identical to the slab's; a quantized sequence quantizes the
    /// same rotated rows at scatter time, so the page codes are the
    /// nearest representable values of exactly what the slab holds).
    /// Claims fresh pages as position `len` crosses page boundaries
    /// and copies a shared partial tail page before the first write
    /// into it (COW).  Returns the first appended position; the caller
    /// must have `rope.ensure(pos0 + t)`d.
    pub fn append_kv_block(&mut self, h: KvHandle, layer: usize,
                           rope: &RopeCache, k_block: &[f32],
                           v_block: &[f32], t: usize)
                           -> Result<usize, OutOfPages> {
        let hd = self.head_dim;
        let w = self.n_kv_heads * hd;
        debug_assert!(k_block.len() >= t * w && v_block.len() >= t * w);
        let pos0 = self.layer_len(h, layer);
        assert!(pos0 + t <= self.max_seq, "kv arena sequence overflow");
        if t == 0 {
            return Ok(pos0);
        }
        self.ensure_tail_pages(h, layer, pos0, t)?;

        // Touched page ids, copied out so the table borrow does not
        // pin `self` while we write the page slabs.  ensure_tail_pages
        // just put every touched page at the append precision (COW
        // converts a mismatched partial tail), so raw ids suffice.
        let first = pos0 / KV_PAGE;
        let n_touched = Self::pages_for(pos0 + t) - first;
        let (pages, prec): (Vec<u32>, KvPrecision) = {
            let s = self.seqs[h.idx()].as_ref().expect("stale handle");
            let prec = s.prec;
            let ids = s.layers[layer].pages[first..first + n_touched]
                .iter()
                .map(|p| {
                    debug_assert_eq!(p.prec, prec,
                                     "append into a foreign-precision \
                                      page (tail COW missed)");
                    p.id
                })
                .collect();
            (ids, prec)
        };
        match prec {
            KvPrecision::F32 => {
                self.append_f32(&pages, first, pos0, t, rope, k_block,
                                v_block);
            }
            KvPrecision::Int8 => {
                let KvArena { pool_i8, rot, n_kv_heads, head_dim, .. } =
                    self;
                append_quant(pool_i8, *n_kv_heads, *head_dim, rot,
                             &pages, first, pos0, t, rope, k_block,
                             v_block);
            }
            KvPrecision::Int4 => {
                let KvArena { pool_u4, rot, n_kv_heads, head_dim, .. } =
                    self;
                append_quant(pool_u4, *n_kv_heads, *head_dim, rot,
                             &pages, first, pos0, t, rope, k_block,
                             v_block);
            }
        }
        self.seqs[h.idx()].as_mut().expect("stale handle")
            .layers[layer].len = pos0 + t;
        Ok(pos0)
    }

    /// The exact f32 scatter (unchanged from the pre-quantization
    /// arena: fused RoPE rotate + head-major page write, bit-identical
    /// to the slab writer).
    fn append_f32(&mut self, pages: &[u32], first: usize, pos0: usize,
                  t: usize, rope: &RopeCache, k_block: &[f32],
                  v_block: &[f32]) {
        let hd = self.head_dim;
        let half = hd / 2;
        let w = self.n_kv_heads * hd;
        let page_elems = self.n_kv_heads * KV_PAGE * hd;
        for i in 0..t {
            let pos = pos0 + i;
            let page = pages[pos / KV_PAGE - first] as usize;
            let off = pos % KV_PAGE;
            debug_assert_eq!(self.pool_f32.refcount[page], 1,
                             "append into a shared page (COW missed)");
            let (cos, sin) = rope.row(pos);
            for head in 0..self.n_kv_heads {
                let base = page * page_elems
                    + (head * KV_PAGE + off) * hd;
                let src = &k_block[i * w + head * hd..][..hd];
                let dst = &mut self.pool_f32.k[base..base + hd];
                for j in 0..half {
                    let (a, b) = (src[2 * j], src[2 * j + 1]);
                    dst[2 * j] = a * cos[j] - b * sin[j];
                    dst[2 * j + 1] = a * sin[j] + b * cos[j];
                }
                let vsrc = &v_block[i * w + head * hd..][..hd];
                self.pool_f32.v[base..base + hd].copy_from_slice(vsrc);
            }
        }
    }

    /// Make positions `[pos0, pos0 + t)` writable at the sequence's
    /// append precision: COW a partial tail page that is shared *or*
    /// sits at a different precision (a requantized sequence growing
    /// past an f32 prefix converts the straddled page down), then
    /// claim fresh pages to cover the range.  Byte availability is
    /// checked up front so a failure leaves the table untouched (no
    /// half-grown state).
    fn ensure_tail_pages(&mut self, h: KvHandle, layer: usize,
                         pos0: usize, t: usize) -> Result<(), OutOfPages> {
        let need_pages = Self::pages_for(pos0 + t);
        let (have, tail_page, prec) = {
            let s = self.seqs[h.idx()].as_ref().expect("stale handle");
            let tbl = &s.layers[layer];
            debug_assert_eq!(tbl.pages.len(), Self::pages_for(pos0));
            let tail = if pos0 % KV_PAGE != 0 {
                Some(tbl.pages[pos0 / KV_PAGE])
            } else {
                None
            };
            (tbl.pages.len(), tail, s.prec)
        };
        let shared = tail_page.is_some_and(|p| self.refcount_of(p) > 1);
        let convert = tail_page.is_some_and(|p| p.prec != prec);
        let cow = shared || convert;
        let fresh_needed = (need_pages - have) + cow as usize;
        let need_bytes = fresh_needed * self.page_bytes_at(prec);
        #[cfg(feature = "failpoints")]
        if fresh_needed > 0 {
            let attempt = self.alloc_attempts;
            self.alloc_attempts += 1;
            if self.fail_plan.as_ref().is_some_and(|p| p.denies(attempt))
            {
                return Err(OutOfPages {
                    needed_bytes: need_bytes,
                    free_bytes: self.free_bytes(),
                });
            }
        }
        if self.free_bytes() < need_bytes {
            return Err(OutOfPages {
                needed_bytes: need_bytes,
                free_bytes: self.free_bytes(),
            });
        }
        if cow {
            let old = tail_page.unwrap();
            let fresh = PageRef::device(self.alloc_page_at(prec), prec);
            let rows = pos0 % KV_PAGE;
            let n_kv = self.n_kv_heads;
            if convert {
                self.convert_page(old, fresh, rows);
            } else {
                match prec {
                    KvPrecision::F32 => self.pool_f32
                        .copy_page_prefix(old.id, fresh.id, rows, n_kv,
                                          self.head_dim),
                    KvPrecision::Int8 => self.pool_i8
                        .copy_page_prefix(old.id, fresh.id, rows, n_kv,
                                          self.head_dim),
                    KvPrecision::Int4 => self.pool_u4
                        .copy_page_prefix(old.id, fresh.id, rows, n_kv,
                                          self.head_dim / 2),
                }
            }
            // shared: the other owners keep the old page's bytes;
            // exclusively-owned (precision-convert case): the old page
            // frees and its bytes return to the budget
            self.decref_at(old.prec, old.id);
            self.seqs[h.idx()].as_mut().expect("stale handle")
                .layers[layer].pages[pos0 / KV_PAGE] = fresh;
        }
        for _ in have..need_pages {
            let p = PageRef::device(self.alloc_page_at(prec), prec);
            self.seqs[h.idx()].as_mut().expect("stale handle")
                .layers[layer].pages.push(p);
        }
        Ok(())
    }

    /// Online-requantize a resident sequence down the ladder: every
    /// exclusively-owned page above `target`'s rank converts in place
    /// (allocate a page in the target pool, dequantize the valid rows,
    /// re-quantize with a fresh per-(head, side) absmax step, free the
    /// old page), and future appends land at `target`.  Shared pages —
    /// a prefix-cache entry or fork still reads them — are skipped:
    /// their other owners expect the bytes they wrote.  Never fails:
    /// if the transient double-hold (new page allocated before the old
    /// one frees) doesn't fit the budget, the pass stops early and
    /// reports what it did convert.
    ///
    /// The conversion is one extra quantization of already-stored
    /// rows, so the requantized tail obeys the same absmax-step error
    /// bound as pages written at `target` directly, plus the source
    /// precision's (smaller) step — within the i8 ≤ 1e-2 / u4 ≤ 0.3
    /// attention tolerances the oracle tests pin.
    pub fn requant_seq_tail(&mut self, h: KvHandle,
                            target: KvPrecision) -> RequantSummary {
        assert!(target != KvPrecision::Int4 || self.head_dim % 2 == 0,
                "int4 KV needs an even head_dim");
        let mut out = RequantSummary::default();
        {
            let s = self.seqs[h.idx()].as_mut().expect("stale handle");
            if target.rank() > s.prec.rank() {
                s.prec = target;
            }
        }
        for layer in 0..self.n_layers {
            let (len, pages) = {
                let s = self.seqs[h.idx()].as_ref().unwrap();
                let t = &s.layers[layer];
                (t.len, t.pages.clone())
            };
            for (pidx, &pref) in pages.iter().enumerate() {
                // host-tier pages are skipped like shared ones: their
                // bytes are already off the device budget, and they
                // convert (if still worth it) after they swap back in
                if pref.loc == PageLocation::Host
                    || pref.prec.rank() >= target.rank()
                    || self.refcount_of(pref) != 1
                {
                    continue;
                }
                if self.free_bytes() < self.page_bytes_at(target) {
                    return out;
                }
                let rows = (len - pidx * KV_PAGE).min(KV_PAGE);
                let dst = PageRef::device(self.alloc_page_at(target),
                                          target);
                self.convert_page(pref, dst, rows);
                self.decref_at(pref.prec, pref.id);
                self.seqs[h.idx()].as_mut().unwrap()
                    .layers[layer].pages[pidx] = dst;
                out.pages += 1;
                out.bytes_freed += self.page_bytes_at(pref.prec)
                    - self.page_bytes_at(target);
            }
        }
        out
    }

    /// Swap a sequence's exclusively-owned **cold** pages out to the
    /// host tier: every full page strictly before the page holding the
    /// last position (the tail page — hot, partially filled, and the
    /// append frontier — never moves) copies its codes + absmax scales
    /// into a host-pool page byte-for-byte and releases its device
    /// bytes back to the budget.  Shared pages (a prefix-cache entry
    /// or fork still reads them) are skipped, like
    /// [`Self::requant_seq_tail`] skips them: evicting a page other
    /// owners resolve would corrupt their reads.  Never fails: when
    /// the host budget runs out (or, under `failpoints`, a host-tier
    /// claim is denied — same semantics) the pass stops early and
    /// reports what it did move.  The sequence must not be dispatched
    /// to the kernels again until [`Self::swap_in_seq`] restores it —
    /// [`KvLayerView`] panics on a host-tier run.
    pub fn swap_out_seq_cold(&mut self, h: KvHandle) -> SwapSummary {
        let mut out = SwapSummary::default();
        if self.host.budget_bytes == 0 {
            return out;
        }
        for layer in 0..self.n_layers {
            let (len, pages) = {
                let s = self.seqs[h.idx()].as_ref()
                    .expect("stale handle");
                let t = &s.layers[layer];
                (t.len, t.pages.clone())
            };
            if len == 0 {
                continue;
            }
            let tail_idx = (len - 1) / KV_PAGE;
            for (pidx, &pref) in pages.iter().enumerate()
                .take(tail_idx)
            {
                if pref.loc == PageLocation::Host
                    || self.refcount_of(pref) != 1
                {
                    continue;
                }
                let pb = self.page_bytes_at(pref.prec);
                #[cfg(feature = "failpoints")]
                {
                    let attempt = self.host_attempts;
                    self.host_attempts += 1;
                    if self.fail_plan.as_ref()
                        .is_some_and(|p| p.denies_host(attempt))
                    {
                        return out;
                    }
                }
                if self.host_free_bytes() < pb {
                    return out;
                }
                let dst = self.host_alloc(pref.prec);
                self.copy_swap_page(pref.prec, pref.id, dst, true);
                self.decref_at(pref.prec, pref.id);
                self.seqs[h.idx()].as_mut().unwrap()
                    .layers[layer].pages[pidx] = PageRef {
                        id: dst,
                        prec: pref.prec,
                        loc: PageLocation::Host,
                    };
                out.pages += 1;
                out.bytes += pb;
            }
        }
        out
    }

    /// Restore every host-tier page of a sequence back into the
    /// device pools (byte-exact — reads after the round trip are
    /// bit-identical to a sequence that never swapped).  Fails with
    /// [`OutOfPages`] when the device budget cannot hold the next
    /// page (or a `failpoints` swap-in denial fires); pages already
    /// restored stay restored, so the caller may retry after freeing
    /// device bytes, or give up and [`Self::free_seq`] — both leave
    /// consistent accounting.
    pub fn swap_in_seq(&mut self, h: KvHandle)
                       -> Result<SwapSummary, OutOfPages> {
        let mut out = SwapSummary::default();
        for layer in 0..self.n_layers {
            let pages = self.seqs[h.idx()].as_ref()
                .expect("stale handle").layers[layer].pages.clone();
            for (pidx, &pref) in pages.iter().enumerate() {
                if pref.loc != PageLocation::Host {
                    continue;
                }
                let pb = self.page_bytes_at(pref.prec);
                #[cfg(feature = "failpoints")]
                {
                    let attempt = self.swap_in_attempts;
                    self.swap_in_attempts += 1;
                    if self.fail_plan.as_ref()
                        .is_some_and(|p| p.denies_swap_in(attempt))
                    {
                        return Err(OutOfPages {
                            needed_bytes: pb,
                            free_bytes: self.free_bytes(),
                        });
                    }
                }
                if self.free_bytes() < pb {
                    return Err(OutOfPages {
                        needed_bytes: pb,
                        free_bytes: self.free_bytes(),
                    });
                }
                let dev = self.alloc_page_at(pref.prec);
                self.copy_swap_page(pref.prec, dev, pref.id, false);
                self.host_release(pref.prec, pref.id);
                self.seqs[h.idx()].as_mut().unwrap()
                    .layers[layer].pages[pidx] =
                    PageRef::device(dev, pref.prec);
                out.pages += 1;
                out.bytes += pb;
            }
        }
        Ok(out)
    }

    /// Pages of this sequence currently parked in the host tier (all
    /// layers).  Non-zero means the sequence must not reach the
    /// attention kernels.
    pub fn seq_swapped_pages(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers.iter()
            .flat_map(|t| t.pages.iter())
            .filter(|p| p.loc == PageLocation::Host)
            .count()
    }

    /// Bytes of this sequence's host-tier pages (all layers, each
    /// page at its own precision) — what [`Self::swap_in_seq`] would
    /// need from the device budget to restore it.
    pub fn seq_host_bytes(&self, h: KvHandle) -> usize {
        self.seqs[h.idx()].as_ref().expect("stale handle")
            .layers.iter()
            .flat_map(|t| t.pages.iter())
            .filter(|p| p.loc == PageLocation::Host)
            .map(|p| self.page_bytes_at(p.prec))
            .sum()
    }

    /// Tokens covered by the sequence's *contiguous* host-resident
    /// prefix: the minimum over layers of leading host-tagged pages
    /// (a budget/failpoint stop mid-pass can leave layers uneven, and
    /// a shared cold page that could not move truncates the run).
    /// This is the length the scheduler may truncate a preempted
    /// sequence to when parking its KV in the host tier — everything
    /// past it must be re-prefilled on resume anyway.
    pub fn seq_host_prefix_len(&self, h: KvHandle) -> usize {
        let s = self.seqs[h.idx()].as_ref().expect("stale handle");
        let pages = s.layers.iter()
            .map(|t| {
                t.pages.iter()
                    .take_while(|p| p.loc == PageLocation::Host)
                    .count()
            })
            .min()
            .unwrap_or(0);
        pages * KV_PAGE
    }

    /// Full-page copy between the device and host pools of one
    /// precision: `dev` / `host` are pool-local ids on their own
    /// tiers; `out` selects the direction (device→host on swap-out).
    fn copy_swap_page(&mut self, prec: KvPrecision, dev: u32,
                      host: u32, out: bool) {
        let (page_elems, _) = self.pool_geom(prec);
        let n_kv = self.n_kv_heads;
        macro_rules! xfer {
            ($pool:ident) => {{
                let KvArena { $pool, host: h, .. } = self;
                if out {
                    copy_page_across(&*$pool, dev, &mut h.$pool, host,
                                     page_elems, n_kv);
                } else {
                    copy_page_across(&h.$pool, host, $pool, dev,
                                     page_elems, n_kv);
                }
            }};
        }
        match prec {
            KvPrecision::F32 => xfer!(pool_f32),
            KvPrecision::Int8 => xfer!(pool_i8),
            KvPrecision::Int4 => xfer!(pool_u4),
        }
    }

    /// Roll a sequence back to `len` positions on every layer,
    /// dropping (and decref'ing) pages past the new end.  This is the
    /// scheduler's OutOfPages recovery primitive: a mid-operation
    /// failure leaves layers at different lengths (appends land layer
    /// by layer), so each table truncates independently back to the
    /// pre-operation snapshot.  Rows already written into a kept
    /// partial page are simply abandoned — scales only ever widen, so
    /// stale rows past `len` are never read and never corrupt later
    /// appends.
    pub fn truncate_seq(&mut self, h: KvHandle, len: usize) {
        let keep = Self::pages_for(len);
        for layer in 0..self.n_layers {
            let mut dropped = Vec::new();
            {
                let s = self.seqs[h.idx()].as_mut()
                    .expect("stale handle");
                let t = &mut s.layers[layer];
                debug_assert!(t.len >= len,
                              "truncate_seq cannot grow a layer");
                while t.pages.len() > keep {
                    dropped.push(t.pages.pop().unwrap());
                }
                t.len = len;
            }
            for p in dropped {
                self.release_page(p);
            }
        }
    }

    /// Byte-exact snapshot of a sequence's append frontier: the current
    /// length plus, for every layer whose tail page is partially filled
    /// *and quantized*, the raw codes and absmax scales of that page's
    /// written rows.  [`Self::truncate_seq`] alone is not an exact undo
    /// on quantized pools — rows appended past the snapshot can widen
    /// the partial tail page's scale, lossily re-coding the kept rows —
    /// so speculative decoding pairs every draft burst with a
    /// checkpoint and restores through [`Self::rollback_seq`], after
    /// which re-appending the same rows reproduces the straight-line
    /// bytes and scales exactly.  f32 tails need no snapshot: appends
    /// never disturb rows before their own position.
    pub fn checkpoint_seq(&self, h: KvHandle) -> SeqCheckpoint {
        let s = self.seqs[h.idx()].as_ref().expect("stale handle");
        let len = s.layers[0].len;
        let rows = len % KV_PAGE;
        let mut tails = Vec::new();
        if rows > 0 {
            for (layer, t) in s.layers.iter().enumerate() {
                debug_assert_eq!(t.len, len,
                                 "checkpoint inside a layer loop");
                let pref = t.pages[len / KV_PAGE];
                debug_assert_eq!(pref.loc, PageLocation::Device,
                                 "partial tail pages never swap out");
                let n_kv = self.n_kv_heads;
                let sidx = pref.id as usize * n_kv;
                let (k, v, ks, vs) = match pref.prec {
                    KvPrecision::F32 => continue,
                    KvPrecision::Int8 => {
                        let re = self.head_dim;
                        (TailCodes::I8(read_tail_codes(
                             &self.pool_i8.k, pref.id, n_kv, re, rows)),
                         TailCodes::I8(read_tail_codes(
                             &self.pool_i8.v, pref.id, n_kv, re, rows)),
                         self.pool_i8.k_scale[sidx..sidx + n_kv]
                             .to_vec(),
                         self.pool_i8.v_scale[sidx..sidx + n_kv]
                             .to_vec())
                    }
                    KvPrecision::Int4 => {
                        let re = self.head_dim / 2;
                        (TailCodes::U4(read_tail_codes(
                             &self.pool_u4.k, pref.id, n_kv, re, rows)),
                         TailCodes::U4(read_tail_codes(
                             &self.pool_u4.v, pref.id, n_kv, re, rows)),
                         self.pool_u4.k_scale[sidx..sidx + n_kv]
                             .to_vec(),
                         self.pool_u4.v_scale[sidx..sidx + n_kv]
                             .to_vec())
                    }
                };
                tails.push(TailSnapshot {
                    layer,
                    prec: pref.prec,
                    rows,
                    k,
                    v,
                    k_scale: ks,
                    v_scale: vs,
                });
            }
        }
        SeqCheckpoint { len, tails }
    }

    /// Restore a sequence to a [`Self::checkpoint_seq`] snapshot:
    /// truncate every layer back to the checkpoint length, then write
    /// the saved tail-page codes and scales back over whatever the
    /// abandoned appends left there.  Works across an intervening COW
    /// (the copy carried the same bytes, and the restore resolves the
    /// *current* table entry); restoring into a still-shared page
    /// writes the bytes it already holds.  A tail whose page changed
    /// precision since the checkpoint (an intervening
    /// [`Self::requant_seq_tail`]) keeps the requantized bytes — the
    /// snapshot's codes no longer apply, and the requant pass already
    /// re-scaled over exactly the valid rows.
    pub fn rollback_seq(&mut self, h: KvHandle, ck: &SeqCheckpoint) {
        self.truncate_seq(h, ck.len);
        if ck.tails.is_empty() {
            return;
        }
        let pidx = ck.len / KV_PAGE;
        let n_kv = self.n_kv_heads;
        for t in &ck.tails {
            let pref = {
                let s = self.seqs[h.idx()].as_ref()
                    .expect("stale handle");
                s.layers[t.layer].pages[pidx]
            };
            if pref.prec != t.prec {
                continue;
            }
            let sidx = pref.id as usize * n_kv;
            match (&t.k, &t.v) {
                (TailCodes::I8(k), TailCodes::I8(v)) => {
                    let re = self.head_dim;
                    write_tail_codes(&mut self.pool_i8.k, pref.id,
                                     n_kv, re, t.rows, k);
                    write_tail_codes(&mut self.pool_i8.v, pref.id,
                                     n_kv, re, t.rows, v);
                    self.pool_i8.k_scale[sidx..sidx + n_kv]
                        .copy_from_slice(&t.k_scale);
                    self.pool_i8.v_scale[sidx..sidx + n_kv]
                        .copy_from_slice(&t.v_scale);
                }
                (TailCodes::U4(k), TailCodes::U4(v)) => {
                    let re = self.head_dim / 2;
                    write_tail_codes(&mut self.pool_u4.k, pref.id,
                                     n_kv, re, t.rows, k);
                    write_tail_codes(&mut self.pool_u4.v, pref.id,
                                     n_kv, re, t.rows, v);
                    self.pool_u4.k_scale[sidx..sidx + n_kv]
                        .copy_from_slice(&t.k_scale);
                    self.pool_u4.v_scale[sidx..sidx + n_kv]
                        .copy_from_slice(&t.v_scale);
                }
                _ => debug_assert!(false, "mismatched tail snapshot"),
            }
        }
    }

    /// Convert the first `rows` positions of page `src` into the
    /// freshly allocated page `dst` (refcount 1, zeroed scales),
    /// dequantizing each (head, side) run and re-quantizing it with a
    /// fresh absmax step over exactly those rows.
    fn convert_page(&mut self, src: PageRef, dst: PageRef, rows: usize) {
        let hd = self.head_dim;
        let n_kv = self.n_kv_heads;
        let mut buf = std::mem::take(&mut self.rot);
        if buf.len() < rows * hd {
            buf.resize(rows * hd, 0.0);
        }
        for head in 0..n_kv {
            for side_k in [true, false] {
                self.read_page_head(src, head, side_k, rows, &mut buf);
                self.write_page_head(dst, head, side_k, rows, &buf);
            }
        }
        self.rot = buf;
    }

    /// Dequantize the first `rows` rows of one (page, head, side) into
    /// `out[..rows * head_dim]`.
    fn read_page_head(&self, p: PageRef, head: usize, side_k: bool,
                      rows: usize, out: &mut [f32]) {
        let hd = self.head_dim;
        let n = rows * hd;
        match p.prec {
            KvPrecision::F32 => {
                let pe = self.n_kv_heads * KV_PAGE * hd;
                let lo = p.id as usize * pe + head * KV_PAGE * hd;
                let side = if side_k {
                    &self.pool_f32.k
                } else {
                    &self.pool_f32.v
                };
                out[..n].copy_from_slice(&side[lo..lo + n]);
            }
            KvPrecision::Int8 => {
                let pe = self.n_kv_heads * KV_PAGE * hd;
                let lo = p.id as usize * pe + head * KV_PAGE * hd;
                let sidx = p.id as usize * self.n_kv_heads + head;
                let (side, sc) = if side_k {
                    (&self.pool_i8.k, self.pool_i8.k_scale[sidx])
                } else {
                    (&self.pool_i8.v, self.pool_i8.v_scale[sidx])
                };
                for (o, &c) in out[..n].iter_mut()
                    .zip(&side[lo..lo + n])
                {
                    *o = c as f32 * sc;
                }
            }
            KvPrecision::Int4 => {
                let re = hd / 2;
                let pe = self.n_kv_heads * KV_PAGE * re;
                let lo = p.id as usize * pe + head * KV_PAGE * re;
                let sidx = p.id as usize * self.n_kv_heads + head;
                let (side, sc) = if side_k {
                    (&self.pool_u4.k, self.pool_u4.k_scale[sidx])
                } else {
                    (&self.pool_u4.v, self.pool_u4.v_scale[sidx])
                };
                let data = &side[lo..lo + rows * re];
                for (i, o) in out[..n].iter_mut().enumerate() {
                    *o = u4_code(data, i) as f32 * sc;
                }
            }
        }
    }

    /// Quantize `rows` dequantized rows into one (page, head, side) of
    /// the freshly allocated `p`, with an absmax step over exactly
    /// these rows (a new page has no widening history to respect).
    fn write_page_head(&mut self, p: PageRef, head: usize, side_k: bool,
                       rows: usize, src: &[f32]) {
        let hd = self.head_dim;
        let n_kv = self.n_kv_heads;
        match p.prec {
            KvPrecision::F32 => {
                let pe = n_kv * KV_PAGE * hd;
                let lo = p.id as usize * pe + head * KV_PAGE * hd;
                let side = if side_k {
                    &mut self.pool_f32.k
                } else {
                    &mut self.pool_f32.v
                };
                side[lo..lo + rows * hd]
                    .copy_from_slice(&src[..rows * hd]);
            }
            KvPrecision::Int8 => {
                write_quant_head(&mut self.pool_i8, n_kv, hd,
                                 p.id as usize, head, side_k, rows, src);
            }
            KvPrecision::Int4 => {
                write_quant_head(&mut self.pool_u4, n_kv, hd,
                                 p.id as usize, head, side_k, rows, src);
            }
        }
    }
}

/// Opaque snapshot from [`KvArena::checkpoint_seq`]: the sequence
/// length plus raw codes + scales of each layer's partially filled
/// quantized tail page, enough for [`KvArena::rollback_seq`] to make a
/// draft-and-reject burst byte-invisible.  O(partial page) per layer —
/// at most `KV_PAGE` rows per side — and nothing at all when the
/// length sits on a page seam or the tail is f32.
#[derive(Debug, Clone)]
pub struct SeqCheckpoint {
    len: usize,
    tails: Vec<TailSnapshot>,
}

impl SeqCheckpoint {
    /// Sequence length the snapshot restores to.
    pub fn len(&self) -> usize {
        self.len
    }
}

/// Saved state of one layer's partial quantized tail page.
#[derive(Debug, Clone)]
struct TailSnapshot {
    layer: usize,
    prec: KvPrecision,
    /// Valid rows in the page (`len % KV_PAGE`).
    rows: usize,
    /// Raw codes, `rows * row_elems` per head, heads concatenated.
    k: TailCodes,
    v: TailCodes,
    /// The page's per-head absmax steps at snapshot time.
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
}

#[derive(Debug, Clone)]
enum TailCodes {
    I8(Vec<i8>),
    U4(Vec<u8>),
}

/// Copy the first `rows` rows of every head of one page side out of a
/// pool slab (checkpoint body).
fn read_tail_codes<T: Copy>(data: &[T], page: u32, n_kv: usize,
                            re: usize, rows: usize) -> Vec<T> {
    let cap = KV_PAGE * re;
    let mut out = Vec::with_capacity(n_kv * rows * re);
    for head in 0..n_kv {
        let lo = page as usize * n_kv * cap + head * cap;
        out.extend_from_slice(&data[lo..lo + rows * re]);
    }
    out
}

/// Write saved tail codes back into a pool slab (rollback body).
fn write_tail_codes<T: Copy>(data: &mut [T], page: u32, n_kv: usize,
                             re: usize, rows: usize, src: &[T]) {
    let cap = KV_PAGE * re;
    for head in 0..n_kv {
        let lo = page as usize * n_kv * cap + head * cap;
        data[lo..lo + rows * re]
            .copy_from_slice(&src[head * rows * re..][..rows * re]);
    }
}

/// Outcome of one [`KvArena::requant_seq_tail`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequantSummary {
    /// Pages converted into the target pool.
    pub pages: usize,
    /// Budget bytes the conversions returned (old size minus new).
    pub bytes_freed: usize,
}

/// Outcome of one [`KvArena::swap_out_seq_cold`] or
/// [`KvArena::swap_in_seq`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SwapSummary {
    /// Pages moved across the tier boundary.
    pub pages: usize,
    /// Device-budget bytes released (swap-out) or re-claimed
    /// (swap-in) — each page at its own storage precision.
    pub bytes: usize,
}

/// Fresh-page quantize body of [`KvArena::write_page_head`]: absmax
/// over the rows, set the (page, head, side) scale, store the rows.
#[allow(clippy::too_many_arguments)]
fn write_quant_head<T: QuantStore>(pool: &mut PagePool<T>, n_kv: usize,
                                   hd: usize, page: usize, head: usize,
                                   side_k: bool, rows: usize,
                                   src: &[f32]) {
    let re = T::row_elems(hd);
    let head_base = page * n_kv * KV_PAGE * re + head * KV_PAGE * re;
    let sidx = page * n_kv + head;
    let amax = src[..rows * hd].iter()
        .fold(0f32, |m, &x| m.max(x.abs()));
    let step = amax / T::QMAX;
    let (data, scale) = if side_k {
        (&mut pool.k, &mut pool.k_scale[sidx])
    } else {
        (&mut pool.v, &mut pool.v_scale[sidx])
    };
    *scale = step;
    for i in 0..rows {
        T::store_row(&mut data[head_base + i * re..][..re],
                     &src[i * hd..(i + 1) * hd], step);
    }
}

/// Quantized scatter shared by the i8 and i4 pools: per touched page
/// and kv head, stage the portion's rows (RoPE-rotating the K side —
/// the same rotation the f32 path applies), widen the page-head scale
/// if the fresh rows exceed it, and quantize in place.  One pass over
/// the block, no dequant buffers.
#[allow(clippy::too_many_arguments)]
fn append_quant<T: QuantStore>(pool: &mut PagePool<T>, n_kv: usize,
                               hd: usize, rot: &mut Vec<f32>,
                               pages: &[u32], first: usize, pos0: usize,
                               t: usize, rope: &RopeCache,
                               k_block: &[f32], v_block: &[f32]) {
    let half = hd / 2;
    let w = n_kv * hd;
    let re = T::row_elems(hd);
    let page_elems = n_kv * KV_PAGE * re;
    let scales_per_page = n_kv;
    let mut p = pos0;
    while p < pos0 + t {
        let pidx = p / KV_PAGE;
        let hi = ((pidx + 1) * KV_PAGE).min(pos0 + t);
        let page = pages[pidx - first] as usize;
        let off0 = p % KV_PAGE;
        let n = hi - p;
        debug_assert_eq!(pool.refcount[page], 1,
                         "append into a shared page (COW missed)");
        if rot.len() < n * hd {
            rot.resize(n * hd, 0.0);
        }
        for head in 0..n_kv {
            let head_base = page * page_elems + head * KV_PAGE * re;
            let sidx = page * scales_per_page + head;
            // K: rotate the portion's rows into the staging scratch
            for i in 0..n {
                let pos = p + i;
                let (cos, sin) = rope.row(pos);
                let src = &k_block[(pos - pos0) * w + head * hd..][..hd];
                let dst = &mut rot[i * hd..(i + 1) * hd];
                for j in 0..half {
                    let (a, b) = (src[2 * j], src[2 * j + 1]);
                    dst[2 * j] = a * cos[j] - b * sin[j];
                    dst[2 * j + 1] = a * sin[j] + b * cos[j];
                }
            }
            quant_append_side::<T>(&mut pool.k, &mut pool.k_scale[sidx],
                                   head_base, off0, rot, hd, n, hd);
            // V needs no rotation, so it quantizes straight from the
            // strided linear output — no staging copy
            quant_append_side::<T>(&mut pool.v, &mut pool.v_scale[sidx],
                                   head_base, off0,
                                   &v_block[(p - pos0) * w + head * hd..],
                                   w, n, hd);
        }
        p = hi;
    }
}

/// Read view of one sequence x layer of a [`KvArena`]: resolves page
/// tables so the attention kernels see contiguous head-major runs, at
/// whatever precision each backing page stores.  Because a run never
/// straddles a page, mixed tables cost nothing in the kernels — each
/// tile still sees exactly one precision and one scale.
pub struct KvLayerView<'a> {
    pages: &'a [PageRef],
    len: usize,
    head_dim: usize,
    n_kv_heads: usize,
    append_prec: KvPrecision,
    pools: PoolViews<'a>,
}

/// Borrowed data + scale slabs of all three pools (scales empty for
/// the f32 pool).
struct PoolViews<'a> {
    f32k: &'a [f32],
    f32v: &'a [f32],
    i8k: &'a [i8],
    i8v: &'a [i8],
    i8ks: &'a [f32],
    i8vs: &'a [f32],
    u4k: &'a [u8],
    u4v: &'a [u8],
    u4ks: &'a [f32],
    u4vs: &'a [f32],
}

impl KvLayerView<'_> {
    /// Precision the sequence's fresh appends land at (the tail pages'
    /// precision; earlier pages may differ — see
    /// [`Self::page_precision`]).
    pub fn precision(&self) -> KvPrecision {
        self.append_prec
    }

    /// Storage precision of the page holding position `pos`.
    pub fn page_precision(&self, pos: usize) -> KvPrecision {
        debug_assert!(pos < self.len);
        self.pages[pos / KV_PAGE].prec
    }

    #[inline]
    fn run(&self, side_k: bool, h: usize, p0: usize, p1: usize)
           -> KvRun<'_> {
        debug_assert!(p0 < p1 && p1 <= self.len);
        debug_assert_eq!(p0 / KV_PAGE, (p1 - 1) / KV_PAGE,
                         "KV run straddles a page");
        let pref = self.pages[p0 / KV_PAGE];
        // the scheduler stalls (or swaps in) any sequence with a
        // host-tier page before dispatching it; reaching one here is a
        // dispatch-ordering bug, not a recoverable condition
        assert_eq!(pref.loc, PageLocation::Device,
                   "KV run touches a swapped-out page (position {p0}): \
                    swap_in_seq must run before this sequence is \
                    dispatched");
        let page = pref.id as usize;
        let off = p0 % KV_PAGE;
        let n = p1 - p0;
        let hd = self.head_dim;
        let sidx = page * self.n_kv_heads + h;
        let p = &self.pools;
        match pref.prec {
            KvPrecision::F32 => {
                let pe = self.n_kv_heads * KV_PAGE * hd;
                let lo = page * pe + (h * KV_PAGE + off) * hd;
                let side = if side_k { p.f32k } else { p.f32v };
                KvRun::F32(&side[lo..lo + n * hd])
            }
            KvPrecision::Int8 => {
                let pe = self.n_kv_heads * KV_PAGE * hd;
                let lo = page * pe + (h * KV_PAGE + off) * hd;
                let (side, sc) = if side_k {
                    (p.i8k, p.i8ks)
                } else {
                    (p.i8v, p.i8vs)
                };
                KvRun::I8 {
                    data: &side[lo..lo + n * hd],
                    scale: sc[sidx],
                }
            }
            KvPrecision::Int4 => {
                let re = hd / 2;
                let pe = self.n_kv_heads * KV_PAGE * re;
                let lo = page * pe + (h * KV_PAGE + off) * re;
                let (side, sc) = if side_k {
                    (p.u4k, p.u4ks)
                } else {
                    (p.u4v, p.u4vs)
                };
                KvRun::U4 {
                    data: &side[lo..lo + n * re],
                    scale: sc[sidx],
                }
            }
        }
    }
}

impl KvSource for KvLayerView<'_> {
    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn k_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_> {
        self.run(true, h, p0, p1)
    }

    #[inline]
    fn v_run(&self, h: usize, p0: usize, p1: usize) -> KvRun<'_> {
        self.run(false, h, p0, p1)
    }
}

// ---------------------------------------------------------------------------
// Per-shard arena group
// ---------------------------------------------------------------------------

/// The tensor-parallel KV store: one [`KvArena`] per shard, each holding
/// that shard's contiguous kv-head range, all sharing one logical byte
/// budget.
///
/// **Mirroring invariant.**  Every lifecycle operation (alloc, fork,
/// free, reset, truncate, requant, checkpoint, rollback, append) is
/// applied to all arenas in the same order, so their page tables evolve
/// in lockstep: identical handle indices, identical page-slot counts,
/// identical per-page precisions and refcounts.  Only the *width* of a
/// page differs (local kv heads x head_dim), and every arena is built
/// with the same `capacity_pages`, so each shard's budget is exactly
/// its head fraction of the whole — summed byte queries reproduce the
/// unsharded arena's numbers bit-for-bit, per-shard occupancy
/// *fractions* are identical across shards even when the GQA remainder
/// rule gives them different head counts, and an append that runs out
/// of pages does so on every shard in the same forward position.
///
/// Page-slot counts (`resident_pages`, `seq_pages`, ...) are identical
/// across mirrored arenas, so those queries report shard 0 rather than
/// an N-times-inflated sum; byte queries sum across shards.  This keeps
/// the pressure controller and metrics numerically identical to the
/// unsharded deployment.
///
/// The scheduler holds a `KvShards` regardless of shard count; the
/// single-shard case exposes the inner arena through
/// [`KvShards::only_mut`] so the pre-PR model entry points run
/// unchanged.
pub struct KvShards {
    arenas: Vec<KvArena>,
}

impl KvShards {
    /// Wrap an already-partitioned arena set (built by
    /// `model::shard::ShardPlan`); single-element vectors are the
    /// unsharded case.
    pub fn new(arenas: Vec<KvArena>) -> KvShards {
        assert!(!arenas.is_empty(), "at least one arena shard");
        let a0 = &arenas[0];
        for a in &arenas[1..] {
            assert_eq!(a.n_layers, a0.n_layers, "mirrored shape");
            assert_eq!(a.max_seq, a0.max_seq, "mirrored shape");
            assert_eq!(a.head_dim, a0.head_dim, "mirrored shape");
            assert_eq!(a.capacity_pages(), a0.capacity_pages(),
                       "shards share one page budget");
        }
        KvShards { arenas }
    }

    /// Single-arena convenience (the shards = 1 deployment).
    pub fn single(arena: KvArena) -> KvShards {
        KvShards { arenas: vec![arena] }
    }

    pub fn n_shards(&self) -> usize {
        self.arenas.len()
    }

    pub fn arenas(&self) -> &[KvArena] {
        &self.arenas
    }

    /// Mutable arena slice for the shard lanes (each lane takes its own
    /// element through a `SharedMut` fan-out; disjointness is by shard
    /// index).
    pub fn arenas_mut(&mut self) -> &mut [KvArena] {
        &mut self.arenas
    }

    /// The unsharded deployment's single arena; panics when sharded —
    /// call sites dispatch on shard count first.
    pub fn only_mut(&mut self) -> &mut KvArena {
        assert_eq!(self.arenas.len(), 1,
                   "only_mut on a sharded KV store");
        &mut self.arenas[0]
    }

    pub fn only(&self) -> &KvArena {
        assert_eq!(self.arenas.len(), 1,
                   "only on a sharded KV store");
        &self.arenas[0]
    }

    // -- mirrored lifecycle ops ---------------------------------------

    pub fn alloc_seq(&mut self) -> KvHandle {
        self.alloc_seq_at(KvPrecision::F32)
    }

    pub fn alloc_seq_at(&mut self, prec: KvPrecision) -> KvHandle {
        let mut hs = self.arenas.iter_mut()
            .map(|a| a.alloc_seq_at(prec));
        let h = hs.next().unwrap();
        assert!(hs.all(|x| x == h), "mirrored handles diverged");
        h
    }

    pub fn fork_prefix(&mut self, src: KvHandle, len: usize)
                       -> KvHandle {
        let mut hs = self.arenas.iter_mut()
            .map(|a| a.fork_prefix(src, len));
        let h = hs.next().unwrap();
        assert!(hs.all(|x| x == h), "mirrored handles diverged");
        h
    }

    pub fn fork_seq(&mut self, src: KvHandle) -> KvHandle {
        let mut hs = self.arenas.iter_mut().map(|a| a.fork_seq(src));
        let h = hs.next().unwrap();
        assert!(hs.all(|x| x == h), "mirrored handles diverged");
        h
    }

    pub fn free_seq(&mut self, h: KvHandle) {
        for a in &mut self.arenas {
            a.free_seq(h);
        }
    }

    pub fn reset_seq(&mut self, h: KvHandle) {
        for a in &mut self.arenas {
            a.reset_seq(h);
        }
    }

    pub fn truncate_seq(&mut self, h: KvHandle, len: usize) {
        for a in &mut self.arenas {
            a.truncate_seq(h, len);
        }
    }

    /// Mirrored tail requant; the returned summary sums the per-shard
    /// byte/page outcomes (pages convert in lockstep, so `pages` is
    /// shard 0's count — the unsharded number — while `bytes_freed`
    /// sums to the unsharded figure).
    pub fn requant_seq_tail(&mut self, h: KvHandle,
                            target: KvPrecision) -> RequantSummary {
        let mut total = RequantSummary::default();
        for (i, a) in self.arenas.iter_mut().enumerate() {
            let s = a.requant_seq_tail(h, target);
            if i == 0 {
                total.pages = s.pages;
            } else {
                debug_assert_eq!(s.pages, total.pages,
                                 "mirrored requant diverged");
            }
            total.bytes_freed += s.bytes_freed;
        }
        total
    }

    /// Size every shard's host swap tier to the same f32-page count.
    /// Each arena derives its byte budget from its *own* page width,
    /// so per-shard host budgets are exactly the head fraction of the
    /// whole and swap passes stop at the same page on every shard —
    /// the mirroring invariant extends to the host tier.
    pub fn set_host_budget_pages(&mut self, pages: usize) {
        for a in &mut self.arenas {
            a.set_host_budget_pages(pages);
        }
    }

    /// Mirrored cold-page swap-out; like
    /// [`KvShards::requant_seq_tail`], `pages` is shard 0's count (the
    /// unsharded number) while `bytes` sums to the unsharded figure.
    pub fn swap_out_seq_cold(&mut self, h: KvHandle) -> SwapSummary {
        let mut total = SwapSummary::default();
        for (i, a) in self.arenas.iter_mut().enumerate() {
            let s = a.swap_out_seq_cold(h);
            if i == 0 {
                total.pages = s.pages;
            } else {
                debug_assert_eq!(s.pages, total.pages,
                                 "mirrored swap-out diverged");
            }
            total.bytes += s.bytes;
        }
        total
    }

    /// Mirrored swap-in.  The deterministic claim order means a
    /// failing shard fails at the same page index on every shard, so
    /// on `Err` all arenas hold the same partially-restored state and
    /// the caller's fallback (retry or free + re-prefill) stays
    /// mirrored too.
    pub fn swap_in_seq(&mut self, h: KvHandle)
                       -> Result<SwapSummary, OutOfPages> {
        let mut total = SwapSummary::default();
        let mut first_err = None;
        for (i, a) in self.arenas.iter_mut().enumerate() {
            match a.swap_in_seq(h) {
                Ok(s) => {
                    if i == 0 {
                        total.pages = s.pages;
                    } else {
                        debug_assert_eq!(s.pages, total.pages,
                                         "mirrored swap-in diverged");
                    }
                    total.bytes += s.bytes;
                }
                Err(e) => {
                    debug_assert!(i == 0 || first_err.is_some(),
                                  "mirrored swap-in diverged");
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(total),
        }
    }

    /// Per-shard checkpoints, index-aligned with [`KvShards::arenas`].
    pub fn checkpoint_seq(&self, h: KvHandle) -> Vec<SeqCheckpoint> {
        self.arenas.iter().map(|a| a.checkpoint_seq(h)).collect()
    }

    pub fn rollback_seq(&mut self, h: KvHandle, cks: &[SeqCheckpoint]) {
        assert_eq!(cks.len(), self.arenas.len(),
                   "one checkpoint per shard");
        for (a, ck) in self.arenas.iter_mut().zip(cks) {
            a.rollback_seq(h, ck);
        }
    }

    #[cfg(feature = "failpoints")]
    pub fn set_fail_plan(&mut self, plan: Option<FailPlan>) {
        for a in &mut self.arenas {
            a.set_fail_plan(plan.clone());
        }
    }

    #[cfg(feature = "failpoints")]
    pub fn alloc_attempts(&self) -> u64 {
        self.arenas[0].alloc_attempts()
    }

    // -- mirrored reads (shard 0 carries the shared state) ------------

    pub fn seq_len(&self, h: KvHandle) -> usize {
        self.arenas[0].seq_len(h)
    }

    pub fn layer_len(&self, h: KvHandle, layer: usize) -> usize {
        self.arenas[0].layer_len(h, layer)
    }

    pub fn seq_precision(&self, h: KvHandle) -> KvPrecision {
        self.arenas[0].seq_precision(h)
    }

    pub fn max_seq(&self) -> usize {
        self.arenas[0].max_seq()
    }

    // -- page-slot queries (identical across mirrored shards) ---------

    pub fn capacity_pages(&self) -> usize {
        self.arenas[0].capacity_pages()
    }

    pub fn resident_pages(&self) -> usize {
        self.arenas[0].resident_pages()
    }

    pub fn resident_pages_at(&self, prec: KvPrecision) -> usize {
        self.arenas[0].resident_pages_at(prec)
    }

    pub fn peak_resident_pages(&self) -> usize {
        self.arenas[0].peak_resident_pages()
    }

    pub fn seq_pages(&self, h: KvHandle) -> usize {
        self.arenas[0].seq_pages(h)
    }

    pub fn seq_worst_pages(&self, positions: usize) -> usize {
        self.arenas[0].seq_worst_pages(positions)
    }

    pub fn seq_swapped_pages(&self, h: KvHandle) -> usize {
        self.arenas[0].seq_swapped_pages(h)
    }

    pub fn seq_host_prefix_len(&self, h: KvHandle) -> usize {
        self.arenas[0].seq_host_prefix_len(h)
    }

    pub fn host_resident_pages(&self) -> usize {
        self.arenas[0].host_resident_pages()
    }

    // -- byte queries (summed across shards == unsharded exactly) -----

    pub fn capacity_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.capacity_bytes()).sum()
    }

    pub fn resident_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.resident_bytes()).sum()
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.peak_resident_bytes()).sum()
    }

    pub fn free_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.free_bytes()).sum()
    }

    pub fn page_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.page_bytes()).sum()
    }

    pub fn page_bytes_at(&self, prec: KvPrecision) -> usize {
        self.arenas.iter().map(|a| a.page_bytes_at(prec)).sum()
    }

    pub fn bytes_saved_vs_f32(&self) -> usize {
        self.arenas.iter().map(|a| a.bytes_saved_vs_f32()).sum()
    }

    pub fn host_capacity_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.host_capacity_bytes()).sum()
    }

    pub fn host_resident_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.host_resident_bytes()).sum()
    }

    pub fn host_peak_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.host_peak_bytes()).sum()
    }

    pub fn seq_bytes(&self, h: KvHandle) -> usize {
        self.arenas.iter().map(|a| a.seq_bytes(h)).sum()
    }

    pub fn seq_host_bytes(&self, h: KvHandle) -> usize {
        self.arenas.iter().map(|a| a.seq_host_bytes(h)).sum()
    }

    pub fn seq_worst_bytes(&self, positions: usize,
                           prec: KvPrecision) -> usize {
        self.arenas.iter()
            .map(|a| a.seq_worst_bytes(positions, prec)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 1, 2);
        assert_eq!(c.push(&[1.0, 2.0], &[3.0, 4.0]), 0);
        assert_eq!(c.push(&[5.0, 6.0], &[7.0, 8.0]), 1);
        assert_eq!(c.k_head_at(0, 0), &[1.0, 2.0]);
        assert_eq!(c.v_head_at(0, 1), &[7.0, 8.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(c.len, 2);
        assert_eq!(c.k_run(0, 0, 2).as_f32().unwrap(), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(c.v_run(0, 1, 2).as_f32().unwrap(), &[7.0, 8.0]);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    fn head_major_scatter() {
        // 2 kv heads x head_dim 2: interleaved rows land in per-head
        // slabs, contiguous over positions.
        let mut c = KvCache::new(3, 2, 2);
        c.push(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        c.push(&[10.0, 20.0, 30.0, 40.0], &[50.0, 60.0, 70.0, 80.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 10.0, 20.0]);
        assert_eq!(c.k_head(1), &[3.0, 4.0, 30.0, 40.0]);
        assert_eq!(c.v_head(0), &[5.0, 6.0, 50.0, 60.0]);
        assert_eq!(c.v_head(1), &[7.0, 8.0, 70.0, 80.0]);
    }

    #[test]
    fn reserve_claims_positions() {
        let mut c = KvCache::new(6, 1, 2);
        assert_eq!(c.reserve(4), 0);
        assert_eq!(c.len, 4);
        assert_eq!(c.reserve(2), 4);
        assert_eq!(c.len, 6);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1);
        c.push(&[0.0], &[0.0]);
        c.push(&[0.0], &[0.0]);
    }

    // -- quantizer codec ---------------------------------------------------

    #[test]
    fn u4_pack_unpack_roundtrip() {
        // every int4 code survives pack -> unpack
        let vals: Vec<f32> = (-7..=7).map(|v| v as f32).collect();
        let mut src = vals.clone();
        src.push(0.0); // even length for pairing
        let mut dst = vec![0u8; src.len() / 2];
        u8::store_row(&mut dst, &src, 1.0);
        for (i, &want) in src.iter().enumerate() {
            assert_eq!(u4_code(&dst, i) as f32, want, "code {i}");
        }
    }

    #[test]
    fn i8_store_row_rounds_and_clamps() {
        let src = [0.0f32, 0.6, -0.6, 200.0, -200.0];
        let mut dst = [0i8; 5];
        i8::store_row(&mut dst, &src, 1.0);
        assert_eq!(dst, [0, 1, -1, 127, -127]);
        // step 0 stores zeros (an all-zero page-head)
        i8::store_row(&mut dst, &src, 0.0);
        assert_eq!(dst, [0i8; 5]);
    }

    #[test]
    fn rescale_recodes_to_wider_step() {
        let mut d = [100i8, -50, 127];
        i8::rescale(&mut d, 0.5, 1.0);
        assert_eq!(d, [50, -25, 64]);
        let src = [3.0f32, -7.0, 1.0, 0.0];
        let mut p = vec![0u8; 2];
        u8::store_row(&mut p, &src, 1.0);
        u8::rescale(&mut p, 1.0, 2.0);
        assert_eq!(u4_code(&p, 0), 2); // round(3 * 0.5)
        assert_eq!(u4_code(&p, 1), -4); // round(-3.5)
        assert_eq!(u4_code(&p, 2), 1); // round(0.5) = 1 (away from 0)
        assert_eq!(u4_code(&p, 3), 0);
    }

    // -- arena -------------------------------------------------------------

    /// 1 layer, 1 kv head, head_dim 2 arena with a tiny page budget
    /// (`cap_pages` f32-page equivalents).
    fn small_arena(cap_pages: usize) -> KvArena {
        KvArena::new(1, 4 * KV_PAGE, 1, 2, cap_pages)
    }

    fn ident_rope() -> RopeCache {
        // theta irrelevant for these tests; positions must be ensured
        let mut r = RopeCache::new(2, 1e4);
        r.ensure(4 * KV_PAGE);
        r
    }

    /// Append `t` constant rows (value tagging the call) to `h`.
    fn fill(a: &mut KvArena, rope: &RopeCache, h: KvHandle, t: usize,
            val: f32) -> Result<usize, OutOfPages> {
        let k: Vec<f32> = vec![val; t * 2];
        let v: Vec<f32> = vec![val + 0.5; t * 2];
        a.append_kv_block(h, 0, rope, &k, &v, t)
    }

    #[test]
    fn lazy_alloc_and_free_list_reuse() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        assert_eq!(a.resident_pages(), 0, "no eager pages");
        assert_eq!(a.resident_bytes(), 0);
        fill(&mut a, &rope, h, KV_PAGE + 1, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        assert_eq!(a.resident_bytes(), 2 * a.page_bytes());
        assert_eq!(a.seq_len(h), KV_PAGE + 1);
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 0, "retire frees pages");
        assert_eq!(a.resident_bytes(), 0);
        // readmit: pages come from the free list, peak unchanged
        let h2 = a.alloc_seq();
        fill(&mut a, &rope, h2, 2 * KV_PAGE, 2.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        assert_eq!(a.peak_resident_pages(), 2);
        assert_eq!(a.peak_resident_bytes(), 2 * a.page_bytes());
    }

    #[test]
    fn out_of_pages_is_clean() {
        let mut a = small_arena(1);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, KV_PAGE, 1.0).unwrap();
        let before = a.seq_len(h);
        let err = fill(&mut a, &rope, h, 1, 2.0).unwrap_err();
        assert_eq!(err, OutOfPages {
            needed_bytes: a.page_bytes(),
            free_bytes: 0,
        });
        assert_eq!(a.seq_len(h), before, "failed append must not grow");
        // freeing recovers the budget
        a.free_seq(h);
        let h2 = a.alloc_seq();
        fill(&mut a, &rope, h2, 3, 3.0).unwrap();
        assert_eq!(a.seq_len(h2), 3);
    }

    #[test]
    fn byte_budget_admits_4x_i8_pages() {
        // the same one-f32-page budget holds four i8 pages (or a
        // 5th append fails with byte-accurate numbers)
        let mut a = small_arena(1);
        let rope = ident_rope();
        let h = a.alloc_seq_at(KvPrecision::Int8);
        assert_eq!(a.seq_precision(h), KvPrecision::Int8);
        fill(&mut a, &rope, h, 4 * KV_PAGE, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 4);
        assert_eq!(a.resident_pages_at(KvPrecision::Int8), 4);
        assert_eq!(a.resident_bytes(), a.capacity_bytes());
        assert_eq!(a.bytes_saved_vs_f32(),
                   4 * (a.page_bytes()
                        - a.page_bytes_at(KvPrecision::Int8)));
        let err = {
            // one more position needs a 5th page
            let h2 = a.alloc_seq_at(KvPrecision::Int8);
            fill(&mut a, &rope, h2, 1, 1.0).unwrap_err()
        };
        assert_eq!(err.needed_bytes, a.page_bytes_at(KvPrecision::Int8));
        assert_eq!(err.free_bytes, 0);
    }

    #[test]
    fn int4_pages_are_8x_smaller() {
        let a = small_arena(1);
        assert_eq!(a.page_bytes_at(KvPrecision::Int4) * 8,
                   a.page_bytes());
        assert_eq!(a.page_bytes_at(KvPrecision::Int8) * 4,
                   a.page_bytes());
    }

    #[test]
    fn fork_shares_pages_and_cow_splits() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        // 1.5 pages: one full shared page + one shared partial page
        let t0 = KV_PAGE + KV_PAGE / 2;
        fill(&mut a, &rope, h, t0, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 2);

        let f = a.fork_prefix(h, t0);
        assert_eq!(a.seq_len(f), t0);
        assert_eq!(a.resident_pages(), 2, "fork copies no pages");
        // both views read the same bytes
        let want = a.layer(h, 0).k_run(0, 0, KV_PAGE).as_f32().unwrap().to_vec();
        assert_eq!(a.layer(f, 0).k_run(0, 0, KV_PAGE).as_f32().unwrap(),
                   &want[..]);

        // appending to the fork COWs only the partial page
        fill(&mut a, &rope, f, 1, 9.0).unwrap();
        assert_eq!(a.resident_pages(), 3, "COW copies one page");
        // source rows are untouched, fork kept the shared prefix
        let src_tail = a.layer(h, 0)
            .k_run(0, KV_PAGE, t0).as_f32().unwrap().to_vec();
        let fork_tail = a.layer(f, 0)
            .k_run(0, KV_PAGE, t0).as_f32().unwrap().to_vec();
        assert_eq!(src_tail, fork_tail,
                   "COW must preserve the shared rows");
        assert_eq!(a.seq_len(f), t0 + 1);
        assert_eq!(a.seq_len(h), t0);

        // freeing the source releases only its exclusive claim on the
        // still-shared full page
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 2);
        a.free_seq(f);
        assert_eq!(a.resident_pages(), 0);
    }

    #[test]
    fn source_append_after_fork_also_cows() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 10, 1.0).unwrap();
        let f = a.fork_prefix(h, 10);
        // the *source* appends first: it must COW too (the fork holds
        // a reference to the partial page)
        fill(&mut a, &rope, h, 1, 5.0).unwrap();
        assert_eq!(a.resident_pages(), 2);
        let hv = a.layer(h, 0).k_run(0, 0, 10).as_f32().unwrap().to_vec();
        let fv = a.layer(f, 0).k_run(0, 0, 10).as_f32().unwrap().to_vec();
        assert_eq!(hv, fv, "shared prefix must survive source COW");
        assert_eq!(a.seq_len(f), 10);
    }

    #[test]
    fn reset_seq_keeps_handle() {
        let mut a = small_arena(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 5, 1.0).unwrap();
        a.reset_seq(h);
        assert_eq!(a.seq_len(h), 0);
        assert_eq!(a.resident_pages(), 0);
        fill(&mut a, &rope, h, 3, 2.0).unwrap();
        assert_eq!(a.seq_len(h), 3);
    }

    #[test]
    fn paged_view_matches_slab_append() {
        // identical K/V blocks through the slab writer and the arena:
        // every head-major run must be bit-identical
        use crate::util::prng::Pcg;
        let (n_kv, hd) = (2usize, 4usize);
        let t = KV_PAGE + 17; // crosses a page boundary
        let mut rng = Pcg::new(77);
        let w = n_kv * hd;
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);
        let mut rope = RopeCache::new(hd, 1e4);
        rope.ensure(t);

        let mut slab = KvCache::new(2 * KV_PAGE, n_kv, hd);
        super::super::attention::append_kv_block(
            &mut slab, &rope, &k_block, &v_block, t);

        let mut a = KvArena::new(1, 2 * KV_PAGE, n_kv, hd, 4);
        let h = a.alloc_seq();
        a.append_kv_block(h, 0, &rope, &k_block, &v_block, t).unwrap();
        let view = a.layer(h, 0);
        assert_eq!(view.len(), t);
        for head in 0..n_kv {
            let mut p = 0usize;
            while p < t {
                let end = (p + KV_PAGE).min(t);
                assert_eq!(view.k_run(head, p, end).as_f32().unwrap(),
                           slab.k_run(head, p, end).as_f32().unwrap(),
                           "K head {head} run [{p}, {end})");
                assert_eq!(view.v_run(head, p, end).as_f32().unwrap(),
                           slab.v_run(head, p, end).as_f32().unwrap(),
                           "V head {head} run [{p}, {end})");
                p = end;
            }
        }
    }

    #[test]
    fn quantized_view_dequant_tracks_slab() {
        // same blocks through the slab and an i8 arena: dequantized
        // runs stay within the absmax step bound of the exact rows
        use crate::util::prng::Pcg;
        let (n_kv, hd) = (2usize, 4usize);
        let t = KV_PAGE + 9;
        let mut rng = Pcg::new(78);
        let w = n_kv * hd;
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);
        let mut rope = RopeCache::new(hd, 1e4);
        rope.ensure(t);

        let mut slab = KvCache::new(2 * KV_PAGE, n_kv, hd);
        super::super::attention::append_kv_block(
            &mut slab, &rope, &k_block, &v_block, t);

        let mut a = KvArena::new(1, 2 * KV_PAGE, n_kv, hd, 4);
        let h = a.alloc_seq_at(KvPrecision::Int8);
        a.append_kv_block(h, 0, &rope, &k_block, &v_block, t).unwrap();
        let view = a.layer(h, 0);
        assert_eq!(view.precision(), KvPrecision::Int8);
        for head in 0..n_kv {
            let mut p = 0usize;
            while p < t {
                let end = (p + KV_PAGE).min(t);
                let run = view.k_run(head, p, end);
                let deq = run.dequant(hd);
                let exact = slab.k_run(head, p, end).as_f32().unwrap();
                // 1.5 steps: the SCALE_GROW hysteresis bounds the
                // geometric re-code error series at 1.5 * step_final
                let tol = 1.5 * run.scale();
                for (i, (a, b)) in deq.iter().zip(exact).enumerate() {
                    assert!((a - b).abs() <= tol,
                            "K head {head} elem {i}: {a} vs {b} \
                             (tol {tol})");
                }
                p = end;
            }
        }
    }

    #[test]
    fn quantized_scale_growth_recodes_page() {
        // small rows first, then a large row into the same page: the
        // early rows must re-code to the wider step and stay accurate
        let mut a = small_arena(4);
        let rope = ident_rope();
        let h = a.alloc_seq_at(KvPrecision::Int8);
        fill(&mut a, &rope, h, 4, 0.5).unwrap();
        fill(&mut a, &rope, h, 1, 100.0).unwrap();
        let view = a.layer(h, 0);
        let run = view.v_run(0, 0, 5);
        let deq = run.dequant(2);
        // V rows are constant val + 0.5 (no RoPE on V)
        let tol = 1.5 * run.scale();
        for &x in &deq[..8] {
            assert!((x - 1.0).abs() <= tol,
                    "early row {x} drifted past {tol} after re-code");
        }
        for &x in &deq[8..10] {
            assert!((x - 100.5).abs() <= tol);
        }
    }

    #[test]
    fn mixed_precision_pools_are_disjoint() {
        // one arena, three sequences at three precisions: per-pool
        // residency is tracked separately and freeing one leaves the
        // others' bytes untouched
        let mut a = small_arena(8);
        let rope = ident_rope();
        let hf = a.alloc_seq();
        let h8 = a.alloc_seq_at(KvPrecision::Int8);
        let h4 = a.alloc_seq_at(KvPrecision::Int4);
        fill(&mut a, &rope, hf, 3, 1.0).unwrap();
        fill(&mut a, &rope, h8, 3, 2.0).unwrap();
        fill(&mut a, &rope, h4, 3, 4.0).unwrap();
        assert_eq!(a.resident_pages_at(KvPrecision::F32), 1);
        assert_eq!(a.resident_pages_at(KvPrecision::Int8), 1);
        assert_eq!(a.resident_pages_at(KvPrecision::Int4), 1);
        let want_bytes = a.page_bytes()
            + a.page_bytes_at(KvPrecision::Int8)
            + a.page_bytes_at(KvPrecision::Int4);
        assert_eq!(a.resident_bytes(), want_bytes);
        let f32_rows = a.layer(hf, 0).k_run(0, 0, 3).as_f32().unwrap().to_vec();
        a.free_seq(h8);
        assert_eq!(a.resident_pages_at(KvPrecision::Int8), 0);
        assert_eq!(a.layer(hf, 0).k_run(0, 0, 3).as_f32().unwrap(),
                   &f32_rows[..],
                   "freeing the i8 pool must not disturb f32 pages");
        assert_eq!(a.resident_bytes(),
                   want_bytes - a.page_bytes_at(KvPrecision::Int8));
    }

    // -- online requantization / pressure primitives (PR 6) ----------------

    #[test]
    fn requant_tail_frees_bytes_and_preserves_rows() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        let t = 2 * KV_PAGE + 8;
        fill(&mut a, &rope, h, t, 1.0).unwrap();
        let before: Vec<f32> =
            a.layer(h, 0).k_run(0, 2 * KV_PAGE, t).dequant(2);
        let used0 = a.resident_bytes();

        let r = a.requant_seq_tail(h, KvPrecision::Int8);
        assert_eq!(r.pages, 3, "all exclusive pages convert");
        assert_eq!(r.bytes_freed,
                   3 * (a.page_bytes()
                        - a.page_bytes_at(KvPrecision::Int8)));
        assert_eq!(a.resident_bytes(), used0 - r.bytes_freed);
        assert_eq!(a.resident_pages_at(KvPrecision::F32), 0);
        assert_eq!(a.resident_pages_at(KvPrecision::Int8), 3);
        assert_eq!(a.seq_precision(h), KvPrecision::Int8);
        assert_eq!(a.seq_len(h), t, "requant must not change length");
        assert_eq!(a.seq_bytes(h),
                   3 * a.page_bytes_at(KvPrecision::Int8));

        // converted rows stay within one fresh absmax step of the
        // exact rows they quantized from
        let view = a.layer(h, 0);
        assert_eq!(view.page_precision(0), KvPrecision::Int8);
        let run = view.k_run(0, 2 * KV_PAGE, t);
        let deq = run.dequant(2);
        let tol = run.scale();
        for (i, (got, want)) in deq.iter().zip(&before).enumerate() {
            assert!((got - want).abs() <= tol,
                    "elem {i}: {got} vs {want} (tol {tol})");
        }
    }

    #[test]
    fn requant_skips_shared_pages_and_converts_on_cow() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let src = a.alloc_seq();
        let t0 = KV_PAGE + KV_PAGE / 2;
        fill(&mut a, &rope, src, t0, 1.0).unwrap();
        let fork = a.fork_prefix(src, t0);
        let fork_rows = a.layer(fork, 0)
            .k_run(0, KV_PAGE, t0).as_f32().unwrap().to_vec();

        // every page is shared -> nothing converts, but the append
        // precision still degrades
        let r = a.requant_seq_tail(src, KvPrecision::Int8);
        assert_eq!(r, RequantSummary::default(),
                   "shared pages must not convert under their owners");
        assert_eq!(a.seq_precision(src), KvPrecision::Int8);
        assert_eq!(a.layer(src, 0).page_precision(0), KvPrecision::F32);

        // the next append COWs the partial tail page *into the i8
        // pool* while the fork keeps reading its f32 bytes
        fill(&mut a, &rope, src, 1, 2.0).unwrap();
        let sv = a.layer(src, 0);
        assert_eq!(sv.page_precision(0), KvPrecision::F32,
                   "full shared page stays at its written precision");
        assert_eq!(sv.page_precision(KV_PAGE), KvPrecision::Int8,
                   "COW'd tail lands in the target pool");
        assert_eq!(a.layer(fork, 0)
                       .k_run(0, KV_PAGE, t0).as_f32().unwrap(),
                   &fork_rows[..],
                   "fork's f32 bytes survive the source's convert-COW");
        // mixed table reads dispatch per page
        assert!(matches!(sv.k_run(0, 0, KV_PAGE), KvRun::F32(_)));
        assert!(matches!(sv.k_run(0, KV_PAGE, t0 + 1),
                         KvRun::I8 { .. }));
    }

    #[test]
    fn requant_stops_when_double_hold_does_not_fit() {
        // budget exactly fits the resident f32 page: the transient
        // new-page-before-old-frees hold cannot be satisfied, so the
        // pass is a clean no-op instead of a panic or partial state
        let mut a = small_arena(1);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 4, 1.0).unwrap();
        assert_eq!(a.free_bytes(), 0);
        let r = a.requant_seq_tail(h, KvPrecision::Int8);
        assert_eq!(r, RequantSummary::default());
        assert_eq!(a.layer(h, 0).page_precision(0), KvPrecision::F32);
    }

    #[test]
    fn truncate_seq_rolls_back_pages() {
        let mut a = small_arena(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 2 * KV_PAGE + 5, 1.0).unwrap();
        assert_eq!(a.resident_pages(), 3);
        a.truncate_seq(h, KV_PAGE + 3);
        assert_eq!(a.seq_len(h), KV_PAGE + 3);
        assert_eq!(a.resident_pages(), 2, "dropped page returns");
        // the kept partial page accepts fresh appends
        fill(&mut a, &rope, h, 2, 2.0).unwrap();
        assert_eq!(a.seq_len(h), KV_PAGE + 5);
        a.truncate_seq(h, 0);
        assert_eq!(a.seq_len(h), 0);
        assert_eq!(a.resident_pages(), 0);
        fill(&mut a, &rope, h, 3, 3.0).unwrap();
        assert_eq!(a.seq_len(h), 3);
    }

    #[test]
    fn requant_then_append_grows_at_target() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, KV_PAGE + 8, 1.0).unwrap();
        let r = a.requant_seq_tail(h, KvPrecision::Int4);
        assert_eq!(r.pages, 2);
        fill(&mut a, &rope, h, KV_PAGE, 2.0).unwrap();
        assert_eq!(a.seq_len(h), 2 * KV_PAGE + 8);
        assert_eq!(a.resident_pages_at(KvPrecision::F32), 0);
        assert_eq!(a.resident_pages_at(KvPrecision::Int4), 3);
        // V rows are constant val + 0.5; spot-check both eras
        let view = a.layer(h, 0);
        let run = view.v_run(0, KV_PAGE + 8, KV_PAGE + 12);
        for &x in &run.dequant(2) {
            assert!((x - 2.5).abs() <= run.scale());
        }
    }

    /// Mirrored per-shard arenas vs one unsharded arena: summed byte
    /// queries match exactly, page-slot queries match shard 0, and the
    /// per-shard occupancy fractions are identical even under a GQA
    /// remainder split (2+1 heads) — the invariant the shard-aware
    /// pressure ladder rests on.
    #[test]
    fn shards_sum_to_unsharded_budget() {
        let (hd, cap, max) = (2usize, 12usize, 4 * KV_PAGE);
        let mut full = KvArena::new(1, max, 3, hd, cap);
        let mut shards = KvShards::new(vec![
            KvArena::new(1, max, 2, hd, cap), // heads 0..2 (remainder)
            KvArena::new(1, max, 1, hd, cap), // head 2
        ]);
        let rope = ident_rope();
        let hf = full.alloc_seq_at(KvPrecision::Int8);
        let hs = shards.alloc_seq_at(KvPrecision::Int8);
        assert_eq!(hf, hs, "mirrored handle allocation");
        let t = KV_PAGE + 9;
        // head-major row blocks: full block is 3 heads wide, shard
        // blocks carry each shard's own head columns
        let kf: Vec<f32> = (0..t * 3 * hd).map(|i| i as f32 * 0.01)
            .collect();
        let vf: Vec<f32> = kf.iter().map(|x| x + 0.5).collect();
        full.append_kv_block(hf, 0, &rope, &kf, &vf, t).unwrap();
        for (s, (h0, h1)) in [(0usize, (0usize, 2usize)), (1, (2, 3))] {
            let w = (h1 - h0) * hd;
            let mut k = vec![0f32; t * w];
            let mut v = vec![0f32; t * w];
            for i in 0..t {
                let lo = i * 3 * hd + h0 * hd;
                k[i * w..(i + 1) * w]
                    .copy_from_slice(&kf[lo..lo + w]);
                v[i * w..(i + 1) * w]
                    .copy_from_slice(&vf[lo..lo + w]);
            }
            shards.arenas_mut()[s]
                .append_kv_block(hs, 0, &rope, &k, &v, t).unwrap();
        }
        assert_eq!(shards.seq_len(hs), full.seq_len(hf));
        assert_eq!(shards.resident_pages(), full.resident_pages());
        assert_eq!(shards.seq_pages(hs), full.seq_pages(hf));
        assert_eq!(shards.capacity_bytes(), full.capacity_bytes());
        assert_eq!(shards.resident_bytes(), full.resident_bytes());
        assert_eq!(shards.seq_bytes(hs), full.seq_bytes(hf));
        assert_eq!(shards.page_bytes(), full.page_bytes());
        assert_eq!(shards.bytes_saved_vs_f32(),
                   full.bytes_saved_vs_f32());
        // identical occupancy fraction on every shard, despite the
        // remainder head split
        let occ_full = full.resident_bytes() as f64
            / full.capacity_bytes() as f64;
        for a in shards.arenas() {
            let occ = a.resident_bytes() as f64
                / a.capacity_bytes() as f64;
            assert!((occ - occ_full).abs() < 1e-12,
                    "per-shard occupancy {occ} vs unsharded {occ_full}");
        }
        // quantized codes/scales per corresponding head are mirrored:
        // shard 1's head 0 IS the full arena's head 2
        let vfull = full.layer(hf, 0);
        let vsh = shards.arenas()[1].layer(hs, 0);
        let rf = vfull.k_run(2, 0, KV_PAGE);
        let rs = vsh.k_run(0, 0, KV_PAGE);
        assert_eq!(rf.scale(), rs.scale());
        assert_eq!(rf.dequant(hd), rs.dequant(hd));
        // mirrored requant: summed bytes_freed matches the unsharded
        // pass, page count stays the slot count
        let sf = full.requant_seq_tail(hf, KvPrecision::Int4);
        let ss = shards.requant_seq_tail(hs, KvPrecision::Int4);
        assert_eq!(ss.pages, sf.pages);
        assert_eq!(ss.bytes_freed, sf.bytes_freed);
        // mirrored checkpoint → append → rollback keeps lockstep
        let ckf = full.checkpoint_seq(hf);
        let cks = shards.checkpoint_seq(hs);
        assert_eq!(cks.len(), 2);
        assert_eq!(cks[0].len(), ckf.len());
        let t2 = 3;
        let k2: Vec<f32> = (0..t2 * 3 * hd)
            .map(|i| 0.3 - i as f32 * 0.02).collect();
        let v2: Vec<f32> = k2.iter().map(|x| x - 0.25).collect();
        full.append_kv_block(hf, 0, &rope, &k2, &v2, t2).unwrap();
        for (s, (h0, h1)) in [(0usize, (0usize, 2usize)), (1, (2, 3))] {
            let w = (h1 - h0) * hd;
            let mut k = vec![0f32; t2 * w];
            let mut v = vec![0f32; t2 * w];
            for i in 0..t2 {
                let lo = i * 3 * hd + h0 * hd;
                k[i * w..(i + 1) * w]
                    .copy_from_slice(&k2[lo..lo + w]);
                v[i * w..(i + 1) * w]
                    .copy_from_slice(&v2[lo..lo + w]);
            }
            shards.arenas_mut()[s]
                .append_kv_block(hs, 0, &rope, &k, &v, t2).unwrap();
        }
        full.rollback_seq(hf, &ckf);
        shards.rollback_seq(hs, &cks);
        assert_eq!(shards.seq_len(hs), full.seq_len(hf));
        assert_eq!(shards.resident_bytes(), full.resident_bytes());
        // mirrored truncate stays in lockstep
        shards.truncate_seq(hs, KV_PAGE);
        full.truncate_seq(hf, KV_PAGE);
        assert_eq!(shards.seq_len(hs), full.seq_len(hf));
        assert_eq!(shards.resident_bytes(), full.resident_bytes());
        shards.free_seq(hs);
        assert_eq!(shards.resident_bytes(), 0);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn failpoint_denies_scheduled_attempt_then_recovers() {
        let mut a = small_arena(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        a.set_fail_plan(Some(FailPlan::deny_at(&[1])));
        fill(&mut a, &rope, h, KV_PAGE, 1.0).unwrap(); // attempt 0
        let len0 = a.seq_len(h);
        let err = fill(&mut a, &rope, h, 1, 2.0).unwrap_err(); // 1: denied
        assert!(err.free_bytes >= err.needed_bytes,
                "synthetic fault reports real free bytes, \
                 distinguishing it from a genuine shortage");
        assert_eq!(a.seq_len(h), len0, "denied append must not grow");
        // the attempt index was consumed: the retry succeeds
        fill(&mut a, &rope, h, 1, 2.0).unwrap(); // attempt 2
        assert_eq!(a.seq_len(h), len0 + 1);
        assert_eq!(a.alloc_attempts(), 3);
        a.set_fail_plan(None);
    }

    // -- host swap tier ----------------------------------------------------

    /// Dequantized K then V of the whole sequence (layer 0, head 0):
    /// equal iff the underlying codes and scales are equal, so
    /// comparing dumps proves bit-identical storage.
    fn dump(a: &KvArena, h: KvHandle) -> Vec<f32> {
        let len = a.seq_len(h);
        let view = a.layer(h, 0);
        let mut out = Vec::new();
        let mut p = 0;
        while p < len {
            let hi = ((p / KV_PAGE + 1) * KV_PAGE).min(len);
            out.extend(view.k_run(0, p, hi).dequant(2));
            out.extend(view.v_run(0, p, hi).dequant(2));
            p = hi;
        }
        out
    }

    #[test]
    fn swap_out_cold_and_back_is_bit_identical() {
        for prec in [KvPrecision::F32, KvPrecision::Int8,
                     KvPrecision::Int4] {
            let mut a = small_arena(8);
            a.set_host_budget_pages(4);
            let rope = ident_rope();
            let h = a.alloc_seq_at(prec);
            // 2.5 pages with per-chunk values so pages are distinct
            fill(&mut a, &rope, h, KV_PAGE, 1.0).unwrap();
            fill(&mut a, &rope, h, KV_PAGE, -3.0).unwrap();
            fill(&mut a, &rope, h, KV_PAGE / 2, 7.0).unwrap();
            let before = dump(&a, h);
            let pb = a.page_bytes_at(prec);
            let dev0 = a.resident_bytes();

            let s = a.swap_out_seq_cold(h);
            assert_eq!(s.pages, 2,
                       "{}: both full cold pages must move",
                       prec.label());
            assert_eq!(s.bytes, 2 * pb);
            assert_eq!(a.seq_swapped_pages(h), 2);
            assert_eq!(a.host_resident_bytes(), 2 * pb);
            assert_eq!(a.host_resident_pages(), 2);
            assert_eq!(a.resident_bytes(), dev0 - 2 * pb,
                       "device bytes must return to the budget");

            // idempotent: nothing left to move
            assert_eq!(a.swap_out_seq_cold(h), SwapSummary::default());

            let r = a.swap_in_seq(h).unwrap();
            assert_eq!(r.pages, 2);
            assert_eq!(r.bytes, 2 * pb);
            assert_eq!(a.seq_swapped_pages(h), 0);
            assert_eq!(a.host_resident_bytes(), 0);
            assert_eq!(a.resident_bytes(), dev0);
            assert_eq!(dump(&a, h), before,
                       "{}: swap round trip must be bit-identical",
                       prec.label());

            // the sequence keeps growing normally afterwards
            fill(&mut a, &rope, h, KV_PAGE / 2, 2.0).unwrap();
            a.free_seq(h);
            assert_eq!(a.resident_pages(), 0);
            assert_eq!(a.host_resident_bytes(), 0);
        }
    }

    #[test]
    fn swap_disabled_at_zero_budget() {
        let mut a = small_arena(8);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 2 * KV_PAGE + 1, 1.0).unwrap();
        assert_eq!(a.swap_out_seq_cold(h), SwapSummary::default());
        assert_eq!(a.seq_swapped_pages(h), 0);
    }

    #[test]
    fn swap_skips_shared_and_tail_pages() {
        let mut a = small_arena(8);
        a.set_host_budget_pages(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 2 * KV_PAGE + KV_PAGE / 2, 1.0).unwrap();
        // page 0 is shared with a fork; page 2 is the partial tail
        let f = a.fork_prefix(h, KV_PAGE);
        let fork_read = dump(&a, f);

        let s = a.swap_out_seq_cold(h);
        assert_eq!(s.pages, 1,
                   "only the exclusively-owned cold page may move");
        assert_eq!(a.seq_swapped_pages(h), 1);
        // the fork still reads its shared page untouched
        assert_eq!(dump(&a, f), fork_read);

        a.swap_in_seq(h).unwrap();
        assert_eq!(a.seq_swapped_pages(h), 0);
        a.free_seq(h);
        a.free_seq(f);
        assert_eq!(a.resident_pages(), 0);
        assert_eq!(a.host_resident_bytes(), 0);
    }

    #[test]
    fn swap_out_stops_at_host_budget() {
        let mut a = small_arena(8);
        a.set_host_budget_pages(1);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 3 * KV_PAGE + 1, 1.0).unwrap();
        let s = a.swap_out_seq_cold(h);
        assert_eq!(s.pages, 1, "one-page host tier holds one page");
        assert_eq!(a.host_free_bytes(), 0);
        a.swap_in_seq(h).unwrap();
        assert_eq!(a.host_resident_bytes(), 0);
    }

    #[test]
    fn free_seq_releases_parked_host_pages() {
        let mut a = small_arena(8);
        a.set_host_budget_pages(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 2 * KV_PAGE + 1, 1.0).unwrap();
        assert_eq!(a.swap_out_seq_cold(h).pages, 2);
        // truncating to the cold boundary keeps the host pages parked
        a.truncate_seq(h, 2 * KV_PAGE);
        assert_eq!(a.seq_swapped_pages(h), 2);
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 0);
        assert_eq!(a.host_resident_bytes(), 0,
                   "free_seq must drain both tiers");
        assert!(a.host_peak_bytes() > 0);
    }

    #[test]
    fn swap_in_fails_cleanly_when_device_is_full() {
        let mut a = small_arena(2);
        a.set_host_budget_pages(2);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, KV_PAGE + 1, 1.0).unwrap();
        assert_eq!(a.swap_out_seq_cold(h).pages, 1);
        // another sequence takes the freed device page
        let h2 = a.alloc_seq();
        fill(&mut a, &rope, h2, KV_PAGE, 2.0).unwrap();
        let err = a.swap_in_seq(h).unwrap_err();
        assert_eq!(err.needed_bytes, a.page_bytes());
        assert_eq!(err.free_bytes, 0);
        assert_eq!(a.seq_swapped_pages(h), 1,
                   "failed swap-in leaves the page parked");
        // freeing device bytes lets the retry through
        a.free_seq(h2);
        a.swap_in_seq(h).unwrap();
        assert_eq!(a.seq_swapped_pages(h), 0);
        a.free_seq(h);
        assert_eq!(a.resident_pages(), 0);
        assert_eq!(a.host_resident_bytes(), 0);
    }

    /// Mirrored swap decisions across shards: same page counts on
    /// every shard, summed bytes equal the unsharded figure, and the
    /// restored bytes stay mirrored.
    #[test]
    fn shards_mirror_swap_decisions() {
        let mut full = KvArena::new(1, 4 * KV_PAGE, 3, 2, 12);
        full.set_host_budget_pages(6);
        let mut shards = KvShards::new(vec![
            KvArena::new(1, 4 * KV_PAGE, 2, 2, 12),
            KvArena::new(1, 4 * KV_PAGE, 1, 2, 12),
        ]);
        shards.set_host_budget_pages(6);
        let mut rope = RopeCache::new(2, 1e4);
        rope.ensure(4 * KV_PAGE);
        let hf = full.alloc_seq();
        let hs = shards.alloc_seq();
        let t = 2 * KV_PAGE + 5;
        let kf: Vec<f32> = (0..t * 3 * 2).map(|i| i as f32 * 0.01)
            .collect();
        let vf: Vec<f32> = kf.iter().map(|x| x + 0.5).collect();
        full.append_kv_block(hf, 0, &rope, &kf, &vf, t).unwrap();
        for (s, (h0, h1)) in [(0usize, (0usize, 2usize)), (1, (2, 3))] {
            let w = (h1 - h0) * 2;
            let mut k = vec![0f32; t * w];
            let mut v = vec![0f32; t * w];
            for i in 0..t {
                let lo = i * 3 * 2 + h0 * 2;
                k[i * w..(i + 1) * w].copy_from_slice(&kf[lo..lo + w]);
                v[i * w..(i + 1) * w].copy_from_slice(&vf[lo..lo + w]);
            }
            shards.arenas_mut()[s]
                .append_kv_block(hs, 0, &rope, &k, &v, t).unwrap();
        }
        let sf = full.swap_out_seq_cold(hf);
        let ss = shards.swap_out_seq_cold(hs);
        assert_eq!(ss.pages, sf.pages);
        assert_eq!(ss.bytes, sf.bytes);
        assert_eq!(shards.seq_swapped_pages(hs),
                   full.seq_swapped_pages(hf));
        assert_eq!(shards.host_resident_bytes(),
                   full.host_resident_bytes());
        assert_eq!(shards.host_resident_pages(),
                   full.host_resident_pages());
        let rf = full.swap_in_seq(hf).unwrap();
        let rs = shards.swap_in_seq(hs).unwrap();
        assert_eq!(rs.pages, rf.pages);
        assert_eq!(rs.bytes, rf.bytes);
        assert_eq!(shards.host_resident_bytes(), 0);
        shards.free_seq(hs);
        full.free_seq(hf);
        assert_eq!(shards.resident_bytes(), full.resident_bytes());
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn host_denial_behaves_as_exhausted_tier() {
        let mut a = small_arena(8);
        a.set_host_budget_pages(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 3 * KV_PAGE + 1, 1.0).unwrap();
        // deny-all: the tier acts permanently full — zero pages move
        a.set_fail_plan(Some(FailPlan::host_all()));
        assert_eq!(a.swap_out_seq_cold(h), SwapSummary::default());
        assert_eq!(a.seq_swapped_pages(h), 0);
        assert_eq!(a.host_attempts(), 1,
                   "the denied claim consumes its attempt index");
        // deny the second claim: one page moves, then the pass stops
        a.set_fail_plan(Some(FailPlan::host_at(&[2])));
        let s = a.swap_out_seq_cold(h);
        assert_eq!(s.pages, 1);
        assert_eq!(a.seq_swapped_pages(h), 1);
        a.set_fail_plan(None);
        a.swap_in_seq(h).unwrap();
        a.free_seq(h);
        assert_eq!(a.host_resident_bytes(), 0);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn swap_in_denial_is_synthetic_oom_and_retryable() {
        let mut a = small_arena(8);
        a.set_host_budget_pages(4);
        let rope = ident_rope();
        let h = a.alloc_seq();
        fill(&mut a, &rope, h, 2 * KV_PAGE + 1, 1.0).unwrap();
        let before = dump(&a, h);
        assert_eq!(a.swap_out_seq_cold(h).pages, 2);
        a.set_fail_plan(Some(FailPlan::swap_in_at(&[1])));
        let err = a.swap_in_seq(h).unwrap_err(); // attempts 0 ok, 1 denied
        assert!(err.free_bytes >= err.needed_bytes,
                "synthetic swap-in fault reports real free bytes");
        assert_eq!(a.seq_swapped_pages(h), 1,
                   "pages restored before the denial stay restored");
        // the denial consumed its index: the retry completes
        a.swap_in_seq(h).unwrap();
        assert_eq!(a.seq_swapped_pages(h), 0);
        assert_eq!(dump(&a, h), before);
        assert_eq!(a.swap_in_attempts(), 3);
        a.set_fail_plan(None);
        a.free_seq(h);
    }
}
