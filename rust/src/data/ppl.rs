//! Perplexity evaluation over the native engine — regenerates every PPL
//! cell in the paper's tables (byte-level over synthetic corpora; the
//! *relative* ordering across methods/bit-widths is the reproduced
//! quantity, not the absolute WikiText2 values).

use anyhow::Result;

use crate::mobiq::engine::Precision;
use crate::model::transformer::DecodeStats;
use crate::model::Model;

#[derive(Debug, Clone)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub avg_bits: f64,
}

/// Evaluate PPL with non-overlapping windows (window = ctx length).
pub fn evaluate(model: &Model, tokens: &[u32], precision: Precision,
                window: usize, max_windows: usize) -> Result<PplResult> {
    let mut total_nll = 0f64;
    let mut count = 0usize;
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let (mut arena, seq) = model.new_kv();
    let mut scratch = model.new_scratch();
    let n = ((tokens.len().saturating_sub(1)) / window).min(max_windows);
    anyhow::ensure!(n > 0, "not enough tokens for one window");
    let vocab = model.cfg.vocab_size;
    let mut win_logits: Vec<f32> = Vec::with_capacity(window * vocab);
    for i in 0..n {
        let chunk = &tokens[i * window..i * window + window + 1];
        arena.reset_seq(seq);
        win_logits.clear();
        // one batched weight-stationary pass over the whole window
        model.prefill_logits(&chunk[..window], &mut arena, seq,
                             precision, &mut scratch, &mut stats,
                             &mut win_logits)?;
        for j in 0..window {
            total_nll += nll_of(&win_logits[j * vocab..(j + 1) * vocab],
                                chunk[j + 1]);
            count += 1;
        }
    }
    Ok(PplResult {
        ppl: (total_nll / count as f64).exp(),
        nll_per_token: total_nll / count as f64,
        tokens: count,
        avg_bits: stats.avg_bits(),
    })
}

/// Negative log-likelihood of `target` under `logits` (log-softmax).
pub fn nll_of(logits: &[f32], target: u32) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits.iter()
        .map(|&l| ((l - max) as f64).exp())
        .sum::<f64>()
        .ln() + max as f64;
    lse - logits[target as usize] as f64
}

/// Sequence log-likelihood of a continuation given a prompt (cloze
/// scoring).  Returns sum log p(cont | prompt).
pub fn continuation_logprob(model: &Model, prompt: &[u32], cont: &[u32],
                            precision: Precision) -> Result<f64> {
    let (mut arena, seq) = model.new_kv();
    let mut scratch = model.new_scratch();
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let mut lp = 0f64;
    let all: Vec<u32> = prompt.iter().chain(cont).cloned().collect();
    for (i, &t) in all[..all.len() - 1].iter().enumerate() {
        model.decode_step(t, &mut arena, seq, precision, &mut scratch,
                          &mut stats)?;
        if i + 1 >= prompt.len() {
            lp -= nll_of(&scratch.logits, all[i + 1]);
        }
    }
    Ok(lp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_uniform() {
        let logits = vec![0f32; 4];
        let n = nll_of(&logits, 2);
        assert!((n - (4f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn nll_confident() {
        let mut logits = vec![0f32; 4];
        logits[1] = 50.0;
        assert!(nll_of(&logits, 1) < 1e-6);
        assert!(nll_of(&logits, 0) > 10.0);
    }
}
