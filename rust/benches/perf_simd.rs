//! §SIMD — runtime-dispatched wide-kernel study (EXPERIMENTS.md §SIMD).
//!
//! Benches each kernel family under forced-scalar (`MOBIQ_SIMD=off`
//! semantics) and auto-detected wide dispatch in the same process —
//! the bench binary owns the process-wide mode, so flipping it here
//! races nothing.  Reported speedups are the ISSUE 9 acceptance
//! numbers: >= 2x on the i8 fused-dequant attention dot, >= 1.5x on
//! LUT plane-word resolution.
//!
//! Build with `RUSTFLAGS="-C target-cpu=x86-64-v3"` to compile the
//! AVX2 paths CI gates on (runtime detection still decides dispatch).

use mobiquant::mobiq::bitplane::PackedSlice;
use mobiquant::mobiq::gemv::{gemv_lut, TokenLut};
use mobiquant::mobiq::quantizer::{decompose, GroupParams};
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;
use mobiquant::util::simd::{self, SimdMode};

/// (mode to force, report tag).
const ARMS: [(SimdMode, &str); 2] = [(SimdMode::Off, "scalar"),
                                     (SimdMode::Auto, "simd")];

fn main() {
    let mut suite = Suite::new("perf_simd");
    suite.header();
    let det = simd::detected();
    suite.row("dispatch", &[("detected_lanes", det.lanes() as f64)]);
    let mut rng = Pcg::new(5);

    // ---- family 1: quantized attention dots / axpys ----
    // One query row against a T x hd code slab — the K-walk shape of
    // `attn_head` (decode: every resident position per head).
    let (t, hd) = (2048usize, 128usize);
    let q = rng.normal_vec(hd, 1.0);
    let k8: Vec<i8> = (0..t * hd)
        .map(|_| (rng.next_u32() & 0xFF) as u8 as i8)
        .collect();
    let k4: Vec<u8> = (0..t * hd / 2)
        .map(|_| (rng.next_u32() & 0xFF) as u8)
        .collect();
    let mut acc_row = vec![0f32; hd];

    let mut ns_i8 = [0f64; 2];
    let mut ns_u4 = [0f64; 2];
    let mut ns_ax = [0f64; 2];
    for (ai, (mode, tag)) in ARMS.iter().enumerate() {
        simd::set_mode(*mode);
        ns_i8[ai] = suite.bench(
            &format!("i8 dot {t}x{hd} [{tag}]"), || {
                let mut acc = 0f32;
                for row in k8.chunks_exact(hd) {
                    acc += simd::dot_f32_i8(&q, row);
                }
                black_box(acc);
            });
        ns_u4[ai] = suite.bench(
            &format!("u4 dot {t}x{hd} [{tag}]"), || {
                let mut acc = 0f32;
                for row in k4.chunks_exact(hd / 2) {
                    acc += simd::dot_f32_u4(&q, row);
                }
                black_box(acc);
            });
        ns_ax[ai] = suite.bench(
            &format!("i8 axpy {t}x{hd} [{tag}]"), || {
                acc_row.fill(0.0);
                for (j, row) in k8.chunks_exact(hd).enumerate() {
                    simd::axpy_f32_i8(&mut acc_row, 1.0 / (j + 1) as f32,
                                      row);
                }
                black_box(acc_row[0]);
            });
    }

    // ---- family 2: LUT plane-word resolution ----
    // Byte-table shape (1024) and nibble-table shape (4096), 2-bit
    // active mask — the per-token `gemv_lut` decode walk.
    let mut ns_lut = Vec::new();
    for (d_in, d_out) in [(1024usize, 1024usize), (4096, 4096)] {
        let gs = 32;
        let w = rng.normal_vec(d_in * d_out, 0.1);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = decompose(&w, &base, 4);
        let slices: Vec<PackedSlice> = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        let x = rng.normal_vec(d_in, 1.0);
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let active = [true, false, false, false];
        let mut out = vec![0f32; d_out];
        let mut ns = [0f64; 2];
        for (ai, (mode, tag)) in ARMS.iter().enumerate() {
            simd::set_mode(*mode);
            ns[ai] = suite.bench(
                &format!("LUT {d_in}x{d_out} @2bit [{tag}]"), || {
                    gemv_lut(&slices, &base, &lut, &active, &mut out);
                    black_box(out[0]);
                });
        }
        ns_lut.push((d_in, ns));
    }

    // ---- family 3: elementwise rows ----
    let d = 4096usize;
    let xr = rng.normal_vec(d, 1.0);
    let wr = rng.normal_vec(d, 0.5);
    let gr = rng.normal_vec(d, 2.0);
    let mut outr = vec![0f32; d];
    let mut ns_rms = [0f64; 2];
    let mut ns_sw = [0f64; 2];
    for (ai, (mode, tag)) in ARMS.iter().enumerate() {
        simd::set_mode(*mode);
        ns_rms[ai] = suite.bench(&format!("rmsnorm d={d} [{tag}]"), || {
            simd::rmsnorm_row(&xr, &wr, 1e-5, &mut outr);
            black_box(outr[0]);
        });
        ns_sw[ai] = suite.bench(&format!("swiglu d={d} [{tag}]"), || {
            simd::swiglu_row(&gr, &xr, &mut outr);
            black_box(outr[0]);
        });
    }
    simd::clear_mode();

    suite.row("speedup scalar/simd", &[
        ("i8_dot", ns_i8[0] / ns_i8[1]),
        ("u4_dot", ns_u4[0] / ns_u4[1]),
        ("i8_axpy", ns_ax[0] / ns_ax[1]),
        ("lut_1024", ns_lut[0].1[0] / ns_lut[0].1[1]),
        ("lut_4096", ns_lut[1].1[0] / ns_lut[1].1[1]),
        ("rmsnorm", ns_rms[0] / ns_rms[1]),
        ("swiglu", ns_sw[0] / ns_sw[1]),
    ]);
    suite.note("targets (ISSUE 9 acceptance): i8_dot >= 2x, LUT \
                resolution >= 1.5x vs forced-scalar.  Both arms run in \
                this one process (the bench owns the dispatch mode); \
                parity of the two arms is pinned by tests/simd_parity.");
    suite.finish();
}
