//! Shared helpers for the bench harnesses (rust/benches/*.rs) — bundle
//! loading with graceful skip, dense-model assembly from baseline
//! kernels, and LWC re-quantization at unseen bit-widths (the paper's
//! calibration/inference mismatch experiments).

use anyhow::Result;

use crate::mobiq::artifact::Bundle;
use crate::mobiq::footprint::KvFootprint;
use crate::mobiq::bitplane::PackedSlice;
use crate::mobiq::engine::MobiqLinear;
use crate::mobiq::quantizer::{decompose, GroupParams};
use crate::mobiq::router::{RouterMlp, ThresholdTable};
use crate::mobiq::static_quant::StaticLinear;
use crate::model::weights::{BackendKind, LayerWeights, LinearBackend,
                            ModelConfig, LINEAR_NAMES};
use crate::model::Model;
use crate::util::prng::Pcg;

/// Load a model bundle, or None (with a note) when artifacts are missing.
pub fn try_bundle(name: &str) -> Option<Bundle> {
    let path = crate::artifacts_dir().join(format!("{name}.mobiq"));
    if !path.exists() {
        println!("  SKIP {name}: {} missing (run `make artifacts`)",
                 path.display());
        return None;
    }
    match Bundle::load(&path) {
        Ok(b) => Some(b),
        Err(e) => {
            println!("  SKIP {name}: {e:#}");
            None
        }
    }
}

pub fn models_available() -> Vec<String> {
    let mut out = Vec::new();
    for m in ["tiny-s", "tiny-m", "tiny-gqa", "tiny-l"] {
        if crate::artifacts_dir().join(format!("{m}.mobiq")).exists() {
            out.push(m.to_string());
        }
    }
    out
}

/// Synthetic MobiqLinear over random weights (group_size 32, 4 slices
/// of 2 bits, linear-grid thresholds) — lets benches and integration
/// tests exercise the full router + kernel path without the artifact
/// bundle.  Deterministic in the rng state.
pub fn synth_mobiq_linear(rng: &mut Pcg, d_in: usize,
                          d_out: usize) -> MobiqLinear {
    let gs = 32;
    let hidden = 8;
    let w = rng.normal_vec(d_in * d_out, 0.2);
    let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
    let codes = decompose(&w, &base, 4);
    let slices = codes.iter()
        .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
        .collect();
    MobiqLinear {
        slices,
        base,
        router: RouterMlp {
            w1: rng.normal_vec(d_in * hidden, 0.2),
            b1: vec![0.0; hidden],
            w2: rng.normal_vec(hidden * 3, 0.2),
            b2: vec![0.0; 3],
            d_in,
            hidden,
            n_residual: 3,
        },
        thresholds: ThresholdTable {
            quantiles: (0..129).map(|i| (i as f32 - 64.0) / 64.0)
                .collect(),
        },
        d_in,
        d_out,
        slice_bits: 2,
        act_bits: None,
    }
}

/// Small synthetic end-to-end model (Mobiq linears + dense lm_head)
/// for tests that must run without `make artifacts`.  Two calls with
/// the same seed build bit-identical models.
pub fn synth_model(seed: u64) -> Model {
    synth_model_shaped(seed, 4, 2, 128)
}

/// [`synth_model`] with an explicit attention shape: `n_heads` query
/// heads over `n_kv_heads` KV heads (GQA when they differ; head_dim
/// stays 16) and a chosen context budget.  Lets parity tests sweep GQA
/// configs and sequences past one prefill block without the artifact
/// bundle.  Same seed + same shape => bit-identical models.
pub fn synth_model_shaped(seed: u64, n_heads: usize, n_kv_heads: usize,
                          max_seq_len: usize) -> Model {
    assert!(n_heads % n_kv_heads.max(1) == 0,
            "GQA needs n_kv_heads | n_heads");
    let cfg = ModelConfig {
        name: "synth".into(),
        vocab_size: 256,
        d_model: 16 * n_heads,
        n_layers: 2,
        n_heads,
        n_kv_heads,
        d_ff: 128,
        max_seq_len,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    };
    let mut rng = Pcg::new(seed);
    let embed = rng.normal_vec(cfg.vocab_size * cfg.d_model, 0.5);
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        let mut lin = |name: &str| {
            let (di, dn) = cfg.linear_dims(name).unwrap();
            LinearBackend::Mobiq(synth_mobiq_linear(&mut rng, di, dn))
        };
        layers.push(LayerWeights {
            attn_norm: vec![1.0; cfg.d_model],
            mlp_norm: vec![1.0; cfg.d_model],
            wq: lin("wq"),
            wk: lin("wk"),
            wv: lin("wv"),
            wo: lin("wo"),
            w_gate: lin("w_gate"),
            w_up: lin("w_up"),
            w_down: lin("w_down"),
        });
    }
    let lm_head = LinearBackend::Dense {
        w: rng.normal_vec(cfg.d_model * cfg.vocab_size, 0.2),
        d_in: cfg.d_model,
        d_out: cfg.vocab_size,
    };
    Model {
        embed,
        final_norm: vec![1.0; cfg.d_model],
        lm_head,
        layers,
        cfg,
        pool: None,
    }
}

/// [`KvFootprint`] matching a model's shape — the analytic counterpart
/// the KV benches and reports compare measured arena residency
/// against.
pub fn kv_footprint(cfg: &ModelConfig) -> KvFootprint {
    KvFootprint {
        n_layers: cfg.n_layers,
        n_kv_heads: cfg.n_kv_heads,
        head_dim: cfg.head_dim(),
        max_seq_len: cfg.max_seq_len,
        kv_page: crate::model::KV_PAGE,
    }
}

/// Valid-set tokens for a domain.
pub fn valid_tokens(domain: &str) -> Result<Vec<u32>> {
    crate::data::corpus::load_tokens(&crate::artifacts_dir(), domain,
                                     crate::data::corpus::Split::Valid)
}

/// Eval-budget knobs (override with MOBIQ_BENCH_WINDOWS).
pub fn eval_windows(default: usize) -> usize {
    std::env::var("MOBIQ_BENCH_WINDOWS").ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// FP weight matrix of one linear.
pub fn fp_weight(bundle: &Bundle, layer: usize, name: &str)
                 -> Result<(Vec<f32>, usize, usize)> {
    let (shape, data) = bundle.f32(
        &format!("fp.layers.{layer}.{name}"))?;
    Ok((data.to_vec(), shape[0], shape[1]))
}

/// Build a model whose quantizable linears are replaced by dense weights
/// produced per-linear by `f(layer, name, w_fp, d_in, d_out)`.
pub fn dense_model_with(
    bundle: &Bundle,
    mut f: impl FnMut(usize, &str, &[f32], usize, usize) -> Vec<f32>,
) -> Result<Model> {
    let mut model = Model::load(bundle, BackendKind::Fp32)?;
    for li in 0..model.cfg.n_layers {
        for name in LINEAR_NAMES {
            let (w, d_in, d_out) = fp_weight(bundle, li, name)?;
            let new = f(li, name, &w, d_in, d_out);
            assert_eq!(new.len(), w.len());
            *linear_mut(&mut model, li, name) =
                LinearBackend::Dense { w: new, d_in, d_out };
        }
    }
    Ok(model)
}

pub fn linear_mut<'a>(model: &'a mut Model, layer: usize,
                      name: &str) -> &'a mut LinearBackend {
    let lw = &mut model.layers[layer];
    match name {
        "wq" => &mut lw.wq,
        "wk" => &mut lw.wk,
        "wv" => &mut lw.wv,
        "wo" => &mut lw.wo,
        "w_gate" => &mut lw.w_gate,
        "w_up" => &mut lw.w_up,
        "w_down" => &mut lw.w_down,
        _ => panic!("unknown linear {name}"),
    }
}

/// Re-quantize FP weights with another method's calibrated *range*
/// (scale/zero) transferred to a different bit-width — the paper's
/// "calibration bits != inference bits" mismatch (Fig. 1, Tab. 4-6).
///
/// Floor quantizer with range [lo, hi]: s_b = range / 2^b, z_b = -lo/s_b;
/// so s_b' = s_b / 2^{b'-b}, z_b' = z_b * 2^{b'-b}.
pub fn requantize_at(w_fp: &[f32], rec: &StaticLinear, new_bits: u32)
                     -> Vec<f32> {
    let p = &rec.params;
    let shift = 2f32.powi(new_bits as i32 - p.bits as i32);
    let p2 = GroupParams {
        scale: p.scale.iter().map(|s| s / shift).collect(),
        zero: p.zero.iter().map(|z| z * shift).collect(),
        bits: new_bits,
        ..p.clone()
    };
    // NOTE: for transformed methods (AWQ/SmoothQuant/QuaRot) the record's
    // codes came from the transformed weight; we must re-quantize the
    // transformed weight, which equals dequant at calib bits only up to
    // quantization error.  Use the stored high-precision reconstruction:
    // transformed w = act-transform applied on the fly at inference, so
    // here we quantize the *stored transformed weight estimate*.
    let w_src: Vec<f32> = if rec.transform
        == crate::mobiq::static_quant::Transform::None
    {
        w_fp.to_vec()
    } else {
        // recover the transformed-space weight from the record itself at
        // its native bits (best available estimate), then re-quantize.
        rec.weights.clone()
    };
    let codes = crate::mobiq::quantizer::quantize(&w_src, &p2);
    crate::mobiq::quantizer::dequantize(&codes, &p2)
}

/// Model with `method`'s calibration applied at `infer_bits` (mismatch
/// experiment).  The activation transform of the method is preserved.
pub fn mismatch_model(bundle: &Bundle, method: &str, infer_bits: u32)
                      -> Result<Model> {
    let mut model = Model::load(bundle,
                                BackendKind::Static(method.to_string()))?;
    for li in 0..model.cfg.n_layers {
        for name in LINEAR_NAMES {
            let (w_fp, _, _) = fp_weight(bundle, li, name)?;
            let lin = linear_mut(&mut model, li, name);
            if let LinearBackend::Static(rec) = lin {
                let new_w = requantize_at(&w_fp, rec, infer_bits);
                rec.weights = new_w;
                rec.bits = infer_bits;
            }
        }
    }
    Ok(model)
}
