//! Self-speculative decoding parity suite.
//!
//! The tentpole invariant: speculative greedy output is token-for-token
//! identical to [`Model::generate_at`] — across GQA configs, page-seam
//! sequence lengths, every KV storage precision, and forced-rejection
//! rounds with adversarial draft tokens.  Plus the arena-level exactness
//! the invariant rests on: checkpoint/rollback of a draft burst must
//! reproduce the straight-line page bytes AND quantization scales, even
//! when the burst widened a partial tail page's absmax scale or forced
//! a copy-on-write of a fork-shared tail.
//!
//! Runs entirely on the synthetic model (no `make artifacts` needed).

use std::time::Duration;

use mobiquant::bench_support::{synth_model, synth_model_shaped};
use mobiquant::coordinator::controller::ControllerConfig;
use mobiquant::coordinator::{Server, ServerConfig};
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::attention::RopeCache;
use mobiquant::model::{DecodeStats, KvArena, KvHandle, KvPrecision,
                       KvRun, KvSource, Model, SpecCapture, SpecConfig,
                       SpecState, KV_PAGE};
use mobiquant::util::prng::Pcg;

const KV_PRECS: [KvPrecision; 3] =
    [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4];

fn prompt_for(id: usize, len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 5 + 11 * id) % 256) as u32).collect()
}

fn verify_prec() -> Precision {
    Precision::elastic(4.0)
}

// ---------------------------------------------------------------------------
// Arena checkpoint/rollback exactness (the primitive the loop rests on)
// ---------------------------------------------------------------------------

/// Byte-and-scale equality of two sequences' first `upto` positions,
/// checked run-by-run so quantized codes and page-uniform scales are
/// compared exactly (not through a dequantized lens).
fn assert_kv_identical(a: &KvArena, x: KvHandle, y: KvHandle,
                       n_layers: usize, n_kv: usize, upto: usize) {
    for li in 0..n_layers {
        let vx = a.layer(x, li);
        let vy = a.layer(y, li);
        assert!(vx.len() >= upto && vy.len() >= upto,
                "layer {li}: lens {} / {} < {upto}", vx.len(), vy.len());
        for h in 0..n_kv {
            let mut p = 0;
            while p < upto {
                let p1 = ((p / KV_PAGE + 1) * KV_PAGE).min(upto);
                assert_run_eq(vx.k_run(h, p, p1), vy.k_run(h, p, p1),
                              "K", li, h, p);
                assert_run_eq(vx.v_run(h, p, p1), vy.v_run(h, p, p1),
                              "V", li, h, p);
                p = p1;
            }
        }
    }
}

fn assert_run_eq(a: KvRun, b: KvRun, what: &str, li: usize, h: usize,
                 p: usize) {
    let at = format!("{what} layer {li} head {h} pos {p}");
    match (a, b) {
        (KvRun::F32(x), KvRun::F32(y)) => {
            assert_eq!(x, y, "f32 rows diverge at {at}");
        }
        (KvRun::I8 { data: dx, scale: sx },
         KvRun::I8 { data: dy, scale: sy }) => {
            assert_eq!(sx.to_bits(), sy.to_bits(),
                       "i8 scale diverges at {at}: {sx} vs {sy}");
            assert_eq!(dx, dy, "i8 codes diverge at {at}");
        }
        (KvRun::U4 { data: dx, scale: sx },
         KvRun::U4 { data: dy, scale: sy }) => {
            assert_eq!(sx.to_bits(), sy.to_bits(),
                       "u4 scale diverges at {at}: {sx} vs {sy}");
            assert_eq!(dx, dy, "u4 codes diverge at {at}");
        }
        (a, b) => panic!("run precision mismatch at {at}: {a:?} vs {b:?}"),
    }
}

/// Straight-line oracle vs checkpoint → garbage burst → rollback →
/// continue, on one shared arena.  `m` is the checkpoint position,
/// `g` the number of garbage rows (huge values, so any scale widening
/// that survives the rollback is loud).
fn rollback_case(prec: KvPrecision, m: usize, g: usize) {
    const L: usize = 2;
    const HD: usize = 4; // one kv head, head_dim 4 (even, for u4)
    let n = 2 * KV_PAGE + 3;
    let mut a = KvArena::new(L, 4 * KV_PAGE, 1, HD, 64);
    let mut rope = RopeCache::new(HD, 1e4);
    rope.ensure(4 * KV_PAGE);
    let mut rng = Pcg::new(0x5eed ^ (m as u64) ^ ((g as u64) << 20));
    let ks = rng.normal_vec(L * n * HD, 1.0);
    let vs = rng.normal_vec(L * n * HD, 1.0);
    let row = |s: &[f32], li: usize, i: usize| &s[(li * n + i) * HD..][..HD];

    let ha = a.alloc_seq_at(prec);
    for i in 0..n {
        for li in 0..L {
            a.append_kv_block(ha, li, &rope, row(&ks, li, i),
                              row(&vs, li, i), 1).unwrap();
        }
    }
    let hb = a.alloc_seq_at(prec);
    for i in 0..m {
        for li in 0..L {
            a.append_kv_block(hb, li, &rope, row(&ks, li, i),
                              row(&vs, li, i), 1).unwrap();
        }
    }
    let ck = a.checkpoint_seq(hb);
    let junk = vec![1.0e4f32; HD];
    for _ in 0..g {
        for li in 0..L {
            a.append_kv_block(hb, li, &rope, &junk, &junk, 1).unwrap();
        }
    }
    a.rollback_seq(hb, &ck);
    assert_eq!(a.seq_len(hb), m, "rollback must restore the length");
    for i in m..n {
        for li in 0..L {
            a.append_kv_block(hb, li, &rope, row(&ks, li, i),
                              row(&vs, li, i), 1).unwrap();
        }
    }
    assert_kv_identical(&a, ha, hb, L, 1, n);
}

#[test]
fn rollback_reproduces_straight_line_bytes_and_scales() {
    for prec in KV_PRECS {
        // checkpoint just under a page boundary, garbage crosses it
        rollback_case(prec, KV_PAGE - 1, 2);
        // checkpoint exactly on a boundary (empty tail: truncate-only)
        rollback_case(prec, KV_PAGE, 1);
        // mid-page tail, garbage burst spills a whole page past it
        rollback_case(prec, KV_PAGE + 3, KV_PAGE);
        // tail one row short of full
        rollback_case(prec, 2 * KV_PAGE - 1, 3);
    }
}

/// Rollback across an intervening copy-on-write: fork a child that
/// shares the parent's partial tail page, checkpoint, append garbage
/// (forcing the COW), roll back, continue.  The child must reproduce
/// the straight line AND the parent's shared prefix must be untouched.
#[test]
fn rollback_survives_cow_fork_of_partial_tail() {
    const L: usize = 2;
    const HD: usize = 4;
    for prec in KV_PRECS {
        let m = KV_PAGE + 5;
        let n = 2 * KV_PAGE + 1;
        let mut a = KvArena::new(L, 4 * KV_PAGE, 1, HD, 64);
        let mut rope = RopeCache::new(HD, 1e4);
        rope.ensure(4 * KV_PAGE);
        let mut rng = Pcg::new(0xf0f0 ^ m as u64);
        let ks = rng.normal_vec(L * n * HD, 1.0);
        let vs = rng.normal_vec(L * n * HD, 1.0);
        let row =
            |s: &[f32], li: usize, i: usize| &s[(li * n + i) * HD..][..HD];

        let ha = a.alloc_seq_at(prec); // straight-line oracle
        for i in 0..n {
            for li in 0..L {
                a.append_kv_block(ha, li, &rope, row(&ks, li, i),
                                  row(&vs, li, i), 1).unwrap();
            }
        }
        let hp = a.alloc_seq_at(prec); // parent, stops at m
        for i in 0..m {
            for li in 0..L {
                a.append_kv_block(hp, li, &rope, row(&ks, li, i),
                                  row(&vs, li, i), 1).unwrap();
            }
        }
        let hc = a.fork_prefix(hp, m); // shares the partial tail page
        let ck = a.checkpoint_seq(hc);
        let junk = vec![2.0e4f32; HD];
        for _ in 0..3 {
            for li in 0..L {
                a.append_kv_block(hc, li, &rope, &junk, &junk, 1)
                    .unwrap();
            }
        }
        a.rollback_seq(hc, &ck);
        // the draft burst COWed the tail; the parent must not have
        // seen any of it
        assert_kv_identical(&a, ha, hp, L, 1, m);
        for i in m..n {
            for li in 0..L {
                a.append_kv_block(hc, li, &rope, row(&ks, li, i),
                                  row(&vs, li, i), 1).unwrap();
            }
        }
        assert_kv_identical(&a, ha, hc, L, 1, n);
    }
}

// ---------------------------------------------------------------------------
// Model-level parity: generate_speculative == generate_at
// ---------------------------------------------------------------------------

fn parity_case(model: &Model, total: usize, kv: KvPrecision) {
    let prompt = prompt_for(3, 31);
    let n_new = total - prompt.len();
    let prec = verify_prec();
    let mut stats = DecodeStats::new(2);
    let oracle =
        model.generate_at(&prompt, n_new, prec, kv, &mut stats).unwrap();

    let cfg = SpecConfig::default();
    let mut st = SpecState::new(&cfg, 2);
    let mut stats2 = DecodeStats::new(2);
    let got = model
        .generate_speculative(&prompt, n_new, prec, kv, &cfg,
                              &mut stats2, &mut st)
        .unwrap();
    assert_eq!(got, oracle, "kv {kv:?} total {total}");
    assert_eq!(st.drafted, st.accepted + st.rejected);
    assert_eq!(st.commit_tokens, (n_new - 1) as u64,
               "every post-prefill token flows through a verify round");
    assert!(st.rounds > 0);
}

/// GQA model (4 heads / 2 kv heads), totals bracketing the page seam
/// (KV_PAGE = 64): 63, 64, 65 and a two-seam length, at every KV
/// storage precision.
#[test]
fn speculative_matches_generate_gqa_page_seams() {
    let model = synth_model_shaped(17, 4, 2, 160);
    for kv in KV_PRECS {
        for total in [63, 64, 65, 129] {
            parity_case(&model, total, kv);
        }
    }
}

/// MHA model (4 heads / 4 kv heads) across the KV precisions.
#[test]
fn speculative_matches_generate_mha() {
    let model = synth_model_shaped(23, 4, 4, 160);
    for kv in KV_PRECS {
        parity_case(&model, 65, kv);
    }
}

/// `verify_commit` holds the parity invariant for ARBITRARY drafts —
/// feed it deterministic mixtures of correct and garbage tokens and
/// the committed stream must still be exactly the oracle's.  Cycles
/// full-accept / partial-accept / full-reject / mixed rounds so the
/// rollback + re-commit path runs with every accepted-prefix shape.
#[test]
fn forced_rejections_preserve_parity() {
    let model = synth_model_shaped(29, 4, 2, 160);
    let prec = verify_prec();
    for (ci, kv) in KV_PRECS.into_iter().enumerate() {
        let prompt = prompt_for(7, 33);
        let n_new = 48;
        let mut stats = DecodeStats::new(2);
        let oracle = model
            .generate_at(&prompt, n_new, prec, kv, &mut stats)
            .unwrap();

        let (mut arena, seq) = model.new_kv_at(kv);
        let mut scratch = model.new_scratch();
        let mut cap = SpecCapture::new();
        let mut rng = Pcg::new(0xbad5eed + ci as u64);
        let mut toks = prompt.clone();
        let mut last = model
            .greedy_prefill(&prompt, &mut arena, seq, prec,
                            &mut scratch, &mut stats)
            .unwrap();
        assert_eq!(last, oracle[prompt.len()]);
        toks.push(last);
        let mut generated = 1usize;
        let (mut full, mut partial, mut rejected) = (0u32, 0u32, 0u32);
        let mut round_no = 0usize;
        while generated < n_new {
            let k = 3.min(n_new - generated - 1);
            let drafts: Vec<u32> = (0..k)
                .map(|j| {
                    let right = oracle[toks.len() + j];
                    let wrong = (right + 1 + rng.below(200) as u32) % 256;
                    match round_no % 4 {
                        0 => right,                            // full accept
                        1 => if j == 0 { right } else { wrong }, // partial
                        2 => wrong,                            // full reject
                        _ => if rng.below(2) == 0 { right } else { wrong },
                    }
                })
                .collect();
            round_no += 1;
            let round = model
                .verify_commit(last, &drafts, &mut arena, seq, prec,
                               &mut scratch, &mut cap, &mut stats)
                .unwrap();
            assert_eq!(round.tokens.len(), round.matched + 1);
            if round.drafted > 0 && round.matched == round.drafted {
                full += 1;
            }
            if round.matched > 0 && round.matched < round.drafted {
                partial += 1;
            }
            if round.matched < round.drafted {
                rejected += 1;
            }
            toks.extend_from_slice(&round.tokens);
            generated += round.tokens.len();
            last = *round.tokens.last().unwrap();
        }
        assert_eq!(toks, oracle, "kv {kv:?}");
        assert!(full > 0 && partial > 0 && rejected > 0,
                "kv {kv:?}: exercise all accept shapes \
                 (full={full} partial={partial} rejected={rejected})");
    }
}

/// k = 0 degenerates to a plain decode step: same token, same length,
/// byte-identical KV pages.
#[test]
fn empty_draft_verify_is_a_decode_step() {
    let model = synth_model_shaped(5, 4, 2, 96);
    let prec = verify_prec();
    for kv in KV_PRECS {
        let mut arena = model.new_arena(2);
        let s1 = arena.alloc_seq_at(kv);
        let s2 = arena.alloc_seq_at(kv);
        let mut scratch = model.new_scratch();
        let mut stats = DecodeStats::new(2);
        let mut cap = SpecCapture::new();
        let prompt = prompt_for(1, 21);
        let mut last1 = model
            .greedy_prefill(&prompt, &mut arena, s1, prec, &mut scratch,
                            &mut stats)
            .unwrap();
        let mut last2 = model
            .greedy_prefill(&prompt, &mut arena, s2, prec, &mut scratch,
                            &mut stats)
            .unwrap();
        assert_eq!(last1, last2);
        for _ in 0..5 {
            let next = model
                .greedy_step(last1, &mut arena, s1, prec, &mut scratch,
                             &mut stats)
                .unwrap();
            let round = model
                .verify_commit(last2, &[], &mut arena, s2, prec,
                               &mut scratch, &mut cap, &mut stats)
                .unwrap();
            assert_eq!((round.drafted, round.matched), (0, 0));
            assert_eq!(round.tokens, vec![next]);
            last1 = next;
            last2 = round.tokens[0];
        }
        let len = arena.seq_len(s1);
        assert_eq!(len, arena.seq_len(s2));
        assert_kv_identical(&arena, s1, s2, 2, 2, len);
    }
}

// ---------------------------------------------------------------------------
// Scheduler-level parity: speculative decode tick vs plain decode tick
// ---------------------------------------------------------------------------

/// With the controller pinned (no precision jitter) and no page
/// pressure, turning speculation on must not change a single output
/// token for any request — it only changes how many verify steps the
/// tokens took.  Also pins the spec accounting surfaced by `Metrics`.
#[test]
fn scheduler_speculative_matches_plain_decode() {
    let base = || ServerConfig {
        max_active: 3,
        controller: ControllerConfig {
            min_bits: 4.0,
            max_bits: 4.0,
            ..ControllerConfig::default()
        },
        ..ServerConfig::default()
    };
    let prompts: Vec<Vec<u32>> =
        (0..3).map(|i| prompt_for(i, 13)).collect();
    let n_new = 24usize;

    let run = |cfg: ServerConfig| {
        let server = Server::start(synth_model(41), cfg);
        let rxs: Vec<_> = prompts
            .iter()
            .zip(KV_PRECS)
            .map(|(p, kv)| server.submit_at(p.clone(), n_new, kv))
            .collect();
        let toks: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|(_, rx)| {
                let r = rx.recv_timeout(Duration::from_secs(120))
                    .expect("response");
                assert_eq!(r.metrics.generated_tokens, n_new);
                r.tokens
            })
            .collect();
        (toks, server.shutdown().unwrap())
    };

    let (plain, m_plain) = run(base());
    let mut cfg = base();
    cfg.speculative = Some(SpecConfig::default());
    let (spec, m_spec) = run(cfg);

    assert_eq!(spec, plain,
               "speculative tick changed scheduler outputs");
    assert_eq!(m_plain.spec_rounds, 0);
    assert!(m_spec.spec_rounds > 0, "no speculative rounds ran");
    assert_eq!(m_spec.spec_drafted,
               m_spec.spec_accepted + m_spec.spec_rejected);
    assert!(m_spec.spec_commit_tokens >= m_spec.spec_rounds,
            "every round commits at least one token");
    assert!(m_spec.spec_tokens_per_round() >= 1.0);
    let s = m_spec.summary();
    assert!(s.contains("spec_rounds="), "summary missing spec: {s}");
}
