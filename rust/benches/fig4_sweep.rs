//! Fig. 4 — any-precision PPL sweep: MoBiQuant (single 3-bit-target
//! calibration, elastic) vs OmniQuant-lite (3-bit calibrated parameters
//! transferred to every inference bit-width) across the model family.
//!
//! Reproduced shape: MoBiQ degrades smoothly down to 2-3 bits while the
//! statically calibrated baseline blows up away from its calibration
//! point.

use mobiquant::bench_support as bs;
use mobiquant::data::ppl;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig4_sweep");
    suite.header();
    let windows = bs::eval_windows(5);
    let Ok(toks) = bs::valid_tokens("wiki") else {
        suite.note("no corpus; run `make artifacts`");
        suite.finish();
        return;
    };

    for mname in bs::models_available() {
        let Some(bundle) = bs::try_bundle(&mname) else { continue };
        if !bundle.static_methods().contains(&"omniquant3".to_string()) {
            continue;
        }
        let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();

        // elastic sweep with sub-bit granularity
        let mut mobi_cells: Vec<(String, f64)> = Vec::new();
        for target in [2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0, 8.0] {
            let r = ppl::evaluate(&mobiq, &toks,
                                  Precision::elastic(target), 128,
                                  windows).unwrap();
            mobi_cells.push((format!("{target}"), r.ppl));
        }
        let named: Vec<(&str, f64)> = mobi_cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("{mname} MoBiQ elastic"), &named);

        // OmniQuant-lite 3-bit params transferred across bit-widths
        let mut omni_cells: Vec<(String, f64)> = Vec::new();
        for bits in [2u32, 3, 4, 5, 6, 8] {
            let model = if bits == 3 {
                Model::load(&bundle,
                            BackendKind::Static("omniquant3".into()))
                    .unwrap()
            } else {
                bs::mismatch_model(&bundle, "omniquant3", bits).unwrap()
            };
            let r = ppl::evaluate(&model, &toks, Precision::Fixed(4), 128,
                                  windows).unwrap();
            omni_cells.push((format!("{bits}"), r.ppl));
        }
        let named: Vec<(&str, f64)> = omni_cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("{mname} Omni calib@3"), &named);
    }
    suite.note("paper shape: MoBiQ smooth across 2-8b; static calib \
                degrades off its calibration point, hardest at 2-3b");
    suite.finish();
}
