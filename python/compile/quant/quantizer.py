"""Floor-aligned group quantizer — paper Eq. (11)-(12), App. B.

    x_int = clamp(floor(x / s + z), 0, 2^b - 1)
    x_deq = s * (x_int - z + 0.5)

The floor (not round) mapping plus the +0.5 centred dequantization is what
makes bit-slice codes *nest*: dropping LSBs of the merged integer code is
exactly quantization with a 2^p-coarser scale (App. B, Eq. 16-21).  All
scales are per-(input-dim group, output channel): W has shape (d_in, d_out)
and groups tile the d_in axis.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

EPS = 1e-8


class GroupQuantParams(NamedTuple):
    """Per-group scale/zero.  Shapes: (n_groups, d_out)."""
    scale: jnp.ndarray
    zero: jnp.ndarray
    bits: int
    group_size: int


def n_groups(d_in: int, group_size: int) -> int:
    assert d_in % group_size == 0, (d_in, group_size)
    return d_in // group_size


def group_view(w: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """(d_in, d_out) -> (n_groups, group_size, d_out)."""
    d_in, d_out = w.shape
    return w.reshape(n_groups(d_in, group_size), group_size, d_out)


def flat_view(wg: jnp.ndarray) -> jnp.ndarray:
    g, gs, d_out = wg.shape
    return wg.reshape(g * gs, d_out)


def params_from_minmax(wmin: jnp.ndarray, wmax: jnp.ndarray, bits: int,
                       group_size: int) -> GroupQuantParams:
    """Scale/zero covering [wmin, wmax] with 2^b floor bins."""
    levels = float(2 ** bits)
    scale = jnp.maximum((wmax - wmin) / levels, EPS)
    zero = -wmin / scale
    return GroupQuantParams(scale, zero, bits, group_size)


def calc_params(w: jnp.ndarray, bits: int, group_size: int,
                clip_lo: jnp.ndarray = None,
                clip_hi: jnp.ndarray = None) -> GroupQuantParams:
    """Min/max (optionally clipped) calibration per group.

    clip_lo/clip_hi in (0, 1]: learnable-weight-clipping factors applied to
    the negative/positive extents (OmniQuant LWC).  Broadcast over groups.
    """
    wg = group_view(w, group_size)
    wmin = jnp.min(wg, axis=1)       # (n_groups, d_out)
    wmax = jnp.max(wg, axis=1)
    if clip_lo is not None:
        wmin = wmin * clip_lo
    if clip_hi is not None:
        wmax = wmax * clip_hi
    wmin = jnp.minimum(wmin, -EPS)
    wmax = jnp.maximum(wmax, EPS)
    return params_from_minmax(wmin, wmax, bits, group_size)


def quantize(w: jnp.ndarray, p: GroupQuantParams) -> jnp.ndarray:
    """-> integer codes, shape (d_in, d_out), dtype int32."""
    wg = group_view(w, p.group_size)
    q = jnp.floor(wg / p.scale[:, None, :] + p.zero[:, None, :])
    q = jnp.clip(q, 0, 2 ** p.bits - 1)
    return flat_view(q).astype(jnp.int32)


def dequantize(q: jnp.ndarray, p: GroupQuantParams) -> jnp.ndarray:
    qg = group_view(q.astype(jnp.float32), p.group_size)
    deq = p.scale[:, None, :] * (qg - p.zero[:, None, :] + 0.5)
    return flat_view(deq)


def quantize_ste(w: jnp.ndarray, p: GroupQuantParams) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient estimator.

    Used by the gradient-based calibrators (OmniQuant-lite / MoBiQuant
    stage 1 & 2) so that d(deq)/d(scale, zero, w) flows.
    """
    wg = group_view(w, p.group_size)
    s = p.scale[:, None, :]
    z = p.zero[:, None, :]
    q_cont = wg / s + z
    q_hard = jnp.clip(jnp.floor(q_cont), 0, 2 ** p.bits - 1)
    # STE: forward uses q_hard, backward flows through clipped q_cont - 0.5
    # (floor(x) ~ x - 0.5 in expectation).
    q_ste = q_cont - 0.5 + jax.lax.stop_gradient(q_hard - (q_cont - 0.5))
    deq = s * (q_ste - z + 0.5)
    return flat_view(deq)


def quant_error(w: jnp.ndarray, p: GroupQuantParams) -> jnp.ndarray:
    return w - dequantize(quantize(w, p), p)


def rtn(w: jnp.ndarray, bits: int, group_size: int
        ) -> Tuple[jnp.ndarray, GroupQuantParams]:
    """Round(floor)-to-nearest baseline: min/max params, no calibration."""
    p = calc_params(w, bits, group_size)
    return dequantize(quantize(w, p), p), p
