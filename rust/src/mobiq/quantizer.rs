//! Floor-aligned group quantizer — exact mirror of
//! python/compile/quant/quantizer.py (paper Eq. 11-12, App. B):
//!
//! ```text
//! q   = clamp(floor(x / s + z), 0, 2^b - 1)
//! deq = s * (q - z + 0.5)
//! ```
//!
//! Weights are (d_in, d_out) with y = x W; scales/zeros are per
//! (input-group, output-channel), stored row-major (n_groups, d_out).

/// Per-linear shared quantization parameters (the paper's single Theta_q).
#[derive(Debug, Clone)]
pub struct GroupParams {
    pub scale: Vec<f32>, // (n_groups * d_out)
    pub zero: Vec<f32>,  // (n_groups * d_out)
    pub n_groups: usize,
    pub d_out: usize,
    pub bits: u32,
    pub group_size: usize,
}

impl GroupParams {
    #[inline]
    pub fn at(&self, g: usize, o: usize) -> (f32, f32) {
        let i = g * self.d_out + o;
        (self.scale[i], self.zero[i])
    }

    /// Min/max calibration from a weight matrix (RTN-style).
    pub fn from_minmax(w: &[f32], d_in: usize, d_out: usize, bits: u32,
                       group_size: usize) -> GroupParams {
        assert_eq!(w.len(), d_in * d_out);
        assert_eq!(d_in % group_size, 0);
        let n_groups = d_in / group_size;
        let levels = (1u32 << bits) as f32;
        let mut scale = vec![0f32; n_groups * d_out];
        let mut zero = vec![0f32; n_groups * d_out];
        for g in 0..n_groups {
            for o in 0..d_out {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for j in 0..group_size {
                    let v = w[(g * group_size + j) * d_out + o];
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let lo = lo.min(-1e-8);
                let hi = hi.max(1e-8);
                let s = ((hi - lo) / levels).max(1e-8);
                scale[g * d_out + o] = s;
                zero[g * d_out + o] = -lo / s;
            }
        }
        GroupParams { scale, zero, n_groups, d_out, bits, group_size }
    }

    /// Derived parameters of slice e (0-based): s_e = s_1 / 2^{b e},
    /// z_e = 2^{b-1} for e >= 1 (App. B Eq. 14).
    pub fn residual(&self, e: usize) -> GroupParams {
        if e == 0 {
            return self.clone();
        }
        let div = (1u64 << (self.bits as usize * e)) as f32;
        GroupParams {
            scale: self.scale.iter().map(|s| s / div).collect(),
            zero: vec![(1u32 << (self.bits - 1)) as f32;
                       self.zero.len()],
            ..self.clone()
        }
    }
}

/// Quantize one weight matrix -> integer codes (d_in * d_out).
pub fn quantize(w: &[f32], p: &GroupParams) -> Vec<u8> {
    let d_in = p.n_groups * p.group_size;
    let maxq = ((1u32 << p.bits) - 1) as f32;
    let mut q = vec![0u8; w.len()];
    for g in 0..p.n_groups {
        for j in 0..p.group_size {
            let row = g * p.group_size + j;
            for o in 0..p.d_out {
                let (s, z) = p.at(g, o);
                let v = (w[row * p.d_out + o] / s + z).floor()
                    .clamp(0.0, maxq);
                q[row * p.d_out + o] = v as u8;
            }
        }
    }
    debug_assert_eq!(d_in * p.d_out, w.len());
    q
}

/// Dequantize integer codes -> f32 weights.
pub fn dequantize(q: &[u8], p: &GroupParams) -> Vec<f32> {
    let mut w = vec![0f32; q.len()];
    for g in 0..p.n_groups {
        for j in 0..p.group_size {
            let row = g * p.group_size + j;
            for o in 0..p.d_out {
                let (s, z) = p.at(g, o);
                w[row * p.d_out + o] =
                    s * (q[row * p.d_out + o] as f32 - z + 0.5);
            }
        }
    }
    w
}

/// Recursive residual decomposition (paper Eq. 2): returns per-slice codes.
pub fn decompose(w: &[f32], base: &GroupParams, n_slices: usize)
                 -> Vec<Vec<u8>> {
    let mut r = w.to_vec();
    let mut out = Vec::with_capacity(n_slices);
    for e in 0..n_slices {
        let p = base.residual(e);
        let q = quantize(&r, &p);
        let deq = dequantize(&q, &p);
        for (ri, di) in r.iter_mut().zip(&deq) {
            *ri -= di;
        }
        out.push(q);
    }
    out
}

/// Reconstruct a weight matrix from the first k slices (Eq. 3).
pub fn reconstruct(codes: &[Vec<u8>], base: &GroupParams, k: usize)
                   -> Vec<f32> {
    let mut w = vec![0f32; codes[0].len()];
    for e in 0..k {
        let p = base.residual(e);
        let deq = dequantize(&codes[e], &p);
        for (wi, di) in w.iter_mut().zip(&deq) {
            *wi += di;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{property, Pcg};

    fn rand_weight(rng: &mut Pcg, d_in: usize, d_out: usize) -> Vec<f32> {
        rng.normal_vec(d_in * d_out, 0.1)
    }

    #[test]
    fn dequant_in_range() {
        property(1, 25, |rng, _| {
            let (d_in, d_out, gs) = (32, 8, 16);
            let w = rand_weight(rng, d_in, d_out);
            let p = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
            let q = quantize(&w, &p);
            let deq = dequantize(&q, &p);
            for (wi, di) in w.iter().zip(&deq) {
                // error bounded by one bin (plus clipping slack at edges)
                assert!((wi - di).abs() <= p.scale.iter().cloned()
                        .fold(0f32, f32::max) * 1.01 + 1e-6);
            }
        });
    }

    #[test]
    fn residual_error_halves_per_slice() {
        // Each extra 2-bit slice must shrink max error by ~4x (Eq. 21).
        property(2, 10, |rng, _| {
            let (d_in, d_out, gs) = (64, 16, 32);
            let w = rand_weight(rng, d_in, d_out);
            let p = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
            let codes = decompose(&w, &p, 4);
            let mut prev = f64::INFINITY;
            for k in 1..=4 {
                let rec = reconstruct(&codes, &p, k);
                let maxerr = w.iter().zip(&rec)
                    .map(|(a, b)| (a - b).abs() as f64)
                    .fold(0.0, f64::max);
                assert!(maxerr < prev * 0.51,
                        "k={} err {} prev {}", k, maxerr, prev);
                prev = maxerr;
            }
        });
    }

    #[test]
    fn residual_slice_never_clips() {
        // After a centred b-bit bin, the residual fits exactly in the next
        // slice's range (App. B coverage argument).
        property(3, 10, |rng, _| {
            let (d_in, d_out, gs) = (32, 8, 16);
            let w = rand_weight(rng, d_in, d_out);
            let p = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
            let p1 = p.residual(1);
            let q0 = quantize(&w, &p);
            let deq0 = dequantize(&q0, &p);
            let r: Vec<f32> = w.iter().zip(&deq0).map(|(a, b)| a - b)
                .collect();
            // ignore rows that were clipped by slice 0 (outside range)
            for g in 0..p.n_groups {
                for j in 0..gs {
                    let row = g * gs + j;
                    for o in 0..d_out {
                        let q = q0[row * d_out + o];
                        if q == 0 || q == 3 {
                            continue; // may be a clipped extreme
                        }
                        let (s1, z1) = p1.at(g, o);
                        let v = (r[row * d_out + o] / s1 + z1).floor();
                        assert!((0.0..4.0).contains(&v),
                                "residual code {} out of range", v);
                    }
                }
            }
        });
    }

    #[test]
    fn reconstruct_full_equals_sum() {
        let mut rng = Pcg::new(9);
        let w = rand_weight(&mut rng, 32, 4);
        let p = GroupParams::from_minmax(&w, 32, 4, 2, 16);
        let codes = decompose(&w, &p, 4);
        let r4 = reconstruct(&codes, &p, 4);
        let mut acc = vec![0f32; w.len()];
        for e in 0..4 {
            let deq = dequantize(&codes[e], &p.residual(e));
            for (a, d) in acc.iter_mut().zip(&deq) {
                *a += d;
            }
        }
        assert_eq!(r4, acc);
    }
}
