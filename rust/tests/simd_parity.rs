//! SIMD dispatch parity (ISSUE 9 acceptance).
//!
//! The bar: `MOBIQ_SIMD=off` runs the byte-identical pre-SIMD scalar
//! loops, `auto` runs the detected wide kernels, and every existing
//! parity suite must hold under *both* — the tiled-vs-oracle attention
//! bound, the quantized KV oracle bounds, and shard bit-identity at
//! N = 2.  On top of that, three exactness pins:
//!
//! * the dispatching i8/u4 dot wrappers are **bit-identical** to the
//!   lane-blocked scalar reference at the active lane count (integer
//!   codes convert exactly to f32; separate mul + add per lane; fixed
//!   reduction tree — see `util/simd.rs`);
//! * the LUT plane-word gather replicates the scalar walk's pairwise
//!   sum trees, so `gemv_lut` is bit-identical **across** modes;
//! * the per-element families (axpy, residual add, scale, SwiGLU) are
//!   bit-identical across modes — only reductions (`Σx²` in rmsnorm)
//!   may reassociate, and then only within 1e-5 relative error.
//!
//! Every test here flips the process-wide dispatch mode, so the whole
//! binary serialises on one lock — these tests must NOT move into the
//! lib crate, where they would race the in-crate numeric parity tests.

use std::sync::{Mutex, MutexGuard};

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::mobiq::bitplane::PackedSlice;
use mobiquant::mobiq::engine::Precision;
use mobiquant::mobiq::gemv::{gemv_lut, TokenLut};
use mobiquant::mobiq::quantizer::{decompose, GroupParams};
use mobiquant::model::attention::{append_kv_block, attention_block,
                                  attention_step, AttnScratch,
                                  RopeCache};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::transformer::{rmsnorm, silu};
use mobiquant::model::weights::ModelConfig;
use mobiquant::model::{KvArena, KvPrecision, ShardRuntime, KV_PAGE};
use mobiquant::util::prng::Pcg;
use mobiquant::util::simd::{self, SimdMode};

const TOL: f32 = 1e-4;

/// Process-wide dispatch mode is global state; serialise every test.
static MODE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // a panicked test poisons the lock but leaves the () intact
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with the dispatch mode forced, restoring env/default
/// resolution afterwards.
fn with_mode<T>(mode: SimdMode, f: impl FnOnce() -> T) -> T {
    simd::set_mode(mode);
    let out = f();
    simd::clear_mode();
    out
}

const MODES: [SimdMode; 2] = [SimdMode::Off, SimdMode::Auto];

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            max_seq: usize) -> ModelConfig {
    ModelConfig {
        name: "simd".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: max_seq,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

fn quant_inputs(rng: &mut Pcg, n: usize) -> (Vec<f32>, Vec<i8>, Vec<u8>) {
    let q = rng.normal_vec(n, 1.0);
    let k: Vec<i8> = (0..n)
        .map(|_| (rng.next_u32() & 0xFF) as u8 as i8)
        .collect();
    let packed: Vec<u8> = (0..n.div_ceil(2))
        .map(|_| (rng.next_u32() & 0xFF) as u8)
        .collect();
    (q, k, packed)
}

/// The tentpole exactness pin: under each mode, the dispatching dot /
/// Σx² wrappers equal the lane-blocked scalar reference at the active
/// lane count, bit for bit (i32-style exact code conversion + fixed
/// reduction order — the "vectorized == restructured scalar" claim).
#[test]
fn dot_wrappers_match_blocked_reference_bitwise() {
    let _g = lock();
    let mut rng = Pcg::new(9001);
    for &n in &[1usize, 4, 7, 8, 15, 16, 64, 65, 127, 256] {
        let (q, k, packed) = quant_inputs(&mut rng, n);
        for mode in MODES {
            with_mode(mode, || {
                let lanes = simd::level().lanes();
                assert_eq!(simd::dot_f32_i8(&q, &k),
                           simd::dot_f32_i8_blocked(&q, &k, lanes),
                           "i8 dot n={n} {mode:?} lanes={lanes}");
                assert_eq!(simd::dot_f32_u4(&q, &packed),
                           simd::dot_f32_u4_blocked(&q, &packed, lanes),
                           "u4 dot n={n} {mode:?} lanes={lanes}");
                assert_eq!(simd::sum_squares(&q),
                           simd::sum_squares_blocked(&q, lanes),
                           "sum_squares n={n} {mode:?} lanes={lanes}");
            });
        }
    }
}

/// Per-element kernel families carry no reduction, so off and auto
/// must agree bit for bit: V-side axpys, residual adds, the
/// online-softmax correction scale, and the SwiGLU combine.
#[test]
fn elementwise_rows_bit_identical_across_modes() {
    let _g = lock();
    let mut rng = Pcg::new(9002);
    for &n in &[1usize, 7, 8, 65, 256] {
        let (q, k, packed) = quant_inputs(&mut rng, n);
        let gate = rng.normal_vec(n, 2.0);
        let base = rng.normal_vec(n, 1.0);
        let per_mode: Vec<_> = MODES.iter().map(|&mode| {
            with_mode(mode, || {
                let mut axi = base.clone();
                simd::axpy_f32_i8(&mut axi, 0.37, &k);
                let mut axu = base.clone();
                simd::axpy_f32_u4(&mut axu, -1.21, &packed);
                let mut add = base.clone();
                simd::add_assign(&mut add, &q);
                let mut sc = base.clone();
                simd::scale_in_place(&mut sc, 0.731);
                let mut sw = vec![0f32; n];
                simd::swiglu_row(&gate, &q, &mut sw);
                (axi, axu, add, sc, sw)
            })
        }).collect();
        assert_eq!(per_mode[0], per_mode[1],
                   "n={n}: an elementwise family diverged across modes");
    }
}

/// Pins `util::simd`'s private `silu` duplicate to
/// `model::transformer::silu` (the util layer keeps no model-layer
/// dependency, so the function body is duplicated).
#[test]
fn swiglu_equals_scalar() {
    let _g = lock();
    let mut rng = Pcg::new(9003);
    let gate = rng.normal_vec(129, 2.0);
    let up = rng.normal_vec(129, 1.0);
    let want: Vec<f32> = gate.iter().zip(&up)
        .map(|(g, u)| silu(*g) * u)
        .collect();
    for mode in MODES {
        with_mode(mode, || {
            let mut got = vec![0f32; gate.len()];
            simd::swiglu_row(&gate, &up, &mut got);
            assert_eq!(got, want, "{mode:?}: swiglu != silu(g)*u");
        });
    }
}

/// RMSNorm: off mode must be byte-identical to the pre-SIMD sequential
/// loop; auto may reassociate Σx² (blocked lanes) but stays within
/// 1e-5 relative error of it.
#[test]
fn rmsnorm_off_exact_auto_within_1e5() {
    let _g = lock();
    let mut rng = Pcg::new(9004);
    for &n in &[8usize, 64, 160, 1024] {
        let x = rng.normal_vec(n, 1.0);
        let w = rng.normal_vec(n, 0.5);
        let eps = 1e-5f32;
        // the pre-SIMD scalar loop, verbatim
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / n as f32;
        let r = 1.0 / (ms + eps).sqrt();
        let want: Vec<f32> = x.iter().zip(&w)
            .map(|(xi, wi)| xi * r * wi)
            .collect();

        let mut off = vec![0f32; n];
        with_mode(SimdMode::Off, || rmsnorm(&x, &w, eps, &mut off));
        assert_eq!(off, want, "n={n}: off-mode rmsnorm not pre-SIMD");

        let mut auto = vec![0f32; n];
        with_mode(SimdMode::Auto, || rmsnorm(&x, &w, eps, &mut auto));
        for (i, (a, b)) in auto.iter().zip(&want).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-6);
            assert!(rel <= 1e-5,
                    "n={n} elem {i}: auto rmsnorm rel err {rel}");
        }
    }
}

/// Family 2: the AVX2 LUT gather replicates the scalar walk's pairwise
/// sum trees, so whole `gemv_lut` outputs are bit-identical across
/// modes — on both the byte-table path (small d_in) and the
/// nibble-table path (d_in past the nibble threshold).
#[test]
fn lut_gemv_bit_identical_across_modes() {
    let _g = lock();
    let mut rng = Pcg::new(77);
    for &(d_in, d_out) in &[(512usize, 96usize), (2048, 64)] {
        let gs = 32;
        let w = rng.normal_vec(d_in * d_out, 0.1);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = decompose(&w, &base, 4);
        let slices: Vec<PackedSlice> = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        let x = rng.normal_vec(d_in, 1.0);
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let active = [true, true, false, true];

        let mut off = vec![0f32; d_out];
        with_mode(SimdMode::Off,
                  || gemv_lut(&slices, &base, &lut, &active, &mut off));
        let mut auto = vec![0f32; d_out];
        with_mode(SimdMode::Auto,
                  || gemv_lut(&slices, &base, &lut, &active, &mut auto));
        assert_eq!(off, auto,
                   "{d_in}x{d_out}: gathered LUT walk diverged from \
                    the scalar word walk");
    }
}

fn filled_cache(rng: &mut Pcg, n_kv: usize, hd: usize,
                positions: usize) -> KvCache {
    let mut cache = KvCache::new(positions, n_kv, hd);
    let w = n_kv * hd;
    for _ in 0..positions {
        let k = rng.normal_vec(w, 1.0);
        let v = rng.normal_vec(w, 1.0);
        cache.push(&k, &v);
    }
    cache
}

/// attention_parity's bar, per mode: the tiled online-softmax kernel
/// tracks the two-pass scalar oracle within 1e-4 whether the dots are
/// scalar or wide (both kernel and oracle dispatch together).
#[test]
fn attention_tiled_matches_oracle_under_both_modes() {
    let _g = lock();
    let (n_heads, n_kv, hd, max_seq) = (4usize, 2usize, 16usize, 256);
    let cfg = attn_cfg(n_heads, n_kv, hd, max_seq);
    let d = cfg.d_model;
    for mode in MODES {
        with_mode(mode, || {
            let mut rng = Pcg::new(4200);
            let cache = filled_cache(&mut rng, n_kv, hd, max_seq);
            for &(pos0, t) in &[(0usize, 33usize), (100, 57), (255, 1)] {
                let q = rng.normal_vec(t * d, 1.0);
                let mut scores = vec![0f32; max_seq];
                let mut want = vec![0f32; t * d];
                for i in 0..t {
                    attention_step(&q[i * d..(i + 1) * d], &cache, &cfg,
                                   pos0 + i, &mut scores,
                                   &mut want[i * d..(i + 1) * d]);
                }
                let mut got = vec![0f32; t * d];
                let mut sc = AttnScratch::new();
                attention_block(&cfg, &q, &cache, pos0, t, &mut sc,
                                None, &mut got);
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < TOL,
                            "{mode:?} pos0={pos0} t={t} ctx[{i}]: \
                             tiled {a} vs oracle {b}");
                }
            }
        });
    }
}

/// Identical K/V stream into a slab and a paged arena sequence at
/// `kvp` (uneven chunks crossing page seams) — kv_arena's fixture.
fn paired_fill(cfg: &ModelConfig, t: usize, seed: u64,
               kvp: KvPrecision) -> (KvCache, KvArena,
                                     mobiquant::model::KvHandle) {
    let hd = cfg.head_dim();
    let n_kv = cfg.n_kv_heads;
    let w = n_kv * hd;
    let mut rng = Pcg::new(seed);
    let k_block = rng.normal_vec(t * w, 1.0);
    let v_block = rng.normal_vec(t * w, 1.0);
    let mut rope = RopeCache::new(hd, cfg.rope_theta);
    rope.ensure(t);

    let mut slab = KvCache::new(cfg.max_seq_len, n_kv, hd);
    let mut arena = KvArena::new(1, cfg.max_seq_len, n_kv, hd, 8);
    let seq = arena.alloc_seq_at(kvp);
    let mut fed = 0usize;
    for chunk in [50usize, 31, 64, 64] {
        let n = chunk.min(t - fed);
        if n == 0 {
            break;
        }
        let lo = fed * w;
        append_kv_block(&mut slab, &rope, &k_block[lo..(fed + n) * w],
                        &v_block[lo..(fed + n) * w], n);
        arena.append_kv_block(seq, 0, &rope,
                              &k_block[lo..(fed + n) * w],
                              &v_block[lo..(fed + n) * w], n)
            .unwrap();
        fed += n;
    }
    assert_eq!(fed, t);
    (slab, arena, seq)
}

fn rel_err(got: &[f32], want: &[f32]) -> f32 {
    let mut max_err = 0f32;
    let mut max_abs = 0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
        max_abs = max_abs.max(b.abs());
    }
    max_err / max_abs.max(1e-6)
}

/// kv_arena's quantized bar, per mode: i8 paged attention within 1e-2
/// of the f32 slab oracle, u4 within 0.3, across a page-seam sweep —
/// the wide fused-dequant dots must not widen either bound.
#[test]
fn quantized_attention_bounds_hold_under_both_modes() {
    let _g = lock();
    let cfg = attn_cfg(4, 2, 16, 3 * KV_PAGE);
    let d = cfg.d_model;
    for mode in MODES {
        with_mode(mode, || {
            for &t in &[65usize, 129] {
                let mut rng = Pcg::new(700 + t as u64);
                let q = rng.normal_vec(t * d, 1.0);
                let (slab, _, _) = paired_fill(&cfg, t, 600 + t as u64,
                                               KvPrecision::F32);
                let mut sc = AttnScratch::new();
                let mut want = vec![0f32; t * d];
                attention_block(&cfg, &q, &slab, 0, t, &mut sc, None,
                                &mut want);
                for &(kvp, tol) in &[(KvPrecision::Int8, 1e-2f32),
                                     (KvPrecision::Int4, 0.3)] {
                    let (_, arena, seq) =
                        paired_fill(&cfg, t, 600 + t as u64, kvp);
                    let view = arena.layer(seq, 0);
                    let mut got = vec![0f32; t * d];
                    attention_block(&cfg, &q, &view, 0, t, &mut sc,
                                    None, &mut got);
                    let e = rel_err(&got, &want);
                    assert!(e <= tol,
                            "{mode:?} {} T={t}: rel err {e} > {tol}",
                            kvp.label());
                }
            }
        });
    }
}

/// shard_parity's bar at N = 2, per mode: sharded execution stays a
/// partition (bit-identical logits), whichever kernels are dispatched
/// — lanes read the same process-wide mode as the unsharded run.
#[test]
fn shard_n2_bit_identical_under_both_modes() {
    let _g = lock();
    let model = synth_model_shaped(131, 4, 2, 160);
    let tokens: Vec<u32> = (0..100)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();
    for mode in MODES {
        with_mode(mode, || {
            for prec in [Precision::Fixed(2), Precision::elastic(4.0)] {
                let want = model.forward_logits(&tokens, prec).unwrap();
                let mut rt = ShardRuntime::new(&model, 2).unwrap();
                let got = rt.forward_logits(&model, &tokens, prec)
                    .unwrap();
                assert_eq!(got, want,
                           "{mode:?} {prec:?}: sharded forward \
                            diverged from unsharded");
            }
        });
    }
}
