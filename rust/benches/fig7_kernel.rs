//! Fig. 7 — kernel evaluation: (left) end-to-end decode latency vs
//! decode length against the FP comparator and an ABQ-LLM-style static
//! low-bit kernel; (middle) latency breakdown (router / LUT-pack /
//! bit-plane GEMV); (right) memory savings vs multi-precision deployment
//! (the §5.2 "3.5x" claim).

use mobiquant::bench_support as bs;
use mobiquant::mobiq::engine::{Precision, Scratch};
use mobiquant::mobiq::footprint::{FootprintInputs, LinearDims};
use mobiquant::model::weights::{BackendKind, ModelConfig, LINEAR_NAMES};
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::Model;
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;

fn main() {
    let mut suite = Suite::new("fig7_kernel");
    suite.header();
    let Some(bundle) = bs::try_bundle("tiny-m")
        .or_else(|| bs::try_bundle("tiny-s")) else {
        suite.note("no bundle");
        suite.finish();
        return;
    };
    let cfg = ModelConfig::from_bundle(&bundle).unwrap();

    // ---------------- left: decode latency vs length ------------------
    let fp = Model::load(&bundle, BackendKind::Fp32).unwrap();
    let abq = Model::load(&bundle, BackendKind::MobiqDenseK(2)).unwrap();
    let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
    for len in [64usize, 128, 192] {
        let mut cells = Vec::new();
        for (name, model, prec) in [
            ("FP32", &fp, Precision::Fixed(4)),
            ("ABQ4b_dense", &abq, Precision::Fixed(2)),
            ("MoBiQ@4b", &mobiq, Precision::elastic(4.0)),
            ("MoBiQ@2.5b", &mobiq, Precision::elastic(2.5)),
        ] {
            let (mut arena, seq) = model.new_kv();
            let mut scratch = model.new_scratch();
            let mut stats = DecodeStats::new(model.cfg.n_layers);
            let t0 = std::time::Instant::now();
            for &t in &[65u32, 32, 110, 101][..] {
                let _ = t;
            }
            arena.reset_seq(seq);
            for i in 0..len {
                let tok = (65 + (i % 26)) as u32;
                model.decode_step(tok, &mut arena, seq, prec,
                                  &mut scratch, &mut stats).unwrap();
            }
            let ms = t0.elapsed().as_secs_f64() * 1000.0;
            cells.push((name.to_string(), ms));
        }
        let named: Vec<(&str, f64)> = cells.iter()
            .map(|(k, v)| (k.as_str(), *v)).collect();
        suite.row(&format!("decode {len} tokens, total ms"), &named);
    }

    // ---------------- middle: latency breakdown -----------------------
    // measured on the Mobiq linears directly: router score, LUT build
    // ("packing"), bit-plane GEMV.
    let mut rng = Pcg::new(3);
    for target in [4.0f64, 8.0] {
        let mut router_ns = 0f64;
        let mut pack_ns = 0f64;
        let mut gemv_ns = 0f64;
        for li in 0..cfg.n_layers {
            for name in LINEAR_NAMES {
                let lin = match mobiq.layers[li].linear(name) {
                    Ok(mobiquant::model::LinearBackend::Mobiq(m)) => m,
                    _ => continue,
                };
                let x = rng.normal_vec(lin.d_in, 1.0);
                let mut scratch = Scratch::new(
                    lin.d_in, lin.base.group_size, lin.router.hidden,
                    cfg.n_slices);
                let mut out = vec![0f32; lin.d_out];
                let reps = 40;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    black_box(lin.route(&x, Precision::elastic(target),
                                        &mut scratch));
                }
                router_ns += t0.elapsed().as_nanos() as f64 / reps as f64;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    scratch.lut.build(&x, lin.base.group_size);
                }
                pack_ns += t0.elapsed().as_nanos() as f64 / reps as f64;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    mobiquant::mobiq::gemv::gemv_lut(
                        &lin.slices, &lin.base, &scratch.lut,
                        &scratch.mask, &mut out);
                }
                gemv_ns += t0.elapsed().as_nanos() as f64 / reps as f64;
            }
        }
        let total = router_ns + pack_ns + gemv_ns;
        suite.row(&format!("breakdown @target {target}b (frac)"), &[
            ("router", router_ns / total),
            ("pack_lut", pack_ns / total),
            ("gemv", gemv_ns / total),
            ("total_us_per_tok", total / 1000.0),
        ]);
    }

    // ---------------- right: memory savings ---------------------------
    let mut linears = Vec::new();
    for _ in 0..cfg.n_layers {
        for name in LINEAR_NAMES {
            let (d_in, d_out) = cfg.linear_dims(name).unwrap();
            linears.push(LinearDims { d_in, d_out });
        }
    }
    let fi = FootprintInputs {
        linears,
        group_size: cfg.group_size,
        n_slices: cfg.n_slices,
        slice_bits: cfg.slice_bits,
        router_hidden: cfg.router_hidden,
        fp_other_bytes: (2 * cfg.vocab_size * cfg.d_model
            + (2 * cfg.n_layers + 1) * cfg.d_model) * 4,
    };
    let served = [2usize, 4, 6, 8];
    suite.row("memory bytes", &[
        ("fp16", fi.fp16_bytes() as f64),
        ("multi_static", fi.multi_static_bytes(&served) as f64),
        ("anybcq", fi.anybcq_bytes(&served) as f64),
        ("mobiq", fi.mobiq_bytes() as f64),
    ]);
    suite.row("memory savings", &[
        ("vs_multi_static", fi.savings_vs_multi(&served)),
        ("router_frac",
         fi.router_bytes() as f64 / fi.mobiq_bytes() as f64),
    ]);
    // paper-scale (LLaMA-2-7B dims) footprint for the headline number
    let d = 4096;
    let f = 11008;
    let per: Vec<LinearDims> = vec![
        LinearDims { d_in: d, d_out: d }, LinearDims { d_in: d, d_out: d },
        LinearDims { d_in: d, d_out: d }, LinearDims { d_in: d, d_out: d },
        LinearDims { d_in: d, d_out: f }, LinearDims { d_in: d, d_out: f },
        LinearDims { d_in: f, d_out: d },
    ];
    let fi7b = FootprintInputs {
        linears: (0..32).flat_map(|_| per.clone()).collect(),
        group_size: 128,
        n_slices: 4,
        slice_bits: 2,
        router_hidden: 16,
        fp_other_bytes: 32000 * d * 4 * 2,
    };
    suite.row("7B-scale savings", &[
        ("vs_multi_static", fi7b.savings_vs_multi(&served)),
    ]);
    suite.note("paper shape: low-bit decode beats FP, routing+packing \
                overhead small and shrinking with precision, ~3x memory \
                saving vs multi-precision deployment");
    suite.finish();
}
