"""Pure-jnp oracle for the MoBiSlice token-adaptive bit-sliced matmul.

This is the CORE correctness signal for the L1 Pallas kernel: pytest sweeps
shapes/dtypes/masks (python/tests/test_kernel.py) and asserts allclose
between ``mobislice_matmul`` (Pallas, interpret mode) and ``ref_matmul``.

Semantics (paper Eq. 3 + Eq. 6): with E bit slices of ``slice_bits`` each,
per-token slice mask m (m[:, 0] == 1, the shared expert):

    y[t] = sum_e m[t, e] * (x[t] @ deq_e(codes_e))
    deq_e = s_e * (q_e - z_e + 0.5),  s_e = s_1 / 2^{b*e},  z_e = 2^{b-1}
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def slice_scale_zero(base_scale: jnp.ndarray, base_zero: jnp.ndarray,
                     e: int, slice_bits: int):
    """Derived (scale, zero) of slice index e (0-based; e=0 is the base)."""
    if e == 0:
        return base_scale, base_zero
    s = base_scale / float(2 ** (slice_bits * e))
    z = jnp.full_like(base_zero, float(2 ** (slice_bits - 1)))
    return s, z


def dequant_slice(codes_e: jnp.ndarray, base_scale: jnp.ndarray,
                  base_zero: jnp.ndarray, e: int, slice_bits: int,
                  group_size: int) -> jnp.ndarray:
    """codes_e: (d_in, d_out) ints -> dequantized f32 weights."""
    d_in, d_out = codes_e.shape
    s, z = slice_scale_zero(base_scale, base_zero, e, slice_bits)
    q = codes_e.astype(jnp.float32).reshape(-1, group_size, d_out)
    deq = s[:, None, :] * (q - z[:, None, :] + 0.5)
    return deq.reshape(d_in, d_out)


def ref_matmul(x: jnp.ndarray, codes: jnp.ndarray, base_scale: jnp.ndarray,
               base_zero: jnp.ndarray, mask: jnp.ndarray, slice_bits: int,
               group_size: int) -> jnp.ndarray:
    """Oracle for the kernel.

    x: (T, d_in) f32; codes: (E, d_in, d_out) int32;
    base_scale/zero: (n_groups, d_out) f32; mask: (T, E) f32 (mask[:,0]=1).
    """
    n_slices = codes.shape[0]
    y = jnp.zeros((x.shape[0], codes.shape[2]), jnp.float32)
    for e in range(n_slices):
        w = dequant_slice(codes[e], base_scale, base_zero, e, slice_bits,
                          group_size)
        y = y + (x * mask[:, e:e + 1]) @ w
    return y


def pack_words(codes: np.ndarray, slice_bits: int) -> np.ndarray:
    """Pack codes (E, d_in, d_out) into int32 bit-plane words for the
    Pallas kernel: (E, slice_bits, d_in // 32, d_out), bit j of word w of
    plane p = bit p of codes[e, w*32 + j, o].

    This is the TPU-facing layout (32-lane int words feeding the VPU
    unpack); the Rust engine uses the 64-bit analogue from
    quant/mobislice.pack_bitplanes.
    """
    codes = np.asarray(codes)
    n_slices, d_in, d_out = codes.shape
    assert d_in % 32 == 0, "d_in must be a multiple of 32 for int32 packing"
    planes = np.zeros((n_slices, slice_bits, d_in // 32, d_out),
                      dtype=np.int64)
    for e in range(n_slices):
        for p in range(slice_bits):
            bits = (codes[e] >> p) & 1                 # (d_in, d_out)
            chunks = bits.reshape(d_in // 32, 32, d_out).astype(np.int64)
            shifts = np.arange(32, dtype=np.int64)[None, :, None]
            planes[e, p] = np.sum(chunks << shifts, axis=1)
    # store as int32 bit pattern (word with bit 31 set becomes negative)
    return (planes & 0xFFFFFFFF).astype(np.uint32).view(np.int32).reshape(
        n_slices, slice_bits, d_in // 32, d_out)


def unpack_words(planes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_words in jnp (used inside the kernel and in tests):
    (E, B, d_in//32, d_out) int32 -> (E, d_in, d_out) int32 codes."""
    n_slices, slice_bits, n_words, d_out = planes.shape
    u = planes.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # (E, B, n_words, 32, d_out) bit extraction
    bits = (u[:, :, :, None, :] >> shifts[None, None, None, :, None]
            ) & jnp.uint32(1)
    codes = jnp.zeros((n_slices, n_words * 32, d_out), jnp.uint32)
    for p in range(slice_bits):
        codes = codes | (bits[:, p].reshape(n_slices, n_words * 32, d_out)
                         << jnp.uint32(p))
    return codes.astype(jnp.int32)
