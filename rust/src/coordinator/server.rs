//! Server facade: owns the model and runs the scheduler on a dedicated
//! thread; clients submit prompts and receive responses over channels.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use super::batcher::Batcher;
use super::controller::{ControllerConfig, ElasticController};
use super::metrics::Metrics;
use super::pressure::PressureConfig;
use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;
use crate::model::kvcache::KvPrecision;
use crate::model::{Model, SpecConfig};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_active: usize,
    pub max_queue: usize,
    /// Prompt tokens prefetched per tick per sequence (one batched
    /// kernel call per chunk).
    pub prefill_chunk: usize,
    /// Cap on sequences fused into one coalesced decode call.
    pub max_decode_batch: usize,
    /// KV arena budget in f32-page equivalents.  `None` = worst case
    /// for `max_active` full-context sequences (no page pressure);
    /// `Some(p)` commits less memory and queues requests when bytes
    /// run short.  Quantized pages draw proportionally less of the
    /// budget, so an i8 deployment admits ~4x the sequences under the
    /// same number.
    pub kv_page_budget: Option<usize>,
    /// Default storage precision of admitted sequences' KV pages
    /// (requests submitted via [`Server::submit_at`] override it).
    pub kv_precision: KvPrecision,
    /// Host swap tier budget in **bytes** (`--host-swap`); `None`
    /// disables the tier.  When set, the pressure ladder's
    /// High/Critical rungs move cold KV pages to host memory by exact
    /// byte copy and preemption parks KV there instead of discarding
    /// it — resume restores by memcpy and re-feeds only the unparked
    /// suffix.
    pub host_swap_bytes: Option<usize>,
    pub controller: ControllerConfig,
    /// Occupancy bands of the memory-pressure degradation ladder
    /// (admission floors, in-place tail requant, preemption).
    pub pressure: PressureConfig,
    /// External resource pressure in [0, 1] sampled each tick via the
    /// shared cell (set by the embedder, e.g. from a workload trace).
    pub initial_pressure: f64,
    /// Self-speculative decoding for the coalesced decode tick: `Some`
    /// drafts every decode group with a low-bit slice mask and verifies
    /// in one batched full-precision step (greedy outputs stay
    /// bit-identical to plain decode); `None` (the default) keeps the
    /// one-token-per-tick decode.
    pub speculative: Option<SpecConfig>,
    /// Tensor-parallel worker shards (`--shards N`).  1 (the default)
    /// serves on the pre-PR single-arena path; N > 1 partitions
    /// attention heads / FFN channels / KV pages across N in-process
    /// shards behind the `Communicator` abstraction.  Must satisfy
    /// `1 <= shards <= n_kv_heads`.  Greedy outputs are bit-identical
    /// for every shard count.
    pub shards: usize,
    /// Runtime override for the LUT-GEMM fan-out threshold
    /// (`MOBIQ_PARALLEL_MIN_DOUT`); `None` keeps the env var or the
    /// compiled-in default.  Moves dispatch only, never arithmetic.
    pub parallel_min_dout: Option<usize>,
    /// Runtime override for the attention fan-out threshold
    /// (`MOBIQ_ATTN_PARALLEL_MIN_WORK`).
    pub attn_parallel_min_work: Option<usize>,
    /// Runtime override for the elementwise row fan-out threshold
    /// (`MOBIQ_ELEMENTWISE_PARALLEL_MIN`).
    pub elementwise_parallel_min: Option<usize>,
    /// Runtime override for the SIMD kernel dispatch (`MOBIQ_SIMD`):
    /// `Some(false)` forces the byte-identical pre-SIMD scalar loops,
    /// `Some(true)` forces auto-detected wide kernels, `None` keeps
    /// the env var or the compiled-in default (auto).
    pub simd: Option<bool>,
}

/// Apply the config's parallel-gate overrides to the process-wide
/// tunables; `None` fields leave the gate on its env/default
/// resolution.  Called once at server start, before the scheduler
/// touches any kernel.
pub fn apply_gate_overrides(cfg: &ServerConfig) {
    if let Some(v) = cfg.parallel_min_dout {
        crate::mobiq::gemv::PARALLEL_MIN_DOUT_GATE.set(v);
    }
    if let Some(v) = cfg.attn_parallel_min_work {
        crate::model::attention::ATTN_PARALLEL_MIN_WORK_GATE.set(v);
    }
    if let Some(v) = cfg.elementwise_parallel_min {
        crate::model::transformer::ELEMENTWISE_PARALLEL_MIN_GATE.set(v);
    }
    if let Some(on) = cfg.simd {
        crate::util::simd::set_enabled(on);
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_active: 4,
            max_queue: 64,
            prefill_chunk: 16,
            max_decode_batch: 32,
            kv_page_budget: None,
            kv_precision: KvPrecision::F32,
            host_swap_bytes: None,
            controller: ControllerConfig::default(),
            pressure: PressureConfig::default(),
            initial_pressure: 0.0,
            speculative: None,
            shards: 1,
            parallel_min_dout: None,
            attn_parallel_min_work: None,
            elementwise_parallel_min: None,
            simd: None,
        }
    }
}

enum Msg {
    Req(Request),
    SetPressure(f64),
    Shutdown(mpsc::Sender<Metrics>),
}

pub struct Server {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
    handle: Option<thread::JoinHandle<()>>,
    kv_precision: KvPrecision,
}

impl Server {
    /// Takes ownership of the model; the scheduler thread drives it.
    pub fn start(model: Model, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let kv_precision = cfg.kv_precision;
        let handle = thread::Builder::new()
            .name("mobiq-scheduler".into())
            .spawn(move || Self::run(model, cfg, rx))
            .expect("spawn scheduler");
        Server {
            tx,
            next_id: Arc::new(AtomicU64::new(0)),
            handle: Some(handle),
            kv_precision,
        }
    }

    fn run(model: Model, cfg: ServerConfig, rx: mpsc::Receiver<Msg>) {
        let mut batcher = Batcher::new(cfg.max_active, cfg.max_queue)
            .with_chunking(cfg.prefill_chunk, cfg.max_decode_batch);
        if let Some(pages) = cfg.kv_page_budget {
            batcher = batcher.with_kv_budget(pages);
        }
        if let Some(spec) = cfg.speculative.clone() {
            batcher = batcher.with_speculative(spec);
        }
        if let Some(bytes) = cfg.host_swap_bytes {
            batcher = batcher.with_host_swap(bytes);
        }
        apply_gate_overrides(&cfg);
        let controller = ElasticController::new(cfg.controller.clone());
        let mut sched = Scheduler::new(&model, batcher, controller)
            .with_pressure(cfg.pressure.clone());
        if cfg.shards > 1 {
            sched = match sched.with_shards(cfg.shards) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("server: cannot shard model: {e:#}");
                    return;
                }
            };
        }
        let mut pressure = cfg.initial_pressure;
        loop {
            // drain control/requests without blocking while busy
            loop {
                let msg = if sched.idle() {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                };
                match msg {
                    Msg::Req(r) => sched.submit(r),
                    Msg::SetPressure(p) => pressure = p,
                    Msg::Shutdown(reply) => {
                        let _ = reply.send(sched.metrics.clone());
                        return;
                    }
                }
            }
            if let Err(e) = sched.tick(pressure) {
                eprintln!("scheduler error: {e:#}");
                return;
            }
        }
    }

    /// Submit a prompt at the server's default KV storage precision;
    /// returns (id, receiver for the response).
    pub fn submit(&self, prompt: Vec<u32>, max_new_tokens: usize)
                  -> (RequestId, mpsc::Receiver<Response>) {
        self.submit_at(prompt, max_new_tokens, self.kv_precision)
    }

    /// Submit a prompt with an explicit per-request KV storage
    /// precision (the elastic analogue for the cache: a latency-
    /// tolerant request can run its KV at i8/i4 and draw a fraction of
    /// the arena budget).
    pub fn submit_at(&self, prompt: Vec<u32>, max_new_tokens: usize,
                     kv_precision: KvPrecision)
                     -> (RequestId, mpsc::Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Req(Request {
            id,
            prompt,
            max_new_tokens,
            kv_precision,
            submitted: Instant::now(),
            reply: tx,
        }));
        (id, rx)
    }

    /// Update the external resource-pressure signal (0 = calm, 1 = starved).
    pub fn set_pressure(&self, p: f64) {
        let _ = self.tx.send(Msg::SetPressure(p));
    }

    /// Graceful shutdown; returns final metrics.
    pub fn shutdown(mut self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Shutdown(tx))
            .map_err(|_| anyhow::anyhow!("scheduler already gone"))?;
        let metrics = rx.recv()?;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        Ok(metrics)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let (tx, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(tx));
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::attention::ATTN_PARALLEL_MIN_WORK_GATE;
    use crate::model::transformer::ELEMENTWISE_PARALLEL_MIN_GATE;

    /// ServerConfig overrides reach the process-wide gates; `None`
    /// leaves them untouched.  (The PARALLEL_MIN_DOUT gate is owned by
    /// gemv's own dispatch test — mutating it here would race.  The
    /// `simd` override is likewise exercised only in the serialized
    /// `tests/simd_parity.rs` binary: flipping the process-wide SIMD
    /// mode here would race the in-crate numeric parity tests.)
    #[test]
    fn gate_overrides_apply() {
        let cfg = ServerConfig {
            attn_parallel_min_work: Some(123_456),
            elementwise_parallel_min: Some(654_321),
            ..ServerConfig::default()
        };
        apply_gate_overrides(&cfg);
        assert_eq!(ATTN_PARALLEL_MIN_WORK_GATE.get(), 123_456);
        assert_eq!(ELEMENTWISE_PARALLEL_MIN_GATE.get(), 654_321);
        // None fields must not clobber an existing setting
        let noop = ServerConfig::default();
        apply_gate_overrides(&noop);
        assert_eq!(ATTN_PARALLEL_MIN_WORK_GATE.get(), 123_456);
        ATTN_PARALLEL_MIN_WORK_GATE.clear();
        ELEMENTWISE_PARALLEL_MIN_GATE.clear();
    }

    #[test]
    fn default_config_is_unsharded() {
        let cfg = ServerConfig::default();
        assert_eq!(cfg.shards, 1);
        assert!(cfg.host_swap_bytes.is_none(),
                "host swap tier must be opt-in");
        assert!(cfg.parallel_min_dout.is_none());
        assert!(cfg.attn_parallel_min_work.is_none());
        assert!(cfg.elementwise_parallel_min.is_none());
        assert!(cfg.simd.is_none(),
                "default must defer to MOBIQ_SIMD / auto-detection");
    }
}
