//! Coordinator coalescing equivalence: N concurrent requests advanced
//! through the batched decode path (one `decode_batch` kernel call per
//! layer per tick) must produce exactly the tokens of N sequential
//! single-request runs.  Runs on the synthetic model, so no `make
//! artifacts` is needed.

use std::time::Duration;

use mobiquant::bench_support::synth_model;
use mobiquant::coordinator::controller::ControllerConfig;
use mobiquant::coordinator::{Server, ServerConfig};

const SEED: u64 = 11;
const N_REQ: usize = 4;
const N_NEW: usize = 8;

/// Pin the elastic controller to one precision so concurrent and
/// sequential runs route identically regardless of queue pressure.
fn fixed_bits_config(max_active: usize) -> ServerConfig {
    ServerConfig {
        max_active,
        controller: ControllerConfig {
            min_bits: 4.0,
            max_bits: 4.0,
            ..ControllerConfig::default()
        },
        ..ServerConfig::default()
    }
}

fn prompts() -> Vec<Vec<u32>> {
    (0..N_REQ)
        .map(|i| {
            format!("concurrent request {i} streaming tokens ")
                .bytes()
                .map(|b| b as u32)
                .collect()
        })
        .collect()
}

#[test]
fn concurrent_batched_decode_matches_sequential() {
    // concurrent: all requests in flight, decode steps coalesced into
    // one batched kernel call per layer
    let server = Server::start(synth_model(SEED),
                               fixed_bits_config(N_REQ));
    let rxs: Vec<_> = prompts().into_iter()
        .map(|p| server.submit(p, N_NEW))
        .collect();
    let mut concurrent = Vec::new();
    for (_, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120))
            .expect("concurrent response");
        assert_eq!(resp.metrics.generated_tokens, N_NEW);
        concurrent.push(resp.tokens);
    }
    server.shutdown().unwrap();

    // sequential: identical weights (same seed), one request at a time
    let server = Server::start(synth_model(SEED), fixed_bits_config(1));
    for (want, p) in concurrent.iter().zip(prompts()) {
        let (_, rx) = server.submit(p, N_NEW);
        let resp = rx.recv_timeout(Duration::from_secs(120))
            .expect("sequential response");
        assert_eq!(&resp.tokens, want,
                   "coalesced decode diverged from a sequential run");
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests_completed, N_REQ as u64);
}
