//! Runtime-dispatched SIMD kernels for the serving hot loops
//! (ISSUE 9 tentpole).
//!
//! Three kernel families live here, each with a sequential scalar
//! fallback that is the byte-identical pre-SIMD code path:
//!
//! 1. **Quantized attention dots** — `dot_f32_i8` / `dot_f32_u4` and
//!    the V-side `axpy_f32_i8` / `axpy_f32_u4`.  The wide variants
//!    follow the *lane-blocked fixed-reduction-order contract*: with
//!    `L = level().lanes()`, lane `j` accumulates elements
//!    `j, j+L, j+2L, …` with a separate multiply then add (never a
//!    fused multiply-add), lanes reduce in the fixed tree
//!    `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, and the `< L` tail is
//!    added sequentially after the reduction.  i8 and 4-bit codes
//!    convert exactly to f32 and per-lane IEEE mul/add are identical
//!    between scalar and vector units, so every SIMD dot is
//!    **bit-identical** to `dot_*_blocked(q, k, L)` — pinned by unit
//!    tests here and `tests/simd_parity.rs`.
//! 2. **LUT plane-word resolution** — `lut_bytes_pair` /
//!    `lut_nibbles_pair` gather the eight byte-table (or sixteen
//!    nibble-table) entries of one 64-bit plane word and reduce them
//!    in *exactly* the pairwise tree the scalar walk in
//!    `mobiq/gemv.rs` uses, so the gathered path is bit-identical to
//!    the scalar kernel (LUT entries are never `-0.0`: every table
//!    starts from `+0.0` and `+0.0 + x` only yields `-0.0` when both
//!    addends are `-0.0`).  AVX2-only (x86 gathers); other levels keep
//!    the scalar walk.
//! 3. **Elementwise rows** — `add_assign`, `swiglu_row`,
//!    `rmsnorm_row`, `scale_in_place`, `sum_squares`.  Per-element
//!    ops are order-independent, hence bit-identical to scalar at any
//!    width; only the `sum_squares` reduction inside `rmsnorm_row`
//!    uses the lane-blocked contract (so f32 norms *do* change
//!    bitwise between `off` and `on` — by design, each mode is
//!    self-consistent and the parity suites pin both arms).
//!
//! Dispatch resolution (highest priority first), mirroring
//! `TunableGate`: a programmatic override (`set_mode`, reachable via
//! `ServerConfig.simd` / `--simd`), then the `MOBIQ_SIMD` env var
//! (read once: `off|0|false|scalar`, `on|1|true|auto`, or a level cap
//! `sse41|avx2|neon`), then the default `auto`.  `auto` resolves to
//! the best level the CPU reports (`is_x86_feature_detected!` for
//! AVX2/SSE4.1; NEON is baseline on aarch64); `off` routes every
//! wrapper to the sequential scalar loop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Environment variable consulted (once) when no programmatic
/// override is set.
pub const ENV_VAR: &str = "MOBIQ_SIMD";

/// Widest lane count any level uses (AVX2: 8 f32 lanes).
pub const MAX_LANES: usize = 8;

/// Instruction-set level a kernel dispatches at.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdLevel {
    /// Sequential scalar loops — the byte-identical pre-SIMD paths.
    Scalar,
    /// x86-64 SSE4.1: 4 f32 lanes (`_mm_cvtepi8_epi32` widening).
    Sse41,
    /// x86-64 AVX2: 8 f32 lanes + `vgatherdps` LUT resolution.
    Avx2,
    /// aarch64 NEON: 4 f32 lanes (baseline feature, always present).
    Neon,
}

impl SimdLevel {
    /// f32 lanes per accumulator block at this level (the `L` of the
    /// fixed-reduction-order contract).
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Sse41 | SimdLevel::Neon => 4,
            SimdLevel::Avx2 => MAX_LANES,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse41 => "sse41",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

/// Requested dispatch mode (before capping by what the CPU has).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdMode {
    /// Force the sequential scalar kernels everywhere.
    Off,
    /// Use the best detected level (the default).
    Auto,
    /// Use at most this level (e.g. pin AVX2 hardware to SSE4.1 to
    /// compare lane widths); caps from the wrong architecture resolve
    /// to scalar.
    Cap(SimdLevel),
}

// Atomic encoding of the programmatic override; 0 = no override.
const MODE_UNSET: usize = 0;

fn encode(m: SimdMode) -> usize {
    match m {
        SimdMode::Off | SimdMode::Cap(SimdLevel::Scalar) => 1,
        SimdMode::Auto => 2,
        SimdMode::Cap(SimdLevel::Sse41) => 3,
        SimdMode::Cap(SimdLevel::Avx2) => 4,
        SimdMode::Cap(SimdLevel::Neon) => 5,
    }
}

fn decode(v: usize) -> Option<SimdMode> {
    match v {
        1 => Some(SimdMode::Off),
        2 => Some(SimdMode::Auto),
        3 => Some(SimdMode::Cap(SimdLevel::Sse41)),
        4 => Some(SimdMode::Cap(SimdLevel::Avx2)),
        5 => Some(SimdMode::Cap(SimdLevel::Neon)),
        _ => None,
    }
}

static MODE_OVERRIDE: AtomicUsize = AtomicUsize::new(MODE_UNSET);
static ENV_MODE: OnceLock<SimdMode> = OnceLock::new();
static DETECTED: OnceLock<SimdLevel> = OnceLock::new();

/// Parse a `MOBIQ_SIMD` value.  Pure (no env access) so tests can pin
/// the grammar without racing the process environment.
pub fn parse_mode(s: &str) -> Option<SimdMode> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "0" | "false" | "scalar" => Some(SimdMode::Off),
        "on" | "1" | "true" | "auto" => Some(SimdMode::Auto),
        "sse41" | "sse4.1" => Some(SimdMode::Cap(SimdLevel::Sse41)),
        "avx2" => Some(SimdMode::Cap(SimdLevel::Avx2)),
        "neon" => Some(SimdMode::Cap(SimdLevel::Neon)),
        _ => None,
    }
}

fn env_mode() -> SimdMode {
    *ENV_MODE.get_or_init(|| {
        std::env::var(ENV_VAR)
            .ok()
            .and_then(|s| parse_mode(&s))
            .unwrap_or(SimdMode::Auto)
    })
}

/// Install a programmatic mode override (wins over `MOBIQ_SIMD`).
/// Process-global: serialize tests that flip it.
pub fn set_mode(m: SimdMode) {
    MODE_OVERRIDE.store(encode(m), Ordering::Relaxed);
}

/// Drop the programmatic override, falling back to env / default.
pub fn clear_mode() {
    MODE_OVERRIDE.store(MODE_UNSET, Ordering::Relaxed);
}

/// `ServerConfig.simd` shorthand: `true` ⇒ `Auto`, `false` ⇒ `Off`.
pub fn set_enabled(on: bool) {
    set_mode(if on { SimdMode::Auto } else { SimdMode::Off });
}

/// The mode currently in force (override > env > `Auto`).
pub fn mode() -> SimdMode {
    decode(MODE_OVERRIDE.load(Ordering::Relaxed)).unwrap_or_else(env_mode)
}

/// Best level this CPU supports (detected once, cached).
pub fn detected() -> SimdLevel {
    *DETECTED.get_or_init(detect)
}

#[allow(unreachable_code)] // per-arch early returns leave dead fallback
fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        if is_x86_feature_detected!("sse4.1") {
            return SimdLevel::Sse41;
        }
        return SimdLevel::Scalar;
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

fn cap_level(det: SimdLevel, cap: SimdLevel) -> SimdLevel {
    match cap {
        SimdLevel::Scalar => SimdLevel::Scalar,
        // NEON cap on x86 (or vice versa below) degrades to scalar:
        // a cap never *raises* past what the CPU has.
        SimdLevel::Neon => {
            if det == SimdLevel::Neon {
                SimdLevel::Neon
            } else {
                SimdLevel::Scalar
            }
        }
        SimdLevel::Sse41 | SimdLevel::Avx2 => match det {
            SimdLevel::Avx2 => cap,
            SimdLevel::Sse41 => SimdLevel::Sse41,
            _ => SimdLevel::Scalar,
        },
    }
}

/// The level every dispatching wrapper below uses for this call.
pub fn level() -> SimdLevel {
    match mode() {
        SimdMode::Off => SimdLevel::Scalar,
        SimdMode::Auto => detected(),
        SimdMode::Cap(c) => cap_level(detected(), c),
    }
}

/// Whether any wide path is active (false ⇒ pre-SIMD scalar kernels).
pub fn enabled() -> bool {
    level() != SimdLevel::Scalar
}

// ---------------------------------------------------------------------
// Shared pieces: fixed-order reduction, 4-bit decode.
// ---------------------------------------------------------------------

/// The fixed lane-reduction tree of the contract.  8 lanes:
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`; 4 lanes: the left half.
#[inline]
pub fn reduce_tree(l: &[f32]) -> f32 {
    match l.len() {
        8 => ((l[0] + l[1]) + (l[2] + l[3]))
            + ((l[4] + l[5]) + (l[6] + l[7])),
        4 => (l[0] + l[1]) + (l[2] + l[3]),
        _ => l.iter().copied().fold(0.0, |a, b| a + b),
    }
}

/// Signed 4-bit code `i` from the packed nibble stream (low nibble
/// first) — same decode as `model::kvcache::u4_code`, duplicated here
/// so `util` stays below `model` in the layer order.
#[inline]
fn u4(packed: &[u8], i: usize) -> i8 {
    let nib = (packed[i >> 1] >> ((i & 1) * 4)) & 0xF;
    ((nib << 4) as i8) >> 4
}

// ---------------------------------------------------------------------
// Family 1+3 sequential fallbacks — byte-identical pre-SIMD loops.
// ---------------------------------------------------------------------

fn dot_f32_i8_seq(q: &[f32], k: &[i8]) -> f32 {
    let mut dot = 0f32;
    for (a, &b) in q.iter().zip(k) {
        dot += a * b as f32;
    }
    dot
}

fn dot_f32_u4_seq(q: &[f32], packed: &[u8]) -> f32 {
    let mut dot = 0f32;
    for (e, a) in q.iter().enumerate() {
        dot += a * u4(packed, e) as f32;
    }
    dot
}

fn axpy_f32_i8_seq(acc: &mut [f32], w: f32, v: &[i8]) {
    for (a, &vv) in acc.iter_mut().zip(v) {
        *a += w * vv as f32;
    }
}

fn axpy_f32_u4_seq(acc: &mut [f32], w: f32, packed: &[u8]) {
    for (e, a) in acc.iter_mut().enumerate() {
        *a += w * u4(packed, e) as f32;
    }
}

fn sum_squares_seq(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>()
}

fn add_assign_seq(acc: &mut [f32], delta: &[f32]) {
    for (a, b) in acc.iter_mut().zip(delta) {
        *a += b;
    }
}

fn scale_in_place_seq(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

// Mirrors `model::transformer::silu` exactly (duplicated here so the
// util layer keeps no model-layer dependency); `swiglu_equals_scalar`
// in tests/simd_parity.rs pins the two bit-identical.
#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn swiglu_row_seq(gate: &[f32], up: &[f32], out: &mut [f32]) {
    for ((f, g), u) in out.iter_mut().zip(gate).zip(up) {
        *f = silu(*g) * u;
    }
}

fn scale_mul_seq(x: &[f32], r: f32, w: &[f32], out: &mut [f32]) {
    for ((o, xi), wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * r * wi;
    }
}

// ---------------------------------------------------------------------
// Lane-blocked scalar references (the contract, executable).  Tests
// pin each SIMD kernel bit-identical to `*_blocked(.., level.lanes())`.
// ---------------------------------------------------------------------

/// Lane-blocked i8 dot: lane `j` accumulates elements `j, j+L, …`,
/// fixed-tree reduce, sequential `< L` tail.  `lanes <= 1` is the
/// sequential loop.
pub fn dot_f32_i8_blocked(q: &[f32], k: &[i8], lanes: usize) -> f32 {
    let n = q.len();
    if lanes <= 1 {
        return dot_f32_i8_seq(q, k);
    }
    debug_assert!(lanes <= MAX_LANES && k.len() >= n);
    let mut l = [0f32; MAX_LANES];
    let blocks = n / lanes;
    for b in 0..blocks {
        let base = b * lanes;
        for (j, lj) in l[..lanes].iter_mut().enumerate() {
            *lj += q[base + j] * k[base + j] as f32;
        }
    }
    let mut dot = reduce_tree(&l[..lanes]);
    for i in blocks * lanes..n {
        dot += q[i] * k[i] as f32;
    }
    dot
}

/// Lane-blocked u4 dot (see [`dot_f32_i8_blocked`]).
pub fn dot_f32_u4_blocked(q: &[f32], packed: &[u8], lanes: usize) -> f32 {
    let n = q.len();
    if lanes <= 1 {
        return dot_f32_u4_seq(q, packed);
    }
    debug_assert!(lanes <= MAX_LANES && packed.len() * 2 >= n);
    let mut l = [0f32; MAX_LANES];
    let blocks = n / lanes;
    for b in 0..blocks {
        let base = b * lanes;
        for (j, lj) in l[..lanes].iter_mut().enumerate() {
            *lj += q[base + j] * u4(packed, base + j) as f32;
        }
    }
    let mut dot = reduce_tree(&l[..lanes]);
    for i in blocks * lanes..n {
        dot += q[i] * u4(packed, i) as f32;
    }
    dot
}

/// Lane-blocked sum of squares (the `rmsnorm_row` reduction).
pub fn sum_squares_blocked(x: &[f32], lanes: usize) -> f32 {
    let n = x.len();
    if lanes <= 1 {
        return sum_squares_seq(x);
    }
    debug_assert!(lanes <= MAX_LANES);
    let mut l = [0f32; MAX_LANES];
    let blocks = n / lanes;
    for b in 0..blocks {
        let base = b * lanes;
        for (j, lj) in l[..lanes].iter_mut().enumerate() {
            *lj += x[base + j] * x[base + j];
        }
    }
    let mut s = reduce_tree(&l[..lanes]);
    for &v in &x[blocks * lanes..n] {
        s += v * v;
    }
    s
}

// ---------------------------------------------------------------------
// Public dispatching wrappers.
// ---------------------------------------------------------------------

/// `Σ q[i] · k[i]` with i8 codes (`k.len() >= q.len()`).  Scalar level
/// is the pre-SIMD sequential loop; wide levels follow the blocked
/// contract at `level().lanes()`.
pub fn dot_f32_i8(q: &[f32], k: &[i8]) -> f32 {
    debug_assert!(k.len() >= q.len());
    match level() {
        SimdLevel::Scalar => dot_f32_i8_seq(q, k),
        // SAFETY: `level()` only returns a wide level after the
        // matching CPU feature was detected at startup.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dot_f32_i8_sse41(q, k) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dot_f32_i8_avx2(q, k) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::dot_f32_i8_neon(q, k) },
        _ => dot_f32_i8_seq(q, k),
    }
}

/// `Σ q[i] · u4(packed, i)` with packed signed 4-bit codes
/// (`packed.len() * 2 >= q.len()`).
pub fn dot_f32_u4(q: &[f32], packed: &[u8]) -> f32 {
    debug_assert!(packed.len() * 2 >= q.len());
    match level() {
        SimdLevel::Scalar => dot_f32_u4_seq(q, packed),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dot_f32_u4_sse41(q, packed) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dot_f32_u4_avx2(q, packed) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::dot_f32_u4_neon(q, packed) },
        _ => dot_f32_u4_seq(q, packed),
    }
}

/// `acc[i] += w · v[i]` with i8 codes (`v.len() >= acc.len()`).
/// Per-element, so every level is bit-identical to scalar.
pub fn axpy_f32_i8(acc: &mut [f32], w: f32, v: &[i8]) {
    debug_assert!(v.len() >= acc.len());
    match level() {
        SimdLevel::Scalar => axpy_f32_i8_seq(acc, w, v),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::axpy_f32_i8_sse41(acc, w, v) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::axpy_f32_i8_avx2(acc, w, v) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::axpy_f32_i8_neon(acc, w, v) },
        _ => axpy_f32_i8_seq(acc, w, v),
    }
}

/// `acc[i] += w · u4(packed, i)` (`packed.len() * 2 >= acc.len()`).
pub fn axpy_f32_u4(acc: &mut [f32], w: f32, packed: &[u8]) {
    debug_assert!(packed.len() * 2 >= acc.len());
    match level() {
        SimdLevel::Scalar => axpy_f32_u4_seq(acc, w, packed),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe {
            x86::axpy_f32_u4_sse41(acc, w, packed)
        },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::axpy_f32_u4_avx2(acc, w, packed) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe {
            neon::axpy_f32_u4_neon(acc, w, packed)
        },
        _ => axpy_f32_u4_seq(acc, w, packed),
    }
}

/// `Σ x[i]²` under the blocked contract (wide levels reassociate —
/// callers that need the pre-SIMD sum must check `enabled()` first,
/// as `model::transformer::rmsnorm` does).
pub fn sum_squares(x: &[f32]) -> f32 {
    match level() {
        SimdLevel::Scalar => sum_squares_seq(x),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::sum_squares_sse41(x) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::sum_squares_avx2(x) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::sum_squares_neon(x) },
        _ => sum_squares_seq(x),
    }
}

/// `acc[i] += delta[i]` (residual rows).  Bit-identical at any level.
pub fn add_assign(acc: &mut [f32], delta: &[f32]) {
    match level() {
        SimdLevel::Scalar => add_assign_seq(acc, delta),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::add_assign_sse41(acc, delta) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::add_assign_avx2(acc, delta) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::add_assign_neon(acc, delta) },
        _ => add_assign_seq(acc, delta),
    }
}

/// `x[i] *= s` (online-softmax correction rows).  Bit-identical at
/// any level.
pub fn scale_in_place(x: &mut [f32], s: f32) {
    match level() {
        SimdLevel::Scalar => scale_in_place_seq(x, s),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::scale_in_place_sse41(x, s) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::scale_in_place_avx2(x, s) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::scale_in_place_neon(x, s) },
        _ => scale_in_place_seq(x, s),
    }
}

/// `out[i] = silu(gate[i]) · up[i]` (SwiGLU rows).  `exp` stays
/// scalar; the multiply vectorizes.  Bit-identical at any level.
pub fn swiglu_row(gate: &[f32], up: &[f32], out: &mut [f32]) {
    match level() {
        SimdLevel::Scalar => swiglu_row_seq(gate, up, out),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe {
            x86::swiglu_row_sse41(gate, up, out)
        },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::swiglu_row_avx2(gate, up, out) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe {
            neon::swiglu_row_neon(gate, up, out)
        },
        _ => swiglu_row_seq(gate, up, out),
    }
}

/// Full RMSNorm row at the active level: lane-blocked `Σx²`, then the
/// per-element `out[i] = (x[i]·r)·w[i]` scale (same association as
/// the scalar loop).  Callers guarantee equal lengths.
pub fn rmsnorm_row(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = sum_squares(x) / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    match level() {
        SimdLevel::Scalar => scale_mul_seq(x, r, w, out),
        // SAFETY: level implies the feature was detected (see above).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe {
            x86::scale_mul_sse41(x, r, w, out)
        },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::scale_mul_avx2(x, r, w, out) },
        // SAFETY: NEON is a baseline feature on aarch64.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::scale_mul_neon(x, r, w, out) },
        _ => scale_mul_seq(x, r, w, out),
    }
}

// ---------------------------------------------------------------------
// Family 2: LUT plane-word gathers (AVX2 only).
// ---------------------------------------------------------------------

/// True when the active level supports the gathered LUT walk
/// (AVX2 `vgatherdps`); hoist this out of the plane-word loop.
pub fn lut_gather_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        level() == SimdLevel::Avx2
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Gather the eight byte-table entries of plane word `pw` and return
/// the two group sums `((t0+t1)+(t2+t3), (t4+t5)+(t6+t7))` — the
/// exact pairwise tree of the scalar walk in `gemv_lut_range`.
///
/// # Safety
/// `c0 + 2048 <= table.len()` (the byte LUT is padded to whole
/// words), and `lut_gather_active()` must have returned true for this
/// dispatch round (AVX2 present).
pub unsafe fn lut_bytes_pair(table: &[f32], c0: usize, pw: u64)
                             -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    {
        x86::lut_bytes_pair_avx2(table, c0, pw)
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (table, c0, pw);
        unreachable!("lut_bytes_pair without gather support")
    }
}

/// Gather the sixteen nibble-table entries of plane word `pw` and
/// return the two group sums with the scalar walk's association
/// (`q0 = ((n0+n1)+n2)+n3`, …, returning `(q0+q1, q2+q3)`).
///
/// # Safety
/// `c0 + 256 <= ntable.len()` and `lut_gather_active()` returned true
/// for this dispatch round (AVX2 present).
pub unsafe fn lut_nibbles_pair(ntable: &[f32], c0: usize, pw: u64)
                               -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    {
        x86::lut_nibbles_pair_avx2(ntable, c0, pw)
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (ntable, c0, pw);
        unreachable!("lut_nibbles_pair without gather support")
    }
}

// ---------------------------------------------------------------------
// x86-64 kernels.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{reduce_tree, silu, u4};
    use std::arch::x86_64::*;

    /// i8 dot, 8 codes/iter: sign-extend to i32, convert, separate
    /// mul + add per lane (no FMA — bit-identity with the blocked
    /// scalar requires two roundings).
    ///
    /// # Safety
    /// AVX2 must be available; `k.len() >= q.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_i8_avx2(q: &[f32], k: &[i8]) -> f32 {
        let n = q.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n <= q.len() <= k.len().
            let kb =
                _mm_loadl_epi64(k.as_ptr().add(i) as *const __m128i);
            let kf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(kb));
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(qv, kf));
            i += 8;
        }
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * k[i] as f32;
            i += 1;
        }
        dot
    }

    /// Unpack 4 packed bytes into 8 signed 4-bit codes in stream
    /// order (low nibble first) in the low 8 bytes of the result.
    ///
    /// # Safety
    /// SSE2 baseline only; `w` holds the 4 bytes.
    #[inline]
    unsafe fn unpack_u4x8(w: u32) -> __m128i {
        let b = _mm_cvtsi32_si128(w as i32);
        let mask = _mm_set1_epi8(0x0F);
        let lo = _mm_and_si128(b, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), mask);
        // interleave -> c0,c1,c2,…,c7 (low nibble of byte t is code
        // 2t, high nibble is code 2t+1)
        let inter = _mm_unpacklo_epi8(lo, hi);
        // sign-extend 4 bits: (x ^ 8) - 8 over unsigned nibbles
        let eight = _mm_set1_epi8(8);
        _mm_sub_epi8(_mm_xor_si128(inter, eight), eight)
    }

    /// u4 dot, 8 codes/iter from 4 packed bytes.
    ///
    /// # Safety
    /// AVX2 must be available; `packed.len() * 2 >= q.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_u4_avx2(q: &[f32], packed: &[u8]) -> f32 {
        let n = q.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i is a multiple of 8, so i/2 + 4 <= n/2 <=
            // packed.len() — the 4-byte read is in bounds.
            let w = (packed.as_ptr().add(i / 2) as *const u32)
                .read_unaligned();
            let kf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(
                unpack_u4x8(w)));
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(qv, kf));
            i += 8;
        }
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * u4(packed, i) as f32;
            i += 1;
        }
        dot
    }

    /// # Safety
    /// AVX2 must be available; `v.len() >= acc.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32_i8_avx2(acc: &mut [f32], w: f32, v: &[i8]) {
        let n = acc.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n <= acc.len() <= v.len().
            let vb =
                _mm_loadl_epi64(v.as_ptr().add(i) as *const __m128i);
            let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(vb));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(
                acc.as_mut_ptr().add(i),
                _mm256_add_ps(av, _mm256_mul_ps(wv, vf)),
            );
            i += 8;
        }
        while i < n {
            acc[i] += w * v[i] as f32;
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available; `packed.len() * 2 >= acc.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32_u4_avx2(acc: &mut [f32], w: f32,
                                   packed: &[u8]) {
        let n = acc.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: as in dot_f32_u4_avx2.
            let word = (packed.as_ptr().add(i / 2) as *const u32)
                .read_unaligned();
            let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(
                unpack_u4x8(word)));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(
                acc.as_mut_ptr().add(i),
                _mm256_add_ps(av, _mm256_mul_ps(wv, vf)),
            );
            i += 8;
        }
        while i < n {
            acc[i] += w * u4(packed, i) as f32;
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_squares_avx2(x: &[f32]) -> f32 {
        let n = x.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n.
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, xv));
            i += 8;
        }
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), acc);
        let mut s = reduce_tree(&l);
        while i < n {
            s += x[i] * x[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// AVX2 must be available; `delta.len() >= acc.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_avx2(acc: &mut [f32], delta: &[f32]) {
        let n = acc.len().min(delta.len());
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n bounds both slices.
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            let dv = _mm256_loadu_ps(delta.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i),
                             _mm256_add_ps(av, dv));
            i += 8;
        }
        while i < n {
            acc[i] += delta[i];
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_in_place_avx2(x: &mut [f32], s: f32) {
        let n = x.len();
        let sv = _mm256_set1_ps(s);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n.
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i),
                             _mm256_mul_ps(xv, sv));
            i += 8;
        }
        while i < n {
            x[i] *= s;
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available; `gate`/`up` cover `out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn swiglu_row_avx2(gate: &[f32], up: &[f32],
                                  out: &mut [f32]) {
        let n = out.len().min(gate.len()).min(up.len());
        let mut sbuf = [0f32; 8];
        let mut i = 0usize;
        while i + 8 <= n {
            for (j, s) in sbuf.iter_mut().enumerate() {
                *s = silu(gate[i + j]);
            }
            // SAFETY: i + 8 <= n bounds all three slices.
            let sv = _mm256_loadu_ps(sbuf.as_ptr());
            let uv = _mm256_loadu_ps(up.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i),
                             _mm256_mul_ps(sv, uv));
            i += 8;
        }
        while i < n {
            out[i] = silu(gate[i]) * up[i];
            i += 1;
        }
    }

    /// `out[i] = (x[i]·r)·w[i]` — the rmsnorm elementwise scale.
    ///
    /// # Safety
    /// AVX2 must be available; `x`/`w` cover `out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_mul_avx2(x: &[f32], r: f32, w: &[f32],
                                 out: &mut [f32]) {
        let n = out.len().min(x.len()).min(w.len());
        let rv = _mm256_set1_ps(r);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n bounds all three slices.
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            _mm256_storeu_ps(
                out.as_mut_ptr().add(i),
                _mm256_mul_ps(_mm256_mul_ps(xv, rv), wv),
            );
            i += 8;
        }
        while i < n {
            out[i] = x[i] * r * w[i];
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available; `c0 + 2048 <= table.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_bytes_pair_avx2(table: &[f32], c0: usize,
                                      pw: u64) -> (f32, f32) {
        let idx = _mm256_set_epi32(
            (1792 + ((pw >> 56) & 0xFF)) as i32,
            (1536 + ((pw >> 48) & 0xFF)) as i32,
            (1280 + ((pw >> 40) & 0xFF)) as i32,
            (1024 + ((pw >> 32) & 0xFF)) as i32,
            (768 + ((pw >> 24) & 0xFF)) as i32,
            (512 + ((pw >> 16) & 0xFF)) as i32,
            (256 + ((pw >> 8) & 0xFF)) as i32,
            (pw & 0xFF) as i32,
        );
        // SAFETY: every index < 2048 and c0 + 2048 <= table.len()
        // (caller contract), so all 8 gather slots are in bounds.
        let g = _mm256_i32gather_ps::<4>(table.as_ptr().add(c0), idx);
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), g);
        ((l[0] + l[1]) + (l[2] + l[3]), (l[4] + l[5]) + (l[6] + l[7]))
    }

    /// # Safety
    /// AVX2 must be available; `c0 + 256 <= ntable.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_nibbles_pair_avx2(ntable: &[f32], c0: usize,
                                        pw: u64) -> (f32, f32) {
        let base = ntable.as_ptr().add(c0);
        let idx_lo = _mm256_set_epi32(
            (7 * 16 + ((pw >> 28) & 0xF)) as i32,
            (6 * 16 + ((pw >> 24) & 0xF)) as i32,
            (5 * 16 + ((pw >> 20) & 0xF)) as i32,
            (4 * 16 + ((pw >> 16) & 0xF)) as i32,
            (3 * 16 + ((pw >> 12) & 0xF)) as i32,
            (2 * 16 + ((pw >> 8) & 0xF)) as i32,
            (16 + ((pw >> 4) & 0xF)) as i32,
            (pw & 0xF) as i32,
        );
        let idx_hi = _mm256_set_epi32(
            (15 * 16 + ((pw >> 60) & 0xF)) as i32,
            (14 * 16 + ((pw >> 56) & 0xF)) as i32,
            (13 * 16 + ((pw >> 52) & 0xF)) as i32,
            (12 * 16 + ((pw >> 48) & 0xF)) as i32,
            (11 * 16 + ((pw >> 44) & 0xF)) as i32,
            (10 * 16 + ((pw >> 40) & 0xF)) as i32,
            (9 * 16 + ((pw >> 36) & 0xF)) as i32,
            (8 * 16 + ((pw >> 32) & 0xF)) as i32,
        );
        // SAFETY: every index < 256 and c0 + 256 <= ntable.len()
        // (caller contract), so all 16 gather slots are in bounds.
        let ga = _mm256_i32gather_ps::<4>(base, idx_lo);
        let gb = _mm256_i32gather_ps::<4>(base, idx_hi);
        let mut a = [0f32; 8];
        let mut b = [0f32; 8];
        _mm256_storeu_ps(a.as_mut_ptr(), ga);
        _mm256_storeu_ps(b.as_mut_ptr(), gb);
        // replicate the scalar left-associated per-group walk
        let q0 = ((a[0] + a[1]) + a[2]) + a[3];
        let q1 = ((a[4] + a[5]) + a[6]) + a[7];
        let q2 = ((b[0] + b[1]) + b[2]) + b[3];
        let q3 = ((b[4] + b[5]) + b[6]) + b[7];
        (q0 + q1, q2 + q3)
    }

    // ---- SSE4.1 tier: 4 lanes, same contract ----

    /// # Safety
    /// SSE4.1 must be available; `k.len() >= q.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dot_f32_i8_sse41(q: &[f32], k: &[i8]) -> f32 {
        let n = q.len();
        let mut acc = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds the 4-byte read.
            let w = (k.as_ptr().add(i) as *const i32).read_unaligned();
            let kb = _mm_cvtsi32_si128(w);
            let kf = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(kb));
            let qv = _mm_loadu_ps(q.as_ptr().add(i));
            acc = _mm_add_ps(acc, _mm_mul_ps(qv, kf));
            i += 4;
        }
        let mut l = [0f32; 4];
        _mm_storeu_ps(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * k[i] as f32;
            i += 1;
        }
        dot
    }

    /// # Safety
    /// SSE4.1 must be available; `packed.len() * 2 >= q.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dot_f32_u4_sse41(q: &[f32], packed: &[u8]) -> f32 {
        let n = q.len();
        let mut acc = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 4 <= n {
            let kf = _mm_set_ps(
                u4(packed, i + 3) as f32,
                u4(packed, i + 2) as f32,
                u4(packed, i + 1) as f32,
                u4(packed, i) as f32,
            );
            // SAFETY: i + 4 <= n bounds the f32 load.
            let qv = _mm_loadu_ps(q.as_ptr().add(i));
            acc = _mm_add_ps(acc, _mm_mul_ps(qv, kf));
            i += 4;
        }
        let mut l = [0f32; 4];
        _mm_storeu_ps(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * u4(packed, i) as f32;
            i += 1;
        }
        dot
    }

    /// # Safety
    /// SSE4.1 must be available; `v.len() >= acc.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_f32_i8_sse41(acc: &mut [f32], w: f32,
                                    v: &[i8]) {
        let n = acc.len();
        let wv = _mm_set1_ps(w);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n <= v.len().
            let word =
                (v.as_ptr().add(i) as *const i32).read_unaligned();
            let vf = _mm_cvtepi32_ps(_mm_cvtepi8_epi32(
                _mm_cvtsi32_si128(word)));
            let av = _mm_loadu_ps(acc.as_ptr().add(i));
            _mm_storeu_ps(acc.as_mut_ptr().add(i),
                          _mm_add_ps(av, _mm_mul_ps(wv, vf)));
            i += 4;
        }
        while i < n {
            acc[i] += w * v[i] as f32;
            i += 1;
        }
    }

    /// # Safety
    /// SSE4.1 must be available; `packed.len() * 2 >= acc.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_f32_u4_sse41(acc: &mut [f32], w: f32,
                                    packed: &[u8]) {
        let n = acc.len();
        let wv = _mm_set1_ps(w);
        let mut i = 0usize;
        while i + 4 <= n {
            let vf = _mm_set_ps(
                u4(packed, i + 3) as f32,
                u4(packed, i + 2) as f32,
                u4(packed, i + 1) as f32,
                u4(packed, i) as f32,
            );
            // SAFETY: i + 4 <= n bounds the loads/stores.
            let av = _mm_loadu_ps(acc.as_ptr().add(i));
            _mm_storeu_ps(acc.as_mut_ptr().add(i),
                          _mm_add_ps(av, _mm_mul_ps(wv, vf)));
            i += 4;
        }
        while i < n {
            acc[i] += w * u4(packed, i) as f32;
            i += 1;
        }
    }

    /// # Safety
    /// SSE4.1 must be available.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn sum_squares_sse41(x: &[f32]) -> f32 {
        let n = x.len();
        let mut acc = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n.
            let xv = _mm_loadu_ps(x.as_ptr().add(i));
            acc = _mm_add_ps(acc, _mm_mul_ps(xv, xv));
            i += 4;
        }
        let mut l = [0f32; 4];
        _mm_storeu_ps(l.as_mut_ptr(), acc);
        let mut s = reduce_tree(&l);
        while i < n {
            s += x[i] * x[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// SSE4.1 must be available; `delta.len() >= acc.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn add_assign_sse41(acc: &mut [f32], delta: &[f32]) {
        let n = acc.len().min(delta.len());
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds both slices.
            let av = _mm_loadu_ps(acc.as_ptr().add(i));
            let dv = _mm_loadu_ps(delta.as_ptr().add(i));
            _mm_storeu_ps(acc.as_mut_ptr().add(i),
                          _mm_add_ps(av, dv));
            i += 4;
        }
        while i < n {
            acc[i] += delta[i];
            i += 1;
        }
    }

    /// # Safety
    /// SSE4.1 must be available.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn scale_in_place_sse41(x: &mut [f32], s: f32) {
        let n = x.len();
        let sv = _mm_set1_ps(s);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n.
            let xv = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(x.as_mut_ptr().add(i),
                          _mm_mul_ps(xv, sv));
            i += 4;
        }
        while i < n {
            x[i] *= s;
            i += 1;
        }
    }

    /// # Safety
    /// SSE4.1 must be available; `gate`/`up` cover `out.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn swiglu_row_sse41(gate: &[f32], up: &[f32],
                                   out: &mut [f32]) {
        let n = out.len().min(gate.len()).min(up.len());
        let mut sbuf = [0f32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            for (j, s) in sbuf.iter_mut().enumerate() {
                *s = silu(gate[i + j]);
            }
            // SAFETY: i + 4 <= n bounds all three slices.
            let sv = _mm_loadu_ps(sbuf.as_ptr());
            let uv = _mm_loadu_ps(up.as_ptr().add(i));
            _mm_storeu_ps(out.as_mut_ptr().add(i),
                          _mm_mul_ps(sv, uv));
            i += 4;
        }
        while i < n {
            out[i] = silu(gate[i]) * up[i];
            i += 1;
        }
    }

    /// # Safety
    /// SSE4.1 must be available; `x`/`w` cover `out.len()`.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn scale_mul_sse41(x: &[f32], r: f32, w: &[f32],
                                  out: &mut [f32]) {
        let n = out.len().min(x.len()).min(w.len());
        let rv = _mm_set1_ps(r);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds all three slices.
            let xv = _mm_loadu_ps(x.as_ptr().add(i));
            let wv = _mm_loadu_ps(w.as_ptr().add(i));
            _mm_storeu_ps(out.as_mut_ptr().add(i),
                          _mm_mul_ps(_mm_mul_ps(xv, rv), wv));
            i += 4;
        }
        while i < n {
            out[i] = x[i] * r * w[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// aarch64 NEON kernels (baseline feature, 4 lanes).
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{reduce_tree, silu, u4};
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is baseline on aarch64; `k.len() >= q.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_i8_neon(q: &[f32], k: &[i8]) -> f32 {
        let n = q.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        // 8 codes per load, accumulated as two in-order 4-blocks —
        // identical association to blocked(4).
        while i + 8 <= n {
            // SAFETY: i + 8 <= n bounds the 8-byte load.
            let k16 = vmovl_s8(vld1_s8(k.as_ptr().add(i)));
            let klo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(k16)));
            let khi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(k16)));
            let qlo = vld1q_f32(q.as_ptr().add(i));
            let qhi = vld1q_f32(q.as_ptr().add(i + 4));
            acc = vaddq_f32(acc, vmulq_f32(qlo, klo));
            acc = vaddq_f32(acc, vmulq_f32(qhi, khi));
            i += 8;
        }
        if i + 4 <= n {
            let kf = [
                k[i] as f32,
                k[i + 1] as f32,
                k[i + 2] as f32,
                k[i + 3] as f32,
            ];
            // SAFETY: stack array + i + 4 <= n bound the loads.
            let kv = vld1q_f32(kf.as_ptr());
            let qv = vld1q_f32(q.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(qv, kv));
            i += 4;
        }
        let mut l = [0f32; 4];
        vst1q_f32(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * k[i] as f32;
            i += 1;
        }
        dot
    }

    /// # Safety
    /// NEON is baseline on aarch64; `packed.len() * 2 >= q.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_u4_neon(q: &[f32], packed: &[u8]) -> f32 {
        let n = q.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let kf = [
                u4(packed, i) as f32,
                u4(packed, i + 1) as f32,
                u4(packed, i + 2) as f32,
                u4(packed, i + 3) as f32,
            ];
            // SAFETY: stack array + i + 4 <= n bound the loads.
            let kv = vld1q_f32(kf.as_ptr());
            let qv = vld1q_f32(q.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(qv, kv));
            i += 4;
        }
        let mut l = [0f32; 4];
        vst1q_f32(l.as_mut_ptr(), acc);
        let mut dot = reduce_tree(&l);
        while i < n {
            dot += q[i] * u4(packed, i) as f32;
            i += 1;
        }
        dot
    }

    /// # Safety
    /// NEON is baseline on aarch64; `v.len() >= acc.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32_i8_neon(acc: &mut [f32], w: f32,
                                   v: &[i8]) {
        let n = acc.len();
        let wv = vdupq_n_f32(w);
        let mut i = 0usize;
        while i + 4 <= n {
            let vf = [
                v[i] as f32,
                v[i + 1] as f32,
                v[i + 2] as f32,
                v[i + 3] as f32,
            ];
            // SAFETY: stack array + i + 4 <= n bound the accesses.
            let vv = vld1q_f32(vf.as_ptr());
            let av = vld1q_f32(acc.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i),
                      vaddq_f32(av, vmulq_f32(wv, vv)));
            i += 4;
        }
        while i < n {
            acc[i] += w * v[i] as f32;
            i += 1;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; `packed.len()*2 >= acc.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32_u4_neon(acc: &mut [f32], w: f32,
                                   packed: &[u8]) {
        let n = acc.len();
        let wv = vdupq_n_f32(w);
        let mut i = 0usize;
        while i + 4 <= n {
            let vf = [
                u4(packed, i) as f32,
                u4(packed, i + 1) as f32,
                u4(packed, i + 2) as f32,
                u4(packed, i + 3) as f32,
            ];
            // SAFETY: stack array + i + 4 <= n bound the accesses.
            let vv = vld1q_f32(vf.as_ptr());
            let av = vld1q_f32(acc.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i),
                      vaddq_f32(av, vmulq_f32(wv, vv)));
            i += 4;
        }
        while i < n {
            acc[i] += w * u4(packed, i) as f32;
            i += 1;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    #[target_feature(enable = "neon")]
    pub unsafe fn sum_squares_neon(x: &[f32]) -> f32 {
        let n = x.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n.
            let xv = vld1q_f32(x.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(xv, xv));
            i += 4;
        }
        let mut l = [0f32; 4];
        vst1q_f32(l.as_mut_ptr(), acc);
        let mut s = reduce_tree(&l);
        while i < n {
            s += x[i] * x[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// NEON is baseline on aarch64; `delta.len() >= acc.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign_neon(acc: &mut [f32], delta: &[f32]) {
        let n = acc.len().min(delta.len());
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds both slices.
            let av = vld1q_f32(acc.as_ptr().add(i));
            let dv = vld1q_f32(delta.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(av, dv));
            i += 4;
        }
        while i < n {
            acc[i] += delta[i];
            i += 1;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_in_place_neon(x: &mut [f32], s: f32) {
        let n = x.len();
        let sv = vdupq_n_f32(s);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n.
            let xv = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(x.as_mut_ptr().add(i), vmulq_f32(xv, sv));
            i += 4;
        }
        while i < n {
            x[i] *= s;
            i += 1;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; `gate`/`up` cover `out.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn swiglu_row_neon(gate: &[f32], up: &[f32],
                                  out: &mut [f32]) {
        let n = out.len().min(gate.len()).min(up.len());
        let mut sbuf = [0f32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            for (j, s) in sbuf.iter_mut().enumerate() {
                *s = silu(gate[i + j]);
            }
            // SAFETY: i + 4 <= n bounds all three slices.
            let sv = vld1q_f32(sbuf.as_ptr());
            let uv = vld1q_f32(up.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(sv, uv));
            i += 4;
        }
        while i < n {
            out[i] = silu(gate[i]) * up[i];
            i += 1;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; `x`/`w` cover `out.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_mul_neon(x: &[f32], r: f32, w: &[f32],
                                 out: &mut [f32]) {
        let n = out.len().min(x.len()).min(w.len());
        let rv = vdupq_n_f32(r);
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds all three slices.
            let xv = vld1q_f32(x.as_ptr().add(i));
            let wv = vld1q_f32(w.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i),
                      vmulq_f32(vmulq_f32(xv, rv), wv));
            i += 4;
        }
        while i < n {
            out[i] = x[i] * r * w[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// Tests.  Per-level kernels are pinned against the blocked scalar
// reference *without* touching the global mode (no races with
// concurrently running tests); mode-resolution tests only exercise
// the pure parser and the encode/decode round-trip.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn mats(n: usize, seed: u64) -> (Vec<f32>, Vec<i8>, Vec<u8>) {
        let mut rng = Pcg::new(seed);
        let q = rng.normal_vec(n, 1.0);
        let k: Vec<i8> =
            (0..n).map(|_| (rng.next_u32() as i8)).collect();
        let packed: Vec<u8> = (0..n.div_ceil(2))
            .map(|_| rng.next_u32() as u8)
            .collect();
        (q, k, packed)
    }

    #[test]
    fn parse_mode_grammar() {
        assert_eq!(parse_mode("off"), Some(SimdMode::Off));
        assert_eq!(parse_mode("0"), Some(SimdMode::Off));
        assert_eq!(parse_mode("SCALAR"), Some(SimdMode::Off));
        assert_eq!(parse_mode("auto"), Some(SimdMode::Auto));
        assert_eq!(parse_mode(" on "), Some(SimdMode::Auto));
        assert_eq!(parse_mode("avx2"),
                   Some(SimdMode::Cap(SimdLevel::Avx2)));
        assert_eq!(parse_mode("sse4.1"),
                   Some(SimdMode::Cap(SimdLevel::Sse41)));
        assert_eq!(parse_mode("neon"),
                   Some(SimdMode::Cap(SimdLevel::Neon)));
        assert_eq!(parse_mode("bogus"), None);
        assert_eq!(parse_mode(""), None);
    }

    #[test]
    fn mode_encoding_round_trips() {
        for m in [
            SimdMode::Off,
            SimdMode::Auto,
            SimdMode::Cap(SimdLevel::Sse41),
            SimdMode::Cap(SimdLevel::Avx2),
            SimdMode::Cap(SimdLevel::Neon),
        ] {
            assert_eq!(decode(encode(m)), Some(m));
        }
        assert_eq!(decode(MODE_UNSET), None);
        // Cap(Scalar) folds into Off
        assert_eq!(decode(encode(SimdMode::Cap(SimdLevel::Scalar))),
                   Some(SimdMode::Off));
    }

    #[test]
    fn cap_never_raises_above_detected() {
        use SimdLevel::*;
        assert_eq!(cap_level(Avx2, Sse41), Sse41);
        assert_eq!(cap_level(Avx2, Avx2), Avx2);
        assert_eq!(cap_level(Sse41, Avx2), Sse41);
        assert_eq!(cap_level(Scalar, Avx2), Scalar);
        assert_eq!(cap_level(Neon, Neon), Neon);
        assert_eq!(cap_level(Neon, Avx2), Scalar);
        assert_eq!(cap_level(Avx2, Neon), Scalar);
        assert_eq!(cap_level(Avx2, Scalar), Scalar);
    }

    #[test]
    fn u4_decode_matches_kvcache() {
        let packed: Vec<u8> = (0..=255u8).collect();
        for i in 0..512 {
            assert_eq!(u4(&packed, i),
                       crate::model::kvcache::u4_code(&packed, i));
        }
    }

    /// blocked(1) degenerates to the sequential loop exactly.
    #[test]
    fn blocked_one_lane_is_sequential() {
        let (q, k, p) = mats(301, 9);
        assert_eq!(dot_f32_i8_blocked(&q, &k, 1),
                   dot_f32_i8_seq(&q, &k));
        assert_eq!(dot_f32_u4_blocked(&q, &p, 1),
                   dot_f32_u4_seq(&q, &p));
        assert_eq!(sum_squares_blocked(&q, 1), sum_squares_seq(&q));
    }

    /// Blocked reductions track the sequential sum closely (they
    /// reassociate, so equality is approximate by design).
    #[test]
    fn blocked_tracks_sequential() {
        for n in [0usize, 1, 3, 7, 8, 9, 63, 64, 65, 300] {
            let (q, k, p) = mats(n, 1000 + n as u64);
            for lanes in [4usize, 8] {
                let a = dot_f32_i8_blocked(&q, &k, lanes);
                let b = dot_f32_i8_seq(&q, &k);
                assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0),
                        "i8 n={n} lanes={lanes}: {a} vs {b}");
                let a = dot_f32_u4_blocked(&q, &p, lanes);
                let b = dot_f32_u4_seq(&q, &p);
                assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0),
                        "u4 n={n} lanes={lanes}: {a} vs {b}");
            }
        }
    }

    /// Each compiled-in wide kernel is bit-identical to the blocked
    /// scalar reference at its lane count — the contract the parity
    /// suites lean on.  Skips levels the CPU doesn't have.
    #[test]
    fn wide_kernels_match_blocked_reference_bitwise() {
        for n in [0usize, 1, 4, 7, 8, 12, 15, 16, 64, 65, 127, 256] {
            let (q, k, p) = mats(n, 40_000 + n as u64);
            #[cfg(target_arch = "x86_64")]
            {
                if is_x86_feature_detected!("avx2") {
                    // SAFETY: feature checked on the line above.
                    let (di, du, ss) = unsafe {
                        (x86::dot_f32_i8_avx2(&q, &k),
                         x86::dot_f32_u4_avx2(&q, &p),
                         x86::sum_squares_avx2(&q))
                    };
                    assert_eq!(di, dot_f32_i8_blocked(&q, &k, 8),
                               "avx2 i8 n={n}");
                    assert_eq!(du, dot_f32_u4_blocked(&q, &p, 8),
                               "avx2 u4 n={n}");
                    assert_eq!(ss, sum_squares_blocked(&q, 8),
                               "avx2 ssq n={n}");
                }
                if is_x86_feature_detected!("sse4.1") {
                    // SAFETY: feature checked on the line above.
                    let (di, du, ss) = unsafe {
                        (x86::dot_f32_i8_sse41(&q, &k),
                         x86::dot_f32_u4_sse41(&q, &p),
                         x86::sum_squares_sse41(&q))
                    };
                    assert_eq!(di, dot_f32_i8_blocked(&q, &k, 4),
                               "sse41 i8 n={n}");
                    assert_eq!(du, dot_f32_u4_blocked(&q, &p, 4),
                               "sse41 u4 n={n}");
                    assert_eq!(ss, sum_squares_blocked(&q, 4),
                               "sse41 ssq n={n}");
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is baseline on aarch64.
                let (di, du, ss) = unsafe {
                    (neon::dot_f32_i8_neon(&q, &k),
                     neon::dot_f32_u4_neon(&q, &p),
                     neon::sum_squares_neon(&q))
                };
                assert_eq!(di, dot_f32_i8_blocked(&q, &k, 4),
                           "neon i8 n={n}");
                assert_eq!(du, dot_f32_u4_blocked(&q, &p, 4),
                           "neon u4 n={n}");
                assert_eq!(ss, sum_squares_blocked(&q, 4),
                           "neon ssq n={n}");
            }
        }
    }

    /// Elementwise kernels (axpy, add, scale, swiglu, rmsnorm scale)
    /// are per-element and must equal the sequential loop exactly at
    /// every compiled-in level.
    #[test]
    fn elementwise_kernels_bit_identical_to_sequential() {
        for n in [0usize, 1, 5, 8, 13, 64, 100] {
            let (q, k, p) = mats(n, 70_000 + n as u64);
            let mut rng = Pcg::new(99 + n as u64);
            let delta = rng.normal_vec(n, 1.0);
            let up = rng.normal_vec(n, 1.0);
            let w = 0.37f32;

            let mut want_axi = q.clone();
            axpy_f32_i8_seq(&mut want_axi, w, &k);
            let mut want_axu = q.clone();
            axpy_f32_u4_seq(&mut want_axu, w, &p);
            let mut want_add = q.clone();
            add_assign_seq(&mut want_add, &delta);
            let mut want_scale = q.clone();
            scale_in_place_seq(&mut want_scale, w);
            let mut want_swi = vec![0f32; n];
            swiglu_row_seq(&q, &up, &mut want_swi);
            let mut want_sm = vec![0f32; n];
            scale_mul_seq(&q, w, &delta, &mut want_sm);

            #[cfg(target_arch = "x86_64")]
            {
                type Apply = (&'static str, bool);
                let levels: [Apply; 2] = [
                    ("avx2", is_x86_feature_detected!("avx2")),
                    ("sse4.1", is_x86_feature_detected!("sse4.1")),
                ];
                for (name, present) in levels {
                    if !present {
                        continue;
                    }
                    let mut axi = q.clone();
                    let mut axu = q.clone();
                    let mut add = q.clone();
                    let mut sc = q.clone();
                    let mut swi = vec![0f32; n];
                    let mut sm = vec![0f32; n];
                    // SAFETY: the matching feature was detected.
                    unsafe {
                        if name == "avx2" {
                            x86::axpy_f32_i8_avx2(&mut axi, w, &k);
                            x86::axpy_f32_u4_avx2(&mut axu, w, &p);
                            x86::add_assign_avx2(&mut add, &delta);
                            x86::scale_in_place_avx2(&mut sc, w);
                            x86::swiglu_row_avx2(&q, &up, &mut swi);
                            x86::scale_mul_avx2(&q, w, &delta,
                                                &mut sm);
                        } else {
                            x86::axpy_f32_i8_sse41(&mut axi, w, &k);
                            x86::axpy_f32_u4_sse41(&mut axu, w, &p);
                            x86::add_assign_sse41(&mut add, &delta);
                            x86::scale_in_place_sse41(&mut sc, w);
                            x86::swiglu_row_sse41(&q, &up, &mut swi);
                            x86::scale_mul_sse41(&q, w, &delta,
                                                 &mut sm);
                        }
                    }
                    assert_eq!(axi, want_axi, "{name} axpy_i8 n={n}");
                    assert_eq!(axu, want_axu, "{name} axpy_u4 n={n}");
                    assert_eq!(add, want_add, "{name} add n={n}");
                    assert_eq!(sc, want_scale, "{name} scale n={n}");
                    assert_eq!(swi, want_swi, "{name} swiglu n={n}");
                    assert_eq!(sm, want_sm, "{name} scale_mul n={n}");
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                let mut axi = q.clone();
                let mut axu = q.clone();
                let mut add = q.clone();
                let mut sc = q.clone();
                let mut swi = vec![0f32; n];
                let mut sm = vec![0f32; n];
                // SAFETY: NEON is baseline on aarch64.
                unsafe {
                    neon::axpy_f32_i8_neon(&mut axi, w, &k);
                    neon::axpy_f32_u4_neon(&mut axu, w, &p);
                    neon::add_assign_neon(&mut add, &delta);
                    neon::scale_in_place_neon(&mut sc, w);
                    neon::swiglu_row_neon(&q, &up, &mut swi);
                    neon::scale_mul_neon(&q, w, &delta, &mut sm);
                }
                assert_eq!(axi, want_axi, "neon axpy_i8 n={n}");
                assert_eq!(axu, want_axu, "neon axpy_u4 n={n}");
                assert_eq!(add, want_add, "neon add n={n}");
                assert_eq!(sc, want_scale, "neon scale n={n}");
                assert_eq!(swi, want_swi, "neon swiglu n={n}");
                assert_eq!(sm, want_sm, "neon scale_mul n={n}");
            }
        }
    }

    /// The AVX2 LUT gathers replicate the scalar pairwise trees
    /// bit-for-bit (byte path: `(t0+t1)+(t2+t3)`; nibble path: the
    /// left-associated 4-entry walk).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn lut_gathers_match_scalar_trees_bitwise() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let mut rng = Pcg::new(7);
        let table = rng.normal_vec(2 * 2048, 1.0);
        let ntable = rng.normal_vec(2 * 256, 1.0);
        for trial in 0..64u64 {
            let pw = rng.next_u64();
            let c0 = if trial % 2 == 0 { 0 } else { 2048 };
            // scalar byte tree, exactly as gemv_lut_range walks it
            let t = |j: usize| {
                table[c0 + j * 256 + ((pw >> (8 * j)) & 0xFF) as usize]
            };
            let q0 = t(0) + t(1);
            let q1 = t(2) + t(3);
            let q2 = t(4) + t(5);
            let q3 = t(6) + t(7);
            // SAFETY: AVX2 checked at fn entry; c0 + 2048 in bounds.
            let got = unsafe { lut_bytes_pair(&table, c0, pw) };
            assert_eq!(got, (q0 + q1, q2 + q3), "byte pw={pw:#x}");

            let nc0 = if trial % 2 == 0 { 0 } else { 256 };
            let nt = |j: usize| {
                ntable[nc0 + j * 16 + ((pw >> (4 * j)) & 0xF) as usize]
            };
            let mut qs = [0f32; 4];
            for (g, qv) in qs.iter_mut().enumerate() {
                for j in 0..4 {
                    *qv += nt(4 * g + j);
                }
            }
            // SAFETY: AVX2 checked at fn entry; nc0 + 256 in bounds.
            let got = unsafe { lut_nibbles_pair(&ntable, nc0, pw) };
            assert_eq!(got, (qs[0] + qs[1], qs[2] + qs[3]),
                       "nibble pw={pw:#x}");
        }
    }
}
