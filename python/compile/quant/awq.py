"""AWQ baseline — activation-aware weight quantization (ref. [15]).

Per-input-channel scaling s_j = E[|x_j|]^alpha chosen by grid search to
minimise the quantized layer-output error.  The scaled weight
W'[j, :] = s_j * W[j, :] is quantized; at inference the activation is
divided channel-wise (x'_j = x_j / s_j), which the Rust engine applies via
the exported ``act_scale`` vector.
"""

from __future__ import annotations

import numpy as np

from .gptq import StaticQuantLinear, dequantize, rtn_record


def awq_quantize(w: np.ndarray, x: np.ndarray, bits: int, group_size: int,
                 n_grid: int = 11) -> StaticQuantLinear:
    """w: (d_in, d_out); x: (n_tokens, d_in)."""
    w = np.asarray(w, np.float64)
    x = np.asarray(x, np.float64)
    mag = np.mean(np.abs(x), axis=0) + 1e-8          # (d_in,)
    y_ref = x @ w
    best_err, best = np.inf, None
    for alpha in np.linspace(0.0, 1.0, n_grid):
        s = mag ** alpha
        s = s / (np.sqrt(s.max() * s.min()) + 1e-12)  # normalise mid-range
        s = np.maximum(s, 1e-4)
        rec = rtn_record((w * s[:, None]).astype(np.float32), bits,
                         group_size)
        deq = dequantize(rec).astype(np.float64)
        y = (x / s) @ deq
        err = float(np.mean((y - y_ref) ** 2))
        if err < best_err:
            best_err = err
            best = rec._replace(act_scale=s.astype(np.float32),
                                transform="chan_scale")
    return best


def top_outlier_tokens(w: np.ndarray, x: np.ndarray,
                       rec: StaticQuantLinear, frac: float = 0.1
                       ) -> np.ndarray:
    """Indices of the top-``frac`` tokens by per-token quantization error.

    Used by the outlier-migration analyses (Fig. 1 right, App. E.1: the
    41% / 16% top-outlier overlap numbers).
    """
    deq = dequantize(rec).astype(np.float64)
    y_ref = x @ np.asarray(w, np.float64)
    y_q = (x / rec.act_scale.astype(np.float64)) @ deq
    err = np.sum((y_ref - y_q) ** 2, axis=-1)
    k = max(1, int(len(err) * frac))
    return np.argsort(err)[::-1][:k]
