//! Serving workload generation: request traces with arrival times, prompt
//! and generation lengths, plus a resource-pressure signal driving the
//! elastic precision controller (the paper's "dynamic runtime latency and
//! memory constraints" motivation, §1).

use crate::util::prng::Pcg;

#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: u64,
    /// Arrival offset from trace start, in milliseconds.
    pub arrival_ms: f64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// Mean arrival rate (requests per second), Poisson process.
    pub rate_per_s: f64,
    pub prompt_len: (usize, usize),   // uniform range
    pub gen_len: (usize, usize),
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 32,
            rate_per_s: 8.0,
            prompt_len: (8, 48),
            gen_len: (8, 32),
            seed: 0,
        }
    }
}

/// Sample a Poisson-arrival request trace with prompts cut from corpus
/// text.
pub fn generate_trace(corpus_tokens: &[u32], cfg: &TraceConfig)
                      -> Vec<RequestSpec> {
    let mut rng = Pcg::new(cfg.seed);
    let mut t_ms = 0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        // exponential inter-arrival
        let u = rng.f64().max(1e-12);
        t_ms += -u.ln() / cfg.rate_per_s * 1000.0;
        let plen = cfg.prompt_len.0
            + rng.below(cfg.prompt_len.1 - cfg.prompt_len.0 + 1);
        let glen = cfg.gen_len.0
            + rng.below(cfg.gen_len.1 - cfg.gen_len.0 + 1);
        let start = rng.below(corpus_tokens.len().saturating_sub(plen + 1));
        out.push(RequestSpec {
            id: id as u64,
            arrival_ms: t_ms,
            prompt: corpus_tokens[start..start + plen].to_vec(),
            max_new_tokens: glen,
        });
    }
    out
}

/// Piecewise resource-pressure signal in [0, 1]: 0 = abundant resources
/// (serve high precision), 1 = contended (drop precision).  Emulates the
/// edge-device contention scenario of §1.
#[derive(Debug, Clone)]
pub struct PressureSignal {
    segments: Vec<(f64, f64)>, // (until_ms, pressure)
}

impl PressureSignal {
    pub fn constant(p: f64) -> PressureSignal {
        PressureSignal { segments: vec![(f64::INFINITY, p)] }
    }

    /// Three-phase trace: calm -> contended -> recovering.
    pub fn phased(total_ms: f64) -> PressureSignal {
        PressureSignal {
            segments: vec![
                (total_ms * 0.33, 0.1),
                (total_ms * 0.66, 0.9),
                (f64::INFINITY, 0.4),
            ],
        }
    }

    /// Sinusoidal oscillation (period_ms), amplitude in [lo, hi].
    pub fn oscillating(period_ms: f64, lo: f64, hi: f64, steps: usize,
                       total_ms: f64) -> PressureSignal {
        let mut segments = Vec::new();
        for i in 0..steps {
            let t = total_ms * (i + 1) as f64 / steps as f64;
            let phase = 2.0 * std::f64::consts::PI * t / period_ms;
            let p = lo + (hi - lo) * 0.5 * (1.0 - phase.cos());
            segments.push((t, p));
        }
        segments.push((f64::INFINITY, lo));
        PressureSignal { segments }
    }

    pub fn at(&self, t_ms: f64) -> f64 {
        for &(until, p) in &self.segments {
            if t_ms < until {
                return p;
            }
        }
        self.segments.last().map(|&(_, p)| p).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_sized() {
        let toks: Vec<u32> = (0..4096).map(|i| i % 256).collect();
        let cfg = TraceConfig { n_requests: 16, ..Default::default() };
        let tr = generate_trace(&toks, &cfg);
        assert_eq!(tr.len(), 16);
        for w in tr.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        for r in &tr {
            assert!(r.prompt.len() >= cfg.prompt_len.0);
            assert!(r.prompt.len() <= cfg.prompt_len.1);
        }
    }

    #[test]
    fn trace_deterministic() {
        let toks: Vec<u32> = (0..2048).map(|i| i % 256).collect();
        let cfg = TraceConfig::default();
        let a = generate_trace(&toks, &cfg);
        let b = generate_trace(&toks, &cfg);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].prompt, b[3].prompt);
    }

    #[test]
    fn pressure_phases() {
        let p = PressureSignal::phased(300.0);
        assert!(p.at(10.0) < 0.2);
        assert!(p.at(150.0) > 0.8);
        assert!((p.at(250.0) - 0.4).abs() < 1e-9);
    }

    #[test]
    fn pressure_oscillates_in_range() {
        let p = PressureSignal::oscillating(100.0, 0.2, 0.8, 50, 500.0);
        for i in 0..50 {
            let v = p.at(i as f64 * 10.0);
            assert!((0.19..=0.81).contains(&v), "{v}");
        }
    }
}
