"""Layer-wise joint calibration — paper Alg. 1 (+ OmniQuant-lite baseline).

For each transformer block we match the quantized block's output to the
full-precision block's output on calibration activations, maintaining two
activation streams (H_fp, H_q) exactly as Alg. 1 does:

  Stage 1 — first-slice stabilisation: optimise the learnable weight
            clipping (LWC) parameters of the shared MSB slice only.
  Stage 2 — joint training: derive residual slices from the shared
            Theta_q, score tokens with MoBiRoute, anneal the gate
            temperature, and optimise reconstruction + budget
            regularisation (Eq. 9).

``mode="omniquant"`` runs the same pipeline with LWC only at a fixed target
bit-width and no router — our OmniQuant-lite baseline (the paper's PTQ
backbone).  Optimiser is a hand-rolled Adam (no optax in this environment).
"""

from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, QuantConfig
from ..model import attention, block as block_fwd, mlp, rmsnorm
from . import mobislice, quantizer
from . import router as router_mod
from .schedules import budget, gate_temperature

LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]
TAU_CAP = 50.0   # sigmoid(50*s) is numerically hard already; avoids inf*0 NaN


# ---------------------------------------------------------------------------
# Hand-rolled Adam over pytrees
# ---------------------------------------------------------------------------

def adam_init(params):
    return {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    ms = 1.0 / (1 - b1 ** t)
    vs = 1.0 / (1 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * ms) / (jnp.sqrt(v_ * vs) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Quantized linear paths
# ---------------------------------------------------------------------------

def clip_factors(raw: jnp.ndarray) -> jnp.ndarray:
    """LWC parameterisation: sigmoid keeps the clip factor in (0, 1)."""
    return jax.nn.sigmoid(raw)


def clipped_params(w, clip_raw_lo, clip_raw_hi, bits, group_size):
    return quantizer.calc_params(
        w, bits, group_size,
        clip_lo=clip_factors(clip_raw_lo), clip_hi=clip_factors(clip_raw_hi))


def static_quant_linear(w, clip_raw_lo, clip_raw_hi, bits, group_size):
    """OmniQuant-lite / stage-1 path: LWC + STE quantize-dequantize."""
    p = clipped_params(w, clip_raw_lo, clip_raw_hi, bits, group_size)
    return quantizer.quantize_ste(w, p)


def mobiq_linear(x, w, qp, rp, tau, qcfg: QuantConfig):
    """Token-routed MoBiSlice linear (Eq. 6).  Returns (y, scores, gates)."""
    base = clipped_params(w, qp["clip_lo"], qp["clip_hi"], qcfg.slice_bits,
                          qcfg.group_size)
    deqs = mobislice.decompose_ste(w, base, qcfg.n_slices, qcfg.slice_bits)
    s = router_mod.scores(rp, x)                       # (..., E-1)
    g = router_mod.gate_tau(s, tau)
    y = x @ deqs[0]                                    # shared expert slice
    for e in range(1, qcfg.n_slices):
        y = y + g[..., e - 1:e] * (x @ deqs[e])
    return y, s, g


def _quant_block_fwd(bp, qparams, rparams, x, tau, cfg: ModelConfig,
                     qcfg: QuantConfig, mode: str, bits: int):
    """Forward one transformer block with quantized linears.

    x: (B, T, d).  Returns (y, scores{name: (B,T,E-1)}, gates{...}).
    """
    def single(xb):
        scores_loc: Dict[str, jnp.ndarray] = {}
        gates_loc: Dict[str, jnp.ndarray] = {}

        def linear_fn(layer, name, xin, w):
            del layer
            if mode == "omniquant":
                wq = static_quant_linear(
                    w, qparams[name]["clip_lo"], qparams[name]["clip_hi"],
                    bits, qcfg.group_size)
                return xin @ wq
            if mode == "stage1":
                wq = static_quant_linear(
                    w, qparams[name]["clip_lo"], qparams[name]["clip_hi"],
                    qcfg.slice_bits, qcfg.group_size)
                return xin @ wq
            y, s, g = mobiq_linear(xin, w, qparams[name], rparams[name],
                                   tau, qcfg)
            scores_loc[name] = s
            gates_loc[name] = g
            return y

        y = block_fwd(xb, bp, cfg, 0, linear_fn)
        return y, scores_loc, gates_loc

    return jax.vmap(single)(x)


# ---------------------------------------------------------------------------
# Results containers
# ---------------------------------------------------------------------------

class LinearCalib(NamedTuple):
    clip_lo: np.ndarray        # raw (pre-sigmoid) LWC params (g, d_out)
    clip_hi: np.ndarray
    router: Optional[Dict[str, np.ndarray]]       # exported router arrays
    quantiles: Optional[np.ndarray]               # pooled score quantiles
    score_sample: Optional[np.ndarray]            # (n_tok, E-1) sample


class CalibResult(NamedTuple):
    mode: str
    bits: int                                     # omniquant target bits
    layers: List[Dict[str, LinearCalib]]
    history: List[Dict[str, float]]


# ---------------------------------------------------------------------------
# Main entry
# ---------------------------------------------------------------------------

def calibrate(params, cfg: ModelConfig, qcfg: QuantConfig,
              calib_tokens: np.ndarray, mode: str = "mobiq",
              bits: int = 3, seed: int = 0,
              schedule: Optional[str] = None,
              target_bits: Optional[float] = None,
              minibatch: int = 16, stage1_steps: int = 30,
              stage2_steps: int = 90,
              verbose: bool = True) -> CalibResult:
    """Run Alg. 1 over all blocks.

    calib_tokens: (nsamples, seq_len) int array.
    mode: "mobiq" (full method) or "omniquant" (LWC-only baseline @ bits).
    """
    schedule = schedule or qcfg.schedule
    target_bits = qcfg.target_bits if target_bits is None else target_bits
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    tokens = jnp.asarray(np.asarray(calib_tokens).astype(np.int32))
    h_fp = params["embed"][tokens]           # (B, T, d)
    h_q = h_fp

    layers_out: List[Dict[str, LinearCalib]] = []
    history: List[Dict[str, float]] = []
    t_start = time.time()
    n = tokens.shape[0]
    mb = min(minibatch, n)

    fp_block = jax.jit(lambda x, bp: jax.vmap(
        lambda xb: block_fwd(xb, bp, cfg, 0, lambda l, nm, xi, w: xi @ w))(x))

    # Jitted steps are defined ONCE and take the block params as traced
    # arguments, so every transformer block reuses the same compilation
    # (identical shapes across blocks) — a large win on this 1-core CPU.
    s1_bits = bits if mode == "omniquant" else qcfg.slice_bits
    s1_mode = "omniquant" if mode == "omniquant" else "stage1"

    def s1_loss(qp, bp, rp, x, y_ref):
        y, _, _ = _quant_block_fwd(bp, qp, rp, x, 1.0, cfg, qcfg,
                                   s1_mode, s1_bits)
        return jnp.mean((y - y_ref) ** 2)

    s1_step = jax.jit(jax.value_and_grad(s1_loss, argnums=0))

    def s2_loss(both, bp, x, y_ref, tau, b_t):
        qp, rp = both
        y, _, gates = _quant_block_fwd(bp, qp, rp, x, tau, cfg, qcfg,
                                       "mobiq", 0)
        rec = jnp.mean((y - y_ref) ** 2)
        reg = 0.0
        for name in LINEARS:
            reg = reg + router_mod.reg_loss_bt(
                gates[name], b_t, qcfg.base_bits, qcfg.slice_bits)
        return rec + qcfg.reg_lambda * reg / len(LINEARS)

    s2_step = jax.jit(jax.value_and_grad(s2_loss, argnums=0))

    prop_mobiq = jax.jit(lambda bp, qp, rp, x: _quant_block_fwd(
        bp, qp, rp, x, TAU_CAP, cfg, qcfg, "mobiq", 0)[0])
    prop_static = jax.jit(lambda bp, qp, rp, x: _quant_block_fwd(
        bp, qp, rp, x, 1.0, cfg, qcfg, "omniquant", bits)[0])

    for li, bp in enumerate(params["layers"]):
        qparams = {}
        for name in LINEARS:
            w = bp[name]
            g = quantizer.n_groups(w.shape[0], qcfg.group_size)
            init = jnp.full((g, w.shape[1]), 4.0)   # sigmoid(4) ~ 0.982
            qparams[name] = {"clip_lo": init, "clip_hi": init}
        rparams = {}
        for name in LINEARS:
            key, sub = jax.random.split(key)
            rparams[name] = router_mod.init_router(
                sub, bp[name].shape[0], qcfg.router_hidden,
                qcfg.n_slices - 1)

        y_fp_full = fp_block(h_fp, bp)

        # ------------------------- Stage 1: LWC ------------------------
        opt = adam_init(qparams)
        s1_final = float("nan")
        for _ in range(stage1_steps):
            idx = rng.choice(n, size=mb, replace=False)
            loss, grads = s1_step(qparams, bp, rparams, h_q[idx],
                                  y_fp_full[idx])
            qparams, opt = adam_update(qparams, grads, opt, qcfg.lwc_lr)
            s1_final = float(loss)

        # ------------------- Stage 2: joint MoBi training --------------
        s2_final = 0.0
        if mode == "mobiq":
            both = (qparams, rparams)
            opt = adam_init(both)
            for t in range(1, stage2_steps + 1):
                tau = min(gate_temperature(t, stage2_steps), TAU_CAP)
                b_t = budget(t, stage2_steps, qcfg.init_bits, target_bits,
                             schedule)
                idx = rng.choice(n, size=mb, replace=False)
                loss, grads = s2_step(both, bp, h_q[idx], y_fp_full[idx],
                                      jnp.float32(tau), jnp.float32(b_t))
                both, opt = adam_update(both, grads, opt, qcfg.mobi_lr)
                s2_final = float(loss)
            qparams, rparams = both

        # ------------------ Commit + propagate streams -----------------
        lin_out: Dict[str, LinearCalib] = {}
        all_scores: Dict[str, np.ndarray] = {}
        if mode == "mobiq":
            for name in LINEARS:
                xin = _linear_input(bp, cfg, h_q, name)
                s = router_mod.scores(rparams[name], xin)
                all_scores[name] = np.asarray(s).reshape(
                    -1, qcfg.n_slices - 1)

        for name in LINEARS:
            rexp = (router_mod.export_arrays(rparams[name])
                    if mode == "mobiq" else None)
            quant = (router_mod.score_quantiles(all_scores[name])
                     if mode == "mobiq" else None)
            sample = (all_scores[name][:512].astype(np.float32)
                      if mode == "mobiq" else None)
            lin_out[name] = LinearCalib(
                clip_lo=np.asarray(qparams[name]["clip_lo"], np.float32),
                clip_hi=np.asarray(qparams[name]["clip_hi"], np.float32),
                router=rexp, quantiles=quant, score_sample=sample)
        layers_out.append(lin_out)

        # propagate: H_fp through FP block, H_q through the quantized block
        h_fp = y_fp_full
        if mode == "mobiq":
            h_q = prop_mobiq(bp, qparams, rparams, h_q)
        else:
            h_q = prop_static(bp, qparams, rparams, h_q)

        history.append({"layer": li, "stage1_loss": s1_final,
                        "stage2_loss": s2_final,
                        "elapsed_s": time.time() - t_start})
        if verbose:
            print(f"  [calib:{mode}] block {li}: s1={s1_final:.5f} "
                  f"s2={s2_final:.5f} ({time.time() - t_start:.1f}s)",
                  flush=True)

    return CalibResult(mode=mode, bits=bits, layers=layers_out,
                       history=history)


def _linear_input(bp, cfg: ModelConfig, x, name: str):
    """Recompute the input activation feeding a given linear in a block.

    Used to collect router scores on the calibration set (App. C.2).
    x: (B, T, d) block input.
    """
    def plain(l, n, xi, w):
        return xi @ w

    xa = jax.vmap(lambda xb: rmsnorm(xb, bp["attn_norm"], cfg.norm_eps))(x)
    if name in ("wq", "wk", "wv"):
        return xa
    if name == "wo":
        outs = {}

        def hooked(xb):
            def hook(layer, n, xi, w):
                if n == "wo":
                    outs["x"] = xi
                return xi @ w
            attention(rmsnorm(xb, bp["attn_norm"], cfg.norm_eps), bp, cfg,
                      0, hook)
            return outs.pop("x")
        return jax.vmap(hooked)(x)
    # MLP linears: input is the post-attention residual, normed
    xr = x + jax.vmap(lambda xb: attention(
        rmsnorm(xb, bp["attn_norm"], cfg.norm_eps), bp, cfg, 0, plain))(x)
    xm = jax.vmap(lambda xb: rmsnorm(xb, bp["mlp_norm"], cfg.norm_eps))(xr)
    if name in ("w_gate", "w_up"):
        return xm
    g = xm @ bp["w_gate"]
    u = xm @ bp["w_up"]
    return jax.nn.silu(g) * u
