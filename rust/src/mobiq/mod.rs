//! The paper's core library: MoBiSlice bit-plane weights, shared-scale
//! shift-add GEMV kernels, MoBiRoute routing and elastic precision control.

pub mod artifact;
pub mod bitplane;
pub mod engine;
pub mod footprint;
pub mod gemv;
pub mod quantizer;
pub mod router;
pub mod static_quant;
