//! Minimal JSON parser / serializer (serde is not vendored here).
//!
//! Supports the full JSON grammar we emit from Python (`json.dumps` output:
//! objects, arrays, strings with escapes, numbers incl. exponents, bool,
//! null).  Used for `.mobiq` manifests, `manifest.json`, configs and
//! bench reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Path lookup: `v.path(&["model", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn literal(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // handle surrogate pairs
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("bad surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            s.push(char::from_u32(c)
                                .ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let chunk = &self.b[start..self.pos];
                        s.push_str(std::str::from_utf8(chunk).map_err(
                            |_| self.err("invalid utf-8"))?);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + (c as char).to_digit(16)
                    .ok_or_else(|| self.err("bad hex"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"},
                      "t": true, "n": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.path(&["b", "c"]).unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64().unwrap(),
                   -300.0);
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_deep() {
        let mut src = String::new();
        for _ in 0..100 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..100 {
            src.push(']');
        }
        assert!(parse(&src).is_ok());
    }
}
