//! Transformer request path: single-token decode with KV cache, plus the
//! batched block forwards (whole-prompt prefill, coalesced multi-sequence
//! decode) that feed the weight-stationary LUT-GEMM kernel.
//!
//! KV state lives in the process-wide paged [`KvArena`]
//! (`model/kvcache.rs`): every forward entry point takes the arena
//! plus a sequence handle, pages are claimed lazily as positions are
//! appended, and shared prompt prefixes map the same physical pages
//! into many sequences (the scheduler's prefix cache drives this).
//!
//! Attention runs through the blocked online-softmax subsystem in
//! `model/attention.rs`: RoPE angles come from cached tables, fresh K/V
//! rows land in the head-major arena pages in one fused rotate+scatter
//! pass, and a whole block's queries stream the cache in L1-sized tiles
//! (head-parallel on the shared `ThreadPool`; the coalesced decode tick
//! dispatches all slots' attention as one cross-slot `slot x head`
//! range).  The remaining elementwise stages — embedding gather,
//! per-token rmsnorm, SwiGLU combine, residual adds — run blockwise
//! over token chunks on the same persistent fork-join pool (see the
//! "Block-parallel elementwise stages" section below).

use std::sync::Arc;

use anyhow::Result;

use super::attention::{attention_block, attention_cross_slots,
                       AttnScratch, RopeCache};
use super::kvcache::{KvArena, KvHandle, KvPrecision, KV_PAGE};
use super::speculative::SpecCapture;
use super::weights::{load_fp_dense, load_linear, BackendKind,
                     LayerWeights, LinearBackend, ModelConfig,
                     LINEAR_NAMES};
use crate::mobiq::artifact::Bundle;
use crate::mobiq::engine::{Precision, Scratch};
use crate::util::threadpool::{SharedMut, ThreadPool};
use crate::util::tunable::TunableGate;

// Re-exported so existing call sites (benches, analysis probes) keep
// their `transformer::` paths after the attention split.
pub use super::attention::{attention_step, rope};

/// Aggregate decode statistics (Fig. 6 / Fig. 7 accounting).
#[derive(Debug, Clone, Default)]
pub struct DecodeStats {
    pub tokens: u64,
    pub linear_calls: u64,
    pub total_bits: u64,
    /// Histogram over effective bits per routed linear call, indexed by
    /// k = bits / slice_bits (bin 0 unused).
    pub bits_hist: Vec<u64>,
    /// Per (layer, linear) bit sums for block-level analysis.
    pub per_linear_bits: Vec<u64>,
    pub per_linear_calls: Vec<u64>,
}

impl DecodeStats {
    pub fn new(n_layers: usize) -> DecodeStats {
        DecodeStats {
            bits_hist: vec![0; 16],
            per_linear_bits: vec![0; n_layers * LINEAR_NAMES.len()],
            per_linear_calls: vec![0; n_layers * LINEAR_NAMES.len()],
            ..Default::default()
        }
    }

    pub fn avg_bits(&self) -> f64 {
        if self.linear_calls == 0 {
            return 0.0;
        }
        self.total_bits as f64 / self.linear_calls as f64
    }

    pub fn block_avg_bits(&self, layer: usize, lin: usize) -> f64 {
        let i = layer * LINEAR_NAMES.len() + lin;
        if self.per_linear_calls[i] == 0 {
            return 0.0;
        }
        self.per_linear_bits[i] as f64 / self.per_linear_calls[i] as f64
    }

    pub(crate) fn record(&mut self, layer: usize, lin: usize, bits: usize,
                         slice_bits: usize) {
        self.linear_calls += 1;
        self.total_bits += bits as u64;
        let k = (bits / slice_bits.max(1)).min(self.bits_hist.len() - 1);
        self.bits_hist[k] += 1;
        let i = layer * LINEAR_NAMES.len() + lin;
        self.per_linear_bits[i] += bits as u64;
        self.per_linear_calls[i] += 1;
    }
}

/// Decode scratch buffers (allocation-free steady state).
pub struct DecodeScratch {
    pub x: Vec<f32>,
    pub xn: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub ctx: Vec<f32>,
    pub attn_out: Vec<f32>,
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    pub ff: Vec<f32>,
    pub mlp_out: Vec<f32>,
    pub logits: Vec<f32>,
    /// staging copies so linear inputs and outputs can alias disjoint
    /// scratch fields without allocating in the decode loop (§Perf)
    pub stage: Vec<f32>,
    pub engine: Scratch,
    /// Cached RoPE tables (inverse frequencies once per model shape,
    /// sin/cos rows grown on demand) — no transcendentals in the token
    /// loop.
    pub rope: RopeCache,
    /// Per-head online-softmax state for the tiled attention kernel.
    pub attn: AttnScratch,
    /// Multi-token buffers for the batched forwards (prefill, coalesced
    /// decode); grow to the largest block seen, then stay put.
    pub block: BlockScratch,
}

/// Grow-on-demand activation buffers for the batched forward paths:
/// whole-prompt prefill and the coordinator's coalesced decode step.
/// All tensors are (T, dim) row-major over the block's tokens.
#[derive(Default)]
pub struct BlockScratch {
    pub xs: Vec<f32>,
    pub xn: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub ctx: Vec<f32>,
    pub attn_out: Vec<f32>,
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    pub ff: Vec<f32>,
    pub mlp_out: Vec<f32>,
    /// (T, vocab) lm_head output of the last batched call that asked
    /// for per-token logits (decode_batch leaves its rows here).
    pub logits: Vec<f32>,
    /// Per-token ids staged for the embedding gather (decode_batch
    /// collects slot tokens here so the gather can run blockwise).
    pub ids: Vec<u32>,
}

impl BlockScratch {
    fn ensure(&mut self, t: usize, d: usize, dkv: usize, d_ff: usize,
              vocab: usize) {
        grow(&mut self.xs, t * d);
        grow(&mut self.xn, t * d);
        grow(&mut self.q, t * d);
        grow(&mut self.k, t * dkv);
        grow(&mut self.v, t * dkv);
        grow(&mut self.ctx, t * d);
        grow(&mut self.attn_out, t * d);
        grow(&mut self.gate, t * d_ff);
        grow(&mut self.up, t * d_ff);
        grow(&mut self.ff, t * d_ff);
        grow(&mut self.mlp_out, t * d);
        grow(&mut self.logits, t * vocab);
    }
}

fn grow(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

/// Largest token block one batched pass materialises LUT tables for:
/// `BatchLut` keeps one capacity-sized `TokenLut` per block token, so
/// this caps that grow-only scratch while leaving enough tokens per
/// pass to amortize plane traffic (which saturates well before 64).
pub const MAX_PREFILL_BLOCK: usize = 64;

/// One active sequence's slot in a coalesced decode step: the token to
/// feed, its KV arena handle and its own routing-stats accumulator.
/// All slots of one `decode_batch` call share the arena the caller
/// passes alongside.
pub struct DecodeSlot<'a> {
    pub token: u32,
    pub seq: KvHandle,
    pub stats: &'a mut DecodeStats,
}

/// Record one batched linear's per-token effective bits.
pub(crate) fn record_block(stats: &mut DecodeStats, bits: &[usize],
                           layer: usize, lin: usize, slice_bits: usize) {
    for &b in bits {
        stats.record(layer, lin, b, slice_bits);
    }
}

/// Record one batched linear's effective bits into each slot's own
/// stats accumulator (slot i routed the batch's i-th token).
pub(crate) fn record_slots(slots: &mut [DecodeSlot], bits: &[usize],
                           layer: usize, lin: usize, slice_bits: usize) {
    for (s, &b) in slots.iter_mut().zip(bits) {
        s.stats.record(layer, lin, b, slice_bits);
    }
}

// ---------------------------------------------------------------------------
// Block-parallel elementwise stages
// ---------------------------------------------------------------------------
//
// After PR 1 (batched linears) and PR 2 (tiled attention), the Amdahl
// remainder of a block forward was these per-token loops: the embedding
// gather, the rmsnorm passes, the SwiGLU gate*up combine and the
// residual adds.  With the persistent fork-join pool a dispatch costs
// ~2 µs, so they are worth chunking over tokens too.  Every helper
// runs the exact same per-token math in the same order as its serial
// loop — workers only partition *which rows* they touch — so
// parallel == serial stays bit-identical (`tests/parallel_parity.rs`).

/// Minimum f32 element count (t x row width) in a blockwise
/// elementwise pass before the fork-join dispatch pays for itself:
/// ~2 µs of dispatch vs ~1 elem/ns of streaming elementwise math, with
/// a 4x margin (EXPERIMENTS.md §Runtime).
pub const ELEMENTWISE_PARALLEL_MIN: usize = 1 << 13;

/// Runtime-overridable view of [`ELEMENTWISE_PARALLEL_MIN`]:
/// `MOBIQ_ELEMENTWISE_PARALLEL_MIN` or
/// `ServerConfig.elementwise_parallel_min` moves the dispatch gate
/// without a rebuild.  Dispatch only — per-row math is identical.
pub static ELEMENTWISE_PARALLEL_MIN_GATE: TunableGate =
    TunableGate::new("MOBIQ_ELEMENTWISE_PARALLEL_MIN",
                     ELEMENTWISE_PARALLEL_MIN);

/// One scaffold for every block helper: run `body(i, row)` for each
/// token row `i in 0..t` (`row` = the `width`-wide &mut slice of `out`
/// at row i), chunked over the pool when `t * width` clears the gate
/// (serial otherwise — tiny blocks, size-1 pools, t == 1).  The gate
/// check and the unsafe row partitioning live only here.
fn par_rows(t: usize, width: usize, pool: Option<&ThreadPool>,
            out: &mut [f32], body: impl Fn(usize, &mut [f32]) + Sync) {
    debug_assert!(out.len() >= t * width);
    let parallel = pool.filter(|p| {
        p.size() > 1 && t > 1
            && t * width >= ELEMENTWISE_PARALLEL_MIN_GATE.get()
    });
    let Some(p) = parallel else {
        for (i, row) in out[..t * width].chunks_exact_mut(width)
            .enumerate() {
            body(i, row);
        }
        return;
    };
    let optr = SharedMut(out.as_mut_ptr());
    p.parallel_chunks(t, |lo, hi| {
        // SAFETY: parallel_chunks hands out disjoint token ranges, so
        // each worker materialises &mut only over its own rows of
        // `out`, which the caller exclusively borrows.
        let rows = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(lo * width),
                                           (hi - lo) * width)
        };
        for (i, row) in (lo..hi).zip(rows.chunks_exact_mut(width)) {
            body(i, row);
        }
    });
}

/// Per-token [`rmsnorm`] over a `(t, d)` block, token-parallel.
fn rmsnorm_block(xs: &[f32], w: &[f32], eps: f32, t: usize, d: usize,
                 pool: Option<&ThreadPool>, out: &mut [f32]) {
    debug_assert!(xs.len() >= t * d);
    par_rows(t, d, pool, out, |i, row| {
        rmsnorm(&xs[i * d..(i + 1) * d], w, eps, row);
    });
}

/// Residual add `acc[..t*d] += delta[..t*d]`, token-parallel.
fn add_block(acc: &mut [f32], delta: &[f32], t: usize, d: usize,
             pool: Option<&ThreadPool>) {
    debug_assert!(delta.len() >= t * d);
    par_rows(t, d, pool, acc, |i, row| {
        crate::util::simd::add_assign(row, &delta[i * d..(i + 1) * d]);
    });
}

/// SwiGLU combine `ff = silu(gate) * up` over a `(t, d_ff)` block,
/// token-parallel.
fn swiglu_block(gate: &[f32], up: &[f32], t: usize, d_ff: usize,
                pool: Option<&ThreadPool>, ff: &mut [f32]) {
    debug_assert!(gate.len() >= t * d_ff && up.len() >= t * d_ff);
    par_rows(t, d_ff, pool, ff, |i, row| {
        let lo = i * d_ff;
        crate::util::simd::swiglu_row(&gate[lo..lo + d_ff],
                                      &up[lo..lo + d_ff], row);
    });
}

/// Embedding-row gather `out[i] = embed[ids[i]]`, token-parallel.
/// Callers have already validated `ids` against the vocab.
fn gather_embed_block(embed: &[f32], ids: &[u32], d: usize,
                      pool: Option<&ThreadPool>, out: &mut [f32]) {
    par_rows(ids.len(), d, pool, out, |i, row| {
        let e = ids[i] as usize * d;
        row.copy_from_slice(&embed[e..e + d]);
    });
}

pub struct Model {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: LinearBackend,
    /// Shared kernel worker pool; scratches from [`Model::new_scratch`]
    /// inherit it so the d_out-parallel kernel paths engage.
    pub pool: Option<Arc<ThreadPool>>,
}

impl Model {
    /// Load with a uniform backend kind for all quantizable linears.
    pub fn load(bundle: &Bundle, kind: BackendKind) -> Result<Model> {
        let cfg = ModelConfig::from_bundle(bundle)?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let lin = |name: &str| load_linear(bundle, &cfg, li, name, &kind);
            layers.push(LayerWeights {
                attn_norm: bundle
                    .f32(&format!("fp.layers.{li}.attn_norm"))?.1.to_vec(),
                mlp_norm: bundle
                    .f32(&format!("fp.layers.{li}.mlp_norm"))?.1.to_vec(),
                wq: lin("wq")?,
                wk: lin("wk")?,
                wv: lin("wv")?,
                wo: lin("wo")?,
                w_gate: lin("w_gate")?,
                w_up: lin("w_up")?,
                w_down: lin("w_down")?,
            });
        }
        Ok(Model {
            embed: bundle.f32("fp.embed")?.1.to_vec(),
            final_norm: bundle.f32("fp.final_norm")?.1.to_vec(),
            lm_head: load_fp_dense(bundle, "fp.lm_head")?,
            cfg,
            layers,
            pool: None,
        })
    }

    /// Attach a shared kernel worker pool (e.g. from the `--threads`
    /// CLI flag); subsequently created scratches inherit it.
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }

    pub fn new_scratch(&self) -> DecodeScratch {
        let c = &self.cfg;
        let dkv = c.kv_dim();
        let mut engine = Scratch::new(c.d_model.max(c.d_ff), c.group_size,
                                      c.router_hidden, c.n_slices);
        if let Some(p) = &self.pool {
            engine = engine.with_pool(Arc::clone(p));
        }
        DecodeScratch {
            x: vec![0f32; c.d_model],
            xn: vec![0f32; c.d_model.max(c.d_ff)],
            q: vec![0f32; c.d_model],
            k: vec![0f32; dkv],
            v: vec![0f32; dkv],
            ctx: vec![0f32; c.d_model],
            attn_out: vec![0f32; c.d_model],
            gate: vec![0f32; c.d_ff],
            up: vec![0f32; c.d_ff],
            ff: vec![0f32; c.d_ff],
            mlp_out: vec![0f32; c.d_model],
            logits: vec![0f32; c.vocab_size],
            stage: vec![0f32; c.d_model.max(c.d_ff)],
            engine,
            rope: RopeCache::new(c.head_dim(), c.rope_theta),
            attn: AttnScratch::new(),
            block: BlockScratch::default(),
        }
    }

    /// Paged KV arena sized so `n_seqs` sequences can each reach the
    /// full `max_seq_len` context — the conservative budget.  Serving
    /// deployments pass a smaller explicit page budget through
    /// [`Model::new_arena_with_pages`] and let the scheduler's
    /// admission backpressure enforce it.
    pub fn new_arena(&self, n_seqs: usize) -> KvArena {
        let c = &self.cfg;
        let pages = n_seqs.max(1) * c.n_layers
            * ((c.max_seq_len + KV_PAGE - 1) / KV_PAGE);
        self.new_arena_with_pages(pages)
    }

    /// Paged KV arena with an explicit page budget (global across
    /// layers and sequences).
    pub fn new_arena_with_pages(&self, capacity_pages: usize) -> KvArena {
        let c = &self.cfg;
        KvArena::new(c.n_layers, c.max_seq_len, c.n_kv_heads,
                     c.head_dim(), capacity_pages)
    }

    /// Single-sequence convenience: a one-sequence arena plus its
    /// allocated handle (what the eager `SequenceKv` slab used to be;
    /// pages are still claimed lazily as the sequence grows).
    pub fn new_kv(&self) -> (KvArena, KvHandle) {
        self.new_kv_at(KvPrecision::F32)
    }

    /// [`Model::new_kv`] with the sequence's KV pages stored at a
    /// chosen precision — the arena quantizes K/V rows at scatter time
    /// (fused with the K-side RoPE rotation) and the attention kernels
    /// dequantize inside their tiles, so every forward entry point
    /// works unchanged over quantized pages.
    pub fn new_kv_at(&self, prec: KvPrecision) -> (KvArena, KvHandle) {
        let mut arena = self.new_arena(1);
        let seq = arena.alloc_seq_at(prec);
        (arena, seq)
    }

    /// Decode one token at position `arena.seq_len(seq)`; returns
    /// logits in `scratch.logits` and records routing stats.
    pub fn decode_step(&self, token: u32, arena: &mut KvArena,
                       seq: KvHandle, precision: Precision,
                       scratch: &mut DecodeScratch,
                       stats: &mut DecodeStats) -> Result<()> {
        let c = &self.cfg;
        let d = c.d_model;
        let pos = arena.seq_len(seq);
        anyhow::ensure!(pos < c.max_seq_len, "sequence too long");
        anyhow::ensure!((token as usize) < c.vocab_size, "token oob");
        scratch.x.copy_from_slice(
            &self.embed[token as usize * d..(token as usize + 1) * d]);
        scratch.rope.ensure(pos + 1);
        let pool = self.pool.as_deref();

        for (li, lw) in self.layers.iter().enumerate() {
            // ---- attention ----
            rmsnorm(&scratch.x, &lw.attn_norm, c.norm_eps,
                    &mut scratch.xn[..d]);
            let xn = &scratch.xn[..d];
            let run =
                |name: &str, x: &[f32], out: &mut [f32],
                 eng: &mut Scratch| -> Result<usize> {
                    Ok(self.layers[li].linear(name)?
                        .forward_token(x, precision, eng, out))
                };
            let b = run("wq", xn, &mut scratch.q, &mut scratch.engine)?;
            stats.record(li, 0, b, c.slice_bits);
            let b = run("wk", xn, &mut scratch.k, &mut scratch.engine)?;
            stats.record(li, 1, b, c.slice_bits);
            let b = run("wv", xn, &mut scratch.v, &mut scratch.engine)?;
            stats.record(li, 2, b, c.slice_bits);

            scratch.rope.apply(&mut scratch.q, pos);
            arena.append_kv_block(seq, li, &scratch.rope, &scratch.k,
                                  &scratch.v, 1)?;
            let view = arena.layer(seq, li);
            attention_block(c, &scratch.q, &view, pos, 1,
                            &mut scratch.attn, pool, &mut scratch.ctx);
            scratch.stage[..d].copy_from_slice(&scratch.ctx);
            let b = run("wo", &scratch.stage[..d], &mut scratch.attn_out,
                        &mut scratch.engine)?;
            stats.record(li, 3, b, c.slice_bits);
            crate::util::simd::add_assign(&mut scratch.x,
                                          &scratch.attn_out[..d]);

            // ---- mlp ----
            rmsnorm(&scratch.x, &lw.mlp_norm, c.norm_eps,
                    &mut scratch.xn[..d]);
            scratch.stage[..d].copy_from_slice(&scratch.xn[..d]);
            let b = run("w_gate", &scratch.stage[..d], &mut scratch.gate,
                        &mut scratch.engine)?;
            stats.record(li, 4, b, c.slice_bits);
            let b = run("w_up", &scratch.stage[..d], &mut scratch.up,
                        &mut scratch.engine)?;
            stats.record(li, 5, b, c.slice_bits);
            crate::util::simd::swiglu_row(&scratch.gate, &scratch.up,
                                          &mut scratch.ff);
            let ff = c.d_ff;
            scratch.stage[..ff].copy_from_slice(&scratch.ff);
            let b = run("w_down", &scratch.stage[..ff],
                        &mut scratch.mlp_out, &mut scratch.engine)?;
            stats.record(li, 6, b, c.slice_bits);
            crate::util::simd::add_assign(&mut scratch.x,
                                          &scratch.mlp_out[..d]);
        }
        stats.tokens += 1;

        rmsnorm(&scratch.x, &self.final_norm, c.norm_eps,
                &mut scratch.xn[..d]);
        scratch.stage[..d].copy_from_slice(&scratch.xn[..d]);
        // split borrow: stage is read-only input, logits the output
        let (stage, logits) = (&scratch.stage[..d], &mut scratch.logits);
        self.lm_head.forward_token(stage, precision, &mut scratch.engine,
                                   logits);
        Ok(())
    }

    /// Batched block forward core shared by prefill, the PPL evaluator
    /// and the probe capture: feeds `tokens` (one sequence, positions
    /// `kv.len()..`) through every layer with **one batched
    /// weight-stationary kernel call per linear**, so each plane word
    /// streams once per mask group instead of once per token.
    ///
    /// * `all_logits: Some(out)` appends every token's logits row to
    ///   `out` and mirrors the last row into `scratch.logits`.
    /// * `all_logits: None` runs the lm_head for the last token only
    ///   (the decode loop discards the others anyway).
    /// * `capture: Some((layer, rows))` pushes each token's attn-norm
    ///   input at `layer` (the Fig. 1/5 probe) and skips the lm_head.
    /// * `spec: Some(cap)` is the speculative **verify** mode: the
    ///   linears stay batched, but KV lands one position at a time with
    ///   per-position attention — the same append granularity as
    ///   [`Model::decode_step`], so quantized page scales widen in
    ///   straight-line order and the logits match a run of decode
    ///   steps bit-for-bit.  Each position's pre-RoPE K/V linear
    ///   outputs are captured into `cap` so a rejection can roll back
    ///   and re-commit only the accepted rows (`model/speculative.rs`).
    fn prefill_inner(&self, tokens: &[u32], arena: &mut KvArena,
                     seq: KvHandle, precision: Precision,
                     scratch: &mut DecodeScratch,
                     stats: &mut DecodeStats,
                     mut all_logits: Option<&mut Vec<f32>>,
                     mut capture: Option<(usize, &mut Vec<Vec<f32>>)>,
                     mut spec: Option<&mut SpecCapture>)
                     -> Result<()> {
        let c = &self.cfg;
        let t = tokens.len();
        if t == 0 {
            return Ok(());
        }
        let d = c.d_model;
        let dkv = c.kv_dim();
        let d_ff = c.d_ff;
        let pos0 = arena.seq_len(seq);
        anyhow::ensure!(pos0 + t <= c.max_seq_len, "sequence too long");
        for &tok in tokens {
            anyhow::ensure!((tok as usize) < c.vocab_size, "token oob");
        }
        let need_logits = all_logits.is_some();
        scratch.block.ensure(t, d, dkv, d_ff,
                             if need_logits { c.vocab_size } else { 0 });
        if let Some(cap) = spec.as_deref_mut() {
            cap.begin(self.layers.len(), t, dkv);
        }
        scratch.rope.ensure(pos0 + t);
        let pool = self.pool.as_deref();
        let bb = &mut scratch.block;
        gather_embed_block(&self.embed, tokens, d, pool,
                           &mut bb.xs[..t * d]);

        for (li, lw) in self.layers.iter().enumerate() {
            // ---- attention ----
            rmsnorm_block(&bb.xs[..t * d], &lw.attn_norm, c.norm_eps, t,
                          d, pool, &mut bb.xn[..t * d]);
            if let Some((cl, rows)) = capture.as_mut() {
                if *cl == li {
                    for i in 0..t {
                        rows.push(bb.xn[i * d..(i + 1) * d].to_vec());
                    }
                }
            }
            lw.wq.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.q[..t * d]);
            record_block(stats, &scratch.engine.batch.bits, li, 0,
                         c.slice_bits);
            lw.wk.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.k[..t * dkv]);
            record_block(stats, &scratch.engine.batch.bits, li, 1,
                         c.slice_bits);
            lw.wv.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.v[..t * dkv]);
            record_block(stats, &scratch.engine.batch.bits, li, 2,
                         c.slice_bits);
            if let Some(cap) = spec.as_deref_mut() {
                // Speculative verify: capture the pre-RoPE K/V rows,
                // then append + attend one position at a time.  A
                // block-wide quantized append takes its absmax over
                // all t rows at once, which is *not* the scale
                // trajectory t single-token decode steps would have
                // produced — serializing only the KV commit keeps the
                // verify bit-identical to `decode_step` while the
                // seven linears above still run batched.
                cap.save_layer(li, &bb.k[..t * dkv], &bb.v[..t * dkv]);
                for i in 0..t {
                    let pos = pos0 + i;
                    scratch.rope.apply(&mut bb.q[i * d..(i + 1) * d],
                                       pos);
                    arena.append_kv_block(
                        seq, li, &scratch.rope,
                        &bb.k[i * dkv..(i + 1) * dkv],
                        &bb.v[i * dkv..(i + 1) * dkv], 1)?;
                    let view = arena.layer(seq, li);
                    attention_block(c, &bb.q[i * d..(i + 1) * d],
                                    &view, pos, 1, &mut scratch.attn,
                                    pool,
                                    &mut bb.ctx[i * d..(i + 1) * d]);
                }
            } else {
                // RoPE from the cached tables, then land the whole
                // block's K/V in the head-major arena pages (fused
                // rotate+scatter, COW/page claims inside), then one
                // tiled attention pass over all t queries — causality
                // is masked inside the kernel instead of being
                // sequenced through per-position pushes.
                for i in 0..t {
                    scratch.rope.apply(&mut bb.q[i * d..(i + 1) * d],
                                       pos0 + i);
                }
                arena.append_kv_block(seq, li, &scratch.rope,
                                      &bb.k[..t * dkv],
                                      &bb.v[..t * dkv], t)?;
                let view = arena.layer(seq, li);
                attention_block(c, &bb.q[..t * d], &view, pos0, t,
                                &mut scratch.attn, pool,
                                &mut bb.ctx[..t * d]);
            }
            lw.wo.forward_batch(&bb.ctx[..t * d], precision,
                                &mut scratch.engine,
                                &mut bb.attn_out[..t * d]);
            record_block(stats, &scratch.engine.batch.bits, li, 3,
                         c.slice_bits);
            add_block(&mut bb.xs, &bb.attn_out, t, d, pool);

            // ---- mlp ----
            rmsnorm_block(&bb.xs[..t * d], &lw.mlp_norm, c.norm_eps, t,
                          d, pool, &mut bb.xn[..t * d]);
            lw.w_gate.forward_batch(&bb.xn[..t * d], precision,
                                    &mut scratch.engine,
                                    &mut bb.gate[..t * d_ff]);
            record_block(stats, &scratch.engine.batch.bits, li, 4,
                         c.slice_bits);
            lw.w_up.forward_batch(&bb.xn[..t * d], precision,
                                  &mut scratch.engine,
                                  &mut bb.up[..t * d_ff]);
            record_block(stats, &scratch.engine.batch.bits, li, 5,
                         c.slice_bits);
            swiglu_block(&bb.gate, &bb.up, t, d_ff, pool, &mut bb.ff);
            lw.w_down.forward_batch(&bb.ff[..t * d_ff], precision,
                                    &mut scratch.engine,
                                    &mut bb.mlp_out[..t * d]);
            record_block(stats, &scratch.engine.batch.bits, li, 6,
                         c.slice_bits);
            add_block(&mut bb.xs, &bb.mlp_out, t, d, pool);
        }
        stats.tokens += t as u64;
        if capture.is_some() {
            return Ok(());
        }

        if need_logits {
            rmsnorm_block(&bb.xs[..t * d], &self.final_norm, c.norm_eps,
                          t, d, pool, &mut bb.xn[..t * d]);
            let v = c.vocab_size;
            self.lm_head.forward_batch(&bb.xn[..t * d], precision,
                                       &mut scratch.engine,
                                       &mut bb.logits[..t * v]);
            if let Some(out) = all_logits.as_mut() {
                out.extend_from_slice(&bb.logits[..t * v]);
            }
            scratch.logits.copy_from_slice(&bb.logits[(t - 1) * v..t * v]);
        } else {
            rmsnorm(&bb.xs[(t - 1) * d..t * d], &self.final_norm,
                    c.norm_eps, &mut bb.xn[..d]);
            let (xn, logits) = (&bb.xn[..d], &mut scratch.logits);
            self.lm_head.forward_token(xn, precision, &mut scratch.engine,
                                       logits);
        }
        Ok(())
    }

    /// Prefill a whole prompt block starting at position
    /// `arena.seq_len(seq)`.  The block's last-token logits are left
    /// in `scratch.logits`; the lm_head is skipped for earlier tokens
    /// (the decode loop discards them anyway).
    pub fn prefill(&self, tokens: &[u32], arena: &mut KvArena,
                   seq: KvHandle, precision: Precision,
                   scratch: &mut DecodeScratch,
                   stats: &mut DecodeStats) -> Result<()> {
        for chunk in tokens.chunks(MAX_PREFILL_BLOCK) {
            self.prefill_inner(chunk, arena, seq, precision, scratch,
                               stats, None, None, None)?;
        }
        Ok(())
    }

    /// Prefill that also appends every token's logits row ((T, vocab)
    /// row-major) to `out` — the batched replacement for per-token
    /// decode in the PPL evaluator and golden-vector parity tests.
    pub fn prefill_logits(&self, tokens: &[u32], arena: &mut KvArena,
                          seq: KvHandle, precision: Precision,
                          scratch: &mut DecodeScratch,
                          stats: &mut DecodeStats, out: &mut Vec<f32>)
                          -> Result<()> {
        for chunk in tokens.chunks(MAX_PREFILL_BLOCK) {
            self.prefill_inner(chunk, arena, seq, precision, scratch,
                               stats, Some(out), None, None)?;
        }
        Ok(())
    }

    /// Batched **verify** forward for the speculative accept loop
    /// (`model/speculative.rs`): feed the pending token plus the draft
    /// tokens through the batched linears with per-position KV commit
    /// (see [`Model::prefill_inner`]'s `spec` mode), appending every
    /// token's logits row ((T, vocab) row-major) to `out` and the
    /// pre-RoPE K/V rows to `cap`.
    pub fn verify_logits(&self, tokens: &[u32], arena: &mut KvArena,
                         seq: KvHandle, precision: Precision,
                         scratch: &mut DecodeScratch,
                         stats: &mut DecodeStats,
                         cap: &mut SpecCapture, out: &mut Vec<f32>)
                         -> Result<()> {
        anyhow::ensure!(tokens.len() <= MAX_PREFILL_BLOCK,
                        "verify block exceeds MAX_PREFILL_BLOCK");
        self.prefill_inner(tokens, arena, seq, precision, scratch,
                           stats, Some(out), None, Some(cap))
    }

    /// Advance several sequences by one token each through **one
    /// batched kernel call per linear and one cross-slot attention
    /// dispatch per layer** — the coordinator's coalesced decode step
    /// with no per-sequence serialization left.  All slots live in the
    /// shared paged `arena`; each keeps its own handle, position and
    /// stats.  Per-slot logits rows land in `scratch.block.logits`
    /// ((n_slots, vocab) row-major, slot order).
    pub fn decode_batch(&self, slots: &mut [DecodeSlot],
                        arena: &mut KvArena, precision: Precision,
                        scratch: &mut DecodeScratch) -> Result<()> {
        let c = &self.cfg;
        let t = slots.len();
        if t == 0 {
            return Ok(());
        }
        let d = c.d_model;
        let dkv = c.kv_dim();
        let d_ff = c.d_ff;
        let mut max_pos = 0usize;
        for s in slots.iter() {
            let len = arena.seq_len(s.seq);
            anyhow::ensure!(len < c.max_seq_len, "sequence too long");
            anyhow::ensure!((s.token as usize) < c.vocab_size,
                            "token oob");
            max_pos = max_pos.max(len);
        }
        scratch.block.ensure(t, d, dkv, d_ff, c.vocab_size);
        scratch.rope.ensure(max_pos + 1);
        let pool = self.pool.as_deref();
        let bb = &mut scratch.block;
        bb.ids.clear();
        bb.ids.extend(slots.iter().map(|s| s.token));
        gather_embed_block(&self.embed, &bb.ids, d, pool,
                           &mut bb.xs[..t * d]);

        for (li, lw) in self.layers.iter().enumerate() {
            rmsnorm_block(&bb.xs[..t * d], &lw.attn_norm, c.norm_eps, t,
                          d, pool, &mut bb.xn[..t * d]);
            lw.wq.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.q[..t * d]);
            record_slots(slots, &scratch.engine.batch.bits, li, 0,
                         c.slice_bits);
            lw.wk.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.k[..t * dkv]);
            record_slots(slots, &scratch.engine.batch.bits, li, 1,
                         c.slice_bits);
            lw.wv.forward_batch(&bb.xn[..t * d], precision,
                                &mut scratch.engine, &mut bb.v[..t * dkv]);
            record_slots(slots, &scratch.engine.batch.bits, li, 2,
                         c.slice_bits);
            // Land every slot's fresh K/V first (serial: one RoPE'd
            // row per slot), then run attention for the whole batch in
            // ONE cross-slot fork-join dispatch over the flattened
            // slot x head grid — the last per-sequence serialization
            // in the coalesced tick.  The slot's position at this
            // layer is the layer's own table length (seq_len() reads
            // layer 0, whose row for this token has already landed
            // once li > 0 — using it here shifted RoPE by one position
            // and attended over an uninitialised row for layers >= 1).
            for (i, s) in slots.iter().enumerate() {
                let pos = arena.layer_len(s.seq, li);
                scratch.rope.apply(&mut bb.q[i * d..(i + 1) * d], pos);
                arena.append_kv_block(s.seq, li, &scratch.rope,
                                      &bb.k[i * dkv..(i + 1) * dkv],
                                      &bb.v[i * dkv..(i + 1) * dkv],
                                      1)?;
            }
            // t <= max_decode_batch page-table views, rebuilt per
            // layer (they borrow the arena, which the append phase
            // above needs mutably).
            let views: Vec<_> = slots.iter()
                .map(|s| arena.layer(s.seq, li))
                .collect();
            attention_cross_slots(c, &bb.q[..t * d], &views,
                                  &mut scratch.attn, pool,
                                  &mut bb.ctx[..t * d]);
            drop(views);
            lw.wo.forward_batch(&bb.ctx[..t * d], precision,
                                &mut scratch.engine,
                                &mut bb.attn_out[..t * d]);
            record_slots(slots, &scratch.engine.batch.bits, li, 3,
                         c.slice_bits);
            add_block(&mut bb.xs, &bb.attn_out, t, d, pool);

            rmsnorm_block(&bb.xs[..t * d], &lw.mlp_norm, c.norm_eps, t,
                          d, pool, &mut bb.xn[..t * d]);
            lw.w_gate.forward_batch(&bb.xn[..t * d], precision,
                                    &mut scratch.engine,
                                    &mut bb.gate[..t * d_ff]);
            record_slots(slots, &scratch.engine.batch.bits, li, 4,
                         c.slice_bits);
            lw.w_up.forward_batch(&bb.xn[..t * d], precision,
                                  &mut scratch.engine,
                                  &mut bb.up[..t * d_ff]);
            record_slots(slots, &scratch.engine.batch.bits, li, 5,
                         c.slice_bits);
            swiglu_block(&bb.gate, &bb.up, t, d_ff, pool, &mut bb.ff);
            lw.w_down.forward_batch(&bb.ff[..t * d_ff], precision,
                                    &mut scratch.engine,
                                    &mut bb.mlp_out[..t * d]);
            record_slots(slots, &scratch.engine.batch.bits, li, 6,
                         c.slice_bits);
            add_block(&mut bb.xs, &bb.mlp_out, t, d, pool);
        }
        for s in slots.iter_mut() {
            s.stats.tokens += 1;
        }

        rmsnorm_block(&bb.xs[..t * d], &self.final_norm, c.norm_eps, t,
                      d, pool, &mut bb.xn[..t * d]);
        let v = c.vocab_size;
        self.lm_head.forward_batch(&bb.xn[..t * d], precision,
                                   &mut scratch.engine,
                                   &mut bb.logits[..t * v]);
        Ok(())
    }

    /// Full-sequence forward; returns (T, vocab) logits row-major.
    /// Used by the PPL evaluator and the golden-vector parity tests.
    pub fn forward_logits(&self, tokens: &[u32], precision: Precision)
                          -> Result<Vec<f32>> {
        let (mut arena, seq) = self.new_kv();
        let mut scratch = self.new_scratch();
        let mut stats = DecodeStats::new(self.cfg.n_layers);
        let mut out = Vec::with_capacity(tokens.len()
            * self.cfg.vocab_size);
        self.prefill_logits(tokens, &mut arena, seq, precision,
                            &mut scratch, &mut stats, &mut out)?;
        Ok(out)
    }

    /// FP-stream activations feeding layer `layer`'s attention linears
    /// (rmsnorm'd block inputs) for each token — the probe used by the
    /// outlier-migration analyses (Figs. 1, 5; App. E.1/E.2).  Probes
    /// run in ctx-length windows through the batched prefill.
    pub fn attn_inputs(&self, tokens: &[u32], layer: usize,
                       precision: Precision) -> Result<Vec<Vec<f32>>> {
        let (mut arena, seq) = self.new_kv();
        let mut scratch = self.new_scratch();
        let mut stats = DecodeStats::new(self.cfg.n_layers);
        let mut out = Vec::with_capacity(tokens.len());
        let win = self.cfg.max_seq_len.saturating_sub(1).max(1);
        for window in tokens.chunks(win) {
            arena.reset_seq(seq);
            for chunk in window.chunks(MAX_PREFILL_BLOCK) {
                self.prefill_inner(chunk, &mut arena, seq, precision,
                                   &mut scratch, &mut stats, None,
                                   Some((layer, &mut out)), None)?;
            }
        }
        Ok(out)
    }

    /// Canonical prefill→argmax head of every greedy loop: prefill
    /// `tokens` and return the greedy next token.  `generate`,
    /// `resume`, the speculative loop and the scheduler all start
    /// here, so tie-break behaviour (see [`argmax`]) is pinned in one
    /// place.
    pub fn greedy_prefill(&self, tokens: &[u32], arena: &mut KvArena,
                          seq: KvHandle, precision: Precision,
                          scratch: &mut DecodeScratch,
                          stats: &mut DecodeStats) -> Result<u32> {
        anyhow::ensure!(!tokens.is_empty(),
                        "greedy prefill needs at least one token");
        self.prefill(tokens, arena, seq, precision, scratch, stats)?;
        Ok(argmax(&scratch.logits) as u32)
    }

    /// Canonical greedy decode step: feed `token`, return the greedy
    /// next token.  The speculative draft loop uses this too — there
    /// is exactly one decode→argmax path in the codebase.
    pub fn greedy_step(&self, token: u32, arena: &mut KvArena,
                       seq: KvHandle, precision: Precision,
                       scratch: &mut DecodeScratch,
                       stats: &mut DecodeStats) -> Result<u32> {
        self.decode_step(token, arena, seq, precision, scratch, stats)?;
        Ok(argmax(&scratch.logits) as u32)
    }

    /// Greedy-sample continuation of a prompt (used by examples/serving):
    /// batched prefill over the whole prompt, then per-token decode.
    pub fn generate(&self, prompt: &[u32], n_new: usize,
                    precision: Precision, stats: &mut DecodeStats)
                    -> Result<Vec<u32>> {
        self.generate_at(prompt, n_new, precision, KvPrecision::F32,
                         stats)
    }

    /// [`Model::generate`] with the sequence's KV pages stored at a
    /// chosen precision — the straight-line oracle the speculative
    /// parity suite compares against at every KV precision.
    pub fn generate_at(&self, prompt: &[u32], n_new: usize,
                       precision: Precision, kv_prec: KvPrecision,
                       stats: &mut DecodeStats) -> Result<Vec<u32>> {
        let (mut arena, seq) = self.new_kv_at(kv_prec);
        let mut scratch = self.new_scratch();
        let mut toks = prompt.to_vec();
        if n_new == 0 || prompt.is_empty() {
            return Ok(toks);
        }
        let mut last = self.greedy_prefill(prompt, &mut arena, seq,
                                           precision, &mut scratch,
                                           stats)?;
        toks.push(last);
        for _ in 1..n_new {
            last = self.greedy_step(last, &mut arena, seq, precision,
                                    &mut scratch, stats)?;
            toks.push(last);
        }
        Ok(toks)
    }

    /// Resume-from-preemption entry: rebuild a parked sequence's KV
    /// state into `seq` and return the next greedy token.  Two shapes
    /// of parked state are accepted:
    ///
    /// * a **fresh handle** (`seq_len == 0`) — re-prefill `tokens`,
    ///   the prompt *plus every token generated before preemption*;
    /// * a **host-parked handle** — a sequence whose cold prefix was
    ///   swapped to the host tier at preemption.  The prefix is
    ///   restored first (byte-exact memcpy; see
    ///   [`KvArena::swap_in_seq`]) and only the *unparked suffix*
    ///   `tokens[seq_len..]` is re-fed, at its absolute positions.
    ///
    /// Decoding is greedy and KV content is a pure function of the
    /// token prefix, so both shapes return exactly the token the
    /// preempted decode would have produced; the scheduler's resume
    /// admission uses the same property chunk-by-chunk, this is the
    /// one-shot form for tests and embedders driving the model
    /// directly.
    pub fn resume(&self, tokens: &[u32], arena: &mut KvArena,
                  seq: KvHandle, precision: Precision,
                  scratch: &mut DecodeScratch,
                  stats: &mut DecodeStats) -> Result<u32> {
        anyhow::ensure!(!tokens.is_empty(),
                        "resume needs at least one token");
        if arena.seq_swapped_pages(seq) > 0 {
            arena.swap_in_seq(seq)?;
        }
        let done = arena.seq_len(seq);
        anyhow::ensure!(done < tokens.len(),
                        "resume needs at least one token past the \
                         parked KV prefix");
        self.greedy_prefill(&tokens[done..], arena, seq, precision,
                            scratch, stats)
    }
}

// ---------------------------------------------------------------------------
// math helpers (mirror python/compile/model.py)
// ---------------------------------------------------------------------------

pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    // With SIMD enabled the Σx² reduction follows the lane-blocked
    // order (util::simd contract); each dispatch mode is internally
    // self-consistent, and `MOBIQ_SIMD=off` keeps the pre-SIMD
    // sequential sum below byte-for-byte.
    if crate::util::simd::enabled() {
        crate::util::simd::rmsnorm_row(x, w, eps, out);
        return;
    }
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for ((o, xi), wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * r * wi;
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Greedy sampling: index of the maximum logit.
///
/// **Tie-break contract (load-bearing for speculative decoding):** on
/// exact ties the *lowest* index wins — the strict `>` only replaces
/// the running best when a later logit exceeds it.  Draft and verify
/// passes compare token ids, so both sides must resolve a tied row to
/// the same id; any change here (e.g. `>=`, or a reversed scan) would
/// make speculative acceptance diverge from [`Model::generate`] on
/// tied logits while both outputs were still "a valid argmax".  NaN
/// logits never win for the same reason (every comparison with NaN is
/// false), so a poisoned row degrades to index 0 deterministically
/// rather than picking a platform-dependent token.  Pinned by
/// `argmax_tie_breaks_to_first`.
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit() {
        let x = vec![3.0, 4.0];
        let w = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt(12.5); out = x / rms
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        let orig = v.clone();
        rope(&mut v, 0, 4, 10000.0);
        assert_eq!(v, orig); // angle 0 at pos 0
        rope(&mut v, 7, 4, 10000.0);
        let n0: f32 = orig.iter().map(|x| x * x).sum();
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-4);
        assert_ne!(v, orig);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    /// The tie-break contract draft-vs-verify acceptance relies on:
    /// first max wins, everywhere, deterministically.
    #[test]
    fn argmax_tie_breaks_to_first() {
        // two-way and three-way exact ties resolve to the lowest index
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[3.0, 3.0, 3.0]), 0);
        assert_eq!(argmax(&[-1.0, -1.0]), 0);
        // ties among negatives and at the end of the row
        assert_eq!(argmax(&[-5.0, -2.0, -2.0]), 1);
        assert_eq!(argmax(&[0.0, 1.0, 1.0]), 1);
        // NaN never wins (all comparisons false): earlier finite max
        // stays, and an all-NaN row degrades to index 0
        assert_eq!(argmax(&[2.0, f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        // infinities follow the same first-max rule
        assert_eq!(argmax(&[f32::INFINITY, f32::INFINITY, 0.0]), 0);
    }

    /// Shapes big enough that `par_rows` engages the pool: the block
    /// helpers must be bit-identical to their serial loops.
    #[test]
    fn elementwise_blocks_parallel_match_serial() {
        use crate::util::prng::Pcg;
        let pool = ThreadPool::new(3);
        let (t, d) = (64usize, 256usize); // t*d = 16384 > gate
        assert!(t * d >= ELEMENTWISE_PARALLEL_MIN);
        let mut rng = Pcg::new(41);
        let xs = rng.normal_vec(t * d, 1.0);
        let w = rng.normal_vec(d, 0.3);

        let mut serial = vec![0f32; t * d];
        rmsnorm_block(&xs, &w, 1e-5, t, d, None, &mut serial);
        let mut par = vec![0f32; t * d];
        rmsnorm_block(&xs, &w, 1e-5, t, d, Some(&pool), &mut par);
        assert_eq!(serial, par, "rmsnorm_block");

        let delta = rng.normal_vec(t * d, 1.0);
        let mut acc_s = xs.clone();
        add_block(&mut acc_s, &delta, t, d, None);
        let mut acc_p = xs.clone();
        add_block(&mut acc_p, &delta, t, d, Some(&pool));
        assert_eq!(acc_s, acc_p, "add_block");

        let gate = rng.normal_vec(t * d, 1.0);
        let up = rng.normal_vec(t * d, 1.0);
        let mut ff_s = vec![0f32; t * d];
        swiglu_block(&gate, &up, t, d, None, &mut ff_s);
        let mut ff_p = vec![0f32; t * d];
        swiglu_block(&gate, &up, t, d, Some(&pool), &mut ff_p);
        assert_eq!(ff_s, ff_p, "swiglu_block");

        let vocab = 32usize;
        let embed = rng.normal_vec(vocab * d, 0.5);
        let ids: Vec<u32> = (0..t).map(|i| ((i * 13 + 5) % vocab) as u32)
            .collect();
        let mut e_s = vec![0f32; t * d];
        gather_embed_block(&embed, &ids, d, None, &mut e_s);
        let mut e_p = vec![0f32; t * d];
        gather_embed_block(&embed, &ids, d, Some(&pool), &mut e_p);
        assert_eq!(e_s, e_p, "gather_embed_block");
    }

    /// Below the gate (or on size-1 pools) the helpers must take the
    /// serial path and still produce correct results.
    #[test]
    fn elementwise_blocks_small_and_serial_pools() {
        let pool1 = ThreadPool::new(1);
        let (t, d) = (2usize, 8usize);
        let xs: Vec<f32> = (0..t * d).map(|i| i as f32 * 0.1).collect();
        let w = vec![1.0f32; d];
        let mut a = vec![0f32; t * d];
        rmsnorm_block(&xs, &w, 1e-5, t, d, Some(&pool1), &mut a);
        let mut b = vec![0f32; t * d];
        for i in 0..t {
            rmsnorm(&xs[i * d..(i + 1) * d], &w, 1e-5,
                    &mut b[i * d..(i + 1) * d]);
        }
        assert_eq!(a, b);
    }
}
