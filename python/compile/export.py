""".mobiq artifact bundle writer.

Binary layout (little-endian, parsed by rust/src/mobiq/artifact.rs):

    bytes 0..8    magic  b"MOBIQ1\\0\\0"
    bytes 8..16   u64    manifest_len (JSON, utf-8)
    bytes 16..16+manifest_len   JSON manifest
    then, 8-byte aligned, the raw tensor blob.

The manifest carries the model/quant configs plus a tensor directory:
``{"tensors": {name: {"dtype": "f32|u8|i32|u64", "shape": [...],
"offset": int, "nbytes": int}}, ...}`` with offsets relative to the blob
start.  Everything the Rust engine needs at runtime — FP weights, MoBiSlice
bit-planes + shared scales, routers + threshold quantiles, static-PTQ
baseline records, golden vectors — lives in one self-contained file, so the
request path never touches Python.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

import numpy as np

MAGIC = b"MOBIQ1\x00\x00"
_DTYPES = {"f32": np.float32, "u8": np.uint8, "i32": np.int32,
           "u64": np.uint64}


class BundleWriter:
    def __init__(self) -> None:
        self._tensors: Dict[str, np.ndarray] = {}
        self.meta: Dict[str, object] = {}

    def add(self, name: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        assert name not in self._tensors, f"duplicate tensor {name}"
        self._tensors[name] = arr

    def write(self, path: str) -> None:
        directory = {}
        blobs: List[bytes] = []
        offset = 0
        for name, arr in self._tensors.items():
            dt = {np.dtype(np.float32): "f32", np.dtype(np.uint8): "u8",
                  np.dtype(np.int32): "i32",
                  np.dtype(np.uint64): "u64"}[arr.dtype]
            raw = arr.tobytes()
            pad = (-len(raw)) % 8
            directory[name] = {"dtype": dt, "shape": list(arr.shape),
                               "offset": offset, "nbytes": len(raw)}
            blobs.append(raw + b"\x00" * pad)
            offset += len(raw) + pad
        manifest = dict(self.meta)
        manifest["tensors"] = directory
        mjson = json.dumps(manifest).encode("utf-8")
        mpad = (-(16 + len(mjson))) % 8
        with open(path, "wb") as f:
            f.write(MAGIC)
            f.write(np.uint64(len(mjson) + mpad).tobytes())
            f.write(mjson + b" " * mpad)
            for b in blobs:
                f.write(b)


def read_bundle(path: str):
    """Python-side reader (tests / analysis)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC
    mlen = int(np.frombuffer(data[8:16], np.uint64)[0])
    manifest = json.loads(data[16:16 + mlen].decode("utf-8"))
    blob = data[16 + mlen:]
    tensors = {}
    for name, info in manifest["tensors"].items():
        dt = _DTYPES[info["dtype"]]
        raw = blob[info["offset"]:info["offset"] + info["nbytes"]]
        tensors[name] = np.frombuffer(raw, dt).reshape(info["shape"]).copy()
    return manifest, tensors


# ---------------------------------------------------------------------------
# Assembly helpers
# ---------------------------------------------------------------------------

def add_fp_params(w: BundleWriter, params) -> None:
    w.add("fp.embed", np.asarray(params["embed"]))
    w.add("fp.final_norm", np.asarray(params["final_norm"]))
    w.add("fp.lm_head", np.asarray(params["lm_head"]))
    for i, lp in enumerate(params["layers"]):
        for name, v in lp.items():
            w.add(f"fp.layers.{i}.{name}", np.asarray(v))


def add_mobiq(w: BundleWriter, params, calib, qcfg) -> None:
    """MoBiSlice bit-planes + shared scales + routers from a CalibResult."""
    from .quant import mobislice
    from .quant.calibrate import clipped_params, LINEARS

    for i, (lp, lc) in enumerate(zip(params["layers"], calib.layers)):
        for name in LINEARS:
            wmat = np.asarray(lp[name])
            cal = lc[name]
            base = clipped_params(
                np.asarray(wmat), cal.clip_lo, cal.clip_hi,
                qcfg.slice_bits, qcfg.group_size)
            sw = mobislice.decompose(wmat, base, qcfg.n_slices,
                                     qcfg.slice_bits)
            pre = f"mobiq.layers.{i}.{name}"
            for e, codes in enumerate(sw.codes):
                planes = mobislice.pack_bitplanes(np.asarray(codes),
                                                  qcfg.slice_bits)
                w.add(f"{pre}.slice{e}.planes", planes)
            w.add(f"{pre}.scale", np.asarray(base.scale, np.float32))
            w.add(f"{pre}.zero", np.asarray(base.zero, np.float32))
            w.add(f"{pre}.router.w1", cal.router["w1"])
            w.add(f"{pre}.router.b1", cal.router["b1"])
            w.add(f"{pre}.router.w2", cal.router["w2"])
            w.add(f"{pre}.router.b2", cal.router["b2"])
            w.add(f"{pre}.quantiles", cal.quantiles)
            w.add(f"{pre}.score_sample", cal.score_sample)


def add_static_record(w: BundleWriter, method: str, layer: int, name: str,
                      rec) -> None:
    pre = f"static.{method}.layers.{layer}.{name}"
    w.add(f"{pre}.codes", rec.codes)
    w.add(f"{pre}.scale", rec.scale)
    w.add(f"{pre}.zero", rec.zero)
    w.add(f"{pre}.act_scale", rec.act_scale)


def static_meta(method: str, bits: int, transform: str) -> Dict:
    return {"method": method, "bits": bits, "transform": transform}


def add_golden(w: BundleWriter, tokens: np.ndarray,
               logits: Dict[str, np.ndarray]) -> None:
    w.add("golden.tokens", tokens.astype(np.int32))
    for k, v in logits.items():
        w.add(f"golden.{k}", v.astype(np.float32))


def model_meta(cfg, qcfg) -> Dict:
    return {"model": dataclasses.asdict(cfg),
            "quant": dataclasses.asdict(qcfg)}
