//! The decode scheduler: continuous batching with elastic precision
//! over the process-wide paged KV arena.
//!
//! Each tick the scheduler (1) picks the tick's precision from the
//! elastic controller, (2) admits queued requests against *real free
//! byte counts* (worst-case bytes for prompt + generation headroom at
//! the request's KV storage precision — an i8 request reserves a
//! quarter of an f32 one — discounted by any shared prompt prefix
//! found in the prefix cache), (3) advances every
//! active sequence by one token — prefilling sequences consume a whole
//! prompt chunk through one batched kernel call, and all decoding
//! sequences are **coalesced into one batched call per layer**
//! (`Model::decode_batch`) — and (4) retires finished sequences,
//! returning their pages to the arena's free list.  The structure
//! mirrors a vLLM-style continuous batcher with paged attention.
//!
//! ## Prefix sharing
//!
//! The "million users, one system prompt" scenario: when a sequence
//! finishes prefill at a single precision, its page-aligned prompt
//! prefix is parked in a small LRU cache (a forked arena handle keeps
//! the pages alive).  A later request whose prompt starts with a
//! cached prefix *at the same weight precision AND the same KV storage
//! precision* forks those pages instead of recomputing them — prefill
//! skips the shared tokens entirely, and the arena's refcounts/COW
//! keep writers isolated.  KV content is a pure function of (token
//! prefix, weight precision, KV storage precision, weights), so shared
//! pages are bit-identical to recomputed ones; a cached f32-page
//! prefix must never be forked into an i8 sequence (or vice versa) —
//! the pools do not even share page-id spaces.  At least one prompt
//! token is always re-fed so the last-token logits that seed the first
//! generated token exist.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{Admission, Batcher};
use super::controller::ElasticController;
use super::metrics::Metrics;
use super::request::{Request, RequestMetrics, Response};
use crate::mobiq::engine::Precision;
use crate::model::kvcache::{KvArena, KvHandle, KvPrecision, KV_PAGE};
use crate::model::transformer::{argmax, DecodeScratch, DecodeSlot,
                                DecodeStats};
use crate::model::Model;

/// Max parked shared-prefix entries; the LRU entry is evicted on
/// insertion past this, or one per tick under page backpressure.
const PREFIX_CACHE_MAX: usize = 16;

struct ActiveSeq {
    req: Request,
    seq: KvHandle,
    tokens: Vec<u32>,
    prompt_len: usize,
    /// Tokens that have entered the model; starts at the shared-prefix
    /// length when admission attached cached pages.
    fed: usize,
    generated: usize,
    /// Storage precision of this sequence's KV pages (from the
    /// request).
    kv_prec: KvPrecision,
    /// Worst-case budget bytes reserved at admission (minus the shared
    /// discount); with `bytes_at_admission` this bounds what the
    /// sequence may still allocate.
    reserved_bytes: usize,
    bytes_at_admission: usize,
    /// Precision every prefill chunk ran at so far; entries are only
    /// registered in the prefix cache when this stayed uniform.
    prefill_prec: Option<Precision>,
    prefill_uniform: bool,
    registered: bool,
    stats: DecodeStats,
    prefill_ms: f64,
    decode_ms: f64,
    admitted_at: Instant,
}

impl ActiveSeq {
    /// Budget bytes this sequence may still claim from the arena (its
    /// admission reservation minus what it has already allocated).
    fn reserved_remaining(&self, arena: &KvArena) -> usize {
        let grown = arena.seq_bytes(self.seq)
            .saturating_sub(self.bytes_at_admission);
        self.reserved_bytes.saturating_sub(grown)
    }
}

/// One parked shared prompt prefix: `handle` is a cache-owned arena
/// sequence whose pages hold the KV of `tokens` computed at weight
/// precision `precision` and stored at `kv_prec` — both are part of
/// the match key, since pages of different storage precisions hold
/// different bytes in different pools.
struct PrefixEntry {
    tokens: Vec<u32>,
    precision: Precision,
    kv_prec: KvPrecision,
    handle: KvHandle,
    last_used: u64,
}

pub struct Scheduler<'m> {
    pub model: &'m Model,
    pub batcher: Batcher,
    pub controller: ElasticController,
    pub metrics: Metrics,
    /// The process-wide paged KV pool all sequences live in.
    pub arena: KvArena,
    active: Vec<ActiveSeq>,
    prefix: Vec<PrefixEntry>,
    scratch: DecodeScratch,
    started: Instant,
    ticks: u64,
}

/// Worst-case budget bytes a request needs: its (truncated) prompt
/// plus full generation headroom, across all layers, at its KV
/// storage precision.
fn worst_bytes(arena: &KvArena, prompt_len: usize, max_new: usize,
               kv_prec: KvPrecision) -> usize {
    arena.seq_worst_bytes(prompt_len + max_new, kv_prec)
}

/// Longest usable shared prefix of `prompt` in the cache at this
/// (weight precision, KV storage precision) pair: returns
/// `(entry index, shared token count)`.  Capped at `prompt.len() - 1`
/// (one token must be re-fed for its logits) and gated at one full
/// page (shorter shares are not worth a fork+COW).
fn best_prefix(entries: &[PrefixEntry], prompt: &[u32],
               precision: Precision, kv_prec: KvPrecision)
               -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize)> = None;
    for (i, e) in entries.iter().enumerate() {
        if e.precision != precision || e.kv_prec != kv_prec {
            continue;
        }
        let cap = prompt.len().saturating_sub(1).min(e.tokens.len());
        let mut n = 0usize;
        while n < cap && prompt[n] == e.tokens[n] {
            n += 1;
        }
        let better = match best {
            None => true,
            Some((_, bn)) => bn < n,
        };
        if n >= KV_PAGE && better {
            best = Some((i, n));
        }
    }
    best
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, batcher: Batcher,
               controller: ElasticController) -> Scheduler<'m> {
        let mut scratch = model.new_scratch();
        // Pre-warm the RoPE sin/cos tables over the whole context
        // budget: the cache grows on demand, but growing it mid-tick
        // would show up as a latency blip on whichever request first
        // reaches a new position.  One-off cost at server start.
        scratch.rope.ensure(model.cfg.max_seq_len);
        // Same for the fork-join workers: they normally spawn lazily
        // on the first parallel dispatch, which would charge thread
        // creation to the first request's tick.
        if let Some(pool) = &model.pool {
            pool.warm();
        }
        // The arena: an explicit page budget commits less memory than
        // the worst case (admission queues when pages run short);
        // otherwise size it so every slot can reach full context.
        let arena = match batcher.kv_page_budget {
            Some(pages) => model.new_arena_with_pages(pages),
            None => model.new_arena(batcher.max_active),
        };
        Scheduler {
            scratch,
            model,
            batcher,
            controller,
            metrics: Metrics::default(),
            arena,
            active: Vec::new(),
            prefix: Vec::new(),
            started: Instant::now(),
            ticks: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        if matches!(self.batcher.submit(req), Admission::Rejected) {
            self.metrics.rejected += 1;
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.batcher.queued() == 0
    }

    /// Drop the least-recently-used prefix entry, returning its pages.
    fn evict_lru_prefix(&mut self) {
        let Some(i) = self.prefix.iter().enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        else {
            return;
        };
        let e = self.prefix.swap_remove(i);
        self.arena.free_seq(e.handle);
        self.metrics.prefix_evictions += 1;
    }

    /// One scheduling tick under the given external pressure.
    /// Returns the number of model steps executed.
    pub fn tick(&mut self, external_pressure: f64) -> Result<usize> {
        self.ticks += 1;

        // 1. precision for this tick — decided up front so admission
        // can match prefix-cache entries against it
        let precision = self.controller
            .update(external_pressure, self.batcher.pressure());

        // 2. admission against real free bytes: each queued request
        // needs its worst-case bytes (at its KV storage precision)
        // minus any full pages a cached shared prefix provides; bytes
        // other active sequences have reserved but not yet allocated
        // are held back
        let max_seq = self.model.cfg.max_seq_len;
        let n_layers = self.model.cfg.n_layers;
        let max_prompt = move |req: &Request| {
            max_seq.saturating_sub(req.max_new_tokens + 1).max(1)
                .min(req.prompt.len())
        };
        // requests that could never run — empty prompt (no token to
        // seed generation) or a worst case exceeding the whole arena —
        // are rejected up front instead of deadlocking the FIFO behind
        // them (the dropped reply sender surfaces as a disconnect)
        let capacity = self.arena.capacity_bytes();
        while let Some(front) = self.batcher.peek() {
            let impossible = front.prompt.is_empty() || {
                let plen = max_prompt(front);
                worst_bytes(&self.arena, plen, front.max_new_tokens,
                            front.kv_precision) > capacity
            };
            if !impossible {
                break;
            }
            let _ = self.batcher.drop_head();
            self.metrics.rejected += 1;
        }
        let held: usize = self.active.iter()
            .map(|s| s.reserved_remaining(&self.arena))
            .sum();
        let avail = self.arena.free_bytes().saturating_sub(held);
        let deferred_before = self.batcher.deferred();
        // prefix matches are recorded here by the accounting closure
        // (one scan per request) and reused for the fork below — the
        // cache must not change in between, which is why eviction
        // waits until after the admitted loop
        let mut hits: Vec<Option<(usize, usize)>> = Vec::new();
        let admitted = {
            let arena = &self.arena;
            let prefix = &self.prefix;
            let n_active = self.active.len();
            self.batcher.admit_with(n_active, avail, |req| {
                let plen = max_prompt(req);
                let worst = worst_bytes(arena, plen,
                                        req.max_new_tokens,
                                        req.kv_precision);
                let hit = best_prefix(prefix, &req.prompt[..plen],
                                      precision, req.kv_precision);
                hits.push(hit);
                // only full shared pages are free; a shared partial
                // page may still cost its COW copy, which `worst`
                // already counts
                let shared = hit.map_or(0, |(_, n)| n);
                let discount = n_layers * (shared / KV_PAGE)
                    * arena.page_bytes_at(req.kv_precision);
                worst.saturating_sub(discount)
            })
        };
        // the closure also ran once for a deferred head, if any
        hits.truncate(admitted.len());
        let page_blocked =
            self.batcher.deferred() > deferred_before;
        self.metrics.admissions_deferred +=
            self.batcher.deferred() - deferred_before;

        for (req, hit) in admitted.into_iter().zip(hits) {
            let plen = max_prompt(&req);
            let kv_prec = req.kv_precision;
            let mut tokens = req.prompt.clone();
            tokens.truncate(plen);
            let worst = worst_bytes(&self.arena, plen,
                                    req.max_new_tokens, kv_prec);
            // attach the shared prefix (fork = refcount bump, no copy;
            // best_prefix only matched entries at this KV storage
            // precision, so the fork lands in the right pool)
            let (seq, shared, reserved) = match hit {
                Some((i, n)) => {
                    self.prefix[i].last_used = self.ticks;
                    debug_assert_eq!(self.prefix[i].kv_prec, kv_prec,
                                     "prefix hit across KV precisions");
                    let h = self.arena
                        .fork_prefix(self.prefix[i].handle, n);
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefix_tokens_reused += n as u64;
                    let discount = self.model.cfg.n_layers
                        * (n / KV_PAGE)
                        * self.arena.page_bytes_at(kv_prec);
                    (h, n, worst.saturating_sub(discount))
                }
                None => {
                    self.metrics.prefix_misses += 1;
                    (self.arena.alloc_seq_at(kv_prec), 0, worst)
                }
            };
            let bytes_at_admission = self.arena.seq_bytes(seq);
            self.active.push(ActiveSeq {
                seq,
                prompt_len: tokens.len(),
                fed: shared,
                kv_prec,
                reserved_bytes: reserved,
                bytes_at_admission,
                prefill_prec: (shared > 0).then_some(precision),
                prefill_uniform: true,
                registered: false,
                tokens,
                generated: 0,
                stats: DecodeStats::new(self.model.cfg.n_layers),
                prefill_ms: 0.0,
                decode_ms: 0.0,
                admitted_at: Instant::now(),
                req,
            });
        }
        // under page pressure, reclaim cache pages one entry per tick
        // — after the admitted forks, so a just-matched entry cannot
        // disappear between its page accounting and its fork (evicting
        // a forked entry is harmless: the fork holds its own refs)
        if page_blocked && !self.prefix.is_empty() {
            self.evict_lru_prefix();
        }

        // 3. advance sequences: prefill chunks first (one batched call
        // per chunk), then one coalesced decode step across every
        // sequence that was already past prefill at tick start.
        let model = self.model;
        let mut steps = 0usize;
        let decode_ready: Vec<bool> = self.active.iter()
            .map(|s| s.fed >= s.prompt_len)
            .collect();
        let prefill_chunk = self.batcher.prefill_chunk;

        // 3a. chunked prefill — a whole prompt chunk per tick through
        // the weight-stationary kernel instead of per-token decodes.
        for (seq, &ready) in self.active.iter_mut().zip(&decode_ready) {
            if ready {
                continue;
            }
            let t0 = Instant::now();
            let end = (seq.fed + prefill_chunk).min(seq.prompt_len);
            model.prefill(&seq.tokens[seq.fed..end], &mut self.arena,
                          seq.seq, precision, &mut self.scratch,
                          &mut seq.stats)?;
            match seq.prefill_prec {
                None => seq.prefill_prec = Some(precision),
                Some(p) if p != precision => seq.prefill_uniform = false,
                _ => {}
            }
            steps += end - seq.fed;
            seq.fed = end;
            seq.prefill_ms += t0.elapsed().as_secs_f64() * 1000.0;
            if seq.fed == seq.prompt_len {
                // emit first generated token right after prefill
                let next = argmax(&self.scratch.logits) as u32;
                seq.tokens.push(next);
                seq.generated = 1;
            }
        }

        // 3b. register freshly completed, uniform-precision prompts in
        // the prefix cache (page-aligned prefix; the fork only bumps
        // refcounts).  Registration is what turns the *next* identical
        // prompt into a page-table copy instead of a recompute.
        for i in 0..self.active.len() {
            let (attempt, worth, aligned, prec, kv_prec) = {
                let s = &self.active[i];
                let aligned = (s.prompt_len / KV_PAGE) * KV_PAGE;
                (s.fed == s.prompt_len && !s.registered,
                 s.prefill_uniform && aligned >= KV_PAGE,
                 aligned,
                 s.prefill_prec,
                 s.kv_prec)
            };
            if !attempt {
                continue;
            }
            // one registration attempt per sequence, made the tick its
            // prefill completes
            self.active[i].registered = true;
            if !worth {
                continue;
            }
            let Some(prec) = prec else { continue };
            let cand = &self.active[i].tokens[..aligned];
            // the same token prefix at a different KV storage
            // precision is a different entry: its pages hold different
            // bytes in a different pool
            let covered = self.prefix.iter().any(|e| {
                e.precision == prec && e.kv_prec == kv_prec
                    && e.tokens.len() >= aligned
                    && e.tokens[..aligned] == *cand
            });
            if covered {
                continue;
            }
            if self.prefix.len() >= PREFIX_CACHE_MAX {
                self.evict_lru_prefix();
            }
            let cand = self.active[i].tokens[..aligned].to_vec();
            let handle = self.arena
                .fork_prefix(self.active[i].seq, aligned);
            self.prefix.push(PrefixEntry {
                tokens: cand,
                precision: prec,
                kv_prec,
                handle,
                last_used: self.ticks,
            });
        }

        // 3c. coalesced decode: fuse ready sequences (up to
        // max_decode_batch per group) into one batched call per layer.
        let vocab = model.cfg.vocab_size;
        let cap = self.batcher.max_decode_batch;
        let arena = &mut self.arena;
        let mut ready: Vec<&mut ActiveSeq> = self.active.iter_mut()
            .zip(&decode_ready)
            .filter_map(|(s, &r)| if r { Some(s) } else { None })
            .collect();
        for group in ready.chunks_mut(cap) {
            let t0 = Instant::now();
            {
                let mut slots: Vec<DecodeSlot> = group.iter_mut()
                    .map(|seq| DecodeSlot {
                        token: seq.tokens[seq.fed],
                        seq: seq.seq,
                        stats: &mut seq.stats,
                    })
                    .collect();
                model.decode_batch(&mut slots, arena, precision,
                                   &mut self.scratch)?;
            }
            // per-token latency attribution: the batch advanced every
            // member one token in one wall interval
            let ms = t0.elapsed().as_secs_f64() * 1000.0
                / group.len() as f64;
            for (row, seq) in group.iter_mut().enumerate() {
                let lo = row * vocab;
                let next = argmax(
                    &self.scratch.block.logits[lo..lo + vocab]) as u32;
                seq.fed += 1;
                seq.tokens.push(next);
                seq.generated += 1;
                seq.decode_ms += ms;
                self.metrics.record_token(ms);
                steps += 1;
            }
        }
        drop(ready);

        let mut finished: Vec<usize> = Vec::new();
        for (i, seq) in self.active.iter().enumerate() {
            let kv_full = self.arena.seq_len(seq.seq) + 1
                >= self.model.cfg.max_seq_len;
            if seq.generated >= seq.req.max_new_tokens || kv_full {
                finished.push(i);
            }
        }

        // 4. retire: pages go back to the free list (minus any still
        // shared with the prefix cache or forked siblings)
        for &i in finished.iter().rev() {
            let seq = self.active.swap_remove(i);
            self.arena.free_seq(seq.seq);
            let total_ms =
                seq.req.submitted.elapsed().as_secs_f64() * 1000.0;
            let queue_ms =
                (seq.admitted_at - seq.req.submitted).as_secs_f64() * 1000.0;
            let prompt_len = seq.prompt_len;
            let resp = Response {
                id: seq.req.id,
                generated: seq.tokens[prompt_len..].to_vec(),
                tokens: seq.tokens,
                metrics: RequestMetrics {
                    queue_ms,
                    prefill_ms: seq.prefill_ms,
                    decode_ms: seq.decode_ms,
                    total_ms,
                    generated_tokens: seq.generated,
                    avg_bits: seq.stats.avg_bits(),
                },
            };
            self.metrics.record_request(total_ms, seq.generated);
            let _ = seq.req.reply.send(resp); // receiver may have gone away
        }

        let avg_bits = if self.active.is_empty() {
            self.controller.target_bits()
        } else {
            self.active.iter().map(|s| s.stats.avg_bits()).sum::<f64>()
                / self.active.len() as f64
        };
        self.metrics.record_tick(avg_bits, self.controller.target_bits());
        self.metrics.record_kv(&self.arena);
        Ok(steps)
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(
        &mut self,
        pressure_at: impl Fn(f64) -> f64,
    ) -> Result<()> {
        while !self.idle() {
            let t_ms = self.started.elapsed().as_secs_f64() * 1000.0;
            self.tick(pressure_at(t_ms))?;
        }
        Ok(())
    }

    pub fn current_precision(&self) -> Precision {
        self.controller.precision()
    }

    pub fn wall_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}
