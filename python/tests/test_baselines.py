"""Static-PTQ baseline calibrators: GPTQ, AWQ, SmoothQuant, rotations."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quant import awq, gptq, rotation, smoothquant


def setup(seed, d_in=32, d_out=16, n_tok=128):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((d_in, d_out)) * 0.2).astype(np.float32)
    x = rng.standard_normal((n_tok, d_in)).astype(np.float32)
    # inject activation outlier channels (what AWQ/SmoothQuant exploit)
    x[:, 3] *= 8.0
    x[:, 11] *= 5.0
    return w, x


def out_err(w, x, rec):
    y_ref = x.astype(np.float64) @ np.asarray(w, np.float64)
    xt = rotation.apply_transform(rec, x)
    y = xt @ gptq.dequantize(rec)
    return float(np.mean((y - y_ref) ** 2))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_gptq_beats_rtn(seed):
    w, x = setup(seed)
    rtn = gptq.rtn_record(w, 3, 16)
    gp = gptq.gptq_quantize(w, x, 3, 16)
    assert out_err(w, x, gp) <= out_err(w, x, rtn) * 1.05


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_awq_beats_rtn_under_outliers(seed):
    w, x = setup(seed)
    rtn = gptq.rtn_record(w, 3, 16)
    aw = awq.awq_quantize(w, x, 3, 16)
    assert out_err(w, x, aw) <= out_err(w, x, rtn) * 1.01
    assert aw.transform == "chan_scale"


def test_smoothquant_produces_scales():
    w, x = setup(1)
    sq = smoothquant.smooth_quantize(w, x, 4, 16)
    assert sq.transform == "chan_scale"
    # outlier channel gets a larger divisor than median channel
    assert sq.act_scale[3] > np.median(sq.act_scale)


def test_rtn_codes_bits():
    w, x = setup(2)
    for bits in (2, 3, 4):
        rec = gptq.rtn_record(w, bits, 16)
        assert rec.codes.max() <= 2 ** bits - 1


def test_fwht_involution_and_norm():
    rng = np.random.default_rng(3)
    v = rng.standard_normal(64)
    h = rotation.block_hadamard(v, 32)
    hh = rotation.block_hadamard(h, 32)
    np.testing.assert_allclose(hh, v, atol=1e-9)
    np.testing.assert_allclose(np.linalg.norm(h), np.linalg.norm(v),
                               rtol=1e-9)


def test_quarot_preserves_fp_output():
    """(x H)(H^T W) == x W before quantization."""
    w, x = setup(4)
    block = rotation.hadamard_block_size(32)
    w_rot = rotation.block_hadamard(np.asarray(w, np.float64).T, block).T
    x_rot = rotation.block_hadamard(x, block)
    np.testing.assert_allclose(x_rot @ w_rot,
                               x.astype(np.float64) @ w, atol=1e-6)


def test_quarot_flattens_outlier_weights():
    rng = np.random.default_rng(5)
    w = (rng.standard_normal((32, 16)) * 0.05).astype(np.float32)
    w[7, :] = 3.0  # an outlier input row
    rec = rotation.quarot_quantize(w, 3, 16)
    deq = gptq.dequantize(rec)
    # rotated-space max magnitude much smaller than the raw outlier
    assert np.abs(deq).max() < 2.0


def test_spinquant_at_least_quarot():
    w, x = setup(6)
    qr = rotation.quarot_quantize(w, 3, 16)
    sp = rotation.spinquant_quantize(w, x, 3, 16, n_signs=8)
    assert out_err(w, x, sp) <= out_err(w, x, qr) * 1.0 + 1e-9


def test_awq_outlier_indices():
    w, x = setup(7)
    rec = awq.awq_quantize(w, x, 3, 16)
    idx = awq.top_outlier_tokens(w, x, rec, 0.1)
    assert len(idx) == 12  # 10% of 128 rounded down to >=1
    assert len(set(idx.tolist())) == len(idx)
