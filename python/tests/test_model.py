"""Model forward / pretrain-loop sanity (L2)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.config import MODEL_ZOO, ModelConfig
from compile import model as M

CFG = ModelConfig(name="t", d_model=32, n_layers=2, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab_size=61)


def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_forward_shapes():
    p = params()
    logits = M.forward(p, jnp.arange(10, dtype=jnp.int32) % 61, CFG)
    assert logits.shape == (10, 61)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    """Changing a future token must not affect earlier logits."""
    p = params()
    t1 = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    t2 = jnp.asarray([1, 2, 3, 4, 60], jnp.int32)
    l1 = M.forward(p, t1, CFG)
    l2 = M.forward(p, t2, CFG)
    np.testing.assert_allclose(np.asarray(l1[:4]), np.asarray(l2[:4]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[4] - l2[4]))) > 1e-4


def test_gqa_variant_runs():
    cfg = ModelConfig(name="g", d_model=32, n_layers=1, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=16)
    p = M.init_params(cfg, jax.random.PRNGKey(1))
    logits = M.forward(p, jnp.arange(6, dtype=jnp.int32) % 16, cfg)
    assert logits.shape == (6, 16)


def test_loss_decreases_with_training():
    from compile.quant.calibrate import adam_init, adam_update
    cfg = ModelConfig(name="t2", d_model=32, n_layers=1, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=11)
    p = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.integers(0, 11, size=(4, 17)), jnp.int32)
    step = jax.jit(jax.value_and_grad(lambda p, t: M.loss_fn(p, t, cfg)))
    opt = adam_init(p)
    l0, _ = step(p, data)
    for _ in range(30):
        loss, g = step(p, data)
        p, opt = adam_update(p, g, opt, 3e-3)
    assert float(loss) < float(l0) * 0.8


def test_linear_hook_intercepts_all():
    p = params()
    seen = set()

    def hook(layer, name, x, w):
        seen.add((layer, name))
        return x @ w

    M.forward(p, jnp.arange(4, dtype=jnp.int32), CFG, linear_fn=hook)
    assert len(seen) == CFG.n_layers * 7


def test_rope_tables_shift_property():
    """RoPE relative-position property: tables at offset o equal rolled
    tables."""
    c0, s0 = M.rope_tables(8, 16, 1e4, offset=0)
    c2, s2 = M.rope_tables(6, 16, 1e4, offset=2)
    np.testing.assert_allclose(np.asarray(c0[2:8]), np.asarray(c2),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s0[2:8]), np.asarray(s2),
                               atol=1e-6)


def test_zoo_configs_consistent():
    for name, cfg in MODEL_ZOO.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.n_heads % cfg.n_kv_heads == 0, name
        assert cfg.d_model % 32 == 0, name   # quant group/packing needs
        assert cfg.d_ff % 32 == 0, name
        assert cfg.n_params > 0
