//! Fig. 5 — router interpretability: (left) MoBiRoute scores correlate
//! with per-token error increments under precision switching; (right)
//! MoBiQuant's error distributions are more consistent across bit-widths
//! than static PTQ's (reduced outlier migration).

use mobiquant::analysis;
use mobiquant::bench_support as bs;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig5_router");
    suite.header();
    let Ok(toks) = bs::valid_tokens("wiki") else {
        suite.note("no corpus");
        suite.finish();
        return;
    };
    let n_probe = (bs::eval_windows(6) * 128).min(768);

    for mname in bs::models_available() {
        let Some(bundle) = bs::try_bundle(&mname) else { continue };
        let fpm = Model::load(&bundle, BackendKind::Fp32).unwrap();
        let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();

        for probe in [0, fpm.cfg.n_layers / 2] {
            let xs = fpm.attn_inputs(&toks[..n_probe], probe,
                                     Precision::Fixed(4)).unwrap();
            let (w_fp, d_in, d_out) =
                bs::fp_weight(&bundle, probe, "wq").unwrap();

            // error increment when dropping 4-bit -> 2-bit (MoBiSlice)
            let lin = match mobiq.layers[probe].linear("wq") {
                Ok(mobiquant::model::LinearBackend::Mobiq(m)) => m,
                _ => unreachable!(),
            };
            let codes: Vec<Vec<u8>> = lin.slices.iter()
                .map(|s| s.unpack()).collect();
            let w2 = mobiquant::mobiq::quantizer::reconstruct(
                &codes, &lin.base, 1);
            let w4 = mobiquant::mobiq::quantizer::reconstruct(
                &codes, &lin.base, 2);
            let e2 = analysis::token_errors(&w_fp, &w2, &xs, d_in, d_out);
            let e4 = analysis::token_errors(&w_fp, &w4, &xs, d_in, d_out);
            let inc: Vec<f64> = e2.iter().zip(&e4).map(|(a, b)| a - b)
                .collect();
            let corr = analysis::router_error_correlation(lin, &xs, &inc);
            suite.row(&format!("{mname} L{probe} score-vs-increment"),
                      &[("spearman", corr)]);

            // error distribution consistency: MoBiQ (fixed k) vs static
            let overlap_mobiq = analysis::outlier_overlap(&e2, &e4, 0.10);
            suite.row(&format!("{mname} L{probe} mobiq slice overlap"),
                      &[("top10_overlap", overlap_mobiq)]);
        }

        // routed avg-bits per token vs its error rank: outlier tokens
        // should get more slices under elastic routing
        let probe = fpm.cfg.n_layers / 2;
        let xs = fpm.attn_inputs(&toks[..n_probe], probe,
                                 Precision::Fixed(4)).unwrap();
        let lin = match mobiq.layers[probe].linear("wq") {
            Ok(mobiquant::model::LinearBackend::Mobiq(m)) => m,
            _ => unreachable!(),
        };
        let mut scratch = mobiquant::mobiq::engine::Scratch::new(
            lin.d_in, lin.base.group_size, lin.router.hidden, 4);
        let bits: Vec<f64> = xs.iter().map(|x| {
            lin.route(x, Precision::elastic(4.0), &mut scratch) as f64
        }).collect();
        let (w_fp, d_in, d_out) = bs::fp_weight(&bundle, probe, "wq")
            .unwrap();
        let codes: Vec<Vec<u8>> = lin.slices.iter().map(|s| s.unpack())
            .collect();
        let w2 = mobiquant::mobiq::quantizer::reconstruct(&codes,
                                                          &lin.base, 1);
        let errs = analysis::token_errors(&w_fp, &w2, &xs, d_in, d_out);
        let corr = mobiquant::util::stats::spearman(&bits, &errs);
        suite.row(&format!("{mname} routed-bits vs 2b-error"),
                  &[("spearman", corr)]);
    }
    suite.note("paper shape: positive score/error-increment correlation; \
                sensitive tokens routed to more slices");
    suite.finish();
}
